"""Bit-packed spike wire format (kernels.exchange pack/unpack +
kernels.route packed consume) — the invariants the 32x exchange cut
rests on:

  * pack -> unpack is the identity for every width, including ragged
    tails (width % 32 != 0), and word popcounts equal fired counts;
  * pack -> hierarchical_gather (over words) -> unpack equals the
    unpacked hierarchical_gather for random widths AND random
    hierarchies (the property pinning the wire format itself);
  * destinations can read any neuron's presence bit with one word
    gather + bit extract (`packed_gather_counts` at
    `packed_positions`), never a full unpack;
  * `exchange_packed` is integer-identical to `exchange` on counts and
    per-level traffic, and the byte accounting matches the collective
    plan stage by stage.

The multi-device half of the contract (packed words over real grouped
`lax.all_gather`s, batched sharded run_batch) lives in
tests/test_mesh_runtime.py's 8-forced-device subprocess suite.
"""
import jax.numpy as jnp
import numpy as np

from _hyp import given, settings, st

from repro.kernels import exchange as exch_k
from repro.kernels import route as route_k
from repro.kernels.exchange import (HierSpec, exchange_bytes_per_step,
                                    event_vector_bytes, pack_events,
                                    packed_positions, packed_words,
                                    unpack_events)


# ------------------------------------------------------- pack primitives
def test_pack_unpack_roundtrip_ragged_tails():
    rng = np.random.default_rng(0)
    for n in (1, 2, 31, 32, 33, 63, 64, 65, 100, 256):
        bits = rng.integers(0, 2, (3, n)).astype(np.int32)
        words = pack_events(jnp.asarray(bits))
        assert words.dtype == jnp.uint32
        assert words.shape == (3, packed_words(n))
        np.testing.assert_array_equal(np.asarray(unpack_events(words, n)),
                                      bits)
        # popcount over the words counts the fired events exactly
        assert int(route_k.popcount32(words).sum()) == int(bits.sum())


def test_pack_is_lsb_first():
    # bit i of word w encodes element w*32 + i
    bits = np.zeros((70,), np.int32)
    bits[[0, 1, 33, 64, 69]] = 1
    words = np.asarray(pack_events(jnp.asarray(bits)))
    assert words.tolist() == [0b11, 1 << 1, (1 << 0) | (1 << 5)]


def test_packed_gather_counts_reads_single_bits():
    rng = np.random.default_rng(1)
    spec = HierSpec(2, 2, 2)
    n_max = 37                                   # ragged tail
    bits = rng.integers(0, 2, (spec.n_cores, n_max)).astype(bool)
    words = exch_k.hierarchical_gather(pack_events(jnp.asarray(bits)),
                                       spec)
    flat = np.asarray(exch_k.hierarchical_gather(
        jnp.asarray(bits, jnp.int32), spec))
    core = np.repeat(np.arange(spec.n_cores), n_max)
    local = np.tile(np.arange(n_max), spec.n_cores)
    wi, bi = packed_positions(core, local, n_max)
    got = route_k.packed_gather_counts(words, jnp.asarray(wi),
                                       jnp.asarray(bi))
    np.testing.assert_array_equal(np.asarray(got), flat)


@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3),
       st.integers(1, 70), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=60, deadline=None)
def test_property_packed_gather_equals_unpacked(servers, fpgas, cores,
                                                n_max, seed):
    """pack -> gather(words) -> unpack == gather(bits) for random
    widths (incl. n_max % 32 != 0 ragged tails) and hierarchies."""
    spec = HierSpec(servers, fpgas, cores)
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (spec.n_cores, n_max)).astype(bool)
    ref = np.asarray(exch_k.hierarchical_gather(
        jnp.asarray(bits, jnp.int32), spec))
    words = exch_k.hierarchical_gather(pack_events(jnp.asarray(bits)),
                                       spec)
    # full unpack of the core-ordered word vector: per-core word blocks
    Wc = packed_words(n_max)
    per_core = unpack_events(words.reshape(spec.n_cores, Wc), n_max)
    np.testing.assert_array_equal(
        np.asarray(per_core).reshape(-1), ref)
    # and the gather-one-bit consume path agrees everywhere
    core = np.repeat(np.arange(spec.n_cores), n_max)
    local = np.tile(np.arange(n_max), spec.n_cores)
    wi, bi = packed_positions(core, local, n_max)
    got = route_k.packed_gather_counts(words, jnp.asarray(wi),
                                       jnp.asarray(bi))
    np.testing.assert_array_equal(np.asarray(got), ref)


# -------------------------------------------------- exchange equivalence
def _random_tables(rng, spec, n_max, n_neurons, n_axons):
    """ExchangeTables over a random neuron placement (every neuron on a
    random (core, local) slot, slots unique)."""
    C = spec.n_cores
    slots = rng.choice(C * n_max, n_neurons, replace=False)
    core, local = slots // n_max, slots % n_max
    wi, bi = packed_positions(core, local, n_max)
    return core, local, exch_k.ExchangeTables(
        pos_of_neuron=jnp.asarray((core * n_max + local), jnp.int32),
        axon_ndest=jnp.asarray(
            rng.integers(0, 4, (n_axons, exch_k.N_LEVELS)), jnp.int32),
        neuron_ndest=jnp.asarray(
            rng.integers(0, 4, (n_neurons, exch_k.N_LEVELS)), jnp.int32),
        pos_word=jnp.asarray(wi), pos_bit=jnp.asarray(bi))


def test_exchange_packed_matches_unpacked():
    rng = np.random.default_rng(2)
    for spec, n_max in ((HierSpec(2, 2, 2), 33), (HierSpec(1, 2, 3), 5),
                        (HierSpec(1, 1, 1), 64)):
        n_neurons = spec.n_cores * n_max // 2 + 1
        core, local, tables = _random_tables(rng, spec, n_max,
                                             n_neurons, n_axons=4)
        spikes_core = np.zeros((spec.n_cores, n_max), bool)
        fired = rng.random(n_neurons) < 0.4
        spikes_core[core[fired], local[fired]] = True
        axon_counts = jnp.asarray(rng.integers(0, 3, (4,)), jnp.int32)
        a = exch_k.exchange(jnp.asarray(spikes_core), axon_counts, spec,
                            tables)
        b = exch_k.exchange_packed(jnp.asarray(spikes_core), axon_counts,
                                   spec, tables)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
        np.testing.assert_array_equal(np.asarray(a[0]) != 0, fired)


# ------------------------------------------------------- byte accounting
def test_exchange_bytes_accounting():
    spec = HierSpec(2, 2, 2)
    # 8 devices, n_max=128: packed blocks of 4 words grow 16->32->64 B
    assert exchange_bytes_per_step(spec, 8, 128, packed=True) == 112
    assert exchange_bytes_per_step(spec, 8, 128, packed=False) == 3584
    assert event_vector_bytes(spec, 128, packed=True) == 128
    assert event_vector_bytes(spec, 128, packed=False) == 4096
    # one device: no collectives, but the replicated floor still shrinks
    assert exchange_bytes_per_step(spec, 1, 128, packed=True) == 0
    assert event_vector_bytes(spec, 33, packed=True) \
        == spec.n_cores * 2 * 4
    # the ratio is exactly n_max / ceil(n_max/32) at every device count
    for n_dev in (2, 4, 8):
        for n_max in (31, 32, 33, 128):
            p = exchange_bytes_per_step(spec, n_dev, n_max, packed=True)
            u = exchange_bytes_per_step(spec, n_dev, n_max, packed=False)
            assert u * packed_words(n_max) == p * n_max
            if n_max >= 16:
                assert p * 16 <= u


# ------------------------------------------- backend knob (single device)
def test_hiaer_packed_knob_bit_exact_and_batched():
    from repro.core.api import CRI_network, Hierarchy
    from test_routing_vectorized import drive, random_net

    axons, neurons, outputs = random_net(13)
    hier = Hierarchy(2, 2, 2, 1000)
    eng = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=13)
    hi_p = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                       backend="hiaer", seed=13, hierarchy=hier)
    hi_u = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                       backend="hiaer", seed=13, hierarchy=hier,
                       packed=False)
    assert hi_p._impl.packed and not hi_u._impl.packed
    r = drive(13, eng, list(axons))
    assert drive(13, hi_p, list(axons)) == r
    assert drive(13, hi_u, list(axons)) == r
    assert hi_p.counter.as_dict() == hi_u.counter.as_dict()

    # batched path: bool dtype and engine==hiaer==mesh on both formats
    rng = np.random.default_rng(4)
    batch = rng.integers(0, 2, (3, 6, len(axons))).astype(np.int32)
    eng2 = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                       backend="engine", seed=13)
    ref = eng2.run_batch(batch)
    assert ref.dtype == np.bool_
    for backend in ("hiaer", "mesh"):
        for pk in (True, False):
            net = CRI_network(axons=axons, neurons=neurons,
                              outputs=outputs, backend=backend, seed=13,
                              hierarchy=hier, packed=pk)
            out = net.run_batch(batch)
            assert out.dtype == np.bool_
            np.testing.assert_array_equal(out, ref)
