"""Integration: one real dry-run cell compiles in a fresh subprocess with
512 virtual devices (the XLA_FLAGS isolation the dry-run requires), and the
artifact carries roofline-usable analysis."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.parametrize("arch,shape,mesh", [
    ("mamba2_780m", "decode_32k", "multi"),
])
def test_dryrun_cell_subprocess(tmp_path, arch, shape, mesh):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(tmp_path),
         "--force"],
        # JAX_PLATFORMS=cpu: the dry-run compiles against 512 *virtual* host
        # devices; without the pin, a stray libtpu install makes the fresh
        # subprocess stall trying to initialize a real TPU backend.
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=500, cwd=str(ROOT))
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    arts = list(tmp_path.glob("*.json"))
    assert len(arts) == 1
    rec = json.loads(arts[0].read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 512
    a = rec["analysis"]
    assert a["hbm_bytes"] > 0 and a["collective_bytes"] > 0
    assert rec["memory"]["temp_size_in_bytes"] > 0
