"""Device-mesh HiAER tier (core.mesh_runtime) — the bit-exactness
contract of the mesh backend: spikes, membranes, AccessCounter
pointer/row statistics AND per-level event traffic must be
integer-identical to `backend="engine"` / `backend="hiaer"` across
randomized topologies, hierarchies, and degenerate placements, while
every device holds only its own cores' ragged shard (no monolithic
`w_ext` anywhere on the path).

The multi-device half runs in a SUBPROCESS with
`XLA_FLAGS=--xla_force_host_platform_device_count=8` (the
launch/dryrun.py pattern — jax pins the device count at first backend
init, so the forcing flag must be set before the interpreter imports
jax; the parent test process keeps its single real CPU device). This
file doubles as that child script: `python tests/test_mesh_runtime.py
--child` executes the 8-device parity suite directly.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np

ROOT = Path(__file__).resolve().parents[1]


# --------------------------------------------------------- pure helpers
def test_collective_stages_structure():
    """The per-level all-gather plan: groups partition the devices at
    every stage, concatenate blocks in core order, and collapse to the
    single-device no-op when one device owns everything."""
    from repro.kernels.exchange import HierSpec, collective_stages
    # 8 cores on 8 devices: one stage per hierarchy level
    st = collective_stages(HierSpec(2, 2, 2), 8)
    assert st == [
        [[0, 1], [2, 3], [4, 5], [6, 7]],            # NoC
        [[0, 2], [1, 3], [4, 6], [5, 7]],            # FireFly
        [[0, 4], [1, 5], [2, 6], [3, 7]],            # Ethernet
    ]
    for groups in st:                    # each stage partitions devices
        flat = sorted(sum(groups, []))
        assert flat == list(range(8))
    # 8 cores on 4 devices: NoC is device-local, two stages remain
    assert collective_stages(HierSpec(2, 2, 2), 4) == [
        [[0, 1], [2, 3]], [[0, 2], [1, 3]]]
    # 8 cores on 2 devices: only the Ethernet hop crosses devices
    assert collective_stages(HierSpec(2, 2, 2), 2) == [[[0, 1]]]
    # one device: everything local, no collectives
    assert collective_stages(HierSpec(2, 2, 2), 1) == []
    # 4 cores in one FPGA on 4 devices: a single NoC-level stage
    assert collective_stages(HierSpec(1, 1, 4), 4) == [
        [[0, 1, 2, 3]]]


def test_device_count_selection_and_validation():
    import pytest

    from repro.core.api import CRI_network, Hierarchy, LIF_neuron
    from repro.core.mesh_runtime import default_device_count
    assert default_device_count(8, available=3) == 2
    assert default_device_count(6, available=8) == 6
    assert default_device_count(5, available=2) == 1
    lif = LIF_neuron(threshold=5, nu=-32, lam=63)
    net_kw = dict(axons={"a": [("x", 3)]},
                  neurons={"x": ([], lif), "y": ([], lif)},
                  outputs=["x"], backend="mesh",
                  hierarchy=Hierarchy(1, 1, 3, 1))
    with pytest.raises(ValueError):      # 2 devices cannot split 3 cores
        CRI_network(n_devices=2, **net_kw)
    with pytest.raises(ValueError):      # more devices than exist
        CRI_network(n_devices=3000, **net_kw)


def test_mesh_single_device_parity():
    """On one device the mesh tier is the shard_map-wrapped hiaer step:
    still bit-exact vs the engine, stages empty (no collectives)."""
    from repro.core.api import CRI_network, Hierarchy
    from test_routing_vectorized import drive, random_net
    axons, neurons, outputs = random_net(21)
    hier = Hierarchy(2, 2, 2, 1000)
    eng = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=21)
    mesh = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                       backend="mesh", seed=21, hierarchy=hier)
    assert mesh._impl.n_devices == 1
    assert mesh._impl._stages == []
    assert drive(21, eng, list(axons)) == drive(21, mesh, list(axons))
    d1, d2 = eng.counter.as_dict(), mesh.counter.as_dict()
    for k in ("pointer_reads", "row_reads", "timesteps",
              "total_accesses"):
        assert d1[k] == d2[k], k


def test_no_dense_weight_image_on_device():
    """Per-core weight storage: the device tables carry exactly the
    ragged entries (linear in synapses) — there is no w_ext field and
    no (R * SLOTS)-sized weight array anywhere in the hiaer/mesh
    tables."""
    from repro.core.api import CRI_network, Hierarchy
    from test_routing_vectorized import random_net
    axons, neurons, outputs = random_net(2, n_neurons=30)
    for backend in ("hiaer", "mesh"):
        net = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                          backend=backend, seed=0,
                          hierarchy=Hierarchy(1, 2, 2, 30))
        t = net._impl._tables
        assert not hasattr(t, "w_ext")
        dense = net.compiled.image.syn_post.size   # R * SLOTS slots
        nnz = net.compiled.shards.n_entries
        assert nnz < dense                          # fillers pad rows
        # weight storage is the ragged entries (padded per device on
        # mesh), never the dense image
        assert t.entry_w.size <= max(nnz, 1) < dense
        import jax
        for leaf in jax.tree_util.tree_leaves(t):   # nothing dense-sized
            assert leaf.size < dense


# ------------------------------------------- the 8-device parity suite
def test_mesh_eight_forced_devices_subprocess():
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).resolve()), "--child"],
        env={"PYTHONPATH": f"{ROOT / 'src'}:{ROOT / 'tests'}",
             "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        capture_output=True, text=True, timeout=560, cwd=str(ROOT))
    assert proc.returncode == 0, \
        proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "MESH-8DEV-OK" in proc.stdout


def _child() -> int:
    import jax

    from repro.core.api import CRI_network, Hierarchy, LIF_neuron
    from test_routing_vectorized import drive, random_net

    assert len(jax.devices()) == 8, jax.devices()

    hiers = [
        Hierarchy(2, 2, 2, 8),           # all three levels, 8 cores
        Hierarchy(1, 2, 2, 12),          # NoC + FireFly, 4 cores
        Hierarchy(1, 1, 4, 12),          # NoC only, 4 cores
        Hierarchy(1, 1, 1, 1000),        # single core (trivial exchange)
    ]

    def check(eng, mesh, hi, ax_keys, seed):
        a = drive(seed, eng, ax_keys)
        b = drive(seed, mesh, ax_keys)
        c = drive(seed, hi, ax_keys)
        assert a == b == c, "spike/membrane mismatch"
        d1, d2 = eng.counter.as_dict(), mesh.counter.as_dict()
        for k in ("pointer_reads", "row_reads", "timesteps",
                  "total_accesses"):
            assert d1[k] == d2[k], k
        assert mesh.counter.level_events == hi.counter.level_events

    # randomized topologies x hierarchies (incl. zero-fanout fillers)
    for seed in range(4):
        hier = hiers[seed % len(hiers)]
        axons, neurons, outputs = random_net(seed)
        eng = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                          backend="engine", seed=seed)
        mesh = CRI_network(axons=axons, neurons=neurons,
                           outputs=outputs, backend="mesh", seed=seed,
                           hierarchy=hier)
        hi = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                         backend="hiaer", seed=seed, hierarchy=hier)
        assert mesh._impl.n_devices == min(8, hier.n_cores)
        check(eng, mesh, hi, list(axons), seed)
    print("randomized topologies OK", flush=True)

    # every divisor device count runs the same 8-core network bit-exact
    axons, neurons, outputs = random_net(31)
    hier = Hierarchy(2, 2, 2, 1000)
    eng = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=31)
    ref = drive(31, eng, list(axons))
    for nd in (2, 4, 8):
        mesh = CRI_network(axons=axons, neurons=neurons,
                           outputs=outputs, backend="mesh", seed=31,
                           hierarchy=hier, n_devices=nd)
        assert mesh._impl.n_devices == nd
        assert len(mesh._impl._stages) == {2: 1, 4: 2, 8: 3}[nd]
        assert drive(31, mesh, list(axons)) == ref
    print("divisor device counts OK", flush=True)

    # packed vs unpacked wire format: bit-exact on spikes, membranes,
    # access counts AND per-level traffic over real 8-device
    # collectives; the packed wire moves ceil(n_max/32) words per core
    # where the unpacked one moves n_max int32 lanes
    mesh_u = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                         backend="mesh", seed=31, hierarchy=hier,
                         n_devices=8, packed=False)
    mesh_p = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                         backend="mesh", seed=31, hierarchy=hier,
                         n_devices=8)
    assert mesh_p._impl.packed and not mesh_u._impl.packed
    assert drive(31, mesh_u, list(axons)) == ref
    assert drive(31, mesh_p, list(axons)) == ref
    assert mesh_p.counter.as_dict() == mesh_u.counter.as_dict()
    n_max = mesh_p._impl.shards.n_max
    words = -(-n_max // 32)
    assert (mesh_u._impl.exchange_bytes_per_step() * words
            == mesh_p._impl.exchange_bytes_per_step() * n_max)
    assert mesh_p._impl.event_vector_bytes() * n_max \
        == mesh_u._impl.event_vector_bytes() * words
    print("packed wire parity OK", flush=True)

    # batched run_batch: B samples folded into the sharded step (one
    # collective per level per step for the whole batch) must be
    # bit-identical to the engine's vmapped batch, bool dtype, on both
    # wire formats
    nprng = np.random.default_rng(3)
    batch = nprng.integers(0, 3, (4, 5, len(axons))).astype(np.int32)
    eng_b = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                        backend="engine", seed=31)
    rb = eng_b.run_batch(batch)
    assert rb.dtype == np.bool_
    for pk in (True, False):
        m = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                        backend="mesh", seed=31, hierarchy=hier,
                        n_devices=8, packed=pk)
        out = m.run_batch(batch)
        assert out.dtype == np.bool_
        np.testing.assert_array_equal(rb, out)
        for k in ("pointer_reads", "row_reads", "timesteps"):
            assert m.counter.as_dict()[k] == eng_b.counter.as_dict()[k]
    print("batched sharded run_batch OK", flush=True)

    # degenerate placement: everything on core 3 — zero cross-level
    axons, neurons, outputs = random_net(5)
    eng = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=5)
    mesh = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                       backend="mesh", seed=5,
                       hierarchy=Hierarchy(2, 2, 2, 1000),
                       placement={k: 3 for k in neurons},
                       axon_placement={k: 3 for k in axons})
    assert drive(5, eng, list(axons)) == drive(5, mesh, list(axons))
    assert mesh.counter.cross_level_events == 0
    assert mesh._impl.shards.stats()["white_entries"] == 0
    print("all-on-one-core OK", flush=True)

    # degenerate placement: ring with neighbours on different servers —
    # every neuron->neuron synapse crosses Ethernet
    n = 12
    lif = LIF_neuron(threshold=2, nu=-32, lam=63)
    names = [f"n{i}" for i in range(n)]
    neurons = {names[i]: ([(names[(i + 1) % n], 5)], lif)
               for i in range(n)}
    axons = {"a0": [(names[i], 9) for i in range(n)]}
    hier = Hierarchy(2, 1, 1, n)
    placement = {names[i]: i % 2 for i in range(n)}
    eng = CRI_network(axons=axons, neurons=neurons, outputs=names[:3],
                      backend="engine", seed=2)
    mesh = CRI_network(axons=axons, neurons=neurons, outputs=names[:3],
                       backend="mesh", seed=2, hierarchy=hier,
                       placement=placement)
    for _ in range(8):
        f1, p1 = eng.step(["a0"], membranePotential=True)
        f2, p2 = mesh.step(["a0"], membranePotential=True)
        assert (f1, p1) == (f2, p2)
    ev = mesh.counter.level_events
    assert ev[0] == 8 and ev[1] == 0 and ev[2] == 0 and ev[3] >= 8
    assert mesh._impl.shards.stats()["white_frac"] > 0.5
    print("every-synapse-cross-core OK", flush=True)

    # run == sequential steps; run_batch parity vs engine
    import random as pyrandom
    a_def = random_net(9)
    hier = Hierarchy(1, 2, 2, 12)
    mk = lambda: CRI_network(axons=a_def[0], neurons=a_def[1],
                             outputs=a_def[2], backend="mesh", seed=4,
                             hierarchy=hier)
    a, b = mk(), mk()
    rng = pyrandom.Random(8)
    sched = [rng.sample(list(a_def[0]), k=rng.randint(0, len(a_def[0])))
             for _ in range(12)]
    assert a.run(sched) == [b.step(s) for s in sched]
    assert a.counter.as_dict() == b.counter.as_dict()
    assert a.read_membrane(*a.neuron_keys) == \
        b.read_membrane(*b.neuron_keys)
    eng = CRI_network(axons=a_def[0], neurons=a_def[1], outputs=a_def[2],
                      backend="engine", seed=4)
    nprng = np.random.default_rng(0)
    batch = nprng.integers(0, 2, (3, 6, len(a_def[0]))) \
        .astype(np.int32)
    np.testing.assert_array_equal(eng.run_batch(batch),
                                  mk().run_batch(batch))
    print("run/run_batch OK", flush=True)

    # weight edits on a live 8-device mesh: shard-local rebuilds only,
    # and the compiled scan sees the batch
    n = 16
    lif = LIF_neuron(threshold=50, nu=-32, lam=3)
    names = [f"n{i}" for i in range(n)]
    neurons = {names[i]: ([(names[(i + 1) % n], 3)], lif)
               for i in range(n)}
    axons = {"a0": [(names[i], 7) for i in range(n)]}
    hier = Hierarchy(2, 2, 2, 2)
    placement = {names[i]: i % 8 for i in range(n)}
    mesh = CRI_network(axons=axons, neurons=neurons, outputs=names[:2],
                       backend="mesh", seed=0, hierarchy=hier,
                       placement=placement)
    eng = CRI_network(axons=axons, neurons=neurons, outputs=names[:2],
                      backend="engine", seed=0)
    ws = list(range(1, n + 1))
    mesh.write_synapses(["a0"] * n, names, ws)
    eng.write_synapses(["a0"] * n, names, ws)
    assert mesh._impl.shard_rebuilds == 8      # every device touched
    mesh.write_synapses(["a0"], [names[0]], [40])
    eng.write_synapses(["a0"], [names[0]], [40])
    assert mesh._impl.shard_rebuilds == 9      # one shard only
    np.testing.assert_array_equal(mesh.read_synapses(["a0"], names),
                                  eng.read_synapses(["a0"], names))
    assert drive(1, eng, ["a0"]) == drive(1, mesh, ["a0"])
    print("shard-local weight edits OK", flush=True)

    print("MESH-8DEV-OK", flush=True)
    return 0


if __name__ == "__main__":
    if "--child" in sys.argv:
        sys.exit(_child())
    sys.exit("run under pytest, or with --child for the 8-device suite")
