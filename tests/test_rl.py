"""DVS-Pong-style RL pipeline (Table 2 row 4's protocol): DQN -> int16 ->
A.2 conversion -> event-driven engine; the hardware policy must score
IDENTICALLY to the quantized software policy over 50 episodes (the paper's
hardware-validation claim), with energy/latency accounted per decision."""
import numpy as np
import pytest

from repro.core.convert import quantize, to_network
from repro.core.rl import (CatchEnv, engine_policy, evaluate,
                           software_policy, train_dqn)


@pytest.fixture(scope="module")
def trained():
    env = CatchEnv(W=5, H=7)
    model, params = train_dqn(env, episodes=400, seed=3)
    qp, _ = quantize(params)
    return model, qp


def test_engine_score_equals_software_score(trained):
    model, qp = trained
    sw = evaluate(CatchEnv(W=5, H=7), software_policy(model, qp),
                  episodes=50)
    net, out_keys = to_network(model, qp, backend="engine")
    hw = evaluate(CatchEnv(W=5, H=7), engine_policy(net, out_keys, model),
                  episodes=50)
    assert hw == sw                      # exact policy parity on hardware
    c = net.counter.as_dict()
    assert c["energy_uJ"] > 0 and c["latency_us"] > 0


def test_dvs_observation_construction():
    """ON = newly-set pixels, OFF = newly-cleared — the paper's frame
    differencing."""
    rng = np.random.default_rng(0)
    env = CatchEnv()
    env.reset(rng)
    obs, _, _ = env.step(1)             # stay
    on, off = obs
    # the falling ball appears at its new position (ON) and vanishes from
    # the old one (OFF)
    assert on.sum() >= 1 and off.sum() >= 1
    assert obs.shape == (2, env.H, env.W)


def test_policy_beats_uniform_random(trained):
    model, qp = trained
    sw = evaluate(CatchEnv(W=5, H=7), software_policy(model, qp),
                  episodes=100, seed=5)
    rng = np.random.default_rng(1)
    rand = evaluate(CatchEnv(W=5, H=7),
                    lambda s: int(rng.integers(0, 3)), episodes=100, seed=5)
    assert sw >= rand                    # trained >= random (often >>)
