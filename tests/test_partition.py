"""Hierarchical partitioner [10]: locality, capacity, cost model, job
allocation."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.partition import (Hierarchy, Job, allocate, partition,
                                  random_assignment, traffic_cost)


def clustered_net(n_clusters=8, size=24, p_in=0.4, p_out=0.01, seed=0):
    rng = np.random.default_rng(seed)
    adj = {}
    n = n_clusters * size
    for i in range(n):
        posts = []
        ci = i // size
        for j in range(n):
            if j == i:
                continue
            p = p_in if j // size == ci else p_out
            if rng.random() < p:
                posts.append((j, int(rng.integers(1, 10))))
        adj[i] = posts
    return adj


HIER = Hierarchy(n_servers=2, fpgas_per_server=2, cores_per_fpga=2,
                 neurons_per_core=32)


def test_capacity_respected():
    adj = clustered_net()
    asg = partition(adj, HIER)
    counts = np.bincount(list(asg.values()), minlength=HIER.n_cores)
    assert counts.max() <= HIER.neurons_per_core
    assert len(asg) == len(adj)


def test_bfs_beats_random_on_clustered_topology():
    adj = clustered_net()
    asg = partition(adj, HIER)
    cost = traffic_cost(adj, asg, HIER)
    rnd = traffic_cost(adj, random_assignment(adj, HIER, seed=1), HIER)
    assert cost["cost"] < 0.7 * rnd["cost"]
    assert cost["local_frac"] > rnd["local_frac"]


def test_level_ordering():
    h = Hierarchy(2, 2, 2, 10)
    assert h.level(0, 0) == 0
    assert h.level(0, 1) == 1          # same FPGA
    assert h.level(0, 2) == 2          # same server, other FPGA
    assert h.level(0, 4) == 3          # other server


def test_capacity_error():
    with pytest.raises(ValueError):
        partition({i: [] for i in range(1000)},
                  Hierarchy(1, 1, 1, 10))


def test_allocate_first_fit():
    h = Hierarchy(1, 2, 4, 100)        # 8 cores
    jobs = [Job("a", 250), Job("b", 90), Job("c", 350)]
    out = allocate(jobs, h)
    assert len(out["c"]) == 4 and len(out["a"]) == 3 and len(out["b"]) == 1
    used = sum(out.values(), [])
    assert len(set(used)) == len(used)     # no core shared
    with pytest.raises(ValueError):
        allocate([Job("x", 10_000)], h)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_partition_deterministic_and_total(seed):
    adj = clustered_net(n_clusters=3, size=10, seed=seed)
    a1 = partition(adj, HIER)
    a2 = partition(adj, HIER)
    assert a1 == a2
    assert set(a1) == set(adj)
