"""Hierarchical partitioner [10]: locality, capacity, cost model, job
allocation — plus the property contracts the hiaer execution tier rests
on: capacity holds for arbitrary Hierarchy shapes, and the static
traffic estimate agrees with the per-level AccessCounter measurements of
the multi-core engine."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.partition import (Hierarchy, Job, allocate,
                                  level_event_counts, partition,
                                  partition_arrays, partition_loop,
                                  random_assignment, traffic_cost)


def clustered_net(n_clusters=8, size=24, p_in=0.4, p_out=0.01, seed=0):
    rng = np.random.default_rng(seed)
    adj = {}
    n = n_clusters * size
    for i in range(n):
        posts = []
        ci = i // size
        for j in range(n):
            if j == i:
                continue
            p = p_in if j // size == ci else p_out
            if rng.random() < p:
                posts.append((j, int(rng.integers(1, 10))))
        adj[i] = posts
    return adj


HIER = Hierarchy(n_servers=2, fpgas_per_server=2, cores_per_fpga=2,
                 neurons_per_core=32)


def test_capacity_respected():
    adj = clustered_net()
    asg = partition(adj, HIER)
    counts = np.bincount(list(asg.values()), minlength=HIER.n_cores)
    assert counts.max() <= HIER.neurons_per_core
    assert len(asg) == len(adj)


def test_bfs_beats_random_on_clustered_topology():
    adj = clustered_net()
    asg = partition(adj, HIER)
    cost = traffic_cost(adj, asg, HIER)
    rnd = traffic_cost(adj, random_assignment(adj, HIER, seed=1), HIER)
    assert cost["cost"] < 0.7 * rnd["cost"]
    assert cost["local_frac"] > rnd["local_frac"]


def test_level_ordering():
    h = Hierarchy(2, 2, 2, 10)
    assert h.level(0, 0) == 0
    assert h.level(0, 1) == 1          # same FPGA
    assert h.level(0, 2) == 2          # same server, other FPGA
    assert h.level(0, 4) == 3          # other server


def test_capacity_error():
    with pytest.raises(ValueError):
        partition({i: [] for i in range(1000)},
                  Hierarchy(1, 1, 1, 10))


def test_allocate_first_fit():
    h = Hierarchy(1, 2, 4, 100)        # 8 cores
    jobs = [Job("a", 250), Job("b", 90), Job("c", 350)]
    out = allocate(jobs, h)
    assert len(out["c"]) == 4 and len(out["a"]) == 3 and len(out["b"]) == 1
    used = sum(out.values(), [])
    assert len(set(used)) == len(used)     # no core shared
    with pytest.raises(ValueError):
        allocate([Job("x", 10_000)], h)


def _check_partition_parity(seed, n):
    """The NumPy frontier-expansion partitioner assigns every neuron to
    exactly the core the reference O(N·frontier) Python walk picks —
    including zero-weight edges, isolated nodes, duplicate synapses and
    self-loops — and respects capacity on every hierarchy shape."""
    rng = np.random.default_rng(seed)
    adj = {}
    for i in range(n):
        k = int(rng.integers(0, min(5, n) + 1))
        adj[i] = [(int(j), int(rng.integers(-9, 10)))   # 0-weights too
                  for j in rng.integers(0, n, k)]       # dups + self ok
    for hier in (Hierarchy(1, 1, 2, -(-n // 2)),
                 Hierarchy(2, 2, 2, max(n // 6, 1) + 1),
                 Hierarchy(1, 1, 1, n)):
        if n > hier.capacity:
            continue
        got = partition(adj, hier)
        ref = partition_loop(adj, hier)
        assert got == ref
        counts = np.bincount(list(got.values()),
                             minlength=hier.n_cores)
        assert counts.max() <= hier.neurons_per_core


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 120))
def test_vectorized_partition_matches_loop(seed, n):
    _check_partition_parity(seed, n)


def test_vectorized_partition_matches_loop_deterministic():
    """Always-run (no hypothesis) parity smoke over fixed seeds."""
    for seed, n in ((0, 1), (1, 2), (2, 17), (3, 60), (4, 120),
                    (5, 90)):
        _check_partition_parity(seed, n)


def test_partition_arrays_column_door():
    """partition_arrays (the compile-path front door) equals the dict
    door on the equivalent adjacency."""
    rng = np.random.default_rng(3)
    n, s = 80, 400
    pre = rng.integers(0, n, s)
    post = rng.integers(0, n, s)
    w = rng.integers(1, 12, s)
    hier = Hierarchy(1, 2, 2, -(-n // 4))
    got = partition_arrays(pre, post, w, n, hier)
    adj = {i: [] for i in range(n)}
    for p, q, ww in zip(pre.tolist(), post.tolist(), w.tolist()):
        adj[p].append((q, ww))
    ref = partition(adj, hier)
    assert got.tolist() == [ref[i] for i in range(n)]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_partition_deterministic_and_total(seed):
    adj = clustered_net(n_clusters=3, size=10, seed=seed)
    a1 = partition(adj, HIER)
    a2 = partition(adj, HIER)
    assert a1 == a2
    assert set(a1) == set(adj)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 4),
       st.integers(1, 40), st.integers(0, 10_000))
def test_capacity_holds_for_arbitrary_hierarchy_shapes(
        servers, fpgas, cores, per_core, seed):
    """For any Hierarchy shape, a network that fits the total capacity
    partitions with every core at or under its per-core limit, every
    core id in range, and every neuron assigned exactly once."""
    hier = Hierarchy(servers, fpgas, cores, per_core)
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, hier.capacity + 1))
    adj = {i: [(int(j), int(rng.integers(1, 5)))
               for j in rng.choice(n, min(3, n), replace=False)]
           for i in range(n)}
    asg = partition(adj, hier)
    assert set(asg) == set(adj)
    counts = np.bincount(list(asg.values()), minlength=hier.n_cores)
    assert counts.max() <= hier.neurons_per_core
    assert 0 <= min(asg.values()) and max(asg.values()) < hier.n_cores


def test_traffic_cost_events_match_level_event_counts():
    """traffic_cost's `events` breakdown is exactly level_event_counts
    with src == dst assignment, and sums to the deduplicated
    (source, destination-core) pair count."""
    adj = clustered_net(n_clusters=3, size=8, seed=4)
    hier = Hierarchy(2, 1, 2, 8)
    asg = partition(adj, hier)
    ev = traffic_cost(adj, asg, hier)["events"]
    assert ev == level_event_counts(adj, asg, asg, hier)
    want = sum(len({asg[p] for p, _ in posts if p in asg})
               for pre, posts in adj.items() if pre in asg)
    assert sum(ev) == want


def test_measured_counter_agrees_with_traffic_cost_events():
    """The satellite contract: on a small always-firing network the
    hiaer engine's measured per-level AccessCounter events equal
    traffic_cost's static `events` estimate times the step count."""
    from repro.core.api import CRI_network, LIF_neuron
    rng = np.random.default_rng(9)
    n = 18
    names = [f"n{i}" for i in range(n)]
    lif = LIF_neuron(threshold=-1, nu=-32, lam=63)   # fires every step
    neurons = {k: ([(names[j], int(rng.integers(1, 6)))
                    for j in rng.choice(n, 2, replace=False)], lif)
               for k in names}
    hier = Hierarchy(2, 2, 1, 6)
    net = CRI_network(axons={}, neurons=neurons, outputs=names[:1],
                      backend="hiaer", seed=0, hierarchy=hier)
    T = 5
    net.run([[] for _ in range(T)])
    key_adj = {k: neurons[k][0] for k in names}
    asg = {k: int(net._impl.neuron_core[net._nid[k]]) for k in names}
    static = traffic_cost(key_adj, asg, hier)["events"]
    assert net.counter.level_events == [T * e for e in static]
