"""A.2 conversion pipeline: QAT -> int16 quantize -> adjacency network;
HiAER membrane potentials must equal the integer reference exactly
(Table 2's Software Acc == HiAER Acc)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.convert import (LayerSpec, QATModel, apply_quantized,
                                infer_image, quantize, to_network, train_qat)
from repro.data.synthetic import digits


@pytest.fixture(scope="module")
def data():
    X, y = digits(700, shape=(12, 12), seed=3)
    return X, y, X.reshape(-1, 1, 12, 12).astype(np.float32)


@pytest.fixture(scope="module")
def mlp(data):
    X, y, Xf = data
    model = QATModel(input_shape=(1, 12, 12),
                     layers=[LayerSpec("dense", out_features=24)],
                     n_classes=10)
    params = train_qat(model, Xf[:500], y[:500], epochs=4)
    return model, params


def test_qat_learns(data, mlp):
    X, y, Xf = data
    model, params = mlp
    import jax
    logits = np.asarray(model.apply(params, jnp.asarray(Xf[500:])))
    acc = (logits.argmax(1) == y[500:]).mean()
    assert acc > 0.5, acc                     # 10 classes, chance = 0.1


def test_quantization_preserves_predictions(data, mlp):
    X, y, Xf = data
    model, params = mlp
    qp, bits = quantize(params)
    assert 1 <= bits <= 14
    ref_int = apply_quantized(model, qp, Xf[500:600])
    logits = np.asarray(model.apply(params, jnp.asarray(Xf[500:600]),
                                    quantized=False))
    agree = (ref_int.argmax(1) == logits.argmax(1)).mean()
    assert agree > 0.9, agree


@pytest.mark.parametrize("backend", ["simulator", "engine"])
def test_converted_network_is_bit_exact(data, mlp, backend):
    X, y, Xf = data
    model, params = mlp
    qp, _ = quantize(params)
    ref_int = apply_quantized(model, qp, Xf[600:620])
    net, out_keys = to_network(model, qp, backend=backend)
    for i in range(20):
        _, pots = infer_image(net, X[600 + i], model, out_keys)
        np.testing.assert_array_equal(np.asarray(pots), ref_int[i])


def test_conv_network_bit_exact(data):
    X, y, Xf = data
    model = QATModel(input_shape=(1, 12, 12),
                     layers=[LayerSpec("conv", channels=3, kernel=5,
                                       stride=2),
                             LayerSpec("dense", out_features=16)],
                     n_classes=10)
    params = train_qat(model, Xf[:400], y[:400], epochs=2)
    qp, _ = quantize(params)
    ref_int = apply_quantized(model, qp, Xf[600:608])
    net, out_keys = to_network(model, qp, backend="engine")
    for i in range(8):
        _, pots = infer_image(net, X[600 + i], model, out_keys)
        np.testing.assert_array_equal(np.asarray(pots), ref_int[i])
    # energy/latency accounting active (Table 2 instrumentation)
    d = net.counter.as_dict()
    assert d["total_accesses"] > 0 and d["energy_uJ"] > 0
