"""Elastic scaling: a checkpoint written under one mesh restores onto a
DIFFERENT mesh (node-failure recovery path) with identical values and valid
shardings. Runs in a subprocess with 8 virtual devices."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.checkpoint import save_tree, restore_tree
from repro.distributed.context import mesh_context
from repro.distributed.elastic import reshard_tree
from repro.launch.sharding import ShardingRules, to_named
from repro.models import lm

cfg = get_reduced("gemma_7b")
from repro.compat import make_mesh
mesh_a = make_mesh((2, 4), ("data", "model"))
mesh_b = make_mesh((4, 2), ("data", "model"))

with mesh_context(mesh_a):
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rules = ShardingRules(cfg, mesh_a, "heads")
    shapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                          params)
    sh = to_named(rules.params_specs(shapes), mesh_a)
    params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, sh)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    loss_a, _ = lm.loss_fn(params, cfg, batch)
    save_tree(r"%(ckpt)s", params)

# 'failure': rebuild on the reshaped mesh and restore
with mesh_context(mesh_b):
    restored, _ = restore_tree(r"%(ckpt)s", params)
    resharded = reshard_tree(restored, cfg, mesh_b, kind="params",
                             layout="heads")
    # values identical
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(resharded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and usable: same loss on the new mesh
    loss_b, _ = lm.loss_fn(resharded, cfg, batch)
    assert abs(float(loss_a) - float(loss_b)) < 1e-4, (loss_a, loss_b)
print("ELASTIC_OK")
"""


def test_elastic_remesh_roundtrip(tmp_path):
    script = SCRIPT % {"src": str(ROOT / "src"),
                       "ckpt": str(tmp_path / "ck")}
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=500)
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-1500:]
    assert "ELASTIC_OK" in proc.stdout
