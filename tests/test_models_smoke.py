"""Per-architecture smoke tests: reduced config of the same family runs one
forward/train step on CPU, asserting output shapes + no NaNs (deliverable f).
Full configs are exercised only by the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, get_reduced
from repro.distributed.context import mesh_context
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_decode_step, make_train_step
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(
            0, cfg.vocab_size, (B, S - (cfg.n_patch_tokens or 0))),
        jnp.int32)}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.zeros((B, cfg.n_patch_tokens,
                                           cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    with mesh_context(make_local_mesh()):
        params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        batch = _batch(cfg)
        oc = AdamWConfig()
        step = jax.jit(make_train_step(cfg, oc))
        p2, o2, m = step(params, adamw_init(params, oc), batch)
        assert np.isfinite(float(m["loss"])), arch
        assert np.isfinite(float(m["grad_norm"])), arch
        # params actually changed (some leaf moved measurably)
        deltas = jax.tree.map(
            lambda a, b: float(np.max(np.abs(np.asarray(a, np.float32)
                                             - np.asarray(b, np.float32)))),
            params, p2)
        assert max(jax.tree.leaves(deltas)) > 1e-6


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode(arch):
    cfg = get_reduced(arch)
    with mesh_context(make_local_mesh()):
        params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        batch = _batch(cfg)
        logits, cache = lm.prefill(params, cfg, batch)
        vp = ((cfg.vocab_size + 127) // 128) * 128
        assert logits.shape == (2, vp)
        assert np.isfinite(np.asarray(logits)).all(), arch
        dcache = lm.init_cache(cfg, 2, 64, jnp.float32)
        dstep = jax.jit(make_decode_step(cfg))
        lg, nc = dstep(params, jnp.ones((2, 1), jnp.int32), dcache,
                       jnp.int32(3))
        assert np.isfinite(np.asarray(lg)).all(), arch


@pytest.mark.parametrize("arch", ["qwen2_7b", "mamba2_780m",
                                  "recurrentgemma_2b", "deepseek_moe_16b"])
def test_decode_matches_teacher_forcing(arch):
    """Feeding tokens one-by-one through decode_step reproduces the full
    forward's next-token logits — cache correctness invariant."""
    cfg = get_reduced(arch)
    with mesh_context(make_local_mesh()):
        params = lm.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
        B, S = 2, 16
        toks = jnp.asarray(np.random.default_rng(1).integers(
            0, cfg.vocab_size, (B, S)), jnp.int32)
        # full forward logits at last position
        h, _, _ = lm.backbone(params, cfg, {"tokens": toks}, remat=False)
        from repro.models.layers import unembed
        full_logits = np.asarray(unembed(params["embed"], h[:, -1], cfg),
                                 np.float32)
        # decode token-by-token
        cache = lm.init_cache(cfg, B, S, jnp.float32)
        dstep = jax.jit(make_decode_step(cfg))
        for t in range(S):
            lg, cache = dstep(params, toks[:, t:t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg), full_logits,
                                   rtol=2e-2, atol=2e-2)


def test_vlm_patch_tokens_prepended():
    cfg = get_reduced("llava_next_mistral_7b")
    with mesh_context(make_local_mesh()):
        params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        batch = _batch(cfg, B=2, S=24)
        h, _, _ = lm.backbone(params, cfg, batch, remat=False)
        assert h.shape[1] == 24          # text + patch tokens


def test_moe_routing_is_sparse_and_loadbalanced():
    cfg = get_reduced("deepseek_moe_16b")
    with mesh_context(make_local_mesh()):
        params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        loss, parts = lm.loss_fn(params, cfg, _batch(cfg))
        assert float(parts["aux"]) > 0        # load-balance loss active
        assert float(parts["aux"]) < 0.2 * float(parts["ce"])
