"""Layer-level properties: chunked CE == direct CE, RoPE norm preservation,
attention q-chunking equivalence, MoE dispatch/unpack inverse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_reduced
from repro.distributed.context import mesh_context
from repro.launch.mesh import make_local_mesh


def test_chunked_ce_equals_direct():
    from repro.models.layers import chunked_ce_loss, embed_init, unembed
    cfg = get_reduced("qwen2_7b")
    key = jax.random.PRNGKey(0)
    with mesh_context(make_local_mesh()):
        emb = embed_init(key, cfg, jnp.float32)
        B, S, D = 2, 24, cfg.d_model
        h = jax.random.normal(key, (B, S, D))
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        mask = jnp.ones((B, S))
        got = chunked_ce_loss(emb, h, labels, mask, cfg)
        logits = unembed(emb, h, cfg).astype(jnp.float32)
        vp = logits.shape[-1]
        logits = jnp.where(jnp.arange(vp) >= cfg.vocab_size, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        want = jnp.mean(lse - gold)
        assert abs(float(got) - float(want)) < 1e-4


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_rope_preserves_norm_and_relativity(seed):
    from repro.models.layers import apply_rope
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.arange(8)
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x)),
                               np.linalg.norm(np.asarray(y)), rtol=1e-5)
    # relativity: <q_m, k_n> depends only on m - n
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, 16))
    def dot_at(m, n):
        qm = apply_rope(jnp.broadcast_to(q, (1, 1, 1, 16)),
                        jnp.asarray([m]), 10_000.0)
        kn = apply_rope(jnp.broadcast_to(k, (1, 1, 1, 16)),
                        jnp.asarray([n]), 10_000.0)
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4


def test_attention_qchunk_equivalence():
    from repro.models.attention import attend
    cfg = get_reduced("gemma_7b")
    key = jax.random.PRNGKey(1)
    B, S, H, hd = 2, 64, 4, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    with mesh_context(make_local_mesh()):
        full = attend(q, k, v, cfg, q_chunk=64)      # single block
        chunked = attend(q, k, v, cfg, q_chunk=16)   # 4 remat'd chunks
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5)


def test_moe_pack_unpack_inverse():
    from repro.models.moe import _capacity, _pack, _unpack
    cfg = get_reduced("deepseek_moe_16b")
    key = jax.random.PRNGKey(2)
    T, d = 32, 16
    E, k = cfg.moe.n_routed, cfg.moe.top_k
    x = jax.random.normal(key, (T, d))
    ids = jax.random.randint(jax.random.fold_in(key, 1), (T, k), 0, E)
    w = jnp.full((T, k), 1.0 / k)
    C = _capacity(T, cfg)
    buf, slot, keep = _pack(x, ids, w, C, E)
    y = _unpack(buf, slot, keep, w, T, k)
    # identity experts + dropless capacity => unpack(pack(x)) == x
    assert bool(jnp.all(keep))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_ssd_chunked_matches_naive_recurrence():
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(3)
    B, S, H, P, N = 1, 32, 2, 4, 8
    xh = jax.random.normal(key, (B, S, H, P))
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 1),
                                         (B, S, H))) * 0.9 + 0.05
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    y, hT = ssd_chunked(xh, a, Bm, Cm, chunk=8)
    # naive recurrence oracle
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        h = h * np.asarray(a)[:, t, :, None, None] + np.einsum(
            "bhp,bn->bhpn", np.asarray(xh)[:, t], np.asarray(Bm)[:, t])
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(Cm)[:, t], h))
    want = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hT), h, atol=1e-3, rtol=1e-3)


def test_rglru_scan_matches_stepwise():
    from repro.models.rglru import rglru_apply, rglru_cache_shape, rglru_init
    cfg = get_reduced("recurrentgemma_2b")
    key = jax.random.PRNGKey(4)
    with mesh_context(make_local_mesh()):
        p = rglru_init(key, cfg, jnp.float32)
        B, S = 2, 12
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (B, S, cfg.d_model)) * 0.3
        y_scan, _ = rglru_apply(p, x, cfg)
        cache = jax.tree.map(lambda a: a.astype(jnp.float32),
                             rglru_cache_shape(cfg, B, jnp.float32))
        ys = []
        for t in range(S):
            yt, cache = rglru_apply(p, x[:, t:t + 1], cfg, cache=cache)
            ys.append(yt)
        y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               atol=2e-4, rtol=2e-3)
