"""Staged build→compile→deploy API (PR 3): columnar NetworkSpec,
compiled artifacts, batched runtime reconfiguration.

Pins the acceptance invariants:
  * a network built via NetworkSpec bulk ops, compiled, saved, loaded,
    and deployed on each backend is bit-exact (spikes, membranes,
    AccessCounter stats) against the legacy dict CRI_network;
  * the vectorized columnar mapper reproduces the legacy Fig. 7 walk
    (hbm.compile_network) bit for bit, pointer dicts included;
  * build-time sharding from columns == shard_image of the monolith;
  * a 1000-synapse write_synapses batch triggers exactly ONE
    update_weights/re-shard;
  * the synapse index preserves KeyError and the axon-vs-neuron pre
    disambiguation.
"""
import random

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import hbm
from repro.core.api import ANN_neuron, CRI_network, LIF_neuron
from repro.core.compile import CompiledNetwork, compile_spec
from repro.core.deploy import deploy
from repro.core.partition import Hierarchy
from repro.core.spec import NetworkSpec


# ---------------------------------------------------------------- helpers
def random_dicts(seed, n_axons=4, n_neurons=18, fanout=4):
    rng = np.random.default_rng(seed)
    names = [f"n{i}" for i in range(n_neurons)]
    models = [LIF_neuron(threshold=5, nu=-32, lam=60),
              LIF_neuron(threshold=9, nu=-32, lam=3),
              ANN_neuron(threshold=4, nu=-32)]
    axons = {f"a{i}": [(names[j], int(rng.integers(1, 9)))
                       for j in rng.choice(n_neurons, fanout,
                                           replace=False)]
             for i in range(n_axons)}
    neurons = {}
    for i, k in enumerate(names):
        fo = int(rng.integers(0, fanout + 1))
        syns = [(names[j], int(rng.integers(-6, 9)))
                for j in rng.choice(n_neurons, fo, replace=False)]
        neurons[k] = (syns, models[int(rng.integers(0, len(models)))])
    outputs = names[:5]
    return axons, neurons, outputs


def bulk_spec_from_dicts(axons, neurons, outputs) -> NetworkSpec:
    """The same network through the BULK columnar route: one add_axons,
    grouped add_neurons, one connect call with array arguments."""
    spec = NetworkSpec()
    ax = spec.add_axons(len(axons), keys=list(axons))
    nid = {k: i for i, k in enumerate(neurons)}
    keys = list(neurons)
    i = 0
    while i < len(keys):                      # per-model runs, bulk adds
        j = i
        while j < len(keys) and neurons[keys[j]][1] == neurons[keys[i]][1]:
            j += 1
        spec.add_neurons(j - i, neurons[keys[i]][1], keys=keys[i:j])
        i = j
    pre, post, w = [], [], []
    for a, (k, syns) in enumerate(axons.items()):
        for p, ww in syns:
            pre.append(int(ax[a]))
            post.append(nid[p])
            w.append(ww)
    for k, (syns, _) in neurons.items():
        for p, ww in syns:
            pre.append(nid[k])
            post.append(nid[p])
            w.append(ww)
    if pre:
        spec.connect(np.asarray(pre), np.asarray(post), np.asarray(w))
    spec.set_outputs([nid[k] for k in outputs])
    return spec


def legacy_image(axons, neurons, outputs, dense_pack=True):
    """The seed-era construction: per-key dicts -> id adjacency ->
    hbm.compile_network (the preserved per-synapse Python mapper)."""
    aid = {k: i for i, k in enumerate(axons)}
    nid = {k: i for i, k in enumerate(neurons)}
    axon_syn = {aid[k]: [(nid[p], int(w)) for p, w in axons[k]]
                for k in axons}
    neuron_syn = {nid[k]: [(nid[p], int(w)) for p, w in neurons[k][0]]
                  for k in neurons}
    sig, model_ids = {}, {}
    for i, k in enumerate(neurons):
        m = neurons[k][1]
        s = (m.kind, m.threshold, m.nu, m.lam)
        model_ids[i] = sig.setdefault(s, len(sig))
    return hbm.compile_network(axon_syn, neuron_syn, model_ids,
                               [nid[k] for k in outputs], len(neurons),
                               dense_pack=dense_pack)


def assert_images_equal(a, b):
    np.testing.assert_array_equal(a.syn_post, b.syn_post)
    np.testing.assert_array_equal(a.syn_weight, b.syn_weight)
    np.testing.assert_array_equal(a.syn_outflag, b.syn_outflag)
    assert a.axon_ptr == b.axon_ptr
    assert a.neuron_ptr == b.neuron_ptr
    assert a.model_groups == b.model_groups


def assert_shards_equal(a, b):
    assert a.n_cores == b.n_cores and a.n_max == b.n_max
    for f in ("core_nids", "core_of_neuron", "local_id", "entry_pos",
              "entry_item", "entry_w", "csr_indptr", "grey_entries",
              "white_entries", "white_sources"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)


def counter_dict(net):
    return None if net.counter is None else net.counter.as_dict()


# ------------------------------------------------- columnar mapper parity
@pytest.mark.parametrize("dense", [True, False])
def test_columnar_compile_matches_legacy_mapper(dense):
    for seed in range(4):
        axons, neurons, outputs = random_dicts(seed)
        spec = NetworkSpec.from_dicts(axons, neurons, outputs)
        compiled = compile_spec(spec, target="engine", dense_pack=dense)
        assert_images_equal(compiled.image,
                            legacy_image(axons, neurons, outputs, dense))


def test_bulk_and_dict_routes_identical():
    axons, neurons, outputs = random_dicts(11)
    img_dict = compile_spec(NetworkSpec.from_dicts(
        axons, neurons, outputs), target="engine").image
    img_bulk = compile_spec(bulk_spec_from_dicts(
        axons, neurons, outputs), target="engine").image
    assert_images_equal(img_dict, img_bulk)


def test_build_time_shards_match_monolith_slicing():
    axons, neurons, outputs = random_dicts(3)
    hier = Hierarchy(2, 2, 2, 4)
    spec = NetworkSpec.from_dicts(axons, neurons, outputs)
    compiled = compile_spec(spec, target="hiaer", hierarchy=hier)
    ref = hbm.shard_image(compiled.image, compiled.flat,
                          compiled.neuron_core, compiled.axon_core,
                          hier.n_cores, compiled.n_neurons)
    assert_shards_equal(compiled.shards, ref)


# ------------------------------------------- spec→compile→deploy parity
@pytest.mark.parametrize("backend", ["simulator", "engine", "hiaer"])
def test_staged_pipeline_bit_exact_vs_legacy_dicts(backend, tmp_path):
    """Bulk-built, compiled, SAVED, LOADED, deployed network == legacy
    dict CRI_network on spikes, membranes, and counter stats."""
    axons, neurons, outputs = random_dicts(7)
    legacy = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                         backend=backend, seed=9)
    spec = bulk_spec_from_dicts(axons, neurons, outputs)
    compiled = compile_spec(spec, target=backend)
    path = tmp_path / f"net_{backend}.npz"
    compiled.save(path)
    staged = CRI_network.from_compiled(CompiledNetwork.load(path), seed=9)
    assert staged.backend == backend

    rng = random.Random(4)
    ax_keys = list(axons)
    for _ in range(10):
        inp = rng.sample(ax_keys, k=rng.randint(0, len(ax_keys)))
        f1, p1 = legacy.step(inp, membranePotential=True)
        f2, p2 = staged.step(inp, membranePotential=True)
        assert f1 == f2 and p1 == p2
    sched = np.asarray(np.stack(
        [np.eye(len(ax_keys), dtype=np.int32)[: len(ax_keys)]] * 2))
    np.testing.assert_array_equal(legacy.run_batch(sched),
                                  staged.run_batch(sched))
    assert legacy.run(sched[0]) == staged.run(sched[0])
    assert counter_dict(legacy) == counter_dict(staged)


def test_save_load_round_trip_bit_identical(tmp_path):
    axons, neurons, outputs = random_dicts(5)
    for target, kw in (("simulator", {}), ("engine", {}),
                       ("hiaer", {"hierarchy": Hierarchy(1, 2, 2, 8)}),
                       ("mesh", {"hierarchy": Hierarchy(1, 2, 2, 8)})):
        compiled = compile_spec(NetworkSpec.from_dicts(
            axons, neurons, outputs), target=target, **kw)
        path = tmp_path / f"art_{target}.npz"
        compiled.save(path)
        loaded = CompiledNetwork.load(path)
        assert loaded.target == target
        assert loaded.axon_keys == compiled.axon_keys
        assert loaded.neuron_keys == compiled.neuron_keys
        for f in ("outputs", "theta", "nu", "lam", "is_lif", "model_gid",
                  "syn_item", "syn_post", "syn_weight"):
            np.testing.assert_array_equal(getattr(loaded, f),
                                          getattr(compiled, f), err_msg=f)
        if target == "simulator":
            np.testing.assert_array_equal(loaded.axonW, compiled.axonW)
            np.testing.assert_array_equal(loaded.neuronW,
                                          compiled.neuronW)
        else:
            np.testing.assert_array_equal(loaded.syn_pos,
                                          compiled.syn_pos)
            assert_images_equal(loaded.image, compiled.image)
            for f in ("axon_base", "axon_rows", "axon_present",
                      "neuron_base", "neuron_rows", "neuron_present",
                      "row_owner_axon", "row_owner_neuron",
                      "axon_row_indptr", "axon_row_indices",
                      "neuron_row_indptr", "neuron_row_indices"):
                np.testing.assert_array_equal(
                    getattr(loaded.flat, f), getattr(compiled.flat, f),
                    err_msg=f)
        if target in ("hiaer", "mesh"):
            assert loaded.hierarchy == compiled.hierarchy
            np.testing.assert_array_equal(loaded.neuron_core,
                                          compiled.neuron_core)
            np.testing.assert_array_equal(loaded.axon_core,
                                          compiled.axon_core)
            np.testing.assert_array_equal(loaded.axon_ndest,
                                          compiled.axon_ndest)
            np.testing.assert_array_equal(loaded.neuron_ndest,
                                          compiled.neuron_ndest)
            assert_shards_equal(loaded.shards, compiled.shards)


# ------------------------------------------------ batched reconfiguration
def big_random_net(seed=0, n_axons=40, n_neurons=100, fanout=25):
    rng = np.random.default_rng(seed)
    names = [f"n{i}" for i in range(n_neurons)]
    lif = LIF_neuron(threshold=50, nu=-32, lam=4)
    axons = {f"a{i}": [(names[j], int(rng.integers(1, 9)))
                       for j in rng.choice(n_neurons, fanout,
                                           replace=False)]
             for i in range(n_axons)}
    neurons = {k: ([], lif) for k in names}
    return axons, neurons, names[:4]


@pytest.mark.parametrize("backend", ["engine", "hiaer"])
def test_thousand_synapse_batch_is_one_upload(backend):
    axons, neurons, outputs = big_random_net()
    kw = {"hierarchy": Hierarchy(1, 1, 2, 64)} if backend == "hiaer" \
        else {}
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend=backend, seed=0, **kw)
    calls = []
    # engine uploads the whole image; hiaer applies the batch as ONE
    # shard-local update_entry_weights call
    meth = "update_weights" if backend == "engine" \
        else "update_entry_weights"
    orig = getattr(net._impl, meth)
    setattr(net._impl, meth,
            lambda *a: (calls.append(1), orig(*a))[1])
    pres, posts, ws = [], [], []
    for a, syns in axons.items():
        for p, w in syns:
            pres.append(a)
            posts.append(p)
            ws.append(w + 1)
    assert len(pres) == 1000
    net.write_synapses(pres, posts, ws)
    assert len(calls) == 1                  # ONE re-upload / re-shard
    assert net._dep.weight_uploads == 1
    np.testing.assert_array_equal(
        net.read_synapses(pres, posts), np.asarray(ws))
    # the compiled scan path must see the batch edit
    net.reset()
    legacy = CRI_network(axons={k: [(p, w + 1) for p, w in v]
                               for k, v in axons.items()},
                         neurons=neurons, outputs=outputs,
                         backend=backend, seed=0, **kw)
    sched = [[k] for k in list(axons)[:6]]
    assert net.run(sched) == legacy.run(sched)


def test_single_core_batch_rebuilds_one_shard():
    """Per-core weight storage: a batch whose edits all land on ONE
    core's shard rebuilds exactly that shard, not the full table set;
    a cross-core batch rebuilds exactly the touched shards."""
    n = 12
    names = [f"n{i}" for i in range(n)]
    lif = LIF_neuron(threshold=50, nu=-32, lam=4)
    axons = {"a0": [(names[i], 5) for i in range(n)]}
    neurons = {k: ([], lif) for k in names}
    placement = {names[i]: i % 2 for i in range(n)}   # even->0, odd->1
    net = CRI_network(axons=axons, neurons=neurons, outputs=names[:2],
                      backend="hiaer", seed=0,
                      hierarchy=Hierarchy(1, 1, 2, n),
                      placement=placement)
    assert net._impl.shard_rebuilds == 0
    core0 = [names[i] for i in range(0, n, 2)]
    net.write_synapses(["a0"] * len(core0), core0,
                       list(range(1, len(core0) + 1)))
    assert net._impl.shard_rebuilds == 1       # only core 0's shard
    assert net._dep.weight_uploads == 1
    net.write_synapses(["a0", "a0"], [names[0], names[1]], [7, 8])
    assert net._impl.shard_rebuilds == 3       # both cores touched
    # the edits are live in the compiled scan
    legacy = {p: w for p, w in zip(core0, range(1, len(core0) + 1))}
    legacy[names[0]], legacy[names[1]] = 7, 8
    ref = CRI_network(
        axons={"a0": [(p, legacy.get(p, 5)) for p, _ in axons["a0"]]},
        neurons=neurons, outputs=names[:2], backend="hiaer", seed=0,
        hierarchy=Hierarchy(1, 1, 2, n), placement=placement)
    sched = [["a0"], [], ["a0"]]
    assert net.run(sched) == ref.run(sched)


def test_write_synapses_batch_semantics():
    axons, neurons, outputs = big_random_net(3)
    nets = {b: CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                           backend=b, seed=1)
            for b in ("simulator", "engine", "hiaer")}
    a0 = "a0"
    posts = [p for p, _ in axons[a0][:3]]
    for b, net in nets.items():
        # duplicate pairs in one batch resolve last-wins
        net.write_synapses([a0, a0], [posts[0], posts[0]], [5, 9])
        assert net.read_synapse(a0, posts[0]) == 9, b
        # a batch with any missing pair mutates NOTHING
        before = [net.read_synapse(a0, p) for p in posts]
        with pytest.raises(KeyError):
            net.write_synapses([a0, a0, "n0"],
                               posts[:2] + [posts[0]], [1, 2, 3])
        assert [net.read_synapse(a0, p) for p in posts] == before, b
    for b, net in nets.items():
        # broadcast: one pre against many posts (and the KeyError for a
        # missing pair names the broadcast key, not an IndexError)
        np.testing.assert_array_equal(
            net.read_synapses([a0], posts),
            [net.read_synapse(a0, p) for p in posts])
        targeted = {p for p, _ in axons[a0]}
        missing = next(k for k in net.neuron_keys if k not in targeted)
        with pytest.raises(KeyError):
            net.read_synapses([a0], [posts[0], missing])
        # records are int16: out-of-range writes clip identically in
        # the readable column and the routed tables
        net.write_synapse(a0, posts[1], 50_000)
        assert net.read_synapse(a0, posts[1]) == 32767, b
    # all three backends agree after the same batched edits
    sched = [[a0], [], [a0]]
    runs = {b: net.run(sched) for b, net in nets.items()}
    assert runs["simulator"] == runs["engine"] == runs["hiaer"]


# -------------------------------------------------- synapse index (PR 3)
def test_synapse_index_keyerrors_and_disambiguation():
    """Regression: a key naming BOTH an axon and a neuron resolves to
    the AXON (the legacy scan order), for reads and writes."""
    lif = LIF_neuron(threshold=1000, nu=-32, lam=63)
    axons = {"shared": [("t", 7)], "a": [("t", 1)]}
    neurons = {"shared": ([("t", 3)], lif), "t": ([], lif)}
    for backend in ("simulator", "engine", "hiaer"):
        net = CRI_network(axons=axons, neurons=neurons, outputs=["t"],
                          backend=backend, seed=0)
        assert net.read_synapse("shared", "t") == 7          # axon wins
        net.write_synapse("shared", "t", 11)
        assert net.read_synapse("shared", "t") == 11
        # the NEURON's synapse is untouched by the axon-space write
        assert net._neuron_syn[0] == [(1, 3)]
        with pytest.raises(KeyError):
            net.read_synapse("a", "missing")                 # bad post
        with pytest.raises(KeyError):
            net.read_synapse("nope", "t")                    # bad pre
        with pytest.raises(KeyError):
            net.read_synapse("t", "t")           # neuron pre, no synapse
        with pytest.raises(KeyError):
            net.write_synapse("a", "a", 5)       # axon->missing post key
        # semantic check: axon drive uses the edited axon weight, the
        # neuron->neuron synapse still carries 3
        net.reset()
        net.step(["shared"])
        assert net.read_membrane("t") == [11]


def test_empty_batch_is_noop():
    axons, neurons, outputs = big_random_net(4)
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=0)
    net.write_synapses([], [], [])
    assert net._dep.weight_uploads == 0
    assert net.read_synapses([], []).shape == (0,)


# ----------------------------------------------------- bulk spec surface
def test_spec_validation_errors():
    spec = NetworkSpec()
    ax = spec.add_axons(2)
    nr = spec.add_neurons(3, LIF_neuron(threshold=1))
    with pytest.raises(ValueError):
        spec.connect([nr[0]], [7], [1])          # unknown post
    with pytest.raises(ValueError):
        spec.connect([-9], [0], [1])             # unknown axon
    with pytest.raises(TypeError):
        spec.connect([int(ax[0])], [0], [1.5])   # float weight
    with pytest.raises(KeyError):
        spec.set_outputs([5])
    with pytest.raises(TypeError):
        spec.add_neurons(1, "not-a-model")


def test_bulk_spec_deploys_on_all_backends():
    rng = np.random.default_rng(2)
    spec = NetworkSpec()
    ax = spec.add_axons(6)
    nr = spec.add_neurons(40, LIF_neuron(threshold=30, nu=-32, lam=5))
    pre = np.concatenate([np.repeat(ax, 10),
                          nr[rng.integers(0, 40, 120)]])
    post = nr[rng.integers(0, 40, pre.shape[0])]
    w = rng.integers(1, 15, pre.shape[0])
    spec.connect(pre, post, w)
    spec.set_outputs(nr[:6])
    sched = (rng.integers(0, 2, (8, 6)) * 2).astype(np.int32)
    outs = {}
    for backend in ("simulator", "engine", "hiaer"):
        net = CRI_network.from_spec(spec, backend=backend, seed=3)
        outs[backend] = (net.run(sched),
                         net.read_membrane(*range(40)))
    assert outs["simulator"] == outs["engine"] == outs["hiaer"]


# ------------------------------------------------- hypothesis properties
@st.composite
def spec_network(draw):
    n_ax = draw(st.integers(1, 5))
    n_nr = draw(st.integers(2, 16))
    nrs = [f"n{i}" for i in range(n_nr)]
    axons = {}
    for i in range(n_ax):
        axons[f"a{i}"] = draw(st.lists(
            st.tuples(st.sampled_from(nrs), st.integers(-40, 40)),
            max_size=5, unique_by=lambda t: t[0]))
    neurons = {}
    for k in nrs:
        fanout = draw(st.lists(
            st.tuples(st.sampled_from(nrs), st.integers(-40, 40)),
            max_size=4, unique_by=lambda t: t[0]))
        if draw(st.booleans()):
            model = LIF_neuron(threshold=draw(st.integers(0, 30)),
                               nu=draw(st.sampled_from([-32, -20, 1])),
                               lam=draw(st.integers(0, 63)))
        else:
            model = ANN_neuron(threshold=draw(st.integers(0, 30)),
                               nu=draw(st.sampled_from([-32, 1])))
        neurons[k] = (fanout, model)
    outputs = draw(st.lists(st.sampled_from(nrs), min_size=1,
                            max_size=3, unique=True))
    return axons, neurons, outputs


@settings(max_examples=8, deadline=None)
@given(spec_network(), st.integers(0, 10_000))
def test_property_three_routes_three_backends(netdef, seed):
    """Bulk NetworkSpec.connect vs from_dicts vs legacy dict
    CRI_network: identical HBM images, identical run_batch outputs on
    simulator/engine/hiaer."""
    axons, neurons, outputs = netdef
    spec_d = NetworkSpec.from_dicts(axons, neurons, outputs)
    spec_b = bulk_spec_from_dicts(axons, neurons, outputs)
    img_ref = legacy_image(axons, neurons, outputs)
    assert_images_equal(compile_spec(spec_d, target="engine").image,
                        img_ref)
    assert_images_equal(compile_spec(spec_b, target="engine").image,
                        img_ref)
    rng = np.random.default_rng(seed)
    batch = rng.integers(0, 2, (2, 5, len(axons))).astype(np.int32)
    ref = None
    for backend in ("simulator", "engine", "hiaer"):
        legacy_out = CRI_network(axons=axons, neurons=neurons,
                                 outputs=outputs, backend=backend,
                                 seed=seed).run_batch(batch)
        for s in (spec_d, spec_b):
            out = CRI_network.from_spec(s, backend=backend,
                                        seed=seed).run_batch(batch)
            np.testing.assert_array_equal(out, legacy_out)
        if ref is None:
            ref = legacy_out
        np.testing.assert_array_equal(legacy_out, ref)
