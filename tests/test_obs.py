"""Observability subsystem (PR 9) — request tracing, unified metrics,
structured logging.

Pins the acceptance invariants:
  * span trees stay consistent under 8 concurrent clients: unique
    span ids, one root per trace, children nested inside their
    parent's [start, end] window, ring bound + dropped accounting;
  * histogram bucket math (property-based): cumulative `_bucket{le=}`
    counts equal #(v <= le) exactly, `_sum`/`_count` match, quantile
    estimates bracket the observed values;
  * `GET /metrics` renders Prometheus text that `parse_prometheus`
    round-trips and that is NUMERICALLY equal to `SpikeServer.stats()`;
  * one portal request produces ONE trace with >= 4 nested stages
    (http_request -> gateway_call -> queue_wait/dispatch) whose id the
    client chose via `X-Trace-Id`, fetchable at `/trace?trace_id=`;
  * with `--workers 2` the trace additionally crosses the bridge
    (>= 5 stages) and `/metrics` aggregates worker registries with a
    `*_by_worker` breakdown that never double-counts the base series;
  * `--log-json` emits one flat JSON record per request with the
    canonical schema for 200 / 400 E_SCHED_WIDTH / 429 / 503 / 504.
"""
import http.client
import json
import math
import threading

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.api import LIF_neuron
from repro.core.compile import compile_spec
from repro.core.spec import NetworkSpec
from repro.obs import (Histogram, MetricsRegistry, Span, Telemetry,
                       Tracer, chrome_trace, log_buckets,
                       merge_snapshots, new_trace_id,
                       parse_prometheus, render_snapshot,
                       snapshot_by_worker, validate_chrome_trace)
from repro.portal import Portal, TokenQuota
from repro.serve import SpikeServer


def small_compiled(n_axons=5, n_neurons=12, seed=3):
    rng = np.random.default_rng(seed)
    spec = NetworkSpec()
    ax = spec.add_axons(n_axons)
    nid = spec.add_neurons(n_neurons,
                           LIF_neuron(threshold=5, nu=-32, lam=50))
    pre = np.concatenate([np.repeat(ax, 4), np.repeat(nid, 3)])
    post = rng.integers(0, n_neurons, pre.shape[0])
    w = rng.integers(-3, 7, pre.shape[0])
    spec.connect(pre, post, w)
    spec.set_outputs([0, 1, 2])
    return compile_spec(spec, target="engine")


def http_raw(port, method, path, body=None, token=None, headers=None,
             timeout=120):
    conn = http.client.HTTPConnection("127.0.0.1", port,
                                      timeout=timeout)
    hs = {"Content-Type": "application/json"}
    if token is not None:
        hs["Authorization"] = f"Bearer {token}"
    hs.update(headers or {})
    conn.request(method, path,
                 json.dumps(body) if body is not None else None, hs)
    resp = conn.getresponse()
    out = (resp.status,
           {k.lower(): v for k, v in resp.getheaders()}, resp.read())
    conn.close()
    return out


def http_json(port, method, path, body=None, **kw):
    s, h, raw = http_raw(port, method, path, body, **kw)
    return s, h, json.loads(raw.decode("utf-8"))


def windows(rng, B, T, A):
    return rng.integers(0, 2, (B, T, A)).astype(np.int32)


# ------------------------------------------------------- tracer units
def test_span_tree_invariants_under_concurrent_clients():
    tr = Tracer(capacity=10000)

    def client(cid):
        for i in range(20):
            root = tr.span("http_request", client=cid, i=i)
            child = tr.span("gateway_call", ctx=root.ctx())
            grand = tr.span("dispatch", ctx=child.ctx())
            grand.finish()
            child.finish()
            root.finish()

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    spans = tr.spans()
    assert len(spans) == 8 * 20 * 3 and tr.dropped == 0
    ids = [s.span_id for s in spans]
    assert len(set(ids)) == len(ids)            # globally unique
    by_id = {s.span_id: s for s in spans}
    roots = {}
    for s in spans:
        assert s.end is not None and s.end >= s.start
        if s.parent_id is None:
            # exactly one root per trace
            assert s.trace_id not in roots
            roots[s.trace_id] = s
        else:
            parent = by_id[s.parent_id]
            assert parent.trace_id == s.trace_id
            assert parent.start <= s.start and s.end <= parent.end
    assert len(roots) == 8 * 20


def test_ring_bound_and_dropped_accounting():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.span("s", i=i).finish()
    assert len(tr.spans()) == 8 and tr.dropped == 12
    # batched-dict commit path (the dispatcher hot loop)
    tr2 = Tracer(capacity=8)
    batch = [tr2.span_record("s", start=0, end=1, i=i)
             for i in range(20)]
    tr2.record_batch(batch)
    assert len(tr2.spans()) == 8 and tr2.dropped == 12
    assert all(isinstance(s, Span) for s in tr2.spans())
    tr2.clear()
    assert tr2.spans() == [] and tr2.dropped == 0


def test_disabled_telemetry_is_noop():
    tel = Telemetry(on=False)
    sp = tel.tracer.span("x", model="m")
    assert sp.ctx() is None
    sp.finish()
    assert tel.tracer.spans() == []
    assert tel.tracer.span_record("x", start=0, end=1) is None
    tel.tracer.record_batch([])
    c = tel.metrics.counter("c_total", "h")
    c.inc()
    assert c.value() == 0.0
    h = tel.metrics.histogram("h_ms", "h")
    h.observe(1.0)
    h.observe_many([1.0, 2.0])
    assert h.count() == 0
    assert not tel.log.enabled            # no sink configured


def test_span_wire_round_trip_and_chrome_export():
    tr = Tracer()
    with tr.span("dispatch", trace_id="f" * 16, model="m",
                 bucket=4) as sp:
        pass
    d = sp.to_dict()
    assert Span.from_dict(d).to_dict() == d
    doc = chrome_trace(tr.spans())
    assert validate_chrome_trace(doc) == []
    (ev,) = doc["traceEvents"]
    assert ev["ph"] == "X" and ev["dur"] >= 0
    assert ev["args"]["trace_id"] == "f" * 16
    assert ev["args"]["bucket"] == 4
    # structural negatives the CI smoke relies on
    assert validate_chrome_trace({"nope": 1})
    assert validate_chrome_trace({"traceEvents": [{}]})
    assert validate_chrome_trace(
        {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0,
                          "pid": 1, "tid": 1, "dur": -1.0,
                          "args": {"trace_id": "t"}}]})


def test_trace_ids_unique_and_well_formed():
    ids = {new_trace_id() for _ in range(1000)}
    assert len(ids) == 1000
    assert all(len(i) == 16 and int(i, 16) >= 0 for i in ids)


# ------------------------------------------------------- metrics units
def test_log_buckets_strictly_increasing_and_cover_range():
    bs = log_buckets()
    assert bs == sorted(bs) and len(set(bs)) == len(bs)
    assert bs[0] == 0.25 and bs[-1] >= 8000.0
    with pytest.raises(ValueError):
        log_buckets(lo=0)
    with pytest.raises(ValueError):
        log_buckets(lo=10, hi=1)


def test_label_mismatch_raises_and_family_conflicts_rejected():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "x", ("model", "outcome"))
    with pytest.raises(ValueError):
        c.inc(model="m")                      # missing label
    with pytest.raises(ValueError):
        c.inc(model="m", wrong="x")           # unknown label
    c.inc(model="m", outcome="ok")
    assert c.value(model="m", outcome="ok") == 1.0
    assert reg.counter("c_total", "x", ("model", "outcome")) is c
    with pytest.raises(ValueError):
        reg.counter("c_total", "x", ("other",))   # label mismatch
    with pytest.raises(ValueError):
        reg.gauge("c_total", "x")                 # kind mismatch


@given(st.lists(st.floats(min_value=1e-3, max_value=1e4,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_histogram_bucket_math_property(values):
    reg = MetricsRegistry()
    h = reg.histogram("h_ms", "x")
    half = len(values) // 2
    for v in values[:half]:
        h.observe(v)
    h.observe_many(values[half:])
    vals = [float(v) for v in values]
    assert h.count() == len(vals)
    assert h.sum() == pytest.approx(sum(vals), rel=1e-9)
    series = parse_prometheus(render_snapshot(reg.collect()))
    # cumulative bucket counts == #(v <= le), exactly (bisect_left
    # puts a sample equal to a boundary IN that boundary's bucket)
    for key, got in series["h_ms_bucket"].items():
        (le,) = [v for k, v in key if k == "le"]
        bound = math.inf if le == "+Inf" else float(le)
        assert got == sum(1 for v in vals if v <= bound)
    assert series["h_ms_count"][frozenset()] == len(vals)
    assert series["h_ms_sum"][frozenset()] == \
        pytest.approx(sum(vals), rel=1e-9)
    # quantile estimates are bucket upper bounds around the data
    assert h.quantile(1.0) >= max(vals)
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0)


def test_merge_snapshots_sum_counters_lastwins_gauges():
    a, b = MetricsRegistry(), MetricsRegistry()
    for reg, n in ((a, 3), (b, 4)):
        reg.counter("c_total", "x", ("model",)).inc(n, model="m")
        reg.gauge("g", "x").set(n)
        h = reg.histogram("h_ms", "x")
        h.observe_many([1.0] * n)
    merged = merge_snapshots([a.collect(), b.collect()])
    series = parse_prometheus(render_snapshot(merged))
    assert series["c_total"][frozenset({("model", "m")})] == 7
    assert series["g"][frozenset()] == 4          # last snapshot wins
    assert series["h_ms_count"][frozenset()] == 7
    assert series["h_ms_sum"][frozenset()] == 7.0


def test_snapshot_by_worker_keeps_base_series_clean():
    a = MetricsRegistry()
    a.counter("c_total", "x").inc(5)
    snap = a.collect()
    merged = merge_snapshots(
        [snap, snapshot_by_worker(snap, 1234)])
    series = parse_prometheus(render_snapshot(merged))
    assert series["c_total"][frozenset()] == 5    # not double-counted
    assert series["c_total_by_worker"][
        frozenset({("worker", "1234")})] == 5


def test_render_parse_roundtrip_with_label_escaping():
    reg = MetricsRegistry()
    weird = 'tok "x"\ny'
    reg.counter("weird_total", "h", ("name",)).inc(name=weird)
    series = parse_prometheus(render_snapshot(reg.collect()))
    assert series["weird_total"][frozenset({("name", weird)})] == 1


# ------------------------------------------- portal integration (obs)
@pytest.fixture(scope="module")
def obs_portal():
    """One resident engine model behind an in-process portal, shared
    by the observability HTTP tests (module-scoped: compile once)."""
    c = small_compiled()
    srv = SpikeServer(max_batch=8, max_wait_ms=3.0)
    srv.add_model("m", c, window=4, n_sessions=2, seed=0)
    with srv, Portal(srv, port=0) as portal:
        yield srv, portal, c


def test_single_request_trace_has_nested_stages(obs_portal):
    srv, portal, c = obs_portal
    tid = new_trace_id()
    w = windows(np.random.default_rng(5), 1, 4, c.n_axons)[0]
    s, h, body = http_json(portal.port, "POST", "/v1/m/run",
                           {"counts": w.tolist()},
                           headers={"X-Trace-Id": tid})
    assert s == 200
    assert h["x-trace-id"] == tid              # id echoed to client
    assert body["trace_id"] == tid

    s, _, doc = http_json(portal.port, "GET",
                          f"/trace?trace_id={tid}")
    assert s == 200 and validate_chrome_trace(doc) == []
    events = doc["traceEvents"]
    names = {e["name"] for e in events}
    assert {"http_request", "gateway_call",
            "queue_wait", "dispatch"} <= names
    assert all(e["args"]["trace_id"] == tid for e in events)
    # single root; every child's parent resolves inside the trace and
    # brackets it in time
    by_id = {e["args"]["span_id"]: e for e in events}
    roots = [e for e in events if not e["args"].get("parent_id")]
    assert len(roots) == 1 and roots[0]["name"] == "http_request"
    for e in events:
        pid = e["args"].get("parent_id")
        if pid:
            p = by_id[pid]
            assert p["ts"] <= e["ts"] + 1e-6
            assert e["ts"] + e["dur"] <= p["ts"] + p["dur"] + 1e-6


def test_metrics_prometheus_parses_and_matches_stats(obs_portal):
    srv, portal, c = obs_portal
    w = windows(np.random.default_rng(6), 1, 4, c.n_axons)[0]
    for _ in range(3):
        s, _, _ = http_json(portal.port, "POST", "/v1/m/run",
                            {"counts": w.tolist()})
        assert s == 200
    s, h, raw = http_raw(portal.port, "GET", "/metrics")
    assert s == 200 and h["content-type"].startswith("text/plain")
    series = parse_prometheus(raw.decode("utf-8"))
    stats = srv.stats()
    served = sum(m["requests"] for m in stats["models"].values())
    ok = sum(v for k, v in series["repro_serve_requests_total"].items()
             if ("outcome", "ok") in k)
    assert ok == served
    total_lat = sum(
        v for k, v in series["repro_serve_latency_ms_count"].items()
        if ("stage", "total") in k)
    assert total_lat == served
    # scrape-time gauges + http-side families present
    assert series["repro_dispatcher_alive"][frozenset()] == 1
    assert "repro_serve_queue_depth" in series
    assert any(("status", "200") in k
               for k in series["repro_http_requests_total"])
    # legacy JSON view still answers (worker-local by design)
    s, _, legacy = http_json(portal.port, "GET",
                             "/metrics?format=json")
    assert s == 200 and "server" in legacy and "clients" in legacy


def test_healthz_reports_queue_lanes_dispatcher(obs_portal):
    srv, portal, c = obs_portal
    s, _, hz = http_json(portal.port, "GET", "/healthz")
    assert s == 200 and hz["ok"]
    assert hz["dispatcher"]["alive"]
    assert "pending" in hz["queue"]
    assert hz["models"]["m"]["window"] == 4
    assert hz["lanes"]["m"]["capacity"] >= hz["lanes"]["m"]["in_use"]


def test_multiworker_metrics_aggregate_and_bridge_trace():
    c = small_compiled()
    srv = SpikeServer(max_batch=8, max_wait_ms=2.0)
    srv.add_model("m", c, window=4, n_sessions=0, seed=0)
    w = windows(np.random.default_rng(7), 1, 4, c.n_axons)[0]
    tid = new_trace_id()
    with srv, Portal(srv, port=0, workers=2) as portal:
        s, h, body = http_json(portal.port, "POST", "/v1/m/run",
                               {"counts": w.tolist()},
                               headers={"X-Trace-Id": tid})
        assert s == 200 and body["trace_id"] == tid

        # drive fresh connections until BOTH SO_REUSEPORT workers have
        # answered (each gateway op forwards that worker's registry
        # snapshot and drained spans to the dispatcher)
        pids = set()
        for _ in range(200):
            s, _, hz = http_json(portal.port, "GET", "/healthz")
            assert s == 200
            pids.add(hz["worker_pid"])
            if len(pids) >= 2:
                break
        assert len(pids) >= 2, \
            f"SO_REUSEPORT never balanced across workers: {pids}"

        # the run's spans reach the dispatcher ring on the serving
        # worker's NEXT bridge call — poll /trace until the full
        # cross-process tree (5 stages incl. the bridge hop) lands
        names, events = set(), []
        for _ in range(200):
            s, _, doc = http_json(portal.port, "GET",
                                  f"/trace?trace_id={tid}")
            assert s == 200 and validate_chrome_trace(doc) == []
            events = doc["traceEvents"]
            names = {e["name"] for e in events}
            if {"http_request", "bridge", "gateway_call",
                    "queue_wait", "dispatch"} <= names:
                break
        assert {"http_request", "bridge", "gateway_call",
                "queue_wait", "dispatch"} <= names, names
        roots = [e for e in events
                 if not e["args"].get("parent_id")]
        assert len(roots) == 1 and roots[0]["name"] == "http_request"
        by_id = {e["args"]["span_id"]: e for e in events}
        assert all(e["args"].get("parent_id") in by_id
                   for e in events if e["args"].get("parent_id"))
        assert len({e["pid"] for e in events}) >= 2   # cross-process

        s, _, raw = http_raw(portal.port, "GET", "/metrics")
        assert s == 200
        series = parse_prometheus(raw.decode("utf-8"))
        by_worker = series.get("repro_http_requests_total_by_worker",
                               {})
        workers_seen = {dict(k)["worker"] for k in by_worker}
        assert len(workers_seen) >= 2
        # aggregated base == sum of the per-worker breakdown (the
        # dispatcher itself serves no HTTP): no double counting
        assert sum(series["repro_http_requests_total"].values()) == \
            sum(by_worker.values())


def test_json_log_schema_and_error_codes(tmp_path):
    log = tmp_path / "requests.ndjson"
    c = small_compiled()
    tokens = {"slow": TokenQuota(rate=0.001, burst=1, max_inflight=8,
                                 name="bob"),
              "good": TokenQuota(rate=1000.0, burst=1000,
                                 max_inflight=8, name="alice")}
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0,
                      telemetry=Telemetry(log_json=str(log)))
    srv.add_model("m", c, window=3, n_sessions=0, seed=0)
    w = windows(np.random.default_rng(0), 1, 3, c.n_axons)[0]
    run = {"counts": w.tolist()}
    with srv, Portal(srv, port=0, tokens=tokens) as portal:
        assert http_json(portal.port, "POST", "/v1/m/run", run,
                         token="slow")[0] == 200
        assert http_json(portal.port, "POST", "/v1/m/run", run,
                         token="slow")[0] == 429
        wide = np.zeros((3, c.n_axons + 7), int)
        assert http_json(portal.port, "POST", "/v1/m/run",
                         {"counts": wide.tolist()},
                         token="good")[0] == 400
        assert http_json(portal.port, "POST", "/v1/m/run",
                         dict(run, timeout=1e-6),
                         token="good")[0] == 504
    # a second server sharing the SAME log file exercises 503 (full
    # buffer) and append-mode interleaving of whole lines
    srv2 = SpikeServer(max_batch=4, max_wait_ms=1.0, max_pending=0,
                       telemetry=Telemetry(log_json=str(log)))
    srv2.add_model("m", c, window=3, n_sessions=0, seed=0)
    with srv2, Portal(srv2, port=0) as portal:
        assert http_json(portal.port, "POST", "/v1/m/run",
                         run)[0] == 503

    recs = [json.loads(ln) for ln in
            log.read_text().strip().splitlines()]
    base = {"ts", "event", "trace_id", "token", "model", "op",
            "status", "code", "latency_ms"}
    for r in recs:
        assert base <= set(r) and r["event"] == "request"
        assert r["trace_id"]
    by_status = {r["status"]: r for r in recs}
    assert {200, 429, 400, 504, 503} <= set(by_status)
    ok = by_status[200]
    assert ok["code"] is None and ok["token"] == "bob"
    assert ok["model"] == "m" and ok["op"] == "run"
    assert {"bucket", "batch_size", "queue_wait_ms",
            "dispatch_ms"} <= set(ok)
    assert by_status[429]["code"] == "E_QUOTA_RATE"
    assert by_status[429]["token"] == "bob"
    assert by_status[400]["code"] == "E_SCHED_WIDTH"
    assert by_status[504]["code"] == "E_DEADLINE"
    assert by_status[503]["code"] == "E_BACKPRESSURE"
    # secrets never land in the log: the raw bearer tokens are absent
    text = log.read_text()
    assert "slow" not in text and "good" not in text
