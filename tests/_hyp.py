"""Optional-hypothesis shim for the test suite.

The property-based tests use hypothesis when it is installed (see
requirements-dev.txt); on bare containers without it, importing this module
instead of `hypothesis` keeps every deterministic test collectable and
runnable while the `@given`-decorated properties are individually skipped
(the per-test equivalent of `pytest.importorskip("hypothesis")`).

Usage in test modules:

    from _hyp import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(reason="hypothesis not installed "
                                    "(pip install -r requirements-dev.txt)")

    def given(*args, **kwargs):
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    class _Stub:
        """Absorbs any strategy construction (`st.integers(0, 5)`,
        `@st.composite`, chained calls) at import time; the decorated
        tests are skipped before any stub value is ever drawn."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Stub()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
