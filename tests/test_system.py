"""End-to-end behaviour tests for the paper's system: the full §6 pipeline
(train -> quantize -> convert -> event-driven engine -> energy/latency),
the distributed HiAER SNN step vs its oracle, STDP, the loop-aware HLO
analyzer, the optimizer, and the data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import ANN_neuron, CRI_network, LIF_neuron
from repro.core.learning import STDP, STDPConfig
from repro.data.synthetic import digits


def test_full_pipeline_train_convert_deploy():
    from repro.core.convert import (LayerSpec, QATModel, infer_image,
                                    quantize, to_network, train_qat)
    X, y = digits(600, shape=(14, 14), seed=2)
    Xf = X.reshape(-1, 1, 14, 14).astype(np.float32)
    model = QATModel(input_shape=(1, 14, 14),
                     layers=[LayerSpec("dense", out_features=32)],
                     n_classes=10)
    params = train_qat(model, Xf[:500], y[:500], epochs=3)
    qp, _ = quantize(params)
    net, out_keys = to_network(model, qp, backend="engine")
    correct = 0
    for i in range(40):
        pred, _ = infer_image(net, X[500 + i], model, out_keys)
        correct += pred == y[500 + i]
    assert correct / 40 > 0.5                 # learned (chance = 0.1)
    c = net.counter.as_dict()
    assert c["energy_uJ"] > 0 and c["latency_us"] > 0
    # event-driven: sparser input -> fewer HBM accesses
    net.counter.reset()
    net.reset()
    net.step(["x0"]); net.step([])
    sparse = net.counter.total_accesses
    net.counter.reset(); net.reset()
    net.step([f"x{i}" for i in range(100)]); net.step([])
    assert sparse < net.counter.total_accesses


def test_distributed_snn_matches_reference():
    from repro.core.distributed_engine import (SNNShardConfig, make_snn_step,
                                               small_reference_step)
    from repro.distributed.context import mesh_context
    from repro.launch.mesh import make_local_mesh
    cfg = SNNShardConfig(n_neurons=1024, fan_window_blocks=2)
    mesh = make_local_mesh()
    key = jax.random.PRNGKey(0)
    W = cfg.fan_window_blocks * cfg.block
    state = {
        "V": jax.random.randint(key, (cfg.n_neurons,), -100, 500, jnp.int32),
        "theta": jnp.full((cfg.n_neurons,), 300, jnp.int32),
        "lam": jnp.full((cfg.n_neurons,), 4, jnp.int32),
        "weights": jax.random.randint(key, (W, cfg.n_neurons), -30, 50,
                                      jnp.int16),
        "spikes": jax.random.bernoulli(key, 0.1, (cfg.n_neurons,)),
    }
    with mesh_context(mesh):
        step = make_snn_step(cfg, mesh)
        k = jax.random.fold_in(key, 1)
        out = step(state, k)
        Vr, sr = small_reference_step(
            state["V"], state["theta"], state["lam"], state["spikes"],
            state["weights"], k)
        np.testing.assert_array_equal(np.asarray(out["V"]), np.asarray(Vr))
        np.testing.assert_array_equal(np.asarray(out["spikes"]),
                                      np.asarray(sr))


def test_stdp_potentiation_and_depression():
    lif = LIF_neuron(threshold=5, nu=-32, lam=63)
    axons = {"in": [("post", 3)]}
    neurons = {"pre": ([("post", 3)], lif), "post": ([], lif)}
    net = CRI_network(axons=axons, neurons=neurons, outputs=["post"],
                      backend="simulator", seed=0)
    stdp = STDP(net, STDPConfig(a_plus=4, a_minus=2, tau_shift=1))
    w0 = net.read_synapse("pre", "post")
    # causal pairing: pre fires (trace builds), then post fires
    stdp.step(inputs=[], fired_keys=["pre"])
    stdp.step(inputs=[], fired_keys=["post"])
    assert net.read_synapse("pre", "post") > w0      # potentiation
    # anti-causal: post then pre -> depression
    stdp2 = STDP(net, STDPConfig(a_plus=4, a_minus=2, tau_shift=1))
    w1 = net.read_synapse("pre", "post")
    stdp2.step(inputs=[], fired_keys=["post"])
    stdp2.step(inputs=[], fired_keys=["pre"])
    assert net.read_synapse("pre", "post") < w1


def test_hlo_analysis_multiplies_scan_bodies():
    from repro.launch import hlo_analysis

    def single(x, w):
        return x @ w

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    f1 = hlo_analysis.analyze(
        jax.jit(single).lower(x, w).compile().as_text())["flops"]
    f10 = hlo_analysis.analyze(
        jax.jit(scanned).lower(x, ws).compile().as_text())["flops"]
    assert f1 > 0
    assert 8 <= f10 / f1 <= 12                # trip count recovered


def test_adamw_converges_quadratic():
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    oc = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                     total_steps=10_000)
    p = {"w": jnp.ones((8,)) * 4.0}
    st = adamw_init(p, oc)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st, _ = adamw_update(p, g, st, oc)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.05


def test_clip_by_global_norm():
    from repro.optim import clip_by_global_norm
    g = {"a": jnp.ones((100,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 100.0) < 1e-3
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4


def test_token_pipeline_sharded_determinism():
    from repro.data.synthetic import TokenPipeline
    a = TokenPipeline(100, 16, 4, seed=3).next_batch()
    b = TokenPipeline(100, 16, 4, seed=3).next_batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = TokenPipeline(100, 16, 4, seed=4).next_batch()
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_train_launcher_end_to_end(tmp_path):
    """The production launcher runs, checkpoints, and the loss is finite."""
    from repro.launch.train import main
    loss = main(["--arch", "qwen2_5_3b", "--reduced", "--steps", "6",
                 "--batch", "2", "--seq", "32", "--ckpt-dir",
                 str(tmp_path / "run"), "--ckpt-every", "3",
                 "--log-every", "100"])
    assert np.isfinite(loss)
    from repro.checkpoint import CheckpointManager
    assert CheckpointManager(tmp_path / "run").latest_step() == 6


def test_serve_launcher_end_to_end():
    from repro.launch.serve import main
    total = main(["--arch", "qwen2_5_3b", "--reduced", "--requests", "2",
                  "--max-new", "4", "--prompt-len", "3"])
    assert total == 2 * 4
