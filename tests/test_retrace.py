"""Retrace detector (PR 6) — proves each backend's `run_batch` compiles
EXACTLY ONCE per (topology, batch-shape) and replays afterwards, and
that the detector catches the failure mode it exists for.

A silent retrace (host value or varying shape in the jit signature)
keeps results bit-exact while destroying throughput — nothing else in
the suite would notice. `benchmarks/mesh_bench.py` wraps its timed
regions in the same `no_retrace` gate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (RetraceDetector, RetraceError,
                            compile_counts, no_retrace)
from repro.core.api import CRI_network, LIF_neuron
from repro.core.partition import Hierarchy

BATCHED_BACKENDS = ("engine", "hiaer", "mesh")


def small_net(backend):
    lif = LIF_neuron(threshold=4, nu=-32, lam=60)
    axons = {"a": [("x", 3), ("y", 2)], "b": [("y", 4)]}
    neurons = {"x": ([("y", 1)], lif), "y": ([("z", 2)], lif),
               "z": ([], lif)}
    kw = {}
    if backend in ("hiaer", "mesh"):
        kw["hierarchy"] = Hierarchy(1, 1, 2, 2)
    if backend == "mesh":
        kw["n_devices"] = 1          # parent test process: 1 CPU device
    return CRI_network(axons=axons, neurons=neurons,
                       outputs=["x", "y", "z"], backend=backend,
                       seed=0, **kw)


def counts_batch(rng, B, T, A):
    return rng.integers(0, 2, (B, T, A)).astype(np.int32)


# ------------------------------------------------- the acceptance gate
@pytest.mark.parametrize("backend", BATCHED_BACKENDS)
def test_run_batch_compiles_exactly_once_per_shape(backend):
    net = small_net(backend)
    rng = np.random.default_rng(0)
    A = len(net.axon_keys)
    counts = counts_batch(rng, 3, 5, A)
    net.run_batch(counts)                        # the one allowed trace
    det = RetraceDetector.of(net._impl)
    net.run_batch(counts)                        # same shapes: replay
    net.run_batch(counts_batch(rng, 3, 5, A))    # same shapes, new data
    assert det.deltas() == {}, det.deltas()
    batch = {k: v for k, v in det.counts().items()
             if "batch" in k[1]}
    assert batch and set(batch.values()) == {1}  # exactly one trace

    # a NEW batch shape is a legitimate second trace — and only one
    counts2 = counts_batch(rng, 5, 5, A)
    net.run_batch(counts2)
    net.run_batch(counts2)
    batch2 = {k: v for k, v in compile_counts(net._impl).items()
              if "batch" in k[1]}
    assert set(batch2.values()) == {2}


@pytest.mark.parametrize("backend", BATCHED_BACKENDS)
def test_run_and_reset_do_not_retrace(backend):
    """reset()/counter churn between identical run() calls must not
    perturb the jit signature (the mesh backend once lost this to an
    uncommitted PRNG key: first run committed it, second retraced)."""
    net = small_net(backend)
    sched = [["a"], [], ["a", "b"], ["b"]]
    net.run(sched)
    with no_retrace(net._impl):
        for _ in range(3):
            net.reset()
            net.run(sched)


# --------------------------------------------------- detector mechanics
def test_detector_counts_raw_jit_functions():
    f = jax.jit(lambda x: x * 2 + 1)
    f(jnp.arange(3))
    assert list(compile_counts(f).values()) == [1]
    f(jnp.arange(3) + 5)                         # same shape: replay
    assert list(compile_counts(f).values()) == [1]
    f(jnp.arange(4))                             # new shape: new entry
    assert list(compile_counts(f).values()) == [2]


def test_no_retrace_raises_on_shape_change():
    f = jax.jit(lambda x: x.sum())
    f(jnp.ones((3,)))
    with no_retrace(f):                          # replay is fine
        f(jnp.zeros((3,)))
    with pytest.raises(RetraceError, match="retrace detected"):
        with no_retrace(f):
            f(jnp.ones((4,)))                    # retrace inside gate


def test_detector_requires_jitted_functions():
    with pytest.raises(ValueError, match="no jitted functions"):
        RetraceDetector.of(object())


def test_detector_finds_backend_jit_attrs():
    net = small_net("engine")
    names = {name for _, name in compile_counts(net._impl)}
    assert {"_jit_step", "_jit_run", "_jit_run_batch"} <= names


# ------------------------------------------------- the serving session
def test_serving_session_compiles_at_most_log2_bmax_plus_one():
    """A serving session with FLUCTUATING client concurrency stays
    within log2(B_max) + 1 lane-path traces: the server buckets every
    micro-batch to a power of two at a fixed window, so wildly varying
    burst sizes reuse at most {1, 2, 4, 8}-lane executables. Then a
    replay pass over the same shapes must not add a single trace."""
    import math

    from repro.serve import SpikeServer
    max_batch = 8
    srv = SpikeServer(max_batch=max_batch, max_wait_ms=3.0)
    net = small_net("engine")
    srv.add_model("m", deployment=net._dep, window=3, n_sessions=2)
    rng = np.random.default_rng(0)
    A = len(net.axon_keys)

    def burst(n):
        futs = [srv.submit("m", rng.integers(0, 2, (3, A))
                           .astype(np.int32), seed=i)
                for i in range(n)]
        for f in futs:
            f.result(timeout=120)

    impl = srv.models["m"].dep.impl
    with srv:
        for n in (1, 5, 3, 8, 2, 7, 4, 6):       # fluctuating load
            burst(n)
        lane = {k: v for k, v in compile_counts(impl).items()
                if "lanes" in k[1]}
        bound = int(math.log2(max_batch)) + 1
        assert lane and sum(lane.values()) <= bound, lane
        det = RetraceDetector.of(impl)
        for n in (8, 1, 6, 3):                   # warm shapes: replay
            burst(n)
        det.assert_no_retrace()
