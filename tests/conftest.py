import warnings

import pytest

warnings.filterwarnings("ignore", category=UserWarning)
warnings.filterwarnings("ignore", category=DeprecationWarning)
