"""Energy/latency model: counting, calibration arithmetic, and the Fig. 10
linear-scaling reproduction (energy/latency linear in neuron count)."""
import numpy as np

from repro.core.costmodel import (E_ACCESS_PJ, NS_PER_ACCESS, AccessCounter)
from repro.core.api import ANN_neuron, CRI_network


def test_counter_arithmetic():
    c = AccessCounter(pointer_reads=100, row_reads=900, timesteps=10)
    assert c.total_accesses == 1000
    assert abs(c.energy_uJ() - 1000 * E_ACCESS_PJ * 1e-6) < 1e-12
    assert c.latency_us() > 1000 * NS_PER_ACCESS * 1e-3


def _mlp_net(n_hidden, seed=0):
    rng = np.random.default_rng(seed)
    n_in = 64
    axons = {f"x{i}": [(f"h{j}", int(rng.integers(1, 9)))
                       for j in range(n_hidden)] for i in range(n_in)}
    neurons = {f"h{j}": ([(f"o{k}", int(rng.integers(1, 9)))
                          for k in range(10)],
                         ANN_neuron(threshold=int(n_in * 2)))
               for j in range(n_hidden)}
    for k in range(10):
        neurons[f"o{k}"] = ([], ANN_neuron(threshold=2 ** 30))
    return CRI_network(axons=axons, neurons=neurons,
                       outputs=[f"o{k}" for k in range(10)],
                       backend="engine", seed=seed), n_in


def test_fig10_energy_latency_linear_in_neurons():
    """Fig. 10: per-inference HBM energy/latency grows linearly with the
    number of neurons (R^2 ~ 0.99 in the paper)."""
    sizes = [16, 32, 64, 128, 256]
    es, ls = [], []
    rng = np.random.default_rng(1)
    for nh in sizes:
        net, n_in = _mlp_net(nh)
        net.counter.reset()
        for _ in range(5):                   # 5 'inferences', 2 steps each
            active = [f"x{i}" for i in
                      rng.choice(n_in, n_in // 4, replace=False)]
            net.reset()
            net.step(active)
            net.step([])
        es.append(net.counter.energy_uJ() / 5)
        ls.append(net.counter.latency_us() / 5)
    x = np.array(sizes, float)
    for ys in (np.array(es), np.array(ls)):
        A = np.vstack([x, np.ones_like(x)]).T
        coef, res, *_ = np.linalg.lstsq(A, ys, rcond=None)
        ss_tot = ((ys - ys.mean()) ** 2).sum()
        r2 = 1 - (res[0] / ss_tot if len(res) else 0.0)
        assert coef[0] > 0                   # cost grows with neurons
        assert r2 > 0.95, r2                 # strongly linear (paper: 0.99)


def test_event_driven_sparsity_saves_energy():
    """Fewer active axons -> fewer HBM accesses (the event-driven claim)."""
    net, n_in = _mlp_net(64)
    net.reset(); net.counter.reset()
    net.step([f"x{i}" for i in range(4)]); net.step([])
    low = net.counter.total_accesses
    net.reset(); net.counter.reset()
    net.step([f"x{i}" for i in range(n_in)]); net.step([])
    high = net.counter.total_accesses
    assert low < high
