"""CRI_network API (A.1) + simulator/engine parity — the paper's 'identical
local-simulator and accelerator results' claim."""
import random

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.api import ANN_neuron, CRI_network, LIF_neuron


def example_network(backend, seed=7):
    lif = LIF_neuron(threshold=3, nu=-32, lam=60)
    axons = {"alpha": [("a", 3), ("c", 2)], "beta": [("b", 3)]}
    neurons = {"a": ([("b", 1), ("a", 2)], lif),
               "b": ([], lif),
               "c": ([], LIF_neuron(threshold=4, nu=-32, lam=2)),
               "d": ([("c", 1)], ANN_neuron(threshold=5, nu=0))}
    return CRI_network(axons=axons, neurons=neurons, outputs=["a", "b"],
                       backend=backend, seed=seed)


def test_a1_example_runs_and_monitors_outputs():
    net = example_network("engine")
    fired = net.step(["alpha", "beta"])
    assert isinstance(fired, list)
    fired, pots = net.step(["alpha"], membranePotential=True)
    assert len(pots) == 4 and all(isinstance(v, int) for _, v in pots)


def test_simulator_engine_parity_50_steps():
    random.seed(3)
    seq = [random.sample(["alpha", "beta"], k=random.randint(0, 2))
           for _ in range(50)]
    sim = example_network("simulator")
    eng = example_network("engine")
    for inp in seq:
        assert sim.step(inp) == eng.step(inp)
    assert sim.read_membrane("a", "b", "c", "d") == \
        eng.read_membrane("a", "b", "c", "d")


def test_read_write_synapse():
    net = example_network("engine")
    w = net.read_synapse("a", "b")
    assert w == 1
    net.write_synapse("a", "b", w + 1)       # the A.1 increment example
    assert net.read_synapse("a", "b") == w + 1
    assert net.read_synapse("alpha", "c") == 2
    with pytest.raises(KeyError):
        net.read_synapse("alpha", "b")


def test_unknown_output_rejected():
    with pytest.raises(KeyError):
        CRI_network(axons={}, neurons={"a": ([], ANN_neuron(threshold=1))},
                    outputs=["zz"])


@st.composite
def random_network(draw):
    n_ax = draw(st.integers(1, 6))
    n_nr = draw(st.integers(2, 24))
    nrs = [f"n{i}" for i in range(n_nr)]
    axons = {}
    for i in range(n_ax):
        fanout = draw(st.lists(st.tuples(st.sampled_from(nrs),
                                         st.integers(-50, 50)),
                               max_size=6, unique_by=lambda t: t[0]))
        axons[f"a{i}"] = fanout
    neurons = {}
    for k in nrs:
        fanout = draw(st.lists(st.tuples(st.sampled_from(nrs),
                                         st.integers(-50, 50)),
                               max_size=5, unique_by=lambda t: t[0]))
        if draw(st.booleans()):
            model = LIF_neuron(threshold=draw(st.integers(0, 40)),
                               nu=draw(st.sampled_from([-32, -20, 0, 2])),
                               lam=draw(st.integers(0, 63)))
        else:
            model = ANN_neuron(threshold=draw(st.integers(0, 40)),
                               nu=draw(st.sampled_from([-32, 1])))
        neurons[k] = (fanout, model)
    outputs = draw(st.lists(st.sampled_from(nrs), min_size=1, max_size=4,
                            unique=True))
    return axons, neurons, outputs


@settings(max_examples=15, deadline=None)
@given(random_network(), st.integers(0, 10_000))
def test_parity_property_random_networks(netdef, seed):
    """Engine (HBM routing table) and simulator (dense matrices) are
    bit-identical on arbitrary topologies — the system invariant."""
    axons, neurons, outputs = netdef
    sim = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="simulator", seed=seed)
    eng = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=seed)
    rng = random.Random(seed)
    ax_keys = list(axons)
    for _ in range(12):
        inp = rng.sample(ax_keys, k=rng.randint(0, len(ax_keys))) \
            if ax_keys else []
        f1, p1 = sim.step(inp, membranePotential=True)
        f2, p2 = eng.step(inp, membranePotential=True)
        assert f1 == f2
        assert p1 == p2
