"""Distributed-training features: gradient-accumulation microbatching and
compression hooks wired through make_train_step."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.synthetic import TokenPipeline
from repro.distributed.context import mesh_context
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init


def _setup(arch="qwen2_5_3b", B=4, S=32):
    cfg = get_reduced(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    oc = AdamWConfig(lr=1e-3)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)),
        jnp.int32)}
    return cfg, params, oc, batch


def test_microbatching_matches_full_batch():
    """grad-accum over 2 microbatches == single full batch (same update),
    up to fp tolerance — the overlap feature must not change math."""
    with mesh_context(make_local_mesh()):
        cfg, params, oc, batch = _setup()
        opt = adamw_init(params, oc)
        p1, _, m1 = jax.jit(make_train_step(cfg, oc, microbatches=1))(
            params, opt, batch)
        p2, _, m2 = jax.jit(make_train_step(cfg, oc, microbatches=2))(
            params, opt, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, rtol=5e-3)


def test_compression_hook_trains():
    from repro.distributed.compression import ErrorFeedback
    with mesh_context(make_local_mesh()):
        cfg, params, oc, batch = _setup()
        ef = ErrorFeedback(mode="int8")
        state = {}

        def compressor(grads):
            nonlocal state
            if not state:
                state = ef.init(grads)
            out, state = ef.apply(grads, state)
            return out

        step = make_train_step(cfg, oc, compressor=compressor)
        opt = adamw_init(params, oc)
        losses = []
        pipe = TokenPipeline(cfg.vocab_size, 32, 4, seed=1)
        p = params
        for _ in range(8):
            p, opt, m = step(p, opt, jax.tree.map(jnp.asarray,
                                                  pipe.next_batch()))
            losses.append(float(m["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0] + 0.5       # not diverging
