"""Serving tier (PR 7) — micro-batched spike serving over resident
deployments.

Pins the acceptance invariants:
  * `Deployment.run_lanes` entry b is bit-identical to running it in a
    batch of ONE on every backend (state/noise isolation between
    micro-batch neighbours), two consecutive windows on a lane equal
    one uninterrupted run, and a fresh lane reproduces `run_batch`;
  * `reset(lanes=[...])` resets ONLY those lanes;
  * a served request (8 concurrent client threads, deadline+max-batch
    admission, pow2 bucketing) returns exactly what the same request
    produces run alone, serially;
  * `write_synapses` reconfiguration interleaved with in-flight
    requests lands BETWEEN batches: everything submitted before it
    sees the old weights, everything after the new ones — and engine
    == mesh on the whole interleaved history;
  * the double buffer preserves FIFO across promotions and a refused
    coalesce item stays at the head; SlotPool never double-allocates;
  * an over-wide schedule raises the structured E_SCHED_WIDTH report.
"""
import threading

import numpy as np
import pytest

from repro.analysis import AnalysisError
from repro.core.api import LIF_neuron
from repro.core.compile import compile_spec
from repro.core.deploy import deploy
from repro.core.partition import Hierarchy
from repro.core.spec import NetworkSpec
from repro.serve import (DoubleBuffer, Reconfigure, SlotPool,
                         SpikeServer, next_pow2)

BACKENDS = ("simulator", "engine", "hiaer", "mesh")


def small_compiled(backend, n_axons=5, n_neurons=12, seed=3):
    rng = np.random.default_rng(seed)
    spec = NetworkSpec()
    ax = spec.add_axons(n_axons)
    nid = spec.add_neurons(n_neurons,
                           LIF_neuron(threshold=5, nu=-32, lam=50))
    pre = np.concatenate([np.repeat(ax, 4), np.repeat(nid, 3)])
    post = rng.integers(0, n_neurons, pre.shape[0])
    w = rng.integers(-3, 7, pre.shape[0])
    spec.connect(pre, post, w)
    spec.set_outputs(list(range(4)))
    kw = {}
    if backend in ("hiaer", "mesh"):
        kw["hierarchy"] = Hierarchy(1, 1, 3, -(-n_neurons // 3))
    return compile_spec(spec, target=backend, **kw)


def windows(rng, B, T, A):
    return rng.integers(0, 2, (B, T, A)).astype(np.int32)


# ---------------------------------------------------------- lane runtime
@pytest.mark.parametrize("backend", BACKENDS)
def test_run_lanes_isolated_and_persistent(backend):
    """Batched lanes == each lane alone; two windows == one double-
    length run; per-lane reset touches only its lane."""
    c = small_compiled(backend)
    rng = np.random.default_rng(0)
    A, T, B = c.n_axons, 4, 3
    w1, w2 = windows(rng, B, T, A), windows(rng, B, T, A)

    dep = deploy(c, seed=1)
    dep.alloc_lanes(B)
    s1, V1 = dep.run_lanes(range(B), w1)
    s2, V2 = dep.run_lanes(range(B), w2)

    # each lane alone (batch of one), same construction seed
    solo = deploy(c, seed=1)
    solo.alloc_lanes(B)
    for b in range(B):
        sa, Va = solo.run_lanes([b], w1[b:b + 1])
        sb, Vb = solo.run_lanes([b], w2[b:b + 1])
        np.testing.assert_array_equal(sa[0], s1[b])
        np.testing.assert_array_equal(Va[0], V1[b])
        np.testing.assert_array_equal(sb[0], s2[b])
        np.testing.assert_array_equal(Vb[0], V2[b])

    # two consecutive T-windows == one uninterrupted 2T window
    long = deploy(c, seed=1)
    long.alloc_lanes(B)
    sl, Vl = long.run_lanes(range(B),
                            np.concatenate([w1, w2], axis=1))
    np.testing.assert_array_equal(sl[:, :T], s1)
    np.testing.assert_array_equal(sl[:, T:], s2)
    np.testing.assert_array_equal(Vl, V2)

    # reset lane 1 only: lane 1 replays its first window, lane 0 and 2
    # continue from where they were
    dep.reset(lanes=[1])
    np.testing.assert_array_equal(dep.lane_membrane(0), V2[0])
    assert np.array_equal(dep.lane_membrane(1),
                          np.zeros_like(V2[1]))
    s3, _ = dep.run_lanes([1], w1[1:2])
    np.testing.assert_array_equal(s3[0], s1[1])


@pytest.mark.parametrize("backend", BACKENDS)
def test_fresh_lanes_match_run_batch_and_scratch_is_stateless(backend):
    """Fresh lane l's first window == run_batch sample l on a fresh
    deployment (same fold_in stream); scratch (-1) entries are
    deterministic in their seed and leave no trace on lane state."""
    c = small_compiled(backend)
    rng = np.random.default_rng(4)
    B, T = 3, 4
    w = windows(rng, B, T, c.n_axons)

    dep = deploy(c, seed=2)
    dep.alloc_lanes(B)
    lanes_spk, _ = dep.run_lanes(range(B), w)
    ref = deploy(c, seed=2).run_batch(w)
    np.testing.assert_array_equal(lanes_spk, ref)

    s1, V1 = dep.run_lanes([-1], w[:1], seeds=[9])
    before = dep.lane_membrane(0).copy()
    s2, V2 = dep.run_lanes([-1, -1], w[:2], seeds=[9, 7])
    np.testing.assert_array_equal(s2[0], s1[0])     # seed-deterministic
    np.testing.assert_array_equal(V2[0], V1[0])     # in ANY batch
    np.testing.assert_array_equal(dep.lane_membrane(0), before)


def test_run_lanes_rejects_bad_ids_and_duplicates():
    dep = deploy(small_compiled("engine"), seed=0)
    dep.alloc_lanes(2)
    w = windows(np.random.default_rng(0), 2, 3, dep.compiled.n_axons)
    with pytest.raises(ValueError, match="appear twice"):
        dep.run_lanes([1, 1], w)
    with pytest.raises(IndexError, match="allocated lanes"):
        dep.run_lanes([0, 5], w)
    with pytest.raises(ValueError, match="lane ids"):
        dep.run_lanes([0], w)


def test_pad_overwide_schedule_is_structured_error():
    dep = deploy(small_compiled("engine"), seed=0)
    wide = np.zeros((3, dep.n_axon_slots + 4), np.int32)
    with pytest.raises(AnalysisError) as ei:
        dep._pad(wide)
    assert "E_SCHED_WIDTH" in str(ei.value)
    assert ei.value.report.findings[0].code == "E_SCHED_WIDTH"


# ------------------------------------------------------ queue primitives
def test_double_buffer_fifo_and_coalesce_barrier():
    buf = DoubleBuffer()
    for i in range(5):
        buf.put(i)
    assert buf.take(3) == [0, 1, 2]            # max-batch cut, FIFO
    buf.put(5)
    # refuse the 5-join: 3,4 dispatch, 5 stays at the head for the
    # next take (barrier semantics without reordering)
    assert buf.take(8, coalesce=lambda b, n: n != 5) == [3, 4]
    assert buf.take(8) == [5]
    assert buf.take(8, idle_wait_s=0.01) == []
    st = buf.stats()
    assert st["pending"] == 0 and st["swaps"] >= 2
    buf.close()
    with pytest.raises(RuntimeError, match="closed"):
        buf.put(99)


def test_double_buffer_deadline_admits_late_items():
    buf = DoubleBuffer()
    buf.put("a")
    t = threading.Timer(0.02, lambda: buf.put("b"))
    t.start()
    try:
        assert buf.take(4, max_wait_s=0.5) == ["a", "b"]
    finally:
        t.cancel()


def test_slot_pool_allocates_each_slot_once():
    pool = SlotPool(3)
    got = {pool.acquire() for _ in range(3)}
    assert got == {0, 1, 2} and pool.acquire() is None
    assert pool.n_active == 3 and pool.mask.all()
    pool.release(1)
    assert pool.acquire() == 1
    with pytest.raises(ValueError, match="not held"):
        pool.release(2) or pool.release(2)
    with pytest.raises(IndexError):
        pool.release(7)


def test_next_pow2():
    assert [next_pow2(i) for i in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


# --------------------------------------------------------- the server
def test_served_results_bit_exact_vs_serial_under_concurrency():
    """8 client threads (stateless + sessions) against one server; every
    response equals the same request run alone, serially."""
    c = small_compiled("engine")
    rng = np.random.default_rng(7)
    T, n_req = 4, 3
    srv = SpikeServer(max_batch=8, max_wait_ms=4.0)
    srv.add_model("m", c, window=T, n_sessions=4, seed=0)
    reqs = {(cl, r): windows(rng, 1, T, c.n_axons)[0]
            for cl in range(8) for r in range(n_req)}
    results = {}

    def client(cl):
        sid = srv.open_session("m") if cl < 4 else None
        for r in range(n_req):
            results[(cl, r)] = srv.submit(
                "m", reqs[(cl, r)], session=sid,
                seed=cl * 100 + r).result(timeout=120)
        if sid is not None:
            results[("lane", cl)] = sid
            srv.close_session("m", sid)

    with srv:
        ts = [threading.Thread(target=client, args=(cl,))
              for cl in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert srv.stats()["requests"] == 8 * n_req

    # serial reference: sessions replay on their actual lane, stateless
    # requests replay as scratch entries with their seed
    ref = deploy(c, seed=0)
    ref.alloc_lanes(4)
    for cl in range(8):
        lane = results.get(("lane", cl), -1)
        for r in range(n_req):
            seeds = [cl * 100 + r] if lane < 0 else None
            spk, V = ref.run_lanes([lane], reqs[(cl, r)][None],
                                   seeds=seeds)
            got = results[(cl, r)]
            np.testing.assert_array_equal(got.spikes, spk[0])
            np.testing.assert_array_equal(got.membrane, V[0])
            assert got.batch_size >= 1 and got.model == "m"


def _reconfigure_history(backend):
    """Serve 4 requests, reconfigure, serve 4 more (all in flight
    together); assert the history equals serial execution and return
    the served (spikes, membrane) pairs."""
    c = small_compiled(backend)
    rng = np.random.default_rng(11)
    T = 4
    pre, post = [-1], [int(c.syn_post[0])]
    w_old = int(c.syn_weight[0])
    reqs = windows(rng, 8, T, c.n_axons)

    srv = SpikeServer(max_batch=4, max_wait_ms=3.0)
    srv.add_model("m", c, window=T, n_sessions=0, seed=0)
    with srv:
        before = [srv.submit("m", reqs[i], seed=i) for i in range(4)]
        fut_rc = srv.reconfigure("m", pre, post, [w_old + 2])
        after = [srv.submit("m", reqs[i], seed=i) for i in range(4, 8)]
        got = [f.result(timeout=120) for f in before + after]
        assert fut_rc.result(timeout=120) >= 1      # applied, counted

    # serial reference on a FRESH compile (the served artifact's weight
    # tables were mutated in place by the reconfiguration)
    ref = deploy(small_compiled(backend), seed=0)
    exp = []
    for i in range(8):
        if i == 4:
            ref.write_synapses(pre, post, [w_old + 2])
        spk, V = ref.run_lanes([-1], reqs[i][None], seeds=[i])
        exp.append((spk[0], V[0]))
    for g, (espk, eV) in zip(got, exp):
        np.testing.assert_array_equal(g.spikes, espk)
        np.testing.assert_array_equal(g.membrane, eV)
    return [(g.spikes, g.membrane) for g in got]


def test_reconfigure_while_serving_engine_matches_mesh():
    """Interleaved reconfiguration is serial-equivalent on both
    backends, and the two backends agree bit for bit."""
    eng = _reconfigure_history("engine")
    mesh = _reconfigure_history("mesh")
    for (se, ve), (sm, vm) in zip(eng, mesh):
        np.testing.assert_array_equal(se, sm)
        np.testing.assert_array_equal(ve, vm)


def test_session_lifecycle_and_window_contract():
    c = small_compiled("simulator")
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0)
    srv.add_model("m", c, window=4, n_sessions=2, seed=0)
    rng = np.random.default_rng(2)
    w = windows(rng, 1, 4, c.n_axons)[0]
    with srv:
        sid = srv.open_session("m")
        srv.submit("m", w, session=sid).result(timeout=60)
        V = srv.session_membrane("m", sid)
        srv.reset_session("m", sid)                  # back to V = 0
        assert not srv.session_membrane("m", sid).any()
        r2 = srv.submit("m", w, session=sid).result(timeout=60)
        np.testing.assert_array_equal(r2.membrane, V)   # same stream
        with pytest.raises(ValueError, match="fill the 4-step"):
            srv.submit("m", w[:2], session=sid)
        with pytest.raises(ValueError, match="split it across"):
            srv.submit("m", np.zeros((9, c.n_axons), np.int32))
        # short STATELESS requests are padded and sliced
        short = srv.submit("m", w[:2]).result(timeout=60)
        assert short.spikes.shape[0] == 2
        srv.close_session("m", sid)
        with pytest.raises(KeyError, match="unknown session"):
            srv.submit("m", w, session=sid)
        srv.open_session("m"), srv.open_session("m")
        with pytest.raises(RuntimeError, match="no free session"):
            srv.open_session("m")
        with pytest.raises(KeyError, match="no resident model"):
            srv.submit("nope", w)


def test_server_batches_only_within_model():
    """Two resident models: batches never mix them, and both serve."""
    ce = small_compiled("engine")
    cs = small_compiled("simulator", n_axons=3, n_neurons=6)
    srv = SpikeServer(max_batch=8, max_wait_ms=3.0)
    srv.add_model("e", ce, window=3, n_sessions=0)
    srv.add_model("s", cs, window=3, n_sessions=0)
    rng = np.random.default_rng(5)
    with srv:
        fe = [srv.submit("e", windows(rng, 1, 3, ce.n_axons)[0],
                         seed=i) for i in range(3)]
        fs = [srv.submit("s", windows(rng, 1, 3, cs.n_axons)[0],
                         seed=i) for i in range(3)]
        re_, rs = [f.result(timeout=60) for f in fe], \
            [f.result(timeout=60) for f in fs]
    assert all(r.spikes.shape[1] == ce.n_neurons for r in re_)
    assert all(r.spikes.shape[1] == cs.n_neurons for r in rs)
    shapes = srv.stats()["models"]
    assert shapes["e"]["requests"] == 3 and shapes["s"]["requests"] == 3
