"""Web-portal front end (PR 8) — HTTP/websocket transport over the
serving tier.

Pins the acceptance invariants:
  * 8 concurrent HTTP clients receive responses BIT-IDENTICAL to
    direct `SpikeServer.submit` (engine in-process; all four backends
    in the forced-devices child below);
  * a websocket streaming session equals the in-process session lane
    window for window (including pipelined windows);
  * auth/quota/backpressure negative paths return structured 401/429/
    503 JSON with Retry-After where promised;
  * an `AnalysisError` crossing the portal renders to a 400 whose
    `message` is exactly `report.render()` (E_SCHED_WIDTH worked
    example);
  * serving through the portal compiles NOTHING the in-process path
    had not already compiled (zero extra retraces);
  * satellites: `next_pow2` rejects n <= 0, `submit(timeout=)`
    resolves with a structured `DeadlineError`, `shutdown(drain=)`
    resolves-or-cancels every pending future, `DoubleBuffer(capacity)`
    sheds with `BufferFull`.
"""
import http.client
import json
import struct
import subprocess
import sys
import threading
import time
from concurrent.futures import CancelledError
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import AnalysisError
from repro.analysis.retrace import compile_counts
from repro.core.api import LIF_neuron
from repro.core.compile import compile_spec
from repro.core.deploy import deploy
from repro.core.partition import Hierarchy
from repro.core.spec import NetworkSpec
from repro.portal import Portal, PortalError, TokenQuota, WSClient
from repro.portal.gateway import result_digest
from repro.serve import (BufferClosed, BufferFull, DeadlineError,
                         DoubleBuffer, SpikeServer, next_pow2)

ROOT = Path(__file__).resolve().parents[1]
BACKENDS = ("simulator", "engine", "hiaer", "mesh")


def small_compiled(backend, n_axons=5, n_neurons=12, seed=3):
    rng = np.random.default_rng(seed)
    spec = NetworkSpec()
    ax = spec.add_axons(n_axons)
    nid = spec.add_neurons(n_neurons,
                           LIF_neuron(threshold=5, nu=-32, lam=50))
    pre = np.concatenate([np.repeat(ax, 4), np.repeat(nid, 3)])
    post = rng.integers(0, n_neurons, pre.shape[0])
    w = rng.integers(-3, 7, pre.shape[0])
    spec.connect(pre, post, w)
    spec.set_outputs(list(range(4)))
    kw = {}
    if backend in ("hiaer", "mesh"):
        kw["hierarchy"] = Hierarchy(1, 1, 3, -(-n_neurons // 3))
    return compile_spec(spec, target=backend, **kw)


def http_req(port, method, path, body=None, token=None, timeout=120):
    conn = http.client.HTTPConnection("127.0.0.1", port,
                                      timeout=timeout)
    headers = {"Content-Type": "application/json"}
    if token is not None:
        headers["Authorization"] = f"Bearer {token}"
    conn.request(method, path,
                 json.dumps(body) if body is not None else None,
                 headers)
    resp = conn.getresponse()
    out = (resp.status, {k.lower(): v for k, v in resp.getheaders()},
           json.loads(resp.read().decode("utf-8")))
    conn.close()
    return out


def windows(rng, B, T, A):
    return rng.integers(0, 2, (B, T, A)).astype(np.int32)


# ------------------------------------------------- shared engine portal
@pytest.fixture(scope="module")
def engine_portal():
    """One resident engine model served in-process, shared by the HTTP
    tests (module-scoped: the compile cost is paid once)."""
    c = small_compiled("engine")
    srv = SpikeServer(max_batch=8, max_wait_ms=3.0)
    srv.add_model("m", c, window=4, n_sessions=4, seed=0)
    with srv, Portal(srv, port=0) as portal:
        yield srv, portal, c


# ---------------------------------------------------------- satellites
def test_next_pow2_rejects_nonpositive():
    assert [next_pow2(i) for i in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]
    for bad in (0, -1, -8):
        with pytest.raises(ValueError, match="positive batch size"):
            next_pow2(bad)


def test_double_buffer_capacity_sheds_with_bufferfull():
    buf = DoubleBuffer(capacity=2)
    buf.put("a")
    buf.put("b")
    with pytest.raises(BufferFull) as ei:
        buf.put("c")
    assert ei.value.pending == 2 and ei.value.capacity == 2
    assert buf.take(8) == ["a", "b"]        # drained -> room again
    buf.put("c")
    st = buf.stats()
    assert st["rejected"] == 1 and st["capacity"] == 2


def test_submit_timeout_resolves_structured_deadline_error():
    c = small_compiled("engine")
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0)
    srv.add_model("m", c, window=3, n_sessions=0, seed=0)
    w = windows(np.random.default_rng(0), 1, 3, c.n_axons)[0]
    # enqueue while the dispatcher is NOT running, so the deadline
    # deterministically expires before any batch can admit it
    fut = srv.submit("m", w, timeout=0.01)
    ok = srv.submit("m", w)                  # no timeout -> served
    time.sleep(0.05)
    with srv:
        with pytest.raises(DeadlineError) as ei:
            fut.result(timeout=60)
        assert ok.result(timeout=60).spikes.shape == (3, c.n_neurons)
    e = ei.value
    assert e.model == "m" and e.waited_s >= e.timeout_s
    assert "expired after waiting" in str(e)


def test_shutdown_drains_or_cancels_every_pending_future():
    c = small_compiled("engine")
    w = windows(np.random.default_rng(1), 1, 3, c.n_axons)[0]

    # drain=True: queued work is served before the dispatcher stops
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0)
    srv.add_model("m", c, window=3, n_sessions=0, seed=0)
    srv.start()
    futs = [srv.submit("m", w, seed=i) for i in range(5)]
    srv.shutdown(drain=True)
    for f in futs:
        assert f.result(timeout=1).spikes.shape == (3, c.n_neurons)

    # drain=False (dispatcher never started): everything is cancelled,
    # nobody hangs
    srv2 = SpikeServer(max_batch=4, max_wait_ms=1.0)
    srv2.add_model("m", c, window=3, n_sessions=0, seed=0)
    futs = [srv2.submit("m", w, seed=i) for i in range(3)]
    srv2.shutdown(drain=False)
    for f in futs:
        assert f.done()
        with pytest.raises(CancelledError):
            f.result(timeout=1)
    with pytest.raises(BufferClosed):
        srv2.submit("m", w)
    srv2.shutdown()                           # idempotent


def test_cancelled_future_never_kills_dispatcher_or_peers():
    """A client cancelling its Future (the portal does on timeout /
    disconnect) must not raise InvalidStateError inside the dispatch
    loop: the cancelled request's batch peers still get results, an
    expired-and-cancelled request is dropped silently, and the
    dispatcher thread survives to serve later submissions."""
    c = small_compiled("engine")
    srv = SpikeServer(max_batch=8, max_wait_ms=1.0)
    srv.add_model("m", c, window=3, n_sessions=0, seed=0)
    w = windows(np.random.default_rng(2), 1, 3, c.n_axons)[0]
    # queue a batch while the dispatcher is down, then cancel members:
    # one rides _run_batch cancelled, one hits _expire cancelled
    gone = srv.submit("m", w, seed=1)
    gone_expired = srv.submit("m", w, seed=2, timeout=0.005)
    ok = srv.submit("m", w, seed=3)
    assert gone.cancel() and gone_expired.cancel()
    time.sleep(0.02)                          # let the deadline lapse
    with srv:
        res = ok.result(timeout=60)
        assert res.spikes.shape == (3, c.n_neurons)
        # the dispatcher thread is still alive and serving
        again = srv.submit("m", w, seed=4).result(timeout=60)
        assert again.spikes.shape == (3, c.n_neurons)


# ------------------------------------------------------- HTTP transport
def test_http_eight_concurrent_clients_bit_identical(engine_portal):
    """8 concurrent HTTP clients x 3 requests == direct submit, bit
    for bit (digest AND full arrays)."""
    srv, portal, c = engine_portal
    rng = np.random.default_rng(7)
    n_req = 3
    reqs = {(cl, r): windows(rng, 1, 4, c.n_axons)[0]
            for cl in range(8) for r in range(n_req)}
    results = {}

    def client(cl):
        for r in range(n_req):
            status, _, body = http_req(
                portal.port, "POST", "/v1/m/run",
                {"counts": reqs[(cl, r)].tolist(),
                 "seed": cl * 100 + r})
            results[(cl, r)] = (status, body)

    ts = [threading.Thread(target=client, args=(cl,))
          for cl in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()

    for (cl, r), w in reqs.items():
        status, body = results[(cl, r)]
        assert status == 200, body
        ref = srv.submit("m", w, seed=cl * 100 + r).result(timeout=120)
        assert body["digest"] == result_digest(ref.spikes,
                                               ref.membrane)
        np.testing.assert_array_equal(
            np.asarray(body["spikes"], bool), ref.spikes)
        np.testing.assert_array_equal(
            np.asarray(body["membrane"], np.int32), ref.membrane)
        assert body["batch_size"] >= 1 and body["model"] == "m"


def test_http_session_lifecycle(engine_portal):
    srv, portal, c = engine_portal
    w = windows(np.random.default_rng(3), 1, 4, c.n_axons)[0]
    _, _, opened = http_req(portal.port, "POST", "/v1/m/session")
    sid = opened["session"]
    assert opened["window"] == 4
    s, _, r1 = http_req(portal.port, "POST", "/v1/m/run",
                        {"counts": w.tolist(), "session": sid})
    assert s == 200 and r1["session"] == sid
    s, _, info = http_req(portal.port, "GET", f"/v1/m/session/{sid}")
    assert s == 200 and info["steps"] == 4
    np.testing.assert_array_equal(np.asarray(info["membrane"]),
                                  np.asarray(r1["membrane"]))
    s, _, _ = http_req(portal.port, "POST",
                       f"/v1/m/session/{sid}/reset")
    assert s == 200
    s, _, info = http_req(portal.port, "GET", f"/v1/m/session/{sid}")
    assert not np.asarray(info["membrane"]).any()
    s, _, r2 = http_req(portal.port, "POST", "/v1/m/run",
                        {"counts": w.tolist(), "session": sid})
    # reset -> same construction stream -> same window result
    assert r2["digest"] == r1["digest"]
    s, _, closed = http_req(portal.port, "DELETE",
                            f"/v1/m/session/{sid}")
    assert s == 200 and closed["closed"] == sid
    s, _, body = http_req(portal.port, "GET", f"/v1/m/session/{sid}")
    assert s == 404 and body["error"]["code"] == "E_NO_SESSION"


def test_http_reconfigure_barrier(engine_portal):
    srv, portal, c = engine_portal
    pre, post = -1, int(c.syn_post[0])
    w_old = int(srv.models["m"].dep.read_synapses([pre], [post])[0])
    s, _, body = http_req(portal.port, "POST", "/v1/m/reconfigure",
                          {"pre": [pre], "post": [post],
                           "weight": [w_old + 1]})
    assert s == 200 and body["uploads"] >= 1
    got = int(srv.models["m"].dep.read_synapses([pre], [post])[0])
    assert got == w_old + 1
    # put it back so later module tests see the original weights
    http_req(portal.port, "POST", "/v1/m/reconfigure",
             {"pre": [pre], "post": [post], "weight": [w_old]})


def test_analysis_error_renders_400_with_exact_report(engine_portal):
    """The portal's 400 body carries the analyzer's own code and a
    message that is EXACTLY `report.render()` (== str(AnalysisError))."""
    srv, portal, c = engine_portal
    wide = np.zeros((4, c.n_axons + 7), int)
    with pytest.raises(AnalysisError) as ei:
        srv.submit("m", wide)
    status, _, body = http_req(portal.port, "POST", "/v1/m/run",
                               {"counts": wide.tolist()})
    assert status == 400
    assert body["error"]["code"] == "E_SCHED_WIDTH"
    assert body["error"]["message"] == str(ei.value)
    f = body["error"]["findings"]["findings"][0]
    assert f["code"] == "E_SCHED_WIDTH" and f["severity"] == "error"


def test_http_negative_routes(engine_portal):
    srv, portal, c = engine_portal
    s, _, body = http_req(portal.port, "GET", "/nope")
    assert s == 404 and body["error"]["code"] == "E_NO_ROUTE"
    s, _, body = http_req(portal.port, "POST", "/v1/ghost/run",
                          {"events": [[0]]})
    assert s == 404 and body["error"]["code"] == "E_NO_MODEL"
    s, _, body = http_req(portal.port, "GET", "/v1/m/run")
    assert s == 405 and body["error"]["code"] == "E_METHOD"
    conn = http.client.HTTPConnection("127.0.0.1", portal.port,
                                      timeout=60)
    conn.request("POST", "/v1/m/run", b"{not json",
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    body = json.loads(resp.read().decode())
    conn.close()
    assert resp.status == 400 and body["error"]["code"] == "E_BAD_JSON"
    s, _, body = http_req(portal.port, "POST", "/v1/m/run",
                          {"counts": [[0] * c.n_axons],
                           "events": [[0]]})
    assert s == 400 and "exactly one" in body["error"]["message"]


def test_metrics_exposes_server_stats_and_clients(engine_portal):
    srv, portal, c = engine_portal
    # bare /metrics is Prometheus text now; JSON moved to ?format=json
    s, _, body = http_req(portal.port, "GET", "/metrics?format=json")
    assert s == 200
    assert body["server"]["models"]["m"]["requests"] >= 1
    assert {"p50_ms", "p99_ms", "buffer"} <= set(body["server"])
    assert body["clients"] == {}            # open portal: no tokens


# -------------------------------------------------- websocket transport
def test_ws_streaming_session_equals_inprocess_lane(engine_portal):
    """A websocket stream == the in-process session lane, window for
    window — including pipelined windows (sent before reading)."""
    srv, portal, c = engine_portal
    rng = np.random.default_rng(9)
    wins = windows(rng, 4, 4, c.n_axons)

    ws = WSClient("127.0.0.1", portal.port, "m")
    lane = ws.session
    for w in wins:                           # pipelined: no recv yet
        ws.send_window(counts=w)
    got = [ws.recv() for _ in range(len(wins))]
    ws.close()
    assert [g["window"] for g in got] == [0, 1, 2, 3]

    # in-process reference: same lane id on a fresh deployment of the
    # same artifact + seed (lane streams are construction-derived)
    ref = deploy(c, seed=0)
    ref.alloc_lanes(4)
    for w, g in zip(wins, got):
        spk, V = ref.run_lanes([lane], w[None])
        assert g["digest"] == result_digest(spk[0], V[0])
        np.testing.assert_array_equal(np.asarray(g["spikes"], bool),
                                      spk[0])
    # the lane is released on close: all 4 session slots free again
    assert srv.models["m"].sessions.n_open == 0


def test_ws_lane_exhaustion_is_http_503(engine_portal):
    srv, portal, c = engine_portal
    clients = [WSClient("127.0.0.1", portal.port, "m")
               for _ in range(4)]
    try:
        with pytest.raises(PortalError) as ei:
            WSClient("127.0.0.1", portal.port, "m")
        assert ei.value.status == 503
        assert ei.value.code == "E_NO_LANES"
    finally:
        for ws in clients:
            ws.close()


def _wait_lanes_free(srv, model, deadline_s=30.0):
    t0 = time.monotonic()
    while srv.models[model].sessions.n_open != 0:
        if time.monotonic() - t0 > deadline_s:
            raise AssertionError(
                f"{srv.models[model].sessions.n_open} lane(s) still "
                "open — leaked by a dead connection")
        time.sleep(0.02)


def test_ws_abrupt_disconnect_releases_lane(engine_portal):
    """A client vanishing mid-frame (routine for network servers) must
    not strand the stream handler: the producer's sentinel still fires,
    handle_stream returns, and the resident lane is released — repeated
    abrupt disconnects must not exhaust the SlotPool."""
    srv, portal, c = engine_portal
    for _ in range(6):                        # > the 4 session slots
        ws = WSClient("127.0.0.1", portal.port, "m")
        ws.sock.sendall(b"\x81")              # half a frame header...
        ws.sock.close()                       # ...then vanish
    _wait_lanes_free(srv, "m")
    # every slot is usable again: open the full complement at once
    clients = [WSClient("127.0.0.1", portal.port, "m")
               for _ in range(4)]
    for ws in clients:
        ws.close()
    _wait_lanes_free(srv, "m")


def test_ws_oversized_frame_rejected_with_close_1009(engine_portal):
    """A frame header claiming more than MAX_FRAME_BYTES is refused
    BEFORE any payload is buffered: the server answers a close frame
    with status 1009 (Message Too Big) and releases the lane."""
    from repro.portal.ws import MAX_FRAME_BYTES, OP_CLOSE

    srv, portal, c = engine_portal
    ws = WSClient("127.0.0.1", portal.port, "m")
    claim = 2 * MAX_FRAME_BYTES
    ws.sock.sendall(bytes([0x81, 0x80 | 127])
                    + struct.pack(">Q", claim))
    while True:                               # pongs etc. skipped
        opcode, payload = ws._read_frame()
        if opcode == OP_CLOSE:
            break
    assert struct.unpack(">H", payload[:2])[0] == 1009
    ws.sock.close()
    _wait_lanes_free(srv, "m")


# ------------------------------------------------ auth + quotas + 503s
def test_auth_and_quota_negative_paths():
    c = small_compiled("engine")
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0)
    srv.add_model("m", c, window=3, n_sessions=2, seed=0)
    tokens = {"good": TokenQuota(rate=1000.0, burst=1000,
                                 max_inflight=8, name="alice"),
              "slow": TokenQuota(rate=0.001, burst=1, max_inflight=8,
                                 name="bob"),
              "narrow": TokenQuota(rate=1000.0, burst=1000,
                                   max_inflight=0, name="carol")}
    w = windows(np.random.default_rng(0), 1, 3, c.n_axons)[0]
    body_run = {"counts": w.tolist()}
    with srv, Portal(srv, port=0, tokens=tokens) as portal:
        # 401: missing, malformed, unknown
        s, _, b = http_req(portal.port, "POST", "/v1/m/run", body_run)
        assert s == 401 and b["error"]["code"] == "E_AUTH"
        s, _, b = http_req(portal.port, "POST", "/v1/m/run", body_run,
                           token="wrong")
        assert s == 401 and b["error"]["code"] == "E_AUTH"
        # healthz stays open (load balancers don't hold tokens)
        s, _, b = http_req(portal.port, "GET", "/healthz")
        assert s == 200 and b["ok"]

        # authorized traffic flows
        s, _, b = http_req(portal.port, "POST", "/v1/m/run", body_run,
                           token="good")
        assert s == 200

        # 429 rate: burst of 1 at 0.001 req/s -> second request sheds
        s, _, _ = http_req(portal.port, "POST", "/v1/m/run", body_run,
                           token="slow")
        assert s == 200
        s, h, b = http_req(portal.port, "POST", "/v1/m/run", body_run,
                           token="slow")
        assert s == 429 and b["error"]["code"] == "E_QUOTA_RATE"
        assert int(h["retry-after"]) >= 1
        assert b["error"]["retry_after_s"] > 0

        # 429 in-flight: zero concurrency allowed
        s, h, b = http_req(portal.port, "POST", "/v1/m/run", body_run,
                           token="narrow")
        assert s == 429 and b["error"]["code"] == "E_QUOTA_INFLIGHT"

        # per-token counters in /metrics, keyed by label not secret
        s, _, m = http_req(portal.port, "GET", "/metrics?format=json")
        assert m["clients"]["bob"]["rejected_rate"] == 1
        assert m["clients"]["carol"]["rejected_inflight"] == 1
        assert m["clients"]["alice"]["admitted"] == 1
        assert "good" not in m["clients"]


def test_backpressure_full_buffer_is_503_with_retry_after():
    c = small_compiled("engine")
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0, max_pending=0)
    srv.add_model("m", c, window=3, n_sessions=0, seed=0)
    w = windows(np.random.default_rng(0), 1, 3, c.n_axons)[0]
    with pytest.raises(BufferFull):
        srv.submit("m", w)
    with Portal(srv, port=0) as portal:
        s, h, b = http_req(portal.port, "POST", "/v1/m/run",
                           {"counts": w.tolist()})
        assert s == 503 and b["error"]["code"] == "E_BACKPRESSURE"
        assert int(h["retry-after"]) >= 1
        assert b["error"]["retry_after_s"] > 0
        # shutdown -> structured 503 E_SHUTDOWN with Retry-After,
        # not a hang
        srv.shutdown()
        s, h, b = http_req(portal.port, "POST", "/v1/m/run",
                           {"counts": w.tolist()})
        assert s == 503 and b["error"]["code"] == "E_SHUTDOWN"
        assert int(h["retry-after"]) >= 1
        assert b["error"]["retry_after_s"] > 0


# ----------------------------------------------------- retrace parity
def test_portal_adds_zero_compiles():
    """Serving the same window shapes through the portal compiles
    NOTHING beyond what in-process serving already traced."""
    c = small_compiled("engine")
    srv = SpikeServer(max_batch=8, max_wait_ms=3.0)
    m = srv.add_model("m", c, window=4, n_sessions=0, seed=0)
    rng = np.random.default_rng(5)
    zero = np.zeros((4, c.n_axons), np.int32)
    # warm every pow2 bucket via direct lane dispatches
    for B in (1, 2, 4, 8):
        m.dep.run_lanes([-1] * B, np.stack([zero] * B))
    before = compile_counts(m.dep.impl)
    with srv, Portal(srv, port=0) as portal:
        def client(cl):
            for r in range(2):
                s, _, b = http_req(
                    portal.port, "POST", "/v1/m/run",
                    {"counts": windows(rng, 1, 4,
                                       c.n_axons)[0].tolist(),
                     "seed": cl * 10 + r})
                assert s == 200, b
        ts = [threading.Thread(target=client, args=(cl,))
              for cl in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    after = compile_counts(m.dep.impl)
    assert after == before, (before, after)


# ------------------------------------------------------ bridge workers
def test_bridge_worker_roundtrip(engine_portal):
    """One spawned jax-free front-end worker over the unix-socket
    bridge: same results as in-process, errors cross intact."""
    srv, portal_inproc, c = engine_portal
    rng = np.random.default_rng(13)
    w = windows(rng, 1, 4, c.n_axons)[0]
    with Portal(srv, port=0, workers=1) as portal:
        s, _, health = http_req(portal.port, "GET", "/healthz")
        assert s == 200
        # the answering process is the worker, not the dispatcher
        assert health["worker_pid"] != health["pid"]
        s, _, body = http_req(portal.port, "POST", "/v1/m/run",
                              {"counts": w.tolist(), "seed": 77})
        assert s == 200
        ref = srv.submit("m", w, seed=77).result(timeout=120)
        assert body["digest"] == result_digest(ref.spikes,
                                               ref.membrane)
        # a structured error crosses the bridge intact
        s, _, body = http_req(
            portal.port, "POST", "/v1/m/run",
            {"counts": np.zeros((4, c.n_axons + 3), int).tolist()})
        assert s == 400 and body["error"]["code"] == "E_SCHED_WIDTH"
        # websocket through the worker
        ws = WSClient("127.0.0.1", portal.port, "m")
        ws.send_window(counts=w)
        got = ws.recv()
        ws.close()
        ref_lane = deploy(c, seed=0)
        ref_lane.alloc_lanes(4)
        spk, V = ref_lane.run_lanes([ws.session], w[None])
        assert got["digest"] == result_digest(spk[0], V[0])


# ------------------------------------------------ fault tolerance (PR 10)
def test_healthz_503_down_when_dispatcher_dead():
    """An UNSUPERVISED dispatcher death flips /healthz to a 503 whose
    body says status=down — the tri-state health satellite."""
    from repro import faults

    c = small_compiled("engine")
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0, supervise=False)
    srv.add_model("m", c, window=3, n_sessions=0, seed=0)
    w = windows(np.random.default_rng(0), 1, 3, c.n_axons)[0]
    faults.install(faults.FaultPlan().arm("dispatch_crash", at=(1,)))
    try:
        with srv, Portal(srv, port=0) as portal:
            s, _, hz = http_req(portal.port, "GET", "/healthz")
            assert s == 200 and hz["status"] == "ok"
            s, _, b = http_req(portal.port, "POST", "/v1/m/run",
                               {"counts": w.tolist()})
            assert s == 500
            assert "injected fault" in b["error"]["message"]
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:
                s, _, hz = http_req(portal.port, "GET", "/healthz")
                if s == 503:
                    break
                time.sleep(0.05)
            assert s == 503, hz
            assert hz["status"] == "down" and hz["ok"] is False
            assert "unsupervised" in hz["reason"]
    finally:
        faults.uninstall()


def test_dispatch_restart_503_with_retry_after_then_recovers():
    """A SUPERVISED dispatcher crash surfaces as one structured 503
    E_DISPATCH_RESTART (with Retry-After), the retried request returns
    the bit-exact fault-free answer, and healthz never leaves 200."""
    from repro import faults

    c = small_compiled("engine")
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0)
    srv.add_model("m", c, window=3, n_sessions=0, seed=0)
    w = windows(np.random.default_rng(2), 1, 3, c.n_axons)[0]
    faults.install(faults.FaultPlan().arm("dispatch_crash", at=(1,)))
    try:
        with srv, Portal(srv, port=0) as portal:
            s, h, b = http_req(portal.port, "POST", "/v1/m/run",
                               {"counts": w.tolist(), "seed": 5})
            assert s == 503, b
            assert b["error"]["code"] == "E_DISPATCH_RESTART"
            assert int(h["retry-after"]) >= 1
            assert b["error"]["retry_after_s"] > 0
            for _ in range(60):               # supervised recovery
                s, _, b = http_req(portal.port, "POST", "/v1/m/run",
                                   {"counts": w.tolist(), "seed": 5})
                if s == 200:
                    break
                time.sleep(0.05)
            assert s == 200, b
            ref = deploy(c, seed=0)
            ref.alloc_lanes(1)
            spk, V = ref.run_lanes([-1], w[None], seeds=[5])
            assert b["digest"] == result_digest(spk[0], V[0])
            s, _, hz = http_req(portal.port, "GET", "/healthz")
            assert s == 200 and hz["status"] in ("ok", "degraded")
            assert hz["restarts"] == 1
    finally:
        faults.uninstall()


def test_bridge_client_auto_reconnect(tmp_path):
    """Severed UDS: the in-flight non-idempotent `run` fails with the
    structured 503 E_BRIDGE_DOWN, an idempotent call across the drop is
    parked + replayed on the redial, and post-reconnect runs are
    bit-exact."""
    import asyncio

    from repro.portal.bridge import BridgeClient, BridgeServer
    from repro.portal.gateway import LocalGateway

    c = small_compiled("engine")
    srv = SpikeServer(max_batch=4, max_wait_ms=100.0)
    srv.add_model("m", c, window=3, n_sessions=0, seed=0)
    w = windows(np.random.default_rng(4), 1, 3, c.n_axons)[0]
    uds = str(Path(tmp_path) / "bridge.sock")

    async def scenario():
        bs = await BridgeServer(LocalGateway(srv), uds).start()
        cl = await BridgeClient.open(uds, backoff_base_s=0.01)
        try:
            hz = await cl.healthz()
            assert hz["ok"]
            # non-idempotent op in flight at drop time (the 100 ms
            # batch deadline holds it) -> structured 503, NOT a replay
            run_t = asyncio.ensure_future(
                cl.run("m", {"counts": w.tolist(), "seed": 1}))
            await asyncio.sleep(0.03)
            cl._writer.transport.abort()      # sever the UDS
            with pytest.raises(PortalError) as ei:
                await run_t
            assert ei.value.status == 503
            assert ei.value.code == "E_BRIDGE_DOWN"
            # idempotent op across the drop: parked + replayed
            hz = await cl.healthz()
            assert hz["ok"]
            assert cl.drops >= 1 and cl.reconnects >= 1
            # non-idempotent traffic works again, bit-exact
            out = await cl.run("m", {"counts": w.tolist(), "seed": 2})
            ref = deploy(c, seed=0)
            ref.alloc_lanes(1)
            spk, V = ref.run_lanes([-1], w[None], seeds=[2])
            assert out["digest"] == result_digest(spk[0], V[0])
        finally:
            await cl.close()
            await bs.stop()

    with srv:
        asyncio.run(scenario())


def test_portal_respawns_killed_worker():
    """SIGKILL one of two bridge front ends: the parent reaper respawns
    it (SO_REUSEPORT keeps the port), traffic keeps flowing bit-exactly
    through survivor and respawn alike, healthz returns to ok."""
    c = small_compiled("engine")
    srv = SpikeServer(max_batch=8, max_wait_ms=2.0)
    srv.add_model("m", c, window=3, n_sessions=0, seed=0)
    w = windows(np.random.default_rng(6), 1, 3, c.n_axons)[0]
    with srv, Portal(srv, port=0, workers=2) as portal:
        direct = srv.submit("m", w, seed=3).result(timeout=120)
        ref = result_digest(direct.spikes, direct.membrane)
        portal._procs[0].kill()
        deadline = time.monotonic() + 30
        while portal.worker_restarts < 1 \
                and time.monotonic() < deadline:
            time.sleep(0.05)
        assert portal.worker_restarts >= 1
        for _ in range(6):
            for attempt in range(8):
                try:
                    s, _, b = http_req(
                        portal.port, "POST", "/v1/m/run",
                        {"counts": w.tolist(), "seed": 3})
                    break
                except OSError:
                    # the struck connection belonged to the dead
                    # worker; the retry lands on a live one
                    time.sleep(0.2)
            assert s == 200 and b["digest"] == ref
        s, _, hz = http_req(portal.port, "GET", "/healthz")
        assert s == 200 and hz["status"] == "ok"


# ------------------------------------- all four backends, forced devices
def test_portal_parity_all_backends_forced_devices_subprocess():
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).resolve()), "--child"],
        env={"PYTHONPATH": f"{ROOT / 'src'}:{ROOT / 'tests'}",
             "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        capture_output=True, text=True, timeout=560, cwd=str(ROOT))
    assert proc.returncode == 0, \
        proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "PORTAL-4BACKEND-OK" in proc.stdout


def _child() -> int:
    """8 concurrent HTTP clients vs direct submit on every backend,
    mesh running on 8 forced host devices."""
    for backend in BACKENDS:
        c = small_compiled(backend)
        srv = SpikeServer(max_batch=8, max_wait_ms=3.0)
        srv.add_model("m", c, window=3, n_sessions=0, seed=0)
        rng = np.random.default_rng(17)
        reqs = {cl: windows(rng, 1, 3, c.n_axons)[0]
                for cl in range(8)}
        results = {}
        with srv, Portal(srv, port=0) as portal:
            def client(cl):
                results[cl] = http_req(
                    portal.port, "POST", "/v1/m/run",
                    {"counts": reqs[cl].tolist(), "seed": cl})
            ts = [threading.Thread(target=client, args=(cl,))
                  for cl in range(8)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            for cl, w in reqs.items():
                status, _, body = results[cl]
                assert status == 200, (backend, body)
                ref = srv.submit("m", w, seed=cl).result(timeout=120)
                assert body["digest"] == result_digest(
                    ref.spikes, ref.membrane), (backend, cl)
        print(f"backend {backend}: 8-client HTTP parity ok")
    print("PORTAL-4BACKEND-OK")
    return 0


if __name__ == "__main__" and "--child" in sys.argv:
    raise SystemExit(_child())
