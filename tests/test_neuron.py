"""Table 1 neuron-model semantics (bit-exact fixed point)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import neuron as nrn


def test_leak_matches_numpy_floor_division():
    V = [-(2**30), -1025, -17, -1, 0, 1, 17, 1025, 2**30]
    for lam in [0, 1, 2, 5, 30, 31, 40, 63]:
        got = np.asarray(nrn.leak(jnp.asarray(V, jnp.int32),
                                  jnp.full((len(V),), lam, jnp.int32)))
        want = np.array([v - v // (2 ** lam) for v in V], np.int64)
        np.testing.assert_array_equal(got, want.astype(np.int32),
                                      err_msg=f"lam={lam}")


def test_noise_disabled_below_minus17():
    key = jax.random.PRNGKey(0)
    for nu in (-17, -20, -32):
        xi = nrn.noise_sample(key, 1000, jnp.full((1000,), nu, jnp.int32))
        assert int(jnp.max(jnp.abs(xi))) == 0, nu


def test_noise_is_odd_and_bounded_at_nu0():
    xi = np.asarray(nrn.noise_sample(jax.random.PRNGKey(1), 4096,
                                     jnp.zeros((4096,), jnp.int32)))
    assert np.all(xi % 2 != 0)          # LSB forced to 1
    assert np.all(np.abs(xi) <= 2 ** 16)
    assert abs(xi.mean()) < 2 ** 16 * 0.05   # balanced around zero


def test_noise_shift_left():
    x0 = np.asarray(nrn.noise_sample(jax.random.PRNGKey(2), 256,
                                     jnp.zeros((256,), jnp.int32)))
    x3 = np.asarray(nrn.noise_sample(jax.random.PRNGKey(2), 256,
                                     jnp.full((256,), 3, jnp.int32)))
    np.testing.assert_array_equal(x3, x0 << 3)


def test_strict_threshold_and_reset():
    V = jnp.array([2, 3, 4], jnp.int32)
    theta = jnp.array([3, 3, 3], jnp.int32)
    V2, spikes = nrn.fire_phase(V, theta, jnp.full((3,), -32, jnp.int32),
                                jnp.full((3,), 63, jnp.int32),
                                jnp.ones((3,), bool), jax.random.PRNGKey(0))
    # spike iff V > theta (strict), spiking neuron resets to 0
    np.testing.assert_array_equal(np.asarray(spikes), [False, False, True])
    assert int(V2[2]) == 0
    assert int(V2[0]) == 2 and int(V2[1]) == 3   # lam=63 -> no leak (V>=0)


def test_ann_zeroes_membrane():
    V = jnp.array([1, -7, 2], jnp.int32)
    V2, _ = nrn.fire_phase(V, jnp.full((3,), 100, jnp.int32),
                           jnp.full((3,), -32, jnp.int32),
                           jnp.full((3,), 63, jnp.int32),
                           jnp.zeros((3,), bool), jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(V2), [0, 0, 0])


def test_param_validation():
    with pytest.raises(ValueError):
        nrn.LIF_neuron(threshold=1, nu=40)
    with pytest.raises(ValueError):
        nrn.LIF_neuron(threshold=1, lam=70)
    with pytest.raises(ValueError):
        nrn.ANN_neuron(threshold=1, nu=-64)


@settings(max_examples=50, deadline=None)
@given(st.integers(-2**30, 2**30), st.integers(0, 63))
def test_leak_property_matches_python_floor(v, lam):
    got = int(nrn.leak(jnp.asarray([v], jnp.int32),
                       jnp.asarray([lam], jnp.int32))[0])
    assert got == np.int32(v - v // 2 ** lam)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(-16, 16))
def test_integrate_is_plain_addition(v, s):
    v = v % 1000
    out = int(nrn.integrate_phase(jnp.asarray([v], jnp.int32),
                                  jnp.asarray([s], jnp.int32))[0])
    assert out == v + s
