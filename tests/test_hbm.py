"""HBM layout invariants — §4 / Fig. 2 / Fig. 7 / A.3 — plus the
ragged-vs-padded CoreShards identity property: the ragged offset-indexed
shard layout carries exactly the information of the padded-to-max
(C, E) expansion it replaced, at memory linear in synapses."""
import numpy as np
from _hyp import given, settings, st

from repro.core import hbm


def _compile(axon_syn, neuron_syn, n, dense=True):
    model_ids = {i: 0 for i in range(n)}
    return hbm.compile_network(axon_syn, neuron_syn, model_ids,
                               outputs=[0], n_neurons=n, dense_pack=dense)


def test_slot_alignment_invariant():
    """Every stored synapse occupies slot == post % 16 (Fig. 2)."""
    img = _compile({0: [(i, i + 1) for i in range(40)]},
                   {i: [((i * 7 + 3) % 40, 5)] for i in range(40)}, 40)
    rows, slots = np.nonzero(img.syn_post >= 0)
    posts = img.syn_post[rows, slots]
    valid = img.syn_weight[rows, slots] != 0
    np.testing.assert_array_equal(slots[valid], posts[valid] % hbm.SLOTS)


def test_pointer_regions_disjoint_and_cover():
    img = _compile({0: [(i, 1) for i in range(20)]},
                   {i: [((i + 1) % 20, 2)] for i in range(20)}, 20)
    spans = []
    for ptr in list(img.axon_ptr.values()) + list(img.neuron_ptr.values()):
        spans.append((ptr.base_row, ptr.base_row + ptr.n_rows))
    spans.sort()
    for (a1, b1), (a2, b2) in zip(spans, spans[1:]):
        assert b1 <= a2, "pointer regions overlap"


def test_zero_fanout_neuron_gets_filler_segment():
    img = _compile({}, {0: [], 1: [(0, 3)]}, 2)
    ptr = img.neuron_ptr[0]
    region = img.syn_post[ptr.base_row:ptr.base_row + ptr.n_rows]
    assert (region >= 0).sum() == hbm.SLOTS          # 16 zero-weight fillers
    w = img.syn_weight[ptr.base_row:ptr.base_row + ptr.n_rows]
    assert (w[region >= 0] == 0).all()


def test_output_flag_set():
    img = _compile({}, {0: [], 1: [(0, 3)]}, 2)      # output neuron = 0
    ptr = img.neuron_ptr[1]
    rows = slice(ptr.base_row, ptr.base_row + ptr.n_rows)
    hit = img.syn_post[rows] == 0
    assert img.syn_outflag[rows][hit].all()


def test_dense_packing_no_worse_than_segment_aligned():
    axon_syn = {a: [((a * 3 + i) % 50, 1) for i in range(7)]
                for a in range(30)}
    neuron_syn = {i: [((i + 13) % 50, 2)] for i in range(50)}
    dense = hbm.compile_network(axon_syn, neuron_syn,
                                {i: 0 for i in range(50)}, [0], 50, True)
    naive = hbm.compile_network(axon_syn, neuron_syn,
                                {i: 0 for i in range(50)}, [0], 50, False)
    assert dense.stats()["packing_density"] >= \
        naive.stats()["packing_density"]
    assert dense.stats()["hbm_bytes"] <= naive.stats()["hbm_bytes"]


def test_pointer_relative_rows_small():
    """Pointers store (base, n_rows) — n_rows must equal actual span."""
    img = _compile({0: [(i, 1) for i in range(33)]}, {}, 40)
    ptr = img.axon_ptr[0]
    region = img.syn_post[ptr.base_row:ptr.base_row + ptr.n_rows]
    assert (region >= 0).sum() == 33
    # 33 synapses over 40 posts -> ceil per-slot occupancy rows
    assert ptr.n_rows <= 3


def _padded_reference(pos, item, post, weight, neuron_core, axon_core,
                      n_cores, n_neurons, n_axon_slots):
    """The retired padded-to-max shard construction, kept as the oracle:
    scatter each entry into a dense (C, E) image sorted by (dest core,
    local post, position)."""
    C = n_cores
    core_of = np.asarray(neuron_core, np.int64)
    counts = np.bincount(core_of, minlength=C) if n_neurons else \
        np.zeros(C, int)
    n_max = max(int(counts.max()) if n_neurons else 0, 1)
    local = np.zeros(n_neurons, np.int64)
    nxt = np.zeros(C, np.int64)
    for i in range(n_neurons):
        local[i] = nxt[core_of[i]]
        nxt[core_of[i]] += 1
    dest = core_of[post]
    lpost = local[post]
    order = np.lexsort((pos, lpost, dest))
    per_core = np.bincount(dest, minlength=C)
    E = max(int(per_core.max()) if len(pos) else 0, 1)
    p = np.full((C, E), -1, np.int64)
    it = np.full((C, E), -1, np.int64)
    w = np.zeros((C, E), np.int32)
    col = np.zeros(C, np.int64)
    for e in order:
        c = dest[e]
        p[c, col[c]] = pos[e]
        it[c, col[c]] = item[e]
        w[c, col[c]] = weight[e]
        col[c] += 1
    ip = np.zeros((C, n_max + 1), np.int64)
    for e in range(len(pos)):
        ip[dest[e], lpost[e] + 1] += 1
    ip = np.cumsum(ip, axis=1)
    return p, it, w, ip


def _check_ragged_vs_padded(n_axons, n_neurons, n_syn, n_cores, seed):
    """The ragged CoreShards layout expands (`padded()`) to exactly the
    padded-to-max image a dense scatter builds — no entry lost,
    reordered, or reweighted — while storing only
    sum(entries) + (C, n_max + 1) offsets (linear in synapses even for
    fully skewed placements)."""
    rng = np.random.default_rng(seed)
    A, N = n_axons, n_neurons
    pos = rng.choice(10_000, n_syn, replace=False).astype(np.int64)
    item = rng.integers(0, A + N, n_syn).astype(np.int64)
    post = rng.integers(0, N, n_syn).astype(np.int64)
    weight = rng.integers(-30_000, 30_000, n_syn).astype(np.int32)
    neuron_core = rng.integers(0, n_cores, N).astype(np.int32)
    axon_core = rng.integers(0, n_cores, A).astype(np.int32)
    sh = hbm.shard_entries(pos, item, post, weight, neuron_core,
                           axon_core, n_cores, N, A)
    got = sh.padded()
    want = _padded_reference(pos, item, post, weight, neuron_core,
                             axon_core, n_cores, N, A)
    for g, w_, name in zip(got, want, ("pos", "item", "w", "indptr")):
        np.testing.assert_array_equal(g, w_, err_msg=name)
    # ragged memory is linear in entries: no padded (C, E) array exists
    assert sh.entry_pos.shape == (n_syn,)
    assert sh.entry_w.shape == (n_syn,)
    assert sh.core_offsets[-1] == n_syn
    # weights are the per-core copy of the record values, entry order
    lookup = dict(zip(pos.tolist(), weight.tolist()))
    assert [lookup[p] for p in sh.entry_pos.tolist()] == \
        sh.entry_w.tolist()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 12), st.integers(0, 30),
       st.integers(1, 6), st.integers(0, 10_000))
def test_ragged_vs_padded_shard_image_identity(n_axons, n_neurons,
                                               n_syn, n_cores, seed):
    _check_ragged_vs_padded(n_axons, n_neurons, n_syn, n_cores, seed)


def test_ragged_vs_padded_deterministic_smoke():
    """The same identity without hypothesis (always runs), including
    the degenerate shapes: empty entries, one core, fully skewed
    all-on-one-core placements."""
    for seed in range(8):
        rng = np.random.default_rng(1000 + seed)
        _check_ragged_vs_padded(int(rng.integers(1, 5)),
                                int(rng.integers(1, 13)),
                                int(rng.integers(0, 31)),
                                int(rng.integers(1, 7)), seed)
    _check_ragged_vs_padded(1, 1, 0, 1, 0)      # no synapses at all
    # fully skewed: every post on one core of many (the padded layout's
    # worst case — ragged memory stays at n_syn entries)
    rng = np.random.default_rng(7)
    pos = rng.choice(1000, 20, replace=False).astype(np.int64)
    sh = hbm.shard_entries(pos, rng.integers(0, 3, 20),
                           np.zeros(20, np.int64),
                           rng.integers(-5, 5, 20).astype(np.int32),
                           np.zeros(1, np.int32), np.zeros(2, np.int32),
                           8, 1, 2)
    assert sh.entry_pos.shape == (20,)
    assert np.diff(sh.core_offsets).tolist() == [20, 0, 0, 0, 0, 0, 0, 0]


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.integers(4, 40), st.integers(0, 4))
def test_all_synapses_stored_exactly_once(n_axons, n_neurons, seed):
    rng = np.random.default_rng(seed)
    axon_syn = {a: [(int(p), int(rng.integers(-9, 9)) or 1)
                    for p in rng.choice(n_neurons,
                                        rng.integers(1, n_neurons + 1),
                                        replace=False)]
                for a in range(n_axons)}
    neuron_syn = {i: [(int(p), int(rng.integers(-9, 9)) or 1)
                      for p in rng.choice(n_neurons,
                                          rng.integers(0, n_neurons),
                                          replace=False)]
                  for i in range(n_neurons)}
    img = hbm.compile_network(axon_syn, neuron_syn,
                              {i: 0 for i in range(n_neurons)}, [0],
                              n_neurons)
    n_expected = sum(len(v) for v in axon_syn.values()) + \
        sum(len(v) if v else hbm.SLOTS for v in neuron_syn.values()) + \
        sum(1 for i in [0] if not neuron_syn.get(i))
    stored = int((img.syn_post >= 0).sum())
    # each synapse appears exactly once (fillers included)
    assert stored >= sum(len(v) for v in axon_syn.values())
    # every item's region reproduces its weights
    for a, syns in axon_syn.items():
        ptr = img.axon_ptr[a]
        rows = slice(ptr.base_row, ptr.base_row + ptr.n_rows)
        got = {}
        for (r, s) in zip(*np.nonzero(img.syn_post[rows] >= 0)):
            p = int(img.syn_post[rows][r, s])
            got[p] = got.get(p, 0) + int(img.syn_weight[rows][r, s])
        want = {}
        for p, w in syns:
            want[p] = want.get(p, 0) + w
        assert got == want
