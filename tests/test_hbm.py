"""HBM layout invariants — §4 / Fig. 2 / Fig. 7 / A.3."""
import numpy as np
from _hyp import given, settings, st

from repro.core import hbm


def _compile(axon_syn, neuron_syn, n, dense=True):
    model_ids = {i: 0 for i in range(n)}
    return hbm.compile_network(axon_syn, neuron_syn, model_ids,
                               outputs=[0], n_neurons=n, dense_pack=dense)


def test_slot_alignment_invariant():
    """Every stored synapse occupies slot == post % 16 (Fig. 2)."""
    img = _compile({0: [(i, i + 1) for i in range(40)]},
                   {i: [((i * 7 + 3) % 40, 5)] for i in range(40)}, 40)
    rows, slots = np.nonzero(img.syn_post >= 0)
    posts = img.syn_post[rows, slots]
    valid = img.syn_weight[rows, slots] != 0
    np.testing.assert_array_equal(slots[valid], posts[valid] % hbm.SLOTS)


def test_pointer_regions_disjoint_and_cover():
    img = _compile({0: [(i, 1) for i in range(20)]},
                   {i: [((i + 1) % 20, 2)] for i in range(20)}, 20)
    spans = []
    for ptr in list(img.axon_ptr.values()) + list(img.neuron_ptr.values()):
        spans.append((ptr.base_row, ptr.base_row + ptr.n_rows))
    spans.sort()
    for (a1, b1), (a2, b2) in zip(spans, spans[1:]):
        assert b1 <= a2, "pointer regions overlap"


def test_zero_fanout_neuron_gets_filler_segment():
    img = _compile({}, {0: [], 1: [(0, 3)]}, 2)
    ptr = img.neuron_ptr[0]
    region = img.syn_post[ptr.base_row:ptr.base_row + ptr.n_rows]
    assert (region >= 0).sum() == hbm.SLOTS          # 16 zero-weight fillers
    w = img.syn_weight[ptr.base_row:ptr.base_row + ptr.n_rows]
    assert (w[region >= 0] == 0).all()


def test_output_flag_set():
    img = _compile({}, {0: [], 1: [(0, 3)]}, 2)      # output neuron = 0
    ptr = img.neuron_ptr[1]
    rows = slice(ptr.base_row, ptr.base_row + ptr.n_rows)
    hit = img.syn_post[rows] == 0
    assert img.syn_outflag[rows][hit].all()


def test_dense_packing_no_worse_than_segment_aligned():
    axon_syn = {a: [((a * 3 + i) % 50, 1) for i in range(7)]
                for a in range(30)}
    neuron_syn = {i: [((i + 13) % 50, 2)] for i in range(50)}
    dense = hbm.compile_network(axon_syn, neuron_syn,
                                {i: 0 for i in range(50)}, [0], 50, True)
    naive = hbm.compile_network(axon_syn, neuron_syn,
                                {i: 0 for i in range(50)}, [0], 50, False)
    assert dense.stats()["packing_density"] >= \
        naive.stats()["packing_density"]
    assert dense.stats()["hbm_bytes"] <= naive.stats()["hbm_bytes"]


def test_pointer_relative_rows_small():
    """Pointers store (base, n_rows) — n_rows must equal actual span."""
    img = _compile({0: [(i, 1) for i in range(33)]}, {}, 40)
    ptr = img.axon_ptr[0]
    region = img.syn_post[ptr.base_row:ptr.base_row + ptr.n_rows]
    assert (region >= 0).sum() == 33
    # 33 synapses over 40 posts -> ceil per-slot occupancy rows
    assert ptr.n_rows <= 3


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.integers(4, 40), st.integers(0, 4))
def test_all_synapses_stored_exactly_once(n_axons, n_neurons, seed):
    rng = np.random.default_rng(seed)
    axon_syn = {a: [(int(p), int(rng.integers(-9, 9)) or 1)
                    for p in rng.choice(n_neurons,
                                        rng.integers(1, n_neurons + 1),
                                        replace=False)]
                for a in range(n_axons)}
    neuron_syn = {i: [(int(p), int(rng.integers(-9, 9)) or 1)
                      for p in rng.choice(n_neurons,
                                          rng.integers(0, n_neurons),
                                          replace=False)]
                  for i in range(n_neurons)}
    img = hbm.compile_network(axon_syn, neuron_syn,
                              {i: 0 for i in range(n_neurons)}, [0],
                              n_neurons)
    n_expected = sum(len(v) for v in axon_syn.values()) + \
        sum(len(v) if v else hbm.SLOTS for v in neuron_syn.values()) + \
        sum(1 for i in [0] if not neuron_syn.get(i))
    stored = int((img.syn_post >= 0).sum())
    # each synapse appears exactly once (fillers included)
    assert stored >= sum(len(v) for v in axon_syn.values())
    # every item's region reproduces its weights
    for a, syns in axon_syn.items():
        ptr = img.axon_ptr[a]
        rows = slice(ptr.base_row, ptr.base_row + ptr.n_rows)
        got = {}
        for (r, s) in zip(*np.nonzero(img.syn_post[rows] >= 0)):
            p = int(img.syn_post[rows][r, s])
            got[p] = got.get(p, 0) + int(img.syn_weight[rows][r, s])
        want = {}
        for p, w in syns:
            want[p] = want.get(p, 0) + w
        assert got == want
