"""Static network analyzer (PR 6) — negative-path coverage of
`repro.analysis.validate` and its wiring into `compile_spec`.

Pins the acceptance invariants:
  * a bad spec raises `AnalysisError` (a ValueError) from
    `compile_spec(..., validate=True)` on EVERY backend target, with
    the offending core/neuron ids on the structured report;
  * `python -m repro.analysis artifact.npz` prints the IDENTICAL
    rendered report on the same network (compiled validate=False);
  * int16-boundary weights (+/-32767, -32768) survive the
    spec -> compile -> save -> load round trip bit-exactly, and
    out-of-range weights are rejected at `connect` time;
  * the accumulation pass bounds fan-in x int16 weights against the
    int32 accumulate range and names neuron AND core ids.
"""
import numpy as np
import pytest

from repro.analysis import (AnalysisError, AnalysisReport,
                            validate_compiled, validate_spec)
from repro.analysis.__main__ import main as analysis_cli
from repro.analysis.validate import accumulation_bounds
from repro.core.api import CRI_network, LIF_neuron
from repro.core.compile import TARGETS, CompiledNetwork, compile_spec
from repro.core.costmodel import ACC_MAX
from repro.core.hbm import W_MAX, W_MIN
from repro.core.partition import Hierarchy
from repro.core.spec import NetworkSpec

PLACED = ("hiaer", "mesh")          # targets with placement/hierarchy


def lif(nu=-32):
    return LIF_neuron(threshold=5, nu=nu, lam=60)


def chain_spec(n_axons=2, n_neurons=6):
    """Every axon feeds neuron 0, neurons chain 0->1->...->N-1, output
    is the chain tail: fully reachable, no dead neurons, no warnings."""
    spec = NetworkSpec()
    ax = spec.add_axons(n_axons)
    spec.add_neurons(n_neurons, lif())
    pre = list(ax) + list(range(n_neurons - 1))
    post = [0] * n_axons + list(range(1, n_neurons))
    spec.connect(np.asarray(pre), np.asarray(post),
                 np.full(len(pre), 3))
    spec.set_outputs([n_neurons - 1])
    return spec


def compile_kwargs(target, n_neurons=6, **kw):
    if target in PLACED:
        kw.setdefault("hierarchy",
                      Hierarchy(1, 1, 2, -(-n_neurons // 2)))
    return kw


# --------------------------------------------------------- clean network
@pytest.mark.parametrize("target", TARGETS)
def test_clean_network_compiles_with_empty_report(target):
    spec = chain_spec()
    c = compile_spec(spec, target, **compile_kwargs(target))
    assert isinstance(c.report, AnalysisReport)
    assert c.report.ok and not c.report.findings


def test_unknown_target_is_structured():
    with pytest.raises(AnalysisError) as ei:
        compile_spec(chain_spec(), "gpu")
    (f,) = ei.value.report.errors
    assert f.code == "E_BAD_TARGET" and f.pass_name == "compile"


# --------------------------------------------------- dangling postsynapse
@pytest.mark.parametrize("target", TARGETS)
def test_dangling_post_raises_on_every_target(target):
    spec = chain_spec()
    n = spec.n_neurons
    # `connect` itself rejects bad ids, so corrupt the stored columns —
    # the shape of a stale/buggy builder the analyzer must catch
    spec._post[-1] = spec._post[-1].copy()
    spec._post[-1][-1] = n + 3
    spec._cols = None
    with pytest.raises(ValueError) as ei:       # AnalysisError IS one
        compile_spec(spec, target, **compile_kwargs(target))
    assert isinstance(ei.value, AnalysisError)
    (f,) = ei.value.report.by_code("E_SYN_POST_RANGE")
    assert f.severity == "error" and f.pass_name == "synapses"
    assert n + 3 in f.ids["neurons"]            # the dangling target id
    assert f.ids["synapses"] == [spec.n_synapses - 1]
    assert str(n + 3) in f.message


# --------------------------------------------------------- overfull core
@pytest.mark.parametrize("target", PLACED)
def test_overfull_core_names_core_and_limit(target):
    spec = chain_spec(n_neurons=8)
    hier = Hierarchy(1, 1, 2, 4)                 # 2 cores x 4 neurons
    place = {i: 0 for i in range(8)}             # all 8 on core 0
    with pytest.raises(AnalysisError) as ei:
        compile_spec(spec, target, hierarchy=hier, placement=place)
    (f,) = ei.value.report.by_code("E_PLACE_OVERFULL")
    assert f.pass_name == "placement"
    assert f.ids["cores"] == [0] and f.ids["loads"] == [8]
    assert "neurons_per_core=4" in f.message
    # validate=False still compiles (overfull breaks nothing structural)
    c = compile_spec(spec, target, hierarchy=hier, placement=place,
                     validate=False)
    assert c.report is None
    assert validate_compiled(c).by_code("E_PLACE_OVERFULL")


@pytest.mark.parametrize("target", PLACED)
def test_structural_placement_errors(target):
    spec = chain_spec()
    hier = Hierarchy(1, 1, 2, 3)
    with pytest.raises(AnalysisError) as ei:     # unknown neuron id
        compile_spec(spec, target, hierarchy=hier,
                     placement={99: 0}, validate=False)
    assert ei.value.report.by_code("E_PLACE_UNKNOWN_ID")
    with pytest.raises(AnalysisError) as ei:     # core out of range
        compile_spec(spec, target, hierarchy=hier,
                     placement={0: 7}, validate=False)
    (f,) = ei.value.report.by_code("E_PLACE_CORE_RANGE")
    assert f.ids["neurons"] == [0] and f.ids["cores"] == [7]


@pytest.mark.parametrize("target", PLACED)
def test_unknown_axon_placement(target):
    spec = chain_spec(n_axons=2)
    hier = Hierarchy(1, 1, 2, 3)
    with pytest.raises(AnalysisError) as ei:     # id not an axon
        compile_spec(spec, target, hierarchy=hier,
                     axon_placement={7: 0}, validate=False)
    (f,) = ei.value.report.by_code("E_PLACE_AXON_UNKNOWN")
    assert f.pass_name == "placement" and f.ids["axons"] == [7]
    with pytest.raises(AnalysisError) as ei:     # core out of range
        compile_spec(spec, target, hierarchy=hier,
                     axon_placement={0: 5}, validate=False)
    (f,) = ei.value.report.by_code("E_PLACE_AXON_RANGE")
    assert f.ids["axons"] == [0] and f.ids["cores"] == [5]


# ------------------------------------------------- accumulation overflow
def overflow_spec(fan_in=66000):
    """`fan_in` distinct axons all feeding neuron 0 at W_MAX: the
    one-step accumulate is fan_in * 32767 > INT32_MAX."""
    spec = NetworkSpec()
    ax = spec.add_axons(fan_in)
    spec.add_neurons(2, lif())
    pre = np.concatenate([np.asarray(ax), [0]])
    post = np.concatenate([np.zeros(fan_in, np.int64), [1]])
    w = np.concatenate([np.full(fan_in, W_MAX), [1]])
    spec.connect(pre, post, w)
    spec.set_outputs([1])
    return spec


@pytest.mark.parametrize("target", TARGETS)
def test_accumulation_overflow_names_neuron(target):
    spec = overflow_spec()
    with pytest.raises(AnalysisError) as ei:
        compile_spec(spec, target,
                     **compile_kwargs(target, n_neurons=2))
    (f,) = ei.value.report.by_code("E_ACC_OVERFLOW")
    assert f.pass_name == "accumulation"
    assert f.ids["neurons"] == [0]
    assert f.ids["bounds"][0] == 66000 * W_MAX
    if target in PLACED:                         # core id on the report
        assert "cores" in f.ids and len(f.ids["cores"]) == 1
        assert "core(s)" in f.message


def test_accumulation_bounds_and_event_multiplicity():
    # 40000 axon synapses at 30000: fine at 1 event/axon/step (1.2e9,
    # but over half the range -> headroom warning), overflow at 2
    spec = NetworkSpec()
    ax = spec.add_axons(40000)
    spec.add_neurons(1, lif())
    spec.connect(np.asarray(ax), np.zeros(40000, np.int64),
                 np.full(40000, 30000))
    spec.set_outputs([0])
    rep1 = validate_spec(spec)
    assert rep1.ok
    (w,) = rep1.by_code("W_ACC_HEADROOM")
    assert w.ids["bounds"][0] == 40000 * 30000 > ACC_MAX // 2
    rep2 = validate_spec(spec, max_events_per_source=2)
    (f,) = rep2.by_code("E_ACC_OVERFLOW")
    assert f.ids["bounds"][0] == 2 * 40000 * 30000
    # the bound helper itself: negative weights bound the low side
    lo, hi = accumulation_bounds(np.asarray([0, 1]), np.asarray([0, 0]),
                                 np.asarray([-5, 7]), A_slots=2, N=1,
                                 max_events_per_source=3)
    assert lo[0] == -15 and hi[0] == 21


# ------------------------------------------------ compile == CLI identity
def test_cli_prints_the_exact_compile_diagnostic(tmp_path, capsys):
    spec = chain_spec(n_neurons=8)
    hier = Hierarchy(1, 1, 2, 4)
    place = {i: 0 for i in range(8)}
    with pytest.raises(AnalysisError) as ei:
        compile_spec(spec, "hiaer", hierarchy=hier, placement=place)
    raised_text = str(ei.value)
    c = compile_spec(spec, "hiaer", hierarchy=hier, placement=place,
                     validate=False)
    path = tmp_path / "bad.npz"
    c.save(path)
    rc = analysis_cli([str(path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert out.strip() == raised_text            # bit-identical report
    assert "E_PLACE_OVERFULL" in out and "neurons_per_core=4" in out


def test_cli_clean_artifact_exits_zero(tmp_path, capsys):
    c = compile_spec(chain_spec(), "engine")
    path = tmp_path / "ok.npz"
    c.save(path)
    rc = analysis_cli([str(path)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 error(s), 0 warning(s)" in out


# ----------------------------------------------------- warnings (non-fatal)
def test_dead_and_unreachable_warnings_do_not_block_compile():
    spec = NetworkSpec()
    ax = spec.add_axons(1)
    spec.add_neurons(4, lif())                   # 0 fed, 1 fed by 0,
    spec.connect(np.asarray([ax[0], 0, 2]),      # 2 dead, 3 fed by 2
                 np.asarray([0, 1, 3]), np.asarray([3, 3, 3]))
    spec.set_outputs([1, 3])
    c = compile_spec(spec, "engine")             # warnings never raise
    dead = c.report.by_code("W_DEAD_NEURON")
    assert dead and dead[0].ids["neurons"] == [2]
    unreach = c.report.by_code("W_UNREACHABLE_OUTPUT")
    assert unreach and unreach[0].ids["neurons"] == [3]


def test_noise_driven_neurons_are_exempt():
    spec = NetworkSpec()
    spec.add_axons(1)
    spec.add_neurons(2, lif(nu=-10))             # noise ON: can self-fire
    spec.set_outputs([0, 1])
    rep = validate_spec(spec)
    assert not rep.by_code("W_DEAD_NEURON")
    assert not rep.by_code("W_UNREACHABLE_OUTPUT")


def test_duplicate_synapse_warning():
    spec = chain_spec()
    spec.connect(np.asarray([0, 0]), np.asarray([1, 1]),
                 np.asarray([2, 2]))             # neuron 0 -> 1 twice+chain
    rep = validate_spec(spec)
    assert rep.ok
    (w,) = rep.by_code("W_SYN_DUPLICATE")
    assert w.pass_name == "synapses"


# ----------------------------------------------------- int16 round-trip
@pytest.mark.parametrize("target", TARGETS)
def test_int16_boundary_weights_roundtrip_bit_exact(tmp_path, target):
    spec = NetworkSpec()
    ax = spec.add_axons(2)
    spec.add_neurons(4, lif())
    weights = np.asarray([W_MIN, W_MAX, -1, 1])
    spec.connect(np.asarray([ax[0], ax[1], 0, 1]),
                 np.asarray([0, 1, 2, 3]), weights)
    spec.set_outputs([2, 3])
    c = compile_spec(spec, target, **compile_kwargs(target, n_neurons=4))
    np.testing.assert_array_equal(c.syn_weight, weights)
    if c.image is not None:                      # the packed HBM record
        np.testing.assert_array_equal(
            np.asarray(c.image.syn_weight).reshape(-1)[c.syn_pos],
            weights)
    if target == "simulator":
        assert c.axonW[0, 0] == W_MIN and c.axonW[1, 1] == W_MAX
    path = tmp_path / "rt.npz"
    c.save(path)
    c2 = CompiledNetwork.load(path)
    np.testing.assert_array_equal(c2.syn_weight, weights)


def test_connect_rejects_out_of_int16_range():
    spec = NetworkSpec()
    ax = spec.add_axons(1)
    spec.add_neurons(1, lif())
    for bad in (W_MAX + 1, W_MIN - 1, 10 ** 9):
        with pytest.raises(ValueError, match="int16"):
            spec.connect(np.asarray(ax), np.asarray([0]),
                         np.asarray([bad]))
    assert spec.n_synapses == 0                  # nothing half-appended


# ------------------------------------------------- facade integration
def test_facade_surfaces_analysis_error():
    lifm = lif()
    axons = {"a": [("x", 3)]}
    neurons = {f"n{i}": ([], lifm) for i in range(7)}
    neurons["x"] = ([], lifm)
    with pytest.raises(ValueError, match="E_PLACE_OVERFULL"):
        CRI_network(axons=axons, neurons=neurons, outputs=["x"],
                    backend="hiaer", hierarchy=Hierarchy(1, 1, 2, 4),
                    placement={k: 0 for k in neurons})
