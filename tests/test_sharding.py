"""Sharding rules: every param/optimizer/cache spec must divide evenly on
the production meshes for every arch — validated symbolically (no 512
devices needed in the test process)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cells, get_arch
from repro.launch.sharding import ShardingRules
from repro.launch.specs import batch_shapes, cache_shapes, params_shapes
from repro.models.lm import _attn_layout
from repro.distributed import context


class FakeMesh:
    """Shape-only stand-in for the production mesh."""
    def __init__(self, multi):
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi
                      else {"data": 16, "model": 16})
        self.axis_names = tuple(self.shape)


def _check(specs, shapes, mesh, where):
    flat_specs = jax.tree.flatten(specs,
                                  is_leaf=lambda x: isinstance(x, P))[0]
    flat_shapes = jax.tree.leaves(shapes)
    assert len(flat_specs) == len(flat_shapes), where
    for sp, sh in zip(flat_specs, flat_shapes):
        for dim, axes in zip(sh.shape, tuple(sp)):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (where, sh.shape, tuple(sp))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_and_cache_specs_divisible(arch, multi):
    cfg = get_arch(arch)
    mesh = FakeMesh(multi)
    prev = getattr(context._state, "mesh", None)

    class _M:
        axis_names = mesh.axis_names
        shape = mesh.shape
    context._state.mesh = _M()
    try:
        layout = _attn_layout(cfg, 16)
        rules = ShardingRules(cfg, mesh, layout)
        ps = params_shapes(cfg)
        _check(rules.params_specs(ps), ps, mesh, f"{arch} params")
        for shape in cells(arch):
            bs = batch_shapes(cfg, shape)
            _check(rules.batch_specs(bs), bs, mesh,
                   f"{arch} batch {shape.name}")
            if shape.kind != "train":
                cs = cache_shapes(cfg, shape)
                _check(rules.cache_specs(cs), cs, mesh,
                       f"{arch} cache {shape.name}")
    finally:
        context._state.mesh = prev


def test_long500k_only_subquadratic():
    runnable = {a for a in ARCH_IDS
                if any(s.name == "long_500k" for s in cells(a))}
    assert runnable == {"mamba2_780m", "recurrentgemma_2b"}


def test_attention_layout_fallback():
    # ragged head counts use the sequence-sharded layout
    assert _attn_layout(get_arch("qwen2_7b"), 16) == "seq"        # 28 heads
    assert _attn_layout(get_arch("musicgen_medium"), 16) == "seq"  # 24
    assert _attn_layout(get_arch("llama3_405b"), 16) == "heads"   # 128
    assert _attn_layout(get_arch("gemma_7b"), 16) == "heads"      # 16
