"""Jit-hygiene lint (PR 6) — rule detection, root discovery, closure
chasing, and the two silencing mechanisms, plus the CI-critical
assertion that the shipped source tree is lint-clean.
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import tracelint
from repro.analysis.tracelint import (_lint_single, lint_paths, main)

SRC_ROOT = Path(__file__).resolve().parents[1] / "src" / "repro"

BAD = textwrap.dedent("""\
    import jax
    import jax.numpy as jnp
    import numpy as np

    TABLE = {"a": 1}

    @jax.jit
    def step(x, cfg):
        for i in range(3):
            x = x + i
        y = x.sum().item()
        z = np.maximum(x, 0)
        t = float(cfg)
        return helper(x) + y + t + z.sum()

    def helper(x):
        for k, v in TABLE.items():
            x = x + v
        return x

    def outer(x):
        def body(c, t):
            return c, c.item()
        return jax.lax.scan(body, x, jnp.arange(3))

    def not_jitted(x):
        return np.zeros(3) + x.item()
""")


def write(tmp_path, name, text):
    p = tmp_path / name
    p.write_text(text)
    return p


def rules_by_qualname(findings):
    out = {}
    for f in findings:
        out.setdefault(f.qualname, set()).add(f.rule)
    return out


# ---------------------------------------------------------- rule matrix
def test_rules_roots_and_closure(tmp_path):
    findings = _lint_single(write(tmp_path, "bad.py", BAD))
    got = rules_by_qualname(findings)
    # the decorated root: loop, two host-scalar forms, numpy call
    assert got["step"] == {"py-loop", "host-scalar", "numpy-call"}
    # reached transitively through step's call, not decorated itself
    assert got["helper"] == {"py-loop", "dict-iter"}
    # a local def handed to lax.scan is a root too
    assert got["outer.body"] == {"host-scalar"}
    # never fed to jit/lax: stays invisible however dirty
    assert "not_jitted" not in got
    # findings carry path:line rendering for editors/CI logs
    f = findings[0]
    assert f.render().startswith(f"{f.path}:{f.line}: [")


def test_host_scalar_only_for_parameters(tmp_path):
    src = textwrap.dedent("""\
        import jax

        @jax.jit
        def f(x):
            n = int(3.5)
            return x * n
    """)
    assert _lint_single(write(tmp_path, "m.py", src)) == []


# ---------------------------------------------------------- silencing
def test_inline_allow_comment(tmp_path):
    src = textwrap.dedent("""\
        import jax

        @jax.jit
        def f(x):
            n = x.shape[0]
            return x.item()  # tracelint: allow=host-scalar
    """)
    assert _lint_single(write(tmp_path, "m.py", src)) == []
    # the comment silences ONLY the named rule
    src2 = src.replace("allow=host-scalar", "allow=py-loop")
    p2 = write(tmp_path, "m2.py", src2)
    assert [f.rule for f in _lint_single(p2)] == ["host-scalar"]


def test_file_allowlist(tmp_path, monkeypatch):
    src = textwrap.dedent("""\
        import jax

        @jax.jit
        def f(x):
            for i in range(2):
                x = x + i
            return x
    """)
    p = write(tmp_path, "homed_builder.py", src)
    assert [f.rule for f in _lint_single(p)] == ["py-loop"]
    monkeypatch.setitem(tracelint.ALLOWLIST, "homed_builder.py",
                        {"py-loop"})
    assert _lint_single(p) == []


def test_allowlist_entries_point_at_real_files():
    """Every ALLOWLIST suffix must still name a file in the tree —
    stale entries would silently mask future regressions."""
    for suffix in tracelint.ALLOWLIST:
        assert (SRC_ROOT / suffix).is_file(), suffix


# ------------------------------------------------------------ CLI + tree
def test_main_exit_codes(tmp_path, capsys):
    bad = write(tmp_path, "bad.py", BAD)
    assert main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "[py-loop]" in out and "finding(s)" in out
    clean = write(tmp_path, "clean.py", "import jax\n")
    assert main([str(clean)]) == 0
    assert main([]) == 2


def test_shipped_tree_is_lint_clean():
    """The CI gate: src/repro has no jit-hygiene findings (modulo the
    documented ALLOWLIST)."""
    findings = lint_paths(SRC_ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)
