"""Checkpoint/restore (atomic, resumable, elastic), gradient compression
(error feedback), straggler watchdog."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.data.synthetic import TokenPipeline
from repro.distributed.compression import (ErrorFeedback, compressed_bytes,
                                           int8_compress, int8_decompress)
from repro.distributed.elastic import StepWatchdog


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (17, 33)),
            "b": {"c": jax.random.normal(k2, (5,)).astype(jnp.bfloat16),
                  "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_tree(tmp_path / "ck", t, aux={"note": "x"})
    r, aux = restore_tree(tmp_path / "ck", t)
    assert aux["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_tmp_left(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    save_tree(tmp_path / "ck", t)
    assert not (tmp_path / "ck.tmp").exists()
    assert (tmp_path / "ck" / "index.json").exists()


def test_manager_retention_and_latest(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    t = _tree(jax.random.PRNGKey(2))
    for s in (10, 20, 30):
        m.save(s, t)
    assert m.steps() == [20, 30]
    assert m.latest_step() == 30
    r, aux = m.restore(t)
    assert aux["step"] == 30


def test_async_save(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    t = _tree(jax.random.PRNGKey(3))
    m.save(1, t, async_=True)
    m.wait()
    assert m.latest_step() == 1


def test_restore_missing_leaf_raises(tmp_path):
    t = _tree(jax.random.PRNGKey(4))
    save_tree(tmp_path / "ck", {"a": t["a"]})
    with pytest.raises(KeyError):
        restore_tree(tmp_path / "ck", t)


def test_pipeline_cursor_resume(tmp_path):
    p1 = TokenPipeline(vocab_size=100, seq_len=8, global_batch=4, seed=5)
    b0 = p1.next_batch()
    b1 = p1.next_batch()
    state = p1.state_dict()
    b2 = p1.next_batch()
    p2 = TokenPipeline(vocab_size=100, seq_len=8, global_batch=4)
    p2.load_state_dict(state)
    np.testing.assert_array_equal(p2.next_batch()["tokens"], b2["tokens"])


def test_train_restart_bit_exact(tmp_path):
    """Kill-and-resume produces the same params as an uninterrupted run —
    the checkpoint/restart requirement."""
    from repro.configs import get_reduced
    from repro.distributed.context import mesh_context
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_train_step
    from repro.models import lm
    from repro.optim import AdamWConfig, adamw_init

    cfg = get_reduced("qwen2_5_3b")
    oc = AdamWConfig(lr=1e-3)
    with mesh_context(make_local_mesh()):
        params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        opt = adamw_init(params, oc)
        step = jax.jit(make_train_step(cfg, oc))
        pipe = TokenPipeline(cfg.vocab_size, 16, 4, seed=1)

        # uninterrupted: 4 steps
        p, o, pp = params, opt, TokenPipeline(cfg.vocab_size, 16, 4, seed=1)
        for _ in range(4):
            p, o, _ = step(p, o, jax.tree.map(jnp.asarray, pp.next_batch()))
        ref = p

        # interrupted at step 2
        m = CheckpointManager(tmp_path / "run")
        p, o = params, opt
        for i in range(2):
            p, o, _ = step(p, o, jax.tree.map(jnp.asarray,
                                              pipe.next_batch()))
        m.save(2, {"params": p, "opt": o}, aux=pipe.state_dict())
        # 'crash' + restore
        restored, aux = m.restore({"params": p, "opt": o})
        pipe2 = TokenPipeline(cfg.vocab_size, 16, 4)
        pipe2.load_state_dict(aux)
        p, o = restored["params"], restored["opt"]
        for _ in range(2):
            p, o, _ = step(p, o, jax.tree.map(jnp.asarray,
                                              pipe2.next_batch()))
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- compression
def test_int8_roundtrip_accuracy():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s, n = int8_compress(g)
    d = int8_decompress(q, s, n, g.shape)
    err = float(jnp.max(jnp.abs(d - g)))
    assert err <= float(jnp.max(jnp.abs(g))) / 127.0 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["int8", "topk"]), st.integers(0, 100))
def test_error_feedback_preserves_signal(mode, seed):
    """Across steps, sum(decompressed) ~ sum(true grads): residual carries
    the error forward instead of dropping it."""
    ef = ErrorFeedback(mode=mode, topk_frac=0.05)
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (300,))}
    res = ef.init(g)
    acc = jnp.zeros((300,))
    for i in range(20):
        out, res = ef.apply(g, res)
        acc = acc + out["w"]
    target = 20.0 * g["w"]
    rel = float(jnp.linalg.norm(acc - target) / jnp.linalg.norm(target))
    # int8 is near-lossless; 5% top-k delivers the mass with bounded lag
    # (the undelivered remainder lives in the residual, not dropped)
    assert rel < (0.02 if mode == "int8" else 0.5), rel
    res_norm = float(jnp.linalg.norm(res["w"]))
    assert res_norm < 25 * float(jnp.linalg.norm(g["w"]))


def test_compressed_sgd_converges():
    """SGD on a quadratic with int8+EF reaches the optimum."""
    ef = ErrorFeedback(mode="int8")
    w = jnp.ones((64,)) * 5.0
    res = ef.init({"w": w})
    for _ in range(300):
        g = {"w": 2 * w}
        cg, res = ef.apply(g, res)
        w = w - 0.05 * cg["w"]
    assert float(jnp.max(jnp.abs(w))) < 1e-2


def test_compressed_bytes_smaller():
    g = {"w": jnp.zeros((10000,), jnp.float32)}
    assert compressed_bytes(g, "int8") < 4 * 10000 / 3
    assert compressed_bytes(g, "topk", 0.01) < 4 * 10000 / 10


# --------------------------------------------------------------- watchdog
def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=2.0, patience=2, window=16)
    import time as _t
    for _ in range(10):
        wd.start(); _t.sleep(0.002); r = wd.stop()
        assert not r["straggler"]
    evict = False
    for _ in range(3):
        wd.start(); _t.sleep(0.05); r = wd.stop()
        evict = evict or r["evict"]
    assert r["straggler"] and evict
