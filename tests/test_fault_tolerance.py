"""Checkpoint/restore (atomic, resumable, elastic), gradient compression
(error feedback), straggler watchdog, serving-tier chaos matrix."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.data.synthetic import TokenPipeline
from repro.distributed.compression import (ErrorFeedback, compressed_bytes,
                                           int8_compress, int8_decompress)
from repro.distributed.elastic import StepWatchdog


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (17, 33)),
            "b": {"c": jax.random.normal(k2, (5,)).astype(jnp.bfloat16),
                  "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_tree(tmp_path / "ck", t, aux={"note": "x"})
    r, aux = restore_tree(tmp_path / "ck", t)
    assert aux["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_tmp_left(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    save_tree(tmp_path / "ck", t)
    assert not (tmp_path / "ck.tmp").exists()
    assert (tmp_path / "ck" / "index.json").exists()


def test_manager_retention_and_latest(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    t = _tree(jax.random.PRNGKey(2))
    for s in (10, 20, 30):
        m.save(s, t)
    assert m.steps() == [20, 30]
    assert m.latest_step() == 30
    r, aux = m.restore(t)
    assert aux["step"] == 30


def test_async_save(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    t = _tree(jax.random.PRNGKey(3))
    m.save(1, t, async_=True)
    m.wait()
    assert m.latest_step() == 1


def test_restore_missing_leaf_raises(tmp_path):
    t = _tree(jax.random.PRNGKey(4))
    save_tree(tmp_path / "ck", {"a": t["a"]})
    with pytest.raises(KeyError):
        restore_tree(tmp_path / "ck", t)


def test_pipeline_cursor_resume(tmp_path):
    p1 = TokenPipeline(vocab_size=100, seq_len=8, global_batch=4, seed=5)
    b0 = p1.next_batch()
    b1 = p1.next_batch()
    state = p1.state_dict()
    b2 = p1.next_batch()
    p2 = TokenPipeline(vocab_size=100, seq_len=8, global_batch=4)
    p2.load_state_dict(state)
    np.testing.assert_array_equal(p2.next_batch()["tokens"], b2["tokens"])


def test_train_restart_bit_exact(tmp_path):
    """Kill-and-resume produces the same params as an uninterrupted run —
    the checkpoint/restart requirement."""
    from repro.configs import get_reduced
    from repro.distributed.context import mesh_context
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_train_step
    from repro.models import lm
    from repro.optim import AdamWConfig, adamw_init

    cfg = get_reduced("qwen2_5_3b")
    oc = AdamWConfig(lr=1e-3)
    with mesh_context(make_local_mesh()):
        params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        opt = adamw_init(params, oc)
        step = jax.jit(make_train_step(cfg, oc))
        pipe = TokenPipeline(cfg.vocab_size, 16, 4, seed=1)

        # uninterrupted: 4 steps
        p, o, pp = params, opt, TokenPipeline(cfg.vocab_size, 16, 4, seed=1)
        for _ in range(4):
            p, o, _ = step(p, o, jax.tree.map(jnp.asarray, pp.next_batch()))
        ref = p

        # interrupted at step 2
        m = CheckpointManager(tmp_path / "run")
        p, o = params, opt
        for i in range(2):
            p, o, _ = step(p, o, jax.tree.map(jnp.asarray,
                                              pipe.next_batch()))
        m.save(2, {"params": p, "opt": o}, aux=pipe.state_dict())
        # 'crash' + restore
        restored, aux = m.restore({"params": p, "opt": o})
        pipe2 = TokenPipeline(cfg.vocab_size, 16, 4)
        pipe2.load_state_dict(aux)
        p, o = restored["params"], restored["opt"]
        for _ in range(2):
            p, o, _ = step(p, o, jax.tree.map(jnp.asarray,
                                              pipe2.next_batch()))
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- compression
def test_int8_roundtrip_accuracy():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s, n = int8_compress(g)
    d = int8_decompress(q, s, n, g.shape)
    err = float(jnp.max(jnp.abs(d - g)))
    assert err <= float(jnp.max(jnp.abs(g))) / 127.0 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["int8", "topk"]), st.integers(0, 100))
def test_error_feedback_preserves_signal(mode, seed):
    """Across steps, sum(decompressed) ~ sum(true grads): residual carries
    the error forward instead of dropping it."""
    ef = ErrorFeedback(mode=mode, topk_frac=0.05)
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (300,))}
    res = ef.init(g)
    acc = jnp.zeros((300,))
    for i in range(20):
        out, res = ef.apply(g, res)
        acc = acc + out["w"]
    target = 20.0 * g["w"]
    rel = float(jnp.linalg.norm(acc - target) / jnp.linalg.norm(target))
    # int8 is near-lossless; 5% top-k delivers the mass with bounded lag
    # (the undelivered remainder lives in the residual, not dropped)
    assert rel < (0.02 if mode == "int8" else 0.5), rel
    res_norm = float(jnp.linalg.norm(res["w"]))
    assert res_norm < 25 * float(jnp.linalg.norm(g["w"]))


def test_compressed_sgd_converges():
    """SGD on a quadratic with int8+EF reaches the optimum."""
    ef = ErrorFeedback(mode="int8")
    w = jnp.ones((64,)) * 5.0
    res = ef.init({"w": w})
    for _ in range(300):
        g = {"w": 2 * w}
        cg, res = ef.apply(g, res)
        w = w - 0.05 * cg["w"]
    assert float(jnp.max(jnp.abs(w))) < 1e-2


def test_compressed_bytes_smaller():
    g = {"w": jnp.zeros((10000,), jnp.float32)}
    assert compressed_bytes(g, "int8") < 4 * 10000 / 3
    assert compressed_bytes(g, "topk", 0.01) < 4 * 10000 / 10


# ============================================ serving-tier chaos (PR 10)
# Deterministic fault injection against the live micro-batching server:
# the same armed FaultPlan produces the same crash at the same batch on
# every run, surviving responses stay bit-exact vs fault-free digests,
# every future settles, no lane leaks, and recovery adds zero compiles.
import threading
import time

from repro import faults
from repro.analysis.retrace import compile_counts
from repro.core.deploy import deploy
from repro.portal.gateway import map_exception, result_digest
from repro.serve import (BufferClosed, DeadlineError, DispatchRestart,
                         SpikeServer)
from test_serve import small_compiled, windows


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every chaos test leaves the global hook disarmed."""
    yield
    faults.uninstall()


def test_fault_plan_spec_roundtrip_and_determinism():
    plan = faults.FaultPlan.from_spec(
        "dispatch_crash@2,5;bridge_drop%0.3;slow_batch@1:delay=0.25",
        seed=7)
    again = faults.FaultPlan.from_spec(plan.spec(), seed=7)
    assert again.spec() == plan.spec()

    # rate-armed triggers are a pure function of (spec, seed)
    def seq(seed):
        p = faults.FaultPlan.from_spec("bridge_drop%0.3", seed=seed)
        return [p.fire("bridge_drop") for _ in range(200)]
    a = seq(1)
    assert a == seq(1) and any(a) and not all(a)
    assert a != seq(2)

    # hit-indexed sites trigger exactly at their 1-based indices
    p = faults.FaultPlan().arm("bridge_drop", at=(3, 5))
    got = [p.fire("bridge_drop") for _ in range(8)]
    assert got == [False, False, True, False, True,
                   False, False, False]
    assert p.stats()["bridge_drop"] == {"hits": 8, "fired": 2,
                                        "action": "flag"}

    # unarmed site on an armed plan / no plan installed: cheap no-op
    assert p.fire("dispatch_crash") is False
    assert faults.fire("dispatch_crash") is False
    with pytest.raises(ValueError):
        faults.FaultPlan().arm("not_a_site")


def test_fault_plan_ndjson_log_records_triggers(tmp_path):
    log = tmp_path / "faults.ndjson"
    p = faults.FaultPlan(log_path=str(log)).arm("bridge_drop", at=(2,))
    p.fire("bridge_drop")
    p.fire("bridge_drop", batch=4)
    recs = [json.loads(ln) for ln in
            log.read_text().strip().splitlines()]
    assert len(recs) == 1
    assert recs[0]["site"] == "bridge_drop" and recs[0]["hit"] == 2
    assert recs[0]["batch"] == 4 and recs[0]["pid"] == os.getpid()


def _chaos_server(max_batch=8, **kw):
    c = small_compiled("engine")
    srv = SpikeServer(max_batch=max_batch, max_wait_ms=2.0, **kw)
    srv.add_model("m", c, window=3, n_sessions=4, seed=0)
    return c, srv


def _retry_result(srv, w, seed, futs, session=None, tries=8):
    """Submit-and-retry: the recovery contract says an injected
    rejection is safe to resubmit bit-exactly."""
    for _ in range(tries):
        fut = srv.submit("m", w, seed=seed, session=session)
        futs.append(fut)
        try:
            return fut.result(timeout=120)
        except (DispatchRestart, faults.InjectedFault):
            time.sleep(0.05)
    raise AssertionError("request never succeeded after retries")


@pytest.mark.parametrize("plan_spec", [
    "dispatch_crash@2",
    "batch_exception@3",
    "slow_batch@2:delay=0.3",
    "dispatch_crash@1;batch_exception@4",
])
def test_chaos_matrix_survivors_bit_exact(plan_spec):
    """8 concurrent clients (4 scratch + 4 resident sessions) through
    an armed fault plan: every surviving response equals the fault-free
    reference bit for bit, every future settles, all lanes return, and
    recovery adds ZERO compiles beyond the warmed buckets."""
    c, srv = _chaos_server()
    m = srv.models["m"]
    rng = np.random.default_rng(11)
    scratch_w = {cl: [windows(rng, 1, 3, c.n_axons)[0]
                      for _ in range(2)] for cl in range(4)}
    sess_w = {cl: [windows(rng, 1, 3, c.n_axons)[0]
                   for _ in range(2)] for cl in range(4)}
    # warm every pow2 bucket (scratch AND lane-resident paths) so the
    # only compiles chaos COULD add are recovery-induced ones — the
    # retrace gate this test pins; reset() puts the warmed lanes back
    # on their construction streams for the session clients
    zero = np.zeros((3, c.n_axons), np.int32)
    for B in (1, 2, 4, 8):
        m.dep.run_lanes([-1] * B, np.stack([zero] * B))
    m.dep.run_lanes([0, 1, 2, 3], np.stack([zero] * 4))
    m.dep.reset()
    before = compile_counts(m.dep.impl)

    faults.install(faults.FaultPlan.from_spec(plan_spec, seed=3))
    futs, out, lanes_used = [], {}, {}
    errors = []

    def scratch_client(cl):
        try:
            for r, w in enumerate(scratch_w[cl]):
                out[("s", cl, r)] = _retry_result(
                    srv, w, seed=cl * 100 + r, futs=futs)
        except Exception as e:     # noqa: BLE001 — surfaced below
            errors.append(e)

    def session_client(cl):
        try:
            sid = srv.open_session("m")
            lanes_used[cl] = sid
            for r, w in enumerate(sess_w[cl]):
                out[("l", cl, r)] = _retry_result(
                    srv, w, seed=0, futs=futs, session=sid)
            srv.close_session("m", sid)
        except Exception as e:     # noqa: BLE001 — surfaced below
            errors.append(e)

    with srv:
        ts = [threading.Thread(target=scratch_client, args=(cl,))
              for cl in range(4)]
        ts += [threading.Thread(target=session_client, args=(cl,))
               for cl in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errors, errors
        # post-fault: the recovered dispatcher still serves
        post = _retry_result(srv, scratch_w[0][0], seed=999, futs=futs)
    faults.uninstall()

    # every future this test ever created settled (none leaked)
    assert all(f.done() for f in futs)
    # every session lane came back to the pool
    assert m.sessions.n_open == 0

    # surviving scratch responses == fault-free reference, bit for bit
    ref = deploy(c, seed=0)
    ref.alloc_lanes(4)
    for cl in range(4):
        for r, w in enumerate(scratch_w[cl]):
            spk, V = ref.run_lanes([-1], w[None],
                                   seeds=[cl * 100 + r])
            res = out[("s", cl, r)]
            assert result_digest(res.spikes, res.membrane) == \
                result_digest(spk[0], V[0]), (plan_spec, cl, r)
    spk, V = ref.run_lanes([-1], scratch_w[0][0][None], seeds=[999])
    assert result_digest(post.spikes, post.membrane) == \
        result_digest(spk[0], V[0])
    # session clients: both windows == the uninterrupted lane run
    # (retries after a crash resume from the rolled-back snapshot)
    for cl, sid in lanes_used.items():
        lane_ref = deploy(c, seed=0)
        lane_ref.alloc_lanes(4)
        for r, w in enumerate(sess_w[cl]):
            spk, V = lane_ref.run_lanes([sid], w[None])
            res = out[("l", cl, r)]
            assert result_digest(res.spikes, res.membrane) == \
                result_digest(spk[0], V[0]), (plan_spec, cl, r)

    # recovery compiled nothing new (case-pinned retrace gate)
    assert compile_counts(m.dep.impl) == before
    if "dispatch_crash" in plan_spec:
        assert srv.health()["restarts"] >= 1


def test_supervisor_restart_is_deterministic_replay():
    """Two identical chaos passes (same plan, same seed, same request
    sequence) produce the same outcome sequence and digests — the
    bit-identical replay property the chaos CLI checks end to end."""
    def one_pass():
        c, srv = _chaos_server()
        faults.install(faults.FaultPlan.from_spec("dispatch_crash@2",
                                                  seed=0))
        rng = np.random.default_rng(0)
        outcomes = []
        try:
            with srv:
                for r in range(5):
                    w = windows(rng, 1, 3, c.n_axons)[0]
                    try:
                        res = srv.submit("m", w, seed=r).result(
                            timeout=120)
                        outcomes.append(
                            ("ok", result_digest(res.spikes,
                                                 res.membrane)))
                    except DispatchRestart as e:
                        outcomes.append(("restart", e.restart))
                outcomes.append(("restarts",
                                 srv.health()["restarts"]))
        finally:
            faults.uninstall()
        return outcomes

    first = one_pass()
    assert first == one_pass()
    assert ("restart", 1) in first


def test_dispatcher_down_after_restart_budget():
    """Past max_restarts the server goes DOWN instead of crash-looping:
    healthz flips to status=down / ok=False and new submissions fail
    fast with BufferClosed."""
    c, srv = _chaos_server(supervise=True, max_restarts=0)
    w = windows(np.random.default_rng(0), 1, 3, c.n_axons)[0]
    faults.install(faults.FaultPlan().arm("dispatch_crash", at=(1,)))
    with srv:
        with pytest.raises(DispatchRestart):
            srv.submit("m", w).result(timeout=120)
        deadline = time.monotonic() + 30
        while srv.health()["status"] != "down" \
                and time.monotonic() < deadline:
            time.sleep(0.02)
        hz = srv.health()
        assert hz["status"] == "down" and hz["ok"] is False
        assert "max_restarts" in hz["reason"]
        with pytest.raises(BufferClosed):
            srv.submit("m", w)
    faults.uninstall()


def test_unsupervised_crash_settles_inflight_and_reports_down():
    """supervise=False: the dying dispatcher itself rejects its
    in-flight batch (no future ever hangs) and healthz reports DOWN."""
    c, srv = _chaos_server(supervise=False)
    w = windows(np.random.default_rng(1), 1, 3, c.n_axons)[0]
    faults.install(faults.FaultPlan().arm("dispatch_crash", at=(1,)))
    with srv:
        with pytest.raises(faults.InjectedFault):
            srv.submit("m", w).result(timeout=120)
        deadline = time.monotonic() + 30
        while srv.health()["status"] != "down" \
                and time.monotonic() < deadline:
            time.sleep(0.02)
        hz = srv.health()
        assert hz["status"] == "down" and hz["ok"] is False
        assert hz["restarts"] == 0
    faults.uninstall()


def test_checkpoint_restore_sessions_resume_bit_exact(tmp_path):
    """k windows -> checkpoint -> FRESH server + restore -> k more ==
    2k uninterrupted windows, including a reconfigure before the
    checkpoint (weights travel with the snapshot) and the original
    session id surviving restore."""
    rng = np.random.default_rng(23)
    wins = [windows(rng, 1, 3, small_compiled("engine").n_axons)[0]
            for _ in range(6)]
    edit = None                     # (pre, post, new_weight)

    def fresh():
        c = small_compiled("engine")
        srv = SpikeServer(max_batch=4, max_wait_ms=1.0)
        srv.add_model("m", c, window=3, n_sessions=4, seed=0)
        return c, srv

    # uninterrupted reference: 6 windows on one session lane
    c, ref_srv = fresh()
    edit = (-1, int(c.syn_post[0]),
            int(deploy(c, seed=0).read_synapses(
                [-1], [int(c.syn_post[0])])[0]) + 2)
    ref_out = []
    with ref_srv:
        sid = ref_srv.open_session("m")
        for i, w in enumerate(wins):
            if i == 2:              # weight edit mid-stream
                ref_srv.reconfigure("m", [edit[0]], [edit[1]],
                                    [edit[2]]).result(timeout=120)
            ref_out.append(ref_srv.submit(
                "m", w, session=sid).result(timeout=120))

    # interrupted run: 3 windows (same edit), checkpoint, "crash"
    _, srv_a = fresh()
    with srv_a:
        sid_a = srv_a.open_session("m")
        assert sid_a == sid
        for i, w in enumerate(wins[:3]):
            if i == 2:
                srv_a.reconfigure("m", [edit[0]], [edit[1]],
                                  [edit[2]]).result(timeout=120)
            srv_a.submit("m", w, session=sid_a).result(timeout=120)
        aux = srv_a.checkpoint(tmp_path / "ck")
    assert aux["models"]["m"]["sessions"][0]["id"] == sid

    # fresh process-equivalent: new server, restore, 3 more windows
    _, srv_b = fresh()
    srv_b.restore(tmp_path / "ck")
    with srv_b:
        for w, ref in zip(wins[3:], ref_out[3:]):
            res = srv_b.submit("m", w, session=sid).result(timeout=120)
            np.testing.assert_array_equal(res.spikes, ref.spikes)
            np.testing.assert_array_equal(res.membrane, ref.membrane)
        # restored session keeps its lane: a second open gets lane 1+
        other = srv_b.open_session("m")
        assert other != sid


def test_shutdown_concurrent_callers_once_guarded():
    """N racing shutdown() callers: exactly one drains, the rest
    return — no double-join, no exception, server ends cleanly."""
    c, srv = _chaos_server()
    w = windows(np.random.default_rng(2), 1, 3, c.n_axons)[0]
    srv.start()
    futs = [srv.submit("m", w, seed=i) for i in range(4)]
    errs = []

    def caller():
        try:
            srv.shutdown(drain=True)
        except Exception as e:     # noqa: BLE001 — assert below
            errs.append(e)

    ts = [threading.Thread(target=caller) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs, errs
    for f in futs:
        assert f.done()
    # restartable after a full shutdown
    srv.start()
    assert srv.submit("m", w, seed=9).result(timeout=120) is not None
    srv.shutdown()


def test_map_exception_retry_after_vocabulary():
    """The portal's structured-error map emits Retry-After for every
    transient failure: deadline (504), shutdown (503), dispatcher
    restart (503 E_DISPATCH_RESTART)."""
    e = map_exception(DeadlineError("m", 0.5, 0.61))
    assert e.status == 504
    assert e.to_body()["error"]["retry_after_s"] > 0
    assert int(e.headers()["Retry-After"]) >= 1

    e = map_exception(BufferClosed())
    assert e.status == 503 and e.code == "E_SHUTDOWN"
    assert e.to_body()["error"]["retry_after_s"] > 0
    assert int(e.headers()["Retry-After"]) >= 1

    e = map_exception(DispatchRestart(2, cause=RuntimeError("boom"),
                                      retry_after_s=0.2))
    assert e.status == 503 and e.code == "E_DISPATCH_RESTART"
    assert e.to_body()["error"]["retry_after_s"] == 0.2
    assert int(e.headers()["Retry-After"]) >= 1
    assert "restart #2" in e.message


# --------------------------------------------------------------- watchdog
def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=2.0, patience=2, window=16)
    import time as _t
    for _ in range(10):
        wd.start(); _t.sleep(0.002); r = wd.stop()
        assert not r["straggler"]
    evict = False
    for _ in range(3):
        wd.start(); _t.sleep(0.05); r = wd.stop()
        evict = evict or r["evict"]
    assert r["straggler"] and evict
