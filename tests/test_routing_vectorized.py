"""Vectorized two-phase routing vs the seed per-pointer engine — the
bit-exactness contract of this PR: spikes, membrane values, and
AccessCounter statistics must be integer-identical on arbitrary
topologies, including the A.3 edge cases (filler synapses on zero-fanout
neurons, empty axons, tiny networks where filler post ids exceed
n_neurons) and duplicated axon events."""
import random

import numpy as np
import pytest

from repro.core.api import ANN_neuron, CRI_network, LIF_neuron
from repro.core.hbm import SLOTS


def random_net(seed, n_neurons=None, zero_fanout_frac=0.3):
    rng = np.random.default_rng(seed)
    n = n_neurons or int(rng.integers(2, 40))
    n_ax = int(rng.integers(1, 7))
    names = [f"n{i}" for i in range(n)]
    axons = {}
    for i in range(n_ax):
        fan = int(rng.integers(0, min(n, 8) + 1))     # 0 => empty axon
        tgt = rng.choice(n, fan, replace=False)
        axons[f"a{i}"] = [(names[j], int(rng.integers(-50, 50)) or 1)
                          for j in tgt]
    neurons = {}
    for k in names:
        if rng.random() < zero_fanout_frac:
            fan = []                                   # A.3 filler segment
        else:
            tgt = rng.choice(n, int(rng.integers(1, min(n, 6) + 1)),
                             replace=False)
            fan = [(names[j], int(rng.integers(-50, 50)) or 1) for j in tgt]
        if rng.random() < 0.7:
            model = LIF_neuron(threshold=int(rng.integers(0, 40)),
                               nu=int(rng.choice([-32, -20, 0, 2])),
                               lam=int(rng.integers(0, 64)))
        else:
            model = ANN_neuron(threshold=int(rng.integers(0, 40)),
                               nu=int(rng.choice([-32, 1])))
        neurons[k] = (fan, model)
    outputs = [names[j] for j in
               rng.choice(n, int(rng.integers(1, min(n, 4) + 1)),
                          replace=False)]
    return axons, neurons, outputs


def make_pair(seed, **net_kw):
    axons, neurons, outputs = random_net(seed, **net_kw)
    vec = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=seed)
    ref = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=seed, vectorized=False)
    return vec, ref, list(axons)


def drive(seed, net, ax_keys, steps=15):
    rng = random.Random(seed)
    outs = []
    for _ in range(steps):
        inp = rng.sample(ax_keys, k=rng.randint(0, len(ax_keys)))
        f, p = net.step(inp, membranePotential=True)
        outs.append((f, p))
    return outs


@pytest.mark.parametrize("seed", range(8))
def test_step_parity_random_networks(seed):
    vec, ref, ax = make_pair(seed)
    assert drive(seed, vec, ax) == drive(seed, ref, ax)
    assert vec.counter.as_dict() == ref.counter.as_dict()


def test_step_parity_tiny_net_filler_out_of_range():
    """n_neurons < SLOTS: A.3 filler posts (0..15) exceed the neuron id
    range and must stay numerically inert in both paths."""
    for seed in range(4):
        vec, ref, ax = make_pair(100 + seed, n_neurons=3,
                                 zero_fanout_frac=0.8)
        assert vec._impl.n < SLOTS
        assert drive(seed, vec, ax) == drive(seed, ref, ax)
        assert vec.counter.as_dict() == ref.counter.as_dict()


def test_duplicate_axon_events_double_count():
    """An axon listed twice in a step is two events: weights applied twice
    and two pointer reads — on every path (engine vectorized/reference,
    simulator, and run() vs the step loop)."""
    lif = LIF_neuron(threshold=100, nu=-32, lam=63)
    axons = {"a": [("x", 7)]}
    neurons = {"x": ([], lif)}

    def mk(backend):
        return CRI_network(axons=axons, neurons=neurons, outputs=["x"],
                           backend=backend, seed=0)

    vec = mk("engine")
    ref = CRI_network(axons=axons, neurons=neurons, outputs=["x"],
                      backend="engine", seed=0, vectorized=False)
    sim = mk("simulator")
    for net in (vec, ref, sim):
        net.step(["a", "a"])
        assert net.read_membrane("x") == [14]
    assert vec.counter.as_dict() == ref.counter.as_dict()
    assert vec.counter.pointer_reads == 2
    for backend in ("engine", "simulator"):
        net = mk(backend)
        net.run([["a", "a"]])
        assert net.read_membrane("x") == [14]


@pytest.mark.parametrize("dense_pack", [True, False])
def test_run_matches_sequential_steps(dense_pack):
    axons, neurons, outputs = random_net(7)
    mk = lambda: CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                             backend="engine", seed=11,
                             dense_pack=dense_pack)
    a, b = mk(), mk()
    rng = random.Random(5)
    sched = [rng.sample(list(axons), k=rng.randint(0, len(axons)))
             for _ in range(25)]
    fired_run = a.run(sched)
    fired_seq = [b.step(s) for s in sched]
    assert fired_run == fired_seq
    assert a.counter.as_dict() == b.counter.as_dict()
    assert a.read_membrane(*a.neuron_keys) == b.read_membrane(*b.neuron_keys)


def test_run_batch_parity_vectorized_vs_reference():
    for seed in range(4):
        vec, ref, ax = make_pair(seed)
        rng = np.random.default_rng(seed)
        batch = rng.integers(0, 2, (3, 10, len(ax))).astype(np.int32)
        sv = vec.run_batch(batch)
        sr = ref.run_batch(batch)
        np.testing.assert_array_equal(sv, sr)
        assert vec.counter.as_dict() == ref.counter.as_dict()


def test_run_batch_parity_engine_vs_simulator():
    """Both backends derive sample streams as fold_in(key, b), so batch
    results agree bit-for-bit even with noise enabled."""
    axons, neurons, outputs = random_net(21)
    e = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                    backend="engine", seed=13)
    s = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                    backend="simulator", seed=13)
    rng = np.random.default_rng(2)
    batch = rng.integers(0, 3, (4, 12, len(axons))).astype(np.int32)
    np.testing.assert_array_equal(e.run_batch(batch), s.run_batch(batch))


def test_run_batch_leaves_sequential_state_untouched():
    vec, _, ax = make_pair(3)
    vec.step(ax[:1])
    V_before = vec.read_membrane(*vec.neuron_keys)
    rng = np.random.default_rng(0)
    vec.run_batch(rng.integers(0, 2, (2, 5, len(ax))).astype(np.int32))
    assert vec.read_membrane(*vec.neuron_keys) == V_before


def test_fused_pallas_step_parity():
    """The fused route+lif Pallas kernel (interpret mode) is bit-exact vs
    the segment_sum path."""
    for seed in (0, 5):
        axons, neurons, outputs = random_net(seed)
        fused = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                            backend="engine", seed=seed, use_pallas=True)
        plain = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                            backend="engine", seed=seed)
        assert drive(seed, fused, list(axons), steps=6) == \
            drive(seed, plain, list(axons), steps=6)
        assert fused.counter.as_dict() == plain.counter.as_dict()


def test_write_synapse_reaches_vectorized_tables():
    """Weight edits must reach every execution path — including scans that
    were already jit-compiled before the edit — on both backends."""
    lif = LIF_neuron(threshold=1000, nu=-32, lam=63)
    axons = {"a": [("x", 7)]}
    neurons = {"x": ([], lif)}
    for backend in ("engine", "simulator"):
        net = CRI_network(axons=axons, neurons=neurons, outputs=["x"],
                          backend=backend, seed=0)
        net.step(["a"])
        assert net.read_membrane("x") == [7]
        net.write_synapse("a", "x", 11)
        net.step(["a"])
        assert net.read_membrane("x") == [18]
        # compiled-scan path sees the edit too
        net.reset()
        net.run([["a"]])                  # traces the scan at weight 11
        assert net.read_membrane("x") == [11]
        net.write_synapse("a", "x", 2)
        net.reset()
        net.run([["a"]])                  # same compiled scan, new weight
        assert net.read_membrane("x") == [2]


def test_jnp_array_schedules_accepted():
    import jax.numpy as jnp
    lif = LIF_neuron(threshold=1000, nu=-32, lam=63)
    for backend in ("engine", "simulator"):
        net = CRI_network(axons={"a": [("x", 7)]}, neurons={"x": ([], lif)},
                          outputs=["x"], backend=backend, seed=0)
        net.run(jnp.ones((2, 1), jnp.int32))
        assert net.read_membrane("x") == [14]
        out = net.run_batch(jnp.ones((2, 2, 1), jnp.int32))
        assert out.shape == (2, 2, 1)


def test_hub_topology_csr_fallback_parity():
    """A hub neuron whose fan-in dwarfs the median forces the engine off
    the padded fan-in transpose onto the CSR-segment accumulate (linear
    in synapses, no scatter) — results and stats must not change."""
    from repro.kernels.route import fanin_is_economical
    n = 400
    lif = LIF_neuron(threshold=20, nu=-32, lam=5)
    names = [f"n{i}" for i in range(n)]
    neurons = {k: ([("hub", 3)], lif) for k in names}   # all feed the hub
    neurons["hub"] = ([(names[0], 1)], lif)
    axons = {"a0": [(names[i], 30) for i in range(0, n, 7)]}
    vec = CRI_network(axons=axons, neurons=neurons, outputs=["hub"],
                      backend="engine", seed=1)
    assert not vec._impl._use_fanin
    assert vec._impl._acc_mode == "csr"
    assert not fanin_is_economical(vec._impl.flat, vec._impl.n)
    ref = CRI_network(axons=axons, neurons=neurons, outputs=["hub"],
                      backend="engine", seed=1, vectorized=False)
    for _ in range(6):
        f1, p1 = vec.step(["a0"], membranePotential=True)
        f2, p2 = ref.step(["a0"], membranePotential=True)
        assert (f1, p1) == (f2, p2)
    assert vec.counter.as_dict() == ref.counter.as_dict()


def test_csr_accumulate_parity_power_law_degrees():
    """All three accumulate formulations agree bit-for-bit on a
    power-law in-degree network (the regime the CSR path exists for:
    max-in-degree padding explodes while CSR stays linear in synapses)."""
    import jax.numpy as jnp
    from repro.kernels import route as route_k
    rng = np.random.default_rng(3)
    n = 300
    lif = LIF_neuron(threshold=10, nu=-32, lam=4)
    names = [f"n{i}" for i in range(n)]
    # in-degree ~ zipf: neuron j receives ~ n/(j+1) synapses
    neurons = {}
    for i, k in enumerate(names):
        fan = rng.zipf(1.3, 4)
        tgt = np.unique(np.minimum(
            rng.zipf(1.2, int(fan.sum()) % 17 + 1) - 1, n - 1))
        neurons[k] = ([(names[j], int(rng.integers(-9, 10)) or 2)
                       for j in tgt], lif)
    axons = {"a0": [(names[j], 25) for j in range(0, n, 11)]}
    net = CRI_network(axons=axons, neurons=neurons, outputs=names[:4],
                      backend="engine", seed=6)
    tables = route_k.RouteTables.from_flat(net._impl.flat, n,
                                           build_fanin=True)
    gate = jnp.asarray(
        rng.integers(0, 3, tables.syn_post.shape[0]).astype(np.int32))
    a = np.asarray(route_k.accumulate(tables, gate, n))
    b = np.asarray(route_k.accumulate_csr(tables, gate, n))
    c = np.asarray(route_k.accumulate_scatter(tables, gate, n))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(b, c)


def test_unknown_axon_ids_dropped_on_both_backends():
    """Out-of-range axon ids are silently dropped (seed engine used
    dict.get) — engine and simulator must agree."""
    lif = LIF_neuron(threshold=100, nu=-32, lam=63)
    for backend in ("engine", "simulator"):
        net = CRI_network(axons={"a": [("x", 5)]}, neurons={"x": ([], lif)},
                          outputs=["x"], backend=backend, seed=0)
        net._impl.step([0, 7, -3])      # raw backend ids, 7/-3 unknown
        assert net.read_membrane("x") == [5]


def test_flatten_invariants():
    """FlatImage owner maps and CSR agree with the pointer dicts."""
    axons, neurons, outputs = random_net(17)
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=0)
    img, flat = net.image, net._impl.flat
    for aid, ptr in img.axon_ptr.items():
        assert flat.axon_present[aid]
        assert flat.axon_base[aid] == ptr.base_row
        assert flat.axon_rows[aid] == ptr.n_rows
        span = flat.axon_row_indices[flat.axon_row_indptr[aid]:
                                     flat.axon_row_indptr[aid + 1]]
        np.testing.assert_array_equal(
            span, np.arange(ptr.base_row, ptr.base_row + ptr.n_rows))
        assert (flat.row_owner_axon[span] == aid).all()
    for nid, ptr in img.neuron_ptr.items():
        assert flat.neuron_present[nid]
        span = flat.neuron_row_indices[flat.neuron_row_indptr[nid]:
                                       flat.neuron_row_indptr[nid + 1]]
        np.testing.assert_array_equal(
            span, np.arange(ptr.base_row, ptr.base_row + ptr.n_rows))
        assert (flat.row_owner_neuron[span] == nid).all()
    # every row has at most one owner of each kind, and owners are disjoint
    both = (flat.row_owner_axon >= 0) & (flat.row_owner_neuron >= 0)
    assert not both.any()
