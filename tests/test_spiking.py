"""Spiking CNN (DVS-gesture family, Table 2 rows 5-8): surrogate-gradient
training, int16 quantization, LIF(λ=63) conversion, engine bit-exactness,
rate decoding."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.convert import LayerSpec, quantize
from repro.core.spiking import (SpikingModel, infer_frames,
                                simulate_quantized, spiking_to_network,
                                train_spiking)
from repro.data.synthetic import event_frames


@pytest.fixture(scope="module")
def trained():
    F, y = event_frames(260, shape=(13, 13), n_classes=4, frames=5, seed=2)
    model = SpikingModel(input_shape=(2, 13, 13),
                         layers=[LayerSpec("conv", channels=3, kernel=5,
                                           stride=2),
                                 LayerSpec("dense", out_features=16)],
                         n_classes=4)
    # 3 epochs (18 Adam steps) leaves the net at chance; 10 epochs at
    # lr=5e-3 reaches 100% held-out on this synthetic task.
    params = train_spiking(model, F[:220].astype(np.float32), y[:220],
                           epochs=10, lr=5e-3)
    return F, y, model, params


def test_snn_learns(trained):
    F, y, model, params = trained
    rates = np.asarray(model.apply(params, jnp.asarray(
        F[220:].astype(np.float32))))
    assert (rates.argmax(1) == y[220:]).mean() > 0.5     # chance = 0.25


def test_engine_matches_integer_oracle(trained):
    F, y, model, params = trained
    qp, _ = quantize(params)
    ref = simulate_quantized(model, qp, F[220:226])
    net, out_keys = spiking_to_network(model, qp, backend="engine")
    for i in range(6):
        _, counts = infer_frames(net, F[220 + i], model, out_keys)
        np.testing.assert_array_equal(counts, ref[i])


def test_simulator_backend_matches_too(trained):
    F, y, model, params = trained
    qp, _ = quantize(params)
    ref = simulate_quantized(model, qp, F[226:229])
    net, out_keys = spiking_to_network(model, qp, backend="simulator")
    for i in range(3):
        _, counts = infer_frames(net, F[226 + i], model, out_keys)
        np.testing.assert_array_equal(counts, ref[i])


def test_rate_decoding_counts_bounded(trained):
    F, y, model, params = trained
    qp, _ = quantize(params)
    T = F.shape[1]
    depth = len(model.layers) + 1
    ref = simulate_quantized(model, qp, F[220:224])
    assert ref.max() <= T + depth            # a neuron spikes <= once/step
