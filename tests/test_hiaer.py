"""Hierarchical multi-core HiAER tier (core.hiaer) vs the monolithic
engine — the bit-exactness contract of this PR: output spikes, membrane
values, and AccessCounter pointer/row statistics must be
integer-identical across randomized topologies, hierarchies, and
placements, including the degenerate extremes (everything on one core;
every synapse cross-core), and the measured per-level event traffic must
equal the partitioner's static prediction times the realized fire
counts."""
import random

import numpy as np
import pytest

from repro.core.api import CRI_network, LIF_neuron
from repro.core.partition import Hierarchy, level_event_counts
from test_routing_vectorized import drive, random_net

HIERS = [
    Hierarchy(1, 1, 1, 1000),            # single core (trivial exchange)
    Hierarchy(1, 1, 4, 12),              # NoC only
    Hierarchy(1, 2, 2, 12),              # NoC + FireFly
    Hierarchy(2, 2, 2, 8),               # all three levels
]


def make_pair(seed, hier, **net_kw):
    axons, neurons, outputs = random_net(seed, **net_kw)
    eng = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=seed)
    hi = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                     backend="hiaer", seed=seed, hierarchy=hier)
    return eng, hi, list(axons)


def assert_counters_match(eng, hi):
    d1, d2 = eng.counter.as_dict(), hi.counter.as_dict()
    for k in ("pointer_reads", "row_reads", "timesteps",
              "total_accesses"):
        assert d1[k] == d2[k], k


@pytest.mark.parametrize("seed", range(6))
def test_step_parity_random_networks_and_hierarchies(seed):
    eng, hi, ax = make_pair(seed, HIERS[seed % len(HIERS)])
    assert drive(seed, eng, ax) == drive(seed, hi, ax)
    assert_counters_match(eng, hi)


def test_parity_tiny_net_filler_out_of_range():
    """n_neurons < SLOTS: A.3 filler posts exceed the neuron id range and
    must stay inert in the sharded tables too."""
    for seed in range(3):
        eng, hi, ax = make_pair(200 + seed, HIERS[3], n_neurons=3,
                                zero_fanout_frac=0.8)
        assert drive(seed, eng, ax) == drive(seed, hi, ax)
        assert_counters_match(eng, hi)


def test_degenerate_placement_all_on_one_core():
    """Everything on core 3 of an 8-core hierarchy: still bit-exact, and
    every delivery is core-local (zero cross-level traffic)."""
    axons, neurons, outputs = random_net(5)
    hier = Hierarchy(2, 2, 2, 1000)
    placement = {k: 3 for k in neurons}
    axon_placement = {k: 3 for k in axons}
    eng = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine", seed=5)
    hi = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                     backend="hiaer", seed=5, hierarchy=hier,
                     placement=placement, axon_placement=axon_placement)
    assert drive(5, eng, ax_keys := list(axons)) == drive(5, hi, ax_keys)
    assert_counters_match(eng, hi)
    assert hi.counter.cross_level_events == 0
    assert hi._impl.shards.stats()["white_entries"] == 0


def test_degenerate_placement_every_synapse_cross_core():
    """Ring topology with neighbours forced onto different servers: every
    neuron-to-neuron synapse crosses a level; zero local deliveries from
    neurons. Still bit-exact vs the monolithic engine."""
    n = 12
    lif = LIF_neuron(threshold=2, nu=-32, lam=63)
    names = [f"n{i}" for i in range(n)]
    neurons = {names[i]: ([(names[(i + 1) % n], 5)], lif)
               for i in range(n)}
    axons = {"a0": [(names[i], 9) for i in range(n)]}
    hier = Hierarchy(2, 1, 1, n)         # 2 cores on different servers
    placement = {names[i]: i % 2 for i in range(n)}
    eng = CRI_network(axons=axons, neurons=neurons, outputs=names[:3],
                      backend="engine", seed=2)
    hi = CRI_network(axons=axons, neurons=neurons, outputs=names[:3],
                     backend="hiaer", seed=2, hierarchy=hier,
                     placement=placement)
    for _ in range(8):
        f1, p1 = eng.step(["a0"], membranePotential=True)
        f2, p2 = hi.step(["a0"], membranePotential=True)
        assert (f1, p1) == (f2, p2)
    assert_counters_match(eng, hi)
    # neighbours alternate cores on different servers, so every neuron
    # delivery is an Ethernet event; the only local deliveries are the
    # broadcast axon's to its own home core (once per drive)
    ev = hi.counter.level_events
    assert ev[0] == 8 and ev[1] == 0 and ev[2] == 0
    assert ev[3] >= 8                     # axon's remote core + all spikes
    assert hi._impl.shards.stats()["white_frac"] > 0.5


def test_run_matches_sequential_steps():
    hier = Hierarchy(1, 2, 2, 12)
    a_def = random_net(9)
    mk = lambda: CRI_network(axons=a_def[0], neurons=a_def[1],
                             outputs=a_def[2], backend="hiaer", seed=4,
                             hierarchy=hier)
    a, b = mk(), mk()
    rng = random.Random(8)
    sched = [rng.sample(list(a_def[0]), k=rng.randint(0, len(a_def[0])))
             for _ in range(20)]
    fired_run = a.run(sched)
    fired_seq = [b.step(s) for s in sched]
    assert fired_run == fired_seq
    assert a.counter.as_dict() == b.counter.as_dict()
    assert a.read_membrane(*a.neuron_keys) == b.read_membrane(*b.neuron_keys)


def test_run_batch_parity_vs_engine():
    """Both engines derive sample streams as fold_in(key, b), so batched
    results agree bit-for-bit even with noise enabled."""
    for seed in range(3):
        eng, hi, ax = make_pair(seed + 40, HIERS[(seed + 1) % len(HIERS)])
        rng = np.random.default_rng(seed)
        batch = rng.integers(0, 2, (3, 10, len(ax))).astype(np.int32)
        np.testing.assert_array_equal(eng.run_batch(batch),
                                      hi.run_batch(batch))
        assert_counters_match(eng, hi)


def test_write_synapse_reaches_shard_tables():
    lif = LIF_neuron(threshold=1000, nu=-32, lam=63)
    axons = {"a": [("x", 7), ("y", 1)]}
    neurons = {"x": ([("y", 2)], lif), "y": ([], lif)}
    hier = Hierarchy(1, 1, 2, 1)
    net = CRI_network(axons=axons, neurons=neurons, outputs=["x"],
                      backend="hiaer", seed=0, hierarchy=hier,
                      placement={"x": 0, "y": 1})
    net.step(["a"])
    assert net.read_membrane("x", "y") == [7, 1]
    net.write_synapse("a", "x", 11)
    net.reset()
    net.run([["a"]])                      # compiled scan sees the edit
    assert net.read_membrane("x", "y") == [11, 1]


def test_measured_traffic_matches_partition_prediction():
    """Deterministic always-fire network: theta < 0 with noise disabled
    makes every neuron fire every step, so the counter's per-level
    events must equal partition.level_event_counts x T exactly — the
    static traffic estimate made empirical."""
    rng = np.random.default_rng(11)
    n = 24
    names = [f"n{i}" for i in range(n)]
    lif = LIF_neuron(threshold=-1, nu=-32, lam=63)   # always fires
    neurons = {}
    for i, k in enumerate(names):
        tgt = rng.choice(n, 3, replace=False)
        neurons[k] = ([(names[j], int(rng.integers(1, 5))) for j in tgt],
                      lif)
    axons = {"a0": [(names[0], 1)], "a1": [(names[5], 1), (names[9], 2)]}
    hier = Hierarchy(2, 2, 2, 4)
    net = CRI_network(axons=axons, neurons=neurons, outputs=names[:2],
                      backend="hiaer", seed=0, hierarchy=hier)
    T = 7
    net.run([[] for _ in range(T)])       # no axon drive: neuron events only
    impl = net._impl
    n_adj = {i: net._neuron_syn[i] for i in range(n)}
    nrn_assign = {i: int(impl.neuron_core[i]) for i in range(n)}
    pred = level_event_counts(n_adj, nrn_assign, nrn_assign, hier)
    assert net.counter.level_events == [T * p for p in pred]
    # axon drives add their own deliveries, also exactly predicted
    # (a1 driven twice in a step = two events to each of its dest cores)
    ax_assign = {a: int(impl.axon_core[a]) for a in range(len(axons))}
    per_axon = {k: level_event_counts(
        {net._aid[k]: [(net._nid[p], w) for p, w in axons[k]]},
        ax_assign, nrn_assign, hier) for k in axons}
    net.counter.reset()
    net.run([["a0", "a1", "a1"]])
    want = [pred[l] + per_axon["a0"][l] + 2 * per_axon["a1"][l]
            for l in range(4)]
    assert net.counter.level_events == want


def test_hierarchical_gather_reconstructs_global_order():
    from repro.kernels.exchange import HierSpec, hierarchical_gather
    spec = HierSpec(2, 2, 2)
    x = np.arange(spec.n_cores * 3).reshape(spec.n_cores, 3)
    out = np.asarray(hierarchical_gather(x, spec))
    np.testing.assert_array_equal(out, np.arange(spec.n_cores * 3))


def test_placement_validation():
    axons, neurons, outputs = random_net(1)
    hier = Hierarchy(1, 1, 2, 2)
    with pytest.raises(ValueError):      # capacity exceeded
        CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                    backend="hiaer", hierarchy=hier,
                    placement={k: 0 for k in neurons})
    with pytest.raises(ValueError):      # core id out of range
        CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                    backend="hiaer", hierarchy=Hierarchy(1, 1, 2, 1000),
                    placement={k: 7 for k in neurons})
    with pytest.raises(ValueError):      # missing neuron
        CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                    backend="hiaer", hierarchy=Hierarchy(1, 1, 2, 1000),
                    placement={list(neurons)[0]: 0})
