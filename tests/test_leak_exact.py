"""λ = 63 leak bit-exactness across the three implementations that must
agree (Table 1: λ=63 approximates an IF neuron):

  * `core.neuron.leak`      — int32 membranes, V >> 31 for λ >= 31;
  * `kernels lif_step`      — the fused Pallas membrane kernel;
  * `core.spiking._if_leak` — int64 oracle, V >> 63.

The published floor-division semantics (`V - V // 2^λ`) give a +1/step
drift for negative membranes and identity for non-negative ones; the
docstring/constant mismatch this test pins down was `_if_leak` claiming
2^63 while shifting by 62."""
import jax.numpy as jnp
import numpy as np

from repro.core.neuron import leak
from repro.core.spiking import _if_leak
from repro.kernels import ops


V32 = np.array([0, 1, -1, 2, -2, 1000, -1000, 2**30, -(2**30),
                2**31 - 1, -(2**31) + 1, 12345, -54321], np.int32)


def _floor_ref(V, lam):
    """Literal Fig. 8 semantics in unbounded Python ints."""
    return np.array([v - (v // 2**lam) for v in V.tolist()], np.int64)


def test_neuron_leak_lambda63_matches_floor_division():
    got = np.asarray(leak(jnp.asarray(V32), jnp.int32(63)))
    np.testing.assert_array_equal(got, _floor_ref(V32, 63).astype(np.int32))


def test_if_leak_matches_floor_division_int64():
    V = V32.astype(np.int64)
    np.testing.assert_array_equal(_if_leak(V), _floor_ref(V, 63))
    # also at int64 extremes the oracle may visit
    big = np.array([2**62, -(2**62), 2**62 - 1, -(2**62) + 1], np.int64)
    np.testing.assert_array_equal(_if_leak(big), _floor_ref(big, 63))


def test_if_leak_matches_neuron_leak():
    a = np.asarray(leak(jnp.asarray(V32), jnp.int32(63)), np.int64)
    b = _if_leak(V32.astype(np.int64))
    np.testing.assert_array_equal(a, b)


def test_lif_step_kernel_lambda63_matches():
    """Full kernel pass with noise disabled and huge threshold: the only
    state change is the λ=63 leak, so V_next - syn == leak(V)."""
    n = 256
    rng = np.random.default_rng(0)
    V = rng.integers(-(2**30), 2**30, n).astype(np.int32)
    syn = np.zeros(n, np.int32)
    u = rng.integers(-(2**16), 2**16, n).astype(np.int32)
    theta = np.full(n, 2**31 - 1, np.int32)      # never fires
    nu = np.full(n, -32, np.int32)               # noise disabled
    lam = np.full(n, 63, np.int32)
    is_lif = np.ones(n, bool)
    V_next, spikes = ops.lif_step(jnp.asarray(V), jnp.asarray(syn),
                                  jnp.asarray(u), jnp.asarray(theta),
                                  jnp.asarray(nu), jnp.asarray(lam),
                                  jnp.asarray(is_lif))
    assert not np.asarray(spikes).any()
    np.testing.assert_array_equal(
        np.asarray(V_next), _floor_ref(V, 63).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(V_next).astype(np.int64),
        _if_leak(V.astype(np.int64)))
