"""Per-kernel allclose vs ref.py oracles, with hypothesis shape/dtype
sweeps (interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref


# ------------------------------------------------------------ spike matmul
@settings(max_examples=12, deadline=None)
@given(st.integers(1, 5), st.integers(1, 4), st.integers(0, 100),
       st.floats(0.0, 0.5))
def test_spike_matmul_sweep(npre_blocks, npost_blocks, seed, density):
    key = jax.random.PRNGKey(seed)
    npre, npost = npre_blocks * 128, npost_blocks * 128
    spikes = jax.random.bernoulli(key, density, (npre,))
    w = jax.random.randint(jax.random.fold_in(key, 1), (npre, npost),
                           -32768, 32767, jnp.int16)
    got = ops.spike_matmul(spikes, w)
    want = ref.spike_matmul_ref(spikes, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_spike_matmul_unaligned_padding():
    key = jax.random.PRNGKey(7)
    spikes = jax.random.bernoulli(key, 0.2, (300,))
    w = jax.random.randint(key, (300, 77), -100, 100, jnp.int16)
    got = ops.spike_matmul(spikes, w)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.spike_matmul_ref(spikes, w)))


def test_spike_matmul_all_silent_is_zero():
    w = jnp.ones((256, 128), jnp.int16)
    out = ops.spike_matmul(jnp.zeros((256,), bool), w)
    assert int(jnp.abs(out).max()) == 0


# ---------------------------------------------------------------- lif step
@settings(max_examples=12, deadline=None)
@given(st.integers(1, 4), st.integers(0, 1000))
def test_lif_step_sweep(blocks, seed):
    key = jax.random.PRNGKey(seed)
    n = blocks * 256
    ks = [jax.random.fold_in(key, i) for i in range(7)]
    V = jax.random.randint(ks[0], (n,), -(2**20), 2**20, jnp.int32)
    syn = jax.random.randint(ks[1], (n,), -5000, 5000, jnp.int32)
    u = jax.random.randint(ks[2], (n,), -(2**16), 2**16, jnp.int32)
    theta = jax.random.randint(ks[3], (n,), 0, 2**16, jnp.int32)
    nu = jax.random.randint(ks[4], (n,), -32, 32, jnp.int32)
    lam = jax.random.randint(ks[5], (n,), 0, 64, jnp.int32)
    is_lif = jax.random.bernoulli(ks[6], 0.5, (n,))
    V2, s2 = ops.lif_step(V, syn, u, theta, nu, lam, is_lif)
    Vr, sr = ref.lif_step_ref(V, syn, u, theta, nu, lam, is_lif)
    np.testing.assert_array_equal(np.asarray(V2), np.asarray(Vr))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(sr))


def test_lif_step_unaligned():
    n = 100
    V = jnp.arange(n, dtype=jnp.int32) * 37 - 1000
    syn = jnp.ones((n,), jnp.int32)
    u = jnp.zeros((n,), jnp.int32)
    theta = jnp.full((n,), 500, jnp.int32)
    nu = jnp.full((n,), -32, jnp.int32)
    lam = jnp.full((n,), 2, jnp.int32)
    is_lif = jnp.ones((n,), bool)
    V2, s2 = ops.lif_step(V, syn, u, theta, nu, lam, is_lif)
    Vr, sr = ref.lif_step_ref(V, syn, u, theta, nu, lam, is_lif)
    np.testing.assert_array_equal(np.asarray(V2), np.asarray(Vr))


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 1, 128, 64), (2, 3, 256, 64),
                                   (1, 2, 512, 128)])
def test_flash_attention_shapes_dtypes(shape, dtype):
    key = jax.random.PRNGKey(0)
    B, H, S, D = shape
    q = jax.random.normal(key, shape, jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape,
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape,
                          jnp.float32).astype(dtype)
    got = ops.flash_attention(q, k, v, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol, err


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 2), st.integers(1, 3), st.sampled_from([128, 256]),
       st.sampled_from([32, 64]), st.integers(0, 50))
def test_flash_attention_sweep(B, H, S, D, seed):
    key = jax.random.PRNGKey(seed)
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i),
                                     (B, H, S, D), jnp.float32)
    q, k, v = mk(0), mk(1), mk(2)
    got = ops.flash_attention(q, k, v, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v)
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


def test_flash_attention_causality():
    """Perturbing a future key must not change earlier outputs."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 1, 128, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 128, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 128, 32))
    o1 = ops.flash_attention(q, k, v, bq=64, bk=64)
    k2 = k.at[:, :, 100:].add(7.0)
    o2 = ops.flash_attention(q, k2, v, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(o1[:, :, :100]),
                               np.asarray(o2[:, :, :100]), atol=1e-6)


# ----------------------------------------------- flash attention backward
@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.sampled_from([128, 256]),
       st.sampled_from([32, 64]), st.integers(0, 30))
def test_flash_attention_trainable_grads(H, S, D, seed):
    """Pallas fwd+bwd kernels match jax.grad of the pure-jnp oracle."""
    from repro.kernels.flash_attention import flash_attention_trainable
    key = jax.random.PRNGKey(seed)
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i),
                                     (1, H, S, D))
    q, k, v = mk(0), mk(1), mk(2)

    def loss_kernel(q, k, v):
        return jnp.sum(jnp.tanh(
            flash_attention_trainable(q, k, v, True, 64, 64, True)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.tanh(ref.flash_attention_ref(q, k, v)))

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4
