"""Batched STDP over the staged write_synapses path (PR 3).

Pins (a) exact equivalence of the batched update engine with the
legacy sequential read_synapse/write_synapse loop, (b) bit-for-bit
STDP-training parity between the engine and hiaer backends (spikes,
weights, traces), and (c) that each STDP phase lands as one batched
upload rather than one per synapse.
"""
import numpy as np

from repro.core.api import CRI_network, LIF_neuron
from repro.core.learning import STDP, STDPConfig


def random_net(seed, n=14, n_axons=3, fanout=3):
    rng = np.random.default_rng(seed)
    names = [f"n{i}" for i in range(n)]
    lif = LIF_neuron(threshold=4, nu=-32, lam=63)
    neurons = {k: ([(names[j], int(rng.integers(1, 6)))
                    for j in rng.choice(n, fanout, replace=False)], lif)
               for k in names}
    axons = {f"a{i}": [(names[j], int(rng.integers(1, 6)))
                       for j in rng.choice(n, 2, replace=False)]
             for i in range(n_axons)}
    return axons, neurons, names


class SequentialSTDP:
    """The seed-era per-synapse loop (scalar read/write_synapse, dict
    traces) — the oracle the batched engine must match exactly."""

    def __init__(self, net, cfg):
        self.net, self.cfg = net, cfg
        self.pre_trace = {k: 0 for k in
                          list(net.axon_keys) + list(net.neuron_keys)}
        self.post_trace = {k: 0 for k in net.neuron_keys}
        ids = {i: k for k, i in net._nid.items()}
        self.adj = {}
        for k in net.axon_keys:
            self.adj[k] = [ids[p] for p, _ in
                           net._axon_syn[net._aid[k]]]
        for k in net.neuron_keys:
            if k not in self.adj:
                self.adj[k] = [ids[p] for p, _ in
                               net._neuron_syn[net._nid[k]]]

    def step(self, inputs, fired_keys):
        cfg = self.cfg
        for d in (self.pre_trace, self.post_trace):
            for k in d:
                d[k] -= d[k] >> cfg.tau_shift
        fired = list(dict.fromkeys(fired_keys))
        pres = list(inputs) + fired
        for pre in pres:
            for post in self.adj.get(pre, ()):
                yt = self.post_trace.get(post, 0)
                if yt:
                    w = self.net.read_synapse(pre, post)
                    w2 = int(np.clip(w - cfg.a_minus * yt,
                                     cfg.w_min, cfg.w_max))
                    if w2 != w:
                        self.net.write_synapse(pre, post, w2)
        for pre, posts in self.adj.items():
            xt = self.pre_trace.get(pre, 0)
            if not xt:
                continue
            for post in posts:
                if post in fired:
                    w = self.net.read_synapse(pre, post)
                    w2 = int(np.clip(w + cfg.a_plus * xt,
                                     cfg.w_min, cfg.w_max))
                    if w2 != w:
                        self.net.write_synapse(pre, post, w2)
        for pre in pres:
            self.pre_trace[pre] = self.pre_trace.get(pre, 0) + 1
        for post in fired:
            self.post_trace[post] = self.post_trace.get(post, 0) + 1


def drive(seed, T=14):
    rng = np.random.default_rng(seed)
    return [[f"a{i}" for i in rng.choice(3, int(rng.integers(0, 3)),
                                         replace=False)]
            for _ in range(T)]


def test_batched_stdp_matches_sequential_loop():
    axons, neurons, names = random_net(0)
    cfg = STDPConfig(a_plus=4, a_minus=3, tau_shift=1, w_min=-20,
                     w_max=20)                    # tight clip on purpose
    net_b = CRI_network(axons=axons, neurons=neurons, outputs=names,
                        backend="simulator", seed=5)
    net_s = CRI_network(axons=axons, neurons=neurons, outputs=names,
                        backend="simulator", seed=5)
    batched, seq = STDP(net_b, cfg), SequentialSTDP(net_s, cfg)
    for inp in drive(1):
        f_b = net_b.step(inp + inp)               # doubled axon events
        f_s = net_s.step(inp + inp)
        assert f_b == f_s
        batched.step(inp + inp, f_b)
        seq.step(inp + inp, f_s)
        np.testing.assert_array_equal(net_b.compiled.syn_weight,
                                      net_s.compiled.syn_weight)
    base = net_b.compiled.item_base
    for k in net_b.axon_keys:
        assert batched.pre_trace[net_b._aid[k]] == seq.pre_trace[k]
    for k in names:
        assert batched.pre_trace[base + net_b._nid[k]] \
            == seq.pre_trace[k]
        assert batched.post_trace[net_b._nid[k]] == seq.post_trace[k]


def test_stdp_hiaer_matches_engine_bit_for_bit():
    from repro.core.partition import Hierarchy
    axons, neurons, names = random_net(3)
    cfg = STDPConfig(a_plus=5, a_minus=2, tau_shift=2)

    def train(backend, **kw):
        net = CRI_network(axons=axons, neurons=neurons, outputs=names,
                          backend=backend, seed=11, **kw)
        stdp = STDP(net, cfg)
        spikes = []
        for inp in drive(9):
            fired = net.step(inp)
            stdp.step(inp, fired)
            spikes.append(tuple(fired))
        return net, stdp, spikes

    eng, stdp_e, spk_e = train("engine")
    hi, stdp_h, spk_h = train("hiaer",
                              hierarchy=Hierarchy(1, 2, 2, 5))
    assert spk_e == spk_h                                  # spikes
    np.testing.assert_array_equal(eng.compiled.syn_weight,
                                  hi.compiled.syn_weight)  # weights
    np.testing.assert_array_equal(stdp_e.pre_trace, stdp_h.pre_trace)
    np.testing.assert_array_equal(stdp_e.post_trace, stdp_h.post_trace)
    assert eng.read_membrane(*names) == hi.read_membrane(*names)
    # training actually changed something
    fresh = CRI_network(axons=axons, neurons=neurons, outputs=names,
                        backend="engine", seed=11)
    assert (eng.compiled.syn_weight
            != fresh.compiled.syn_weight).any()


def test_stdp_batches_uploads_per_phase():
    """Each STDP step applies at most 2 batched uploads (depression +
    potentiation), never one per synapse."""
    axons, neurons, names = random_net(6)
    net = CRI_network(axons=axons, neurons=neurons, outputs=names,
                      backend="hiaer", seed=2)
    stdp = STDP(net, STDPConfig(a_plus=4, a_minus=3, tau_shift=1))
    for inp in drive(2, T=10):
        before = net._dep.weight_uploads
        fired = net.step(inp)
        stdp.step(inp, fired)
        assert net._dep.weight_uploads - before <= 2
    assert net._dep.weight_uploads > 0    # learning did happen, batched
