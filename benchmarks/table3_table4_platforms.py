"""Tables 3 & 4: cross-platform comparison (MNIST / DVS-Gesture) — our
engine's energy/latency from the calibrated HBM cost model next to the
paper's published numbers for HiAER-Spike, Loihi, SpiNNaker(2), TrueNorth.
"""
from __future__ import annotations

from benchmarks.table2_vision import run as run_table2

TABLE3 = [  # system, neurons, acc %, energy uJ, latency us  (published)
    ("HiAER-Spike (paper)", 138, 96.59, 1.1, 4.2),
    ("HiAER-Spike (paper)", 5814, 98.14, 17.1, 48.6),
    ("Loihi", 5400, 99.23, 182.46, 4900.0),
    ("SpiNNaker", 1790, 95.01, None, 20000.0),
    ("TrueNorth", 7680, 99.42, 108.0, None),
]

TABLE4 = [
    ("HiAER-Spike (paper)", 1115, 54.51, 79.8, 184.9),
    ("HiAER-Spike (paper)", 17709, 68.75, 510.7, 1156.2),
    ("Loihi", None, 89.64, None, 11430.0),
    ("SpiNNaker2", 9907, 94.13, 459000.0, None),
    ("TrueNorth", None, 96.49, 18700.0, 104600.0),
]


def run(quiet=False, table2_rows=None):
    rows = table2_rows if table2_rows is not None else run_table2(quiet=True)
    ours = rows[0]
    out = [("HiAER-Spike (this repro, synthetic)", ours["neurons"],
            ours["hw_acc"], ours["energy_uJ"], ours["latency_us"])]
    if not quiet:
        print("table3,system,neurons,acc,energy_uJ,latency_us")
        for sys_, n, a, e, l in out + TABLE3:
            print(f"table3,{sys_},{n},{a},{e},{l}")
        print("table4,system,neurons,acc,energy_uJ,latency_us")
        for sys_, n, a, e, l in TABLE4:
            print(f"table4,{sys_},{n},{a},{e},{l}")
    # the reproduction claim: our per-inference energy & latency sit in the
    # HiAER-Spike band (orders of magnitude under Loihi/SpiNNaker columns)
    assert out[0][3] < 100.0 and out[0][4] < 1000.0
    return out + TABLE3


if __name__ == "__main__":
    run()
