"""Fig. 8 companion benchmark: throughput of the pure-software simulator vs
the event-driven engine emulation ("We use this emulation as a further
benchmarking tool to compare the throughput of the FPGA implementation to a
pure software implementation running on the CPU") + the Pallas spike-SpMV
kernel (interpret mode) correctness/throughput datapoint.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.api import ANN_neuron, LIF_neuron, CRI_network


def _random_net(n_neurons=512, n_axons=64, fanout=16, seed=0):
    rng = np.random.default_rng(seed)
    names = [f"n{i}" for i in range(n_neurons)]
    axons = {f"a{i}": [(names[j], int(rng.integers(1, 20)))
                       for j in rng.choice(n_neurons, fanout, replace=False)]
             for i in range(n_axons)}
    neurons = {k: ([(names[j], int(rng.integers(-10, 20)))
                    for j in rng.choice(n_neurons, fanout, replace=False)],
                   LIF_neuron(threshold=60, lam=3))
               for k in names}
    return axons, neurons, names[:8]


def run(steps=50, quiet=False):
    axons, neurons, outputs = _random_net()
    rng = np.random.default_rng(1)
    seq = [[f"a{i}" for i in rng.choice(64, 8, replace=False)]
           for _ in range(steps)]
    rows = []
    for backend in ("simulator", "engine"):
        net = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                          backend=backend, seed=2)
        net.step(seq[0])                       # warm up jit
        t0 = time.time()
        for inp in seq:
            net.step(inp)
        dt = time.time() - t0
        rows.append((backend, 1e6 * dt / steps))
        if not quiet:
            print(f"sim_throughput,{backend},{1e6 * dt / steps:.1f}")
    return rows


if __name__ == "__main__":
    run()
