"""Fig. 8 companion benchmark, extended for the vectorized routing PR:
throughput of (a) the pure-software dense simulator, (b) the seed
per-pointer Python routing loop ("before"), and (c) the vectorized
jit/scan engine paths ("after") — per-step dispatch, whole-run lax.scan,
and the B-samples-per-dispatch batched path.

Events/sec counts synaptic events = HBM row reads × 16 slot lanes, the
quantity the paper's "faster than real time" claim is about. Results are
also written to BENCH_routing.json (CI artifact) with the before/after
ratio; the PR's acceptance bar is >= 10x on the batched path.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.api import CRI_network, LIF_neuron
from repro.core.hbm import SLOTS


def _random_net(n_neurons=512, n_axons=64, fanout=16, seed=0):
    rng = np.random.default_rng(seed)
    names = [f"n{i}" for i in range(n_neurons)]
    axons = {f"a{i}": [(names[j], int(rng.integers(1, 20)))
                       for j in rng.choice(n_neurons, fanout, replace=False)]
             for i in range(n_axons)}
    neurons = {k: ([(names[j], int(rng.integers(-10, 20)))
                    for j in rng.choice(n_neurons, fanout, replace=False)],
                   LIF_neuron(threshold=60, lam=3))
               for k in names}
    return axons, neurons, names[:8]


def _events_per_sec(counter, dt):
    return counter.row_reads * SLOTS / max(dt, 1e-9)


def run(steps=200, batch=32, quiet=False, out_json="BENCH_routing.json",
        min_speedup=0.0):
    """min_speedup > 0 turns the batched-path before/after ratio into a
    hard gate (SystemExit) — CI uses a conservative 5x so a routing
    regression fails the build without making loaded runners flaky; the
    PR acceptance measurement on an idle machine is >= 10x."""
    axons, neurons, outputs = _random_net()
    n_axons = len(axons)
    rng = np.random.default_rng(1)
    sched = np.zeros((steps, n_axons), np.int32)
    for t in range(steps):
        sched[t, rng.choice(n_axons, 8, replace=False)] = 1
    seq = [[f"a{i}" for i in np.nonzero(sched[t])[0]] for t in range(steps)]

    results = {}

    def mknet(**kw):
        return CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                           backend="engine", seed=2, **kw)

    # --- before: seed per-pointer host loop
    net = mknet(vectorized=False)
    net.step(seq[0])
    net.reset(); net.counter.reset()
    t0 = time.time()
    for inp in seq:
        net.step(inp)
    dt = time.time() - t0
    results["engine_reference_loop"] = {
        "us_per_step": 1e6 * dt / steps,
        "events_per_sec": _events_per_sec(net.counter, dt)}

    # --- dense simulator, per-step dispatch (legacy datapoint)
    sim = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="simulator", seed=2)
    sim.step(seq[0])
    sim.reset()
    t0 = time.time()
    for inp in seq:
        sim.step(inp)
    dt = time.time() - t0
    results["simulator_per_step"] = {"us_per_step": 1e6 * dt / steps}

    # --- after: vectorized engine, per-step jit dispatch
    net = mknet()
    net.step(seq[0])
    net.reset(); net.counter.reset()
    t0 = time.time()
    for inp in seq:
        net.step(inp)
    dt = time.time() - t0
    results["engine_vectorized_step"] = {
        "us_per_step": 1e6 * dt / steps,
        "events_per_sec": _events_per_sec(net.counter, dt)}

    # --- after: whole-run lax.scan (one dispatch for all T steps)
    net = mknet()
    net.run(sched)                         # compile at the timed T
    net.reset(); net.counter.reset()
    t0 = time.time()
    net.run(sched)
    dt = time.time() - t0
    results["engine_vectorized_run"] = {
        "us_per_step": 1e6 * dt / steps,
        "events_per_sec": _events_per_sec(net.counter, dt)}

    # --- after: batched path, B samples per dispatch
    bsched = np.broadcast_to(sched, (batch, steps, n_axons)).copy()
    net = mknet()
    net.run_batch(bsched)                  # compile at the timed shape
    net.counter.reset()
    t0 = time.time()
    net.run_batch(bsched)
    dt = time.time() - t0
    results["engine_vectorized_run_batch"] = {
        "batch": batch,
        "us_per_step": 1e6 * dt / (steps * batch),
        "events_per_sec": _events_per_sec(net.counter, dt)}

    before = results["engine_reference_loop"]["events_per_sec"]
    for key in ("engine_vectorized_run", "engine_vectorized_run_batch"):
        results[key]["speedup_vs_reference"] = \
            results[key]["events_per_sec"] / max(before, 1e-9)

    if not quiet:
        for name, r in results.items():
            ev = r.get("events_per_sec")
            print(f"sim_throughput,{name},{r['us_per_step']:.1f}us/step"
                  + (f",{ev:.3e} ev/s" if ev else "")
                  + (f",{r['speedup_vs_reference']:.1f}x"
                     if "speedup_vs_reference" in r else ""))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=2)
    got = results["engine_vectorized_run_batch"]["speedup_vs_reference"]
    if min_speedup and got < min_speedup:
        raise SystemExit(
            f"routing regression: batched path {got:.1f}x < required "
            f"{min_speedup:.1f}x vs the seed per-pointer loop")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="fail (exit 1) if the batched path's events/sec "
                         "speedup vs the reference loop is below this")
    run(min_speedup=ap.parse_args().min_speedup)
