"""Table 2 reproduction: accuracy / HBM energy / latency per inference for
MLP, LeNet-5-style, and DVS-gesture-style spiking CNN variants.

Datasets are the synthetic stand-ins (DESIGN.md §7); the *protocol* is the
paper's: QAT -> int16 quantize -> convert (A.2) -> event-driven engine ->
argmax membrane potential (MLP/LeNet, 1 frame) or spike-rate over 10 frames
(DVS CNN); energy = accesses x E_access, latency from the access pipeline.
Software Acc == HiAER Acc is asserted (the paper's exact-match column).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.convert import (LayerSpec, QATModel, apply_quantized,
                                infer_image, quantize, to_network, train_qat)
from repro.data.synthetic import digits

PAPER_ROWS = [
    # name, axons, neurons, weights, sw_acc, hw_acc, energy_uJ, latency_us
    ("MLP 128->10 (paper)", 784, 138, 101_632, 96.59, 96.59, 1.1, 4.2),
    ("MLP 2k->1k->10 (paper)", 784, 3_010, 3_578_000, 97.66, 97.66, 19.3,
     45.5),
    ("LeNet-5 s2 (paper)", 784, 1_334, 44_190, 97.76, 97.76, 6.4, 18.9),
    ("SpikingCNN 63x63 (paper)", 7_938, 1_115, 119_054, 55.47, 54.51, 79.8,
     184.9),
]

VARIANTS = [
    ("MLP 64->10", dict(shape=(28, 28),
                        layers=[LayerSpec("dense", out_features=64)])),
    ("MLP 128->64->10", dict(shape=(28, 28),
                             layers=[LayerSpec("dense", out_features=128),
                                     LayerSpec("dense", out_features=64)])),
    ("LeNet C6-C16-FC (s2)", dict(shape=(28, 28),
                                  layers=[LayerSpec("conv", channels=6,
                                                    kernel=5, stride=2),
                                          LayerSpec("conv", channels=16,
                                                    kernel=5, stride=2),
                                          LayerSpec("dense",
                                                    out_features=32)])),
]


def _dvs_row(n_train=220, n_test=30, epochs=3):
    """The Table 2 spiking-CNN (DVS Gesture) row: LIF/IF neurons, 10-frame
    rate decoding (reduced spatial size for CPU wall-clock)."""
    from repro.core.spiking import (SpikingModel, infer_frames,
                                    simulate_quantized, spiking_to_network,
                                    train_spiking)
    from repro.data.synthetic import event_frames
    F, y = event_frames(n_train + n_test, shape=(15, 15), n_classes=5,
                        frames=10, seed=7)
    model = SpikingModel(input_shape=(2, 15, 15),
                         layers=[LayerSpec("conv", channels=4, kernel=5,
                                           stride=2),
                                 LayerSpec("dense", out_features=24)],
                         n_classes=5)
    params = train_spiking(model, F[:n_train].astype(np.float32),
                           y[:n_train], epochs=epochs)
    qp, _ = quantize(params)
    ref = simulate_quantized(model, qp, F[n_train:])
    sw_acc = float((ref.argmax(1) == y[n_train:]).mean())
    net, out_keys = spiking_to_network(model, qp, backend="engine")
    net.counter.reset()
    hw_correct, exact = 0, True
    for i in range(n_test):
        pred, counts = infer_frames(net, F[n_train + i], model, out_keys)
        hw_correct += pred == y[n_train + i]
        exact &= bool(np.array_equal(counts, ref[i]))
    c = net.counter.as_dict()
    assert exact, "spiking CNN: engine != integer oracle"
    return {
        "name": "SpikingCNN 2x15x15 (DVS-style, 10 frames)",
        "axons": len(net.axon_keys), "neurons": len(net.neuron_keys),
        "weights": sum(len(v) for v in net._axon_syn.values())
        + sum(len(v) for v in net._neuron_syn.values()),
        "sw_acc": 100 * sw_acc, "hw_acc": 100 * hw_correct / n_test,
        "exact": exact, "energy_uJ": c["energy_uJ"] / n_test,
        "latency_us": c["latency_us"] / n_test, "wall_s": 0.0,
    }


def _pong_row():
    """Table 2 row 4's protocol (DQN -> convert -> engine, mean score over
    50 episodes) on the DVS catch stand-in; 'accuracy' columns carry the
    mean score (max +1.0, random ~-0.8) — paper: 20.74 ANN / 20.36 SNN of
    max 21 on Atari Pong."""
    from repro.core.rl import (CatchEnv, engine_policy, evaluate,
                               software_policy, train_dqn)
    model, params = train_dqn(CatchEnv(W=5, H=7), episodes=800, seed=3)
    qp, _ = quantize(params)
    sw = evaluate(CatchEnv(W=5, H=7), software_policy(model, qp),
                  episodes=50)
    net, out_keys = to_network_rl(model, qp)
    net.counter.reset()
    hw = evaluate(CatchEnv(W=5, H=7), engine_policy(net, out_keys, model),
                  episodes=50)
    c = net.counter.as_dict()
    n_dec = max(c["timesteps"] // 2, 1)
    assert hw == sw
    return {"name": "DQN DVS-catch (score of +1)", "axons": len(net.axon_keys),
            "neurons": len(net.neuron_keys), "weights": 0,
            "sw_acc": sw, "hw_acc": hw, "exact": True,
            "energy_uJ": c["energy_uJ"] / n_dec,
            "latency_us": c["latency_us"] / n_dec, "wall_s": 0.0}


def to_network_rl(model, qp):
    from repro.core.convert import to_network
    return to_network(model, qp, backend="engine")


def run(n_train=1200, n_test=60, epochs=4, quiet=False):
    rows = []
    for name, spec in VARIANTS:
        t0 = time.time()
        X, y = digits(n_train + n_test, shape=spec["shape"], seed=11)
        Xf = X.reshape(-1, 1, *spec["shape"]).astype(np.float32)
        model = QATModel(input_shape=(1, *spec["shape"]),
                         layers=spec["layers"], n_classes=10)
        params = train_qat(model, Xf[:n_train], y[:n_train], epochs=epochs)
        qp, _ = quantize(params)
        ref = apply_quantized(model, qp, Xf[n_train:].astype(np.int64))
        sw_acc = float((ref.argmax(1) == y[n_train:]).mean())
        net, out_keys = to_network(model, qp, backend="engine")
        net.counter.reset()
        hw_correct = 0
        exact = True
        for i in range(n_test):
            pred, pots = infer_image(net, X[n_train + i], model, out_keys)
            hw_correct += pred == y[n_train + i]
            exact &= bool(np.array_equal(np.asarray(pots), ref[i]))
        c = net.counter.as_dict()
        n_neurons = len(net.neuron_keys)
        n_weights = sum(len(v) for v in net._axon_syn.values()) + \
            sum(len(v) for v in net._neuron_syn.values())
        rows.append({
            "name": name, "axons": len(net.axon_keys),
            "neurons": n_neurons, "weights": n_weights,
            "sw_acc": 100 * sw_acc, "hw_acc": 100 * hw_correct / n_test,
            "exact": exact,
            "energy_uJ": c["energy_uJ"] / n_test,
            "latency_us": c["latency_us"] / n_test,
            "wall_s": time.time() - t0,
        })
        assert exact, f"{name}: HiAER != software reference"
    rows.append(_dvs_row())
    rows.append(_pong_row())
    if not quiet:
        print("table2,name,axons,neurons,weights,sw_acc,hiaer_acc,"
              "energy_uJ,latency_us,exact")
        for r in rows:
            print(f"table2,{r['name']},{r['axons']},{r['neurons']},"
                  f"{r['weights']},{r['sw_acc']:.2f},{r['hw_acc']:.2f},"
                  f"{r['energy_uJ']:.2f},{r['latency_us']:.2f},{r['exact']}")
        for p in PAPER_ROWS:
            print(f"table2,{p[0]},{p[1]},{p[2]},{p[3]},{p[4]:.2f},"
                  f"{p[5]:.2f},{p[6]:.2f},{p[7]:.2f},published")
    return rows


if __name__ == "__main__":
    run()
