import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
"""Device-mesh tier benchmark — events/sec, PEAK PER-DEVICE shard
memory, EXCHANGE BYTES, and batched-vs-sequential throughput of
`backend="mesh"` vs 1/2/4/8 forced host devices, on the clustered
topology of benchmarks/hiaer_scaling.py.

Three structural claims, each gated so CI catches a regression
(violations exit nonzero):

  * per-device synapse-shard memory SHRINKS with the device count
    (each device stores only its own cores' ragged entries) — strictly
    below the monolithic dense `w_ext` weight image at 4+ devices;
  * the bit-packed wire format moves >= 16x fewer exchange bytes than
    the unpacked int32 event lanes — both the per-level collective
    bytes (`exchange_bytes_per_step`, device counts with real hops)
    and the replicated per-device event-vector floor
    (`event_vector_bytes`, every device count);
  * the batched sharded `run_batch` (samples folded into the
    shard_mapped state, one collective per level per step for the
    whole batch) delivers >= 2x the events/sec of the sequential
    per-sample path at B=8.

The XLA_FLAGS line above MUST precede every jax-touching import (jax
pins the device count at first backend init) — the launch/dryrun.py
pattern. Results go to BENCH_mesh.json (CI artifact).
"""
import json
import time

import numpy as np

from benchmarks.hiaer_scaling import clustered_net
from repro.analysis import no_retrace
from repro.core.api import CRI_network
from repro.core.costmodel import LEVEL_NAMES
from repro.core.hbm import SLOTS
from repro.core.partition import Hierarchy


def _run_point(axons, neurons, outputs, hier, n_devices, sched, steps):
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="mesh", seed=2, hierarchy=hier,
                      n_devices=n_devices)
    net.run(sched)                        # compile at the timed shape
    net.reset(); net.counter.reset()
    t0 = time.time()
    with no_retrace(net._impl):           # timed run must replay, not
        net.run(sched)                    # re-trace (RetraceError = gate)
    dt = time.time() - t0
    c = net.counter
    impl = net._impl
    dense_slots = net.compiled.image.syn_post.size + 1
    point = {
        "n_devices": impl.n_devices,
        "us_per_step": 1e6 * dt / steps,
        "events_per_sec": c.row_reads * SLOTS / max(dt, 1e-9),
        "cross_level_events": c.cross_level_events,
        "peak_device_shard_bytes": max(impl.device_shard_bytes()),
        "total_shard_entries": impl.shards.n_entries,
        "monolithic_w_ext_bytes": dense_slots * 4,
        "collective_stages": len(impl._stages),
        # wire accounting: per-level collective bytes one device
        # receives per exchange round, packed vs unpacked, plus the
        # replicated per-device event-vector floor
        "exchange_bytes_per_step_packed":
            impl.exchange_bytes_per_step(packed=True),
        "exchange_bytes_per_step_unpacked":
            impl.exchange_bytes_per_step(packed=False),
        "event_vector_bytes_packed": impl.event_vector_bytes(packed=True),
        "event_vector_bytes_unpacked":
            impl.event_vector_bytes(packed=False),
    }
    for k, v in zip(LEVEL_NAMES, c.level_events):
        point[f"events_{k}"] = v
    return point


def _batch_point(axons, neurons, outputs, hier, n_devices, counts):
    """Batched sharded run_batch vs the sequential per-sample path
    (B separate run() dispatches), same compiled network, events/sec
    from each window's own measured row reads."""
    B = counts.shape[0]
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="mesh", seed=3, hierarchy=hier,
                      n_devices=n_devices)
    net.run_batch(counts)                 # compile the batched stream
    net.counter.reset()
    t0 = time.time()
    with no_retrace(net._impl):           # fixed (topology, B, T): the
        net.run_batch(counts)             # timed call must hit the cache
    dt_b = time.time() - t0
    ev_b = net.counter.row_reads * SLOTS / max(dt_b, 1e-9)

    net.reset(); net.run(counts[0])       # compile the per-sample scan
    net.counter.reset()
    t0 = time.time()
    with no_retrace(net._impl):           # every sample shares one trace
        for b in range(B):
            net.reset()
            net.run(counts[b])
    dt_s = time.time() - t0
    ev_s = net.counter.row_reads * SLOTS / max(dt_s, 1e-9)
    return {
        "batch_size": int(B),
        "batched_events_per_sec": ev_b,
        "sequential_events_per_sec": ev_s,
        "batched_speedup": ev_b / max(ev_s, 1e-9),
    }


def run(n_clusters=16, size=64, steps=60, device_counts=(1, 2, 4, 8),
        quiet=False, out_json="BENCH_mesh.json"):
    axons, neurons, outputs = clustered_net(n_clusters, size)
    n = len(neurons)
    hier = Hierarchy(2, 2, 2, -(-n // 8))          # 8 cores, all levels
    rng = np.random.default_rng(1)
    ax_keys = list(axons)
    sched = [[k for k in rng.choice(ax_keys, 3, replace=False)]
             for _ in range(steps)]

    results = {"n_neurons": n, "n_clusters": n_clusters, "steps": steps,
               "hierarchy": [hier.n_servers, hier.fpgas_per_server,
                             hier.cores_per_fpga], "by_devices": {}}
    failures = []
    for D in device_counts:
        point = _run_point(axons, neurons, outputs, hier, D, sched,
                           steps)
        # the memory gate: per-device shard strictly below the retired
        # monolithic dense weight image once the mesh is 4+ wide
        if D >= 4:
            ok = point["peak_device_shard_bytes"] < \
                point["monolithic_w_ext_bytes"]
            point["below_monolith"] = ok
            if not ok:
                failures.append(f"shard-bytes@{D}")
        # the wire gate: packed exchange <= 1/16 of the unpacked bytes,
        # on the replicated event-vector floor everywhere and on the
        # collective wire wherever a real hop exists
        ok = point["event_vector_bytes_packed"] * 16 \
            <= point["event_vector_bytes_unpacked"]
        if point["collective_stages"]:
            ok = ok and point["exchange_bytes_per_step_packed"] * 16 \
                <= point["exchange_bytes_per_step_unpacked"]
        point["packed_16x"] = ok
        if not ok:
            failures.append(f"packed-bytes@{D}")
        results["by_devices"][str(D)] = point
        if not quiet:
            print(f"mesh_bench,devices={D},"
                  f"ev={point['events_per_sec']:.3e}/s,"
                  f"peak_dev_bytes={point['peak_device_shard_bytes']},"
                  f"monolith={point['monolithic_w_ext_bytes']},"
                  f"xchg_packed={point['exchange_bytes_per_step_packed']},"
                  f"xchg_unpacked="
                  f"{point['exchange_bytes_per_step_unpacked']}")

    # batched vs sequential run_batch at the widest mesh, B=8
    D = max(device_counts)
    rngb = np.random.default_rng(5)
    counts = rngb.integers(0, 2, (8, steps, len(ax_keys))) \
        .astype(np.int32)
    bp = _batch_point(axons, neurons, outputs, hier, D, counts)
    bp["n_devices"] = D
    results["batched"] = bp
    if bp["batched_speedup"] < 2.0:
        failures.append(f"batched-speedup@{D}"
                        f"={bp['batched_speedup']:.2f}")
    if not quiet:
        print(f"mesh_bench,batched,B=8,devices={D},"
              f"batched={bp['batched_events_per_sec']:.3e}/s,"
              f"sequential={bp['sequential_events_per_sec']:.3e}/s,"
              f"speedup={bp['batched_speedup']:.2f}x")

    if out_json:
        with open(out_json, "w") as fh:
            json.dump(results, fh, indent=2)
    if failures:
        raise SystemExit(
            f"mesh bench gates failed: {failures} — shard layout, "
            f"packed wire, or batched-run_batch regression")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (seconds, not minutes)")
    args = ap.parse_args()
    if args.smoke:
        run(n_clusters=8, size=24, steps=20)
    else:
        run()
