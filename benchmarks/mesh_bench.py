import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
"""Device-mesh tier benchmark — events/sec and PEAK PER-DEVICE shard
memory of `backend="mesh"` vs 1/2/4/8 forced host devices, on the
clustered topology of benchmarks/hiaer_scaling.py.

The structural claim the mesh tier exists for: per-device synapse-shard
memory SHRINKS with the device count because each device stores only
its own cores' ragged entries with their own weight storage — strictly
below the monolithic dense `w_ext` weight image (R * SLOTS + 1 int32
slots) the single-device hiaer tier used to hold, at 4+ devices. Any
violation exits nonzero so CI catches a shard-layout regression.

The XLA_FLAGS line above MUST precede every jax-touching import (jax
pins the device count at first backend init) — the launch/dryrun.py
pattern. Results go to BENCH_mesh.json (CI artifact).
"""
import json
import time

import numpy as np

from benchmarks.hiaer_scaling import clustered_net
from repro.core.api import CRI_network
from repro.core.costmodel import LEVEL_NAMES
from repro.core.hbm import SLOTS
from repro.core.partition import Hierarchy


def _run_point(axons, neurons, outputs, hier, n_devices, sched, steps):
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="mesh", seed=2, hierarchy=hier,
                      n_devices=n_devices)
    net.run(sched)                        # compile at the timed shape
    net.reset(); net.counter.reset()
    t0 = time.time()
    net.run(sched)
    dt = time.time() - t0
    c = net.counter
    impl = net._impl
    dense_slots = net.compiled.image.syn_post.size + 1
    point = {
        "n_devices": impl.n_devices,
        "us_per_step": 1e6 * dt / steps,
        "events_per_sec": c.row_reads * SLOTS / max(dt, 1e-9),
        "cross_level_events": c.cross_level_events,
        "peak_device_shard_bytes": max(impl.device_shard_bytes()),
        "total_shard_entries": impl.shards.n_entries,
        "monolithic_w_ext_bytes": dense_slots * 4,
        "collective_stages": len(impl._stages),
    }
    for k, v in zip(LEVEL_NAMES, c.level_events):
        point[f"events_{k}"] = v
    return point


def run(n_clusters=16, size=64, steps=60, device_counts=(1, 2, 4, 8),
        quiet=False, out_json="BENCH_mesh.json"):
    axons, neurons, outputs = clustered_net(n_clusters, size)
    n = len(neurons)
    hier = Hierarchy(2, 2, 2, -(-n // 8))          # 8 cores, all levels
    rng = np.random.default_rng(1)
    ax_keys = list(axons)
    sched = [[k for k in rng.choice(ax_keys, 3, replace=False)]
             for _ in range(steps)]

    results = {"n_neurons": n, "n_clusters": n_clusters, "steps": steps,
               "hierarchy": [hier.n_servers, hier.fpgas_per_server,
                             hier.cores_per_fpga], "by_devices": {}}
    failures = []
    for D in device_counts:
        point = _run_point(axons, neurons, outputs, hier, D, sched,
                           steps)
        # the memory gate: per-device shard strictly below the retired
        # monolithic dense weight image once the mesh is 4+ wide
        if D >= 4:
            ok = point["peak_device_shard_bytes"] < \
                point["monolithic_w_ext_bytes"]
            point["below_monolith"] = ok
            if not ok:
                failures.append(D)
        results["by_devices"][str(D)] = point
        if not quiet:
            print(f"mesh_bench,devices={D},"
                  f"ev={point['events_per_sec']:.3e}/s,"
                  f"peak_dev_bytes={point['peak_device_shard_bytes']},"
                  f"monolith={point['monolithic_w_ext_bytes']}")

    if out_json:
        with open(out_json, "w") as fh:
            json.dump(results, fh, indent=2)
    if failures:
        raise SystemExit(
            f"per-device shard bytes not below the monolithic w_ext "
            f"image at device counts {failures} — shard layout "
            f"regression")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (seconds, not minutes)")
    args = ap.parse_args()
    if args.smoke:
        run(n_clusters=8, size=24, steps=20)
    else:
        run()
