"""Roofline analysis over the dry-run artifacts (§Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
  memory term     = HLO_bytes_per_device / HBM_bw              [s]
  collective term = collective_bytes_per_device / link_bw      [s]
(the dry-run HLO is post-SPMD, so analyzer outputs are already per chip;
dividing per-device quantities by per-chip rates == the assignment's
global/(chips*rate) formula).

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N_active for MoE; the
MODEL/HLO ratio flags remat & redundancy waste. Dominant term = bottleneck;
'roofline fraction' = useful-compute time / bound time
= (MODEL_FLOPS/peak) / max(term) — the score §Perf hillclimbs.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def _attn_layer_flops(cfg, S_q, S_kv):
    """Forward qk+av flops for one attention layer over S_q query tokens
    attending S_kv keys (per sequence)."""
    H = cfg.n_heads
    if cfg.mla:
        per = H * (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
                   + cfg.mla.v_head_dim)
    else:
        per = 2 * H * cfg.resolved_head_dim
    return 2.0 * S_q * S_kv * per


def model_flops(arch_id: str, kind: str, seq_len: int, batch: int) -> float:
    """Useful ('model') FLOPs: 6·N·D train / 2·N·D inference (N_active for
    MoE) + causal attention score/value flops (which 6·N·D excludes)."""
    from repro.configs import get_arch
    if arch_id == "hiaer_snn_40b":
        return 2.0 * 160e6 * 512          # 2 flops per synapse slot per step
    cfg = get_arch(arch_id)
    n_act = cfg.n_active_params()
    if cfg.family == "ssm":
        n_attn = 0
    elif cfg.rglru is not None:
        n_attn = cfg.n_layers // len(cfg.rglru.pattern)
    else:
        n_attn = cfg.n_layers
    window = cfg.rglru.window if cfg.rglru else None
    if kind == "train":
        toks = seq_len * batch
        # causal full attention: mean kv length = S/2; train = 3x forward
        kv_mean = min(window, seq_len) if window else seq_len / 2
        attn = 3 * n_attn * batch * _attn_layer_flops(cfg, seq_len, kv_mean)
        return 6.0 * n_act * toks + attn
    if kind == "prefill":
        kv_mean = min(window, seq_len) if window else seq_len / 2
        attn = n_attn * batch * _attn_layer_flops(cfg, seq_len, kv_mean)
        return 2.0 * n_act * seq_len * batch + attn
    # decode: one token per sequence, attention over the full cache
    kv = min(window, seq_len) if window else seq_len
    attn = n_attn * batch * _attn_layer_flops(cfg, 1, kv)
    return 2.0 * n_act * batch + attn


def load_cells(variant="baseline"):
    recs = []
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok" or r.get("variant", "baseline") != variant:
            continue
        recs.append(r)
    return recs


def terms(rec):
    a = rec["analysis"]
    compute = a["flops"] / PEAK_FLOPS
    memory = a.get("hbm_bytes_tight", a["hbm_bytes"]) / HBM_BW
    coll = a["collective_bytes"] / LINK_BW
    bound = max(compute, memory, coll)
    dom = max((("compute", compute), ("memory", memory),
               ("collective", coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec.get("kind", "train"),
                     rec["seq_len"], rec["global_batch"])
    mf_dev = mf / rec["n_devices"]
    useful = mf_dev / PEAK_FLOPS
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "bound_s": bound, "dominant": dom,
        "model_flops_per_dev": mf_dev,
        "model_over_hlo": (mf_dev / a["flops"]) if a["flops"] else 0.0,
        "roofline_fraction": (useful / bound) if bound else 0.0,
    }


def report(variant="baseline", mesh=None, out=sys.stdout):
    rows = []
    for rec in load_cells(variant):
        if mesh and rec["mesh"] != mesh:
            continue
        t = terms(rec)
        rows.append((rec, t))
    rows.sort(key=lambda rt: rt[1]["roofline_fraction"])
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "model/hlo,roofline_frac", file=out)
    for rec, t in rows:
        print(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
              f"{t['compute_s']:.4f},{t['memory_s']:.4f},"
              f"{t['collective_s']:.4f},{t['dominant']},"
              f"{t['model_over_hlo']:.3f},{t['roofline_fraction']:.4f}",
              file=out)
    return rows


def markdown_table(variant="baseline", mesh="pod16x16"):
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in load_cells(variant):
        if rec["mesh"] != mesh:
            continue
        t = terms(rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant']} | {t['model_over_hlo']:.3f} | "
            f"{t['roofline_fraction']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    report(mesh=sys.argv[1] if len(sys.argv) > 1 else None)
