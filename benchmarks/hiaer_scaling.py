"""Hierarchical multi-core scaling benchmark — events/sec and measured
per-level event traffic of the hiaer execution tier vs core count, BFS
(locality-first partitioner) vs random placement, on a clustered
topology (the paper's 'grey matter local, white matter sparse' regime).

For each core count C the hierarchy shape activates successively more
interconnect levels (1 core -> trivial; 2 -> NoC; 4 -> NoC + FireFly;
8 -> + Ethernet). Events/sec counts synaptic events = HBM row reads x 16
slot lanes (same metric as sim_throughput.py); traffic is the
AccessCounter's measured per-level (source -> destination core)
deliveries, which `partition.traffic_cost` only estimates statically.

Results go to BENCH_hiaer.json (CI artifact). The structural claim the
paper's partitioner rests on — BFS placement strictly reduces
cross-level traffic vs random placement on clustered topologies — is
checked for every C > 1 and recorded per data point; any violation exits
nonzero so CI catches a partitioner regression.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.api import CRI_network, LIF_neuron
from repro.core.costmodel import LEVEL_NAMES
from repro.core.hbm import SLOTS
from repro.core.partition import Hierarchy, random_assignment

# hierarchy shapes per core count: (servers, fpgas/server, cores/fpga)
HIER_SHAPES = {1: (1, 1, 1), 2: (1, 1, 2), 4: (1, 2, 2), 8: (2, 2, 2)}


def clustered_net(n_clusters, size, fan_in_cluster=6, fan_out_cluster=1,
                  threshold=40, seed=0):
    """Clustered random SNN: dense within clusters, sparse across —
    the topology BFS placement is supposed to exploit."""
    rng = np.random.default_rng(seed)
    n = n_clusters * size
    names = [f"n{i}" for i in range(n)]
    lif = LIF_neuron(threshold=threshold, nu=-32, lam=3)
    neurons = {}
    for i in range(n):
        c0 = (i // size) * size
        inside = c0 + rng.choice(size, min(fan_in_cluster, size),
                                 replace=False)
        outside = rng.choice(n, fan_out_cluster, replace=False)
        fan = [(names[int(j)], int(rng.integers(5, 20)))
               for j in np.concatenate([inside, outside]) if j != i]
        neurons[names[i]] = (fan, lif)
    # one driving axon per cluster, fanning into its own cluster
    axons = {f"a{c}": [(names[c * size + int(j)], 30)
                       for j in rng.choice(size, min(8, size),
                                           replace=False)]
             for c in range(n_clusters)}
    return axons, neurons, names[:4]


def _run_point(axons, neurons, outputs, hier, placement, sched, steps):
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="hiaer", seed=2, hierarchy=hier,
                      placement=placement)
    net.run(sched)                        # compile at the timed shape
    net.reset(); net.counter.reset()
    t0 = time.time()
    net.run(sched)
    dt = time.time() - t0
    c = net.counter
    point = {
        "us_per_step": 1e6 * dt / steps,
        "events_per_sec": c.row_reads * SLOTS / max(dt, 1e-9),
        "cross_level_events": c.cross_level_events,
        "shards": net._impl.shards.stats(),
    }
    for k, v in zip(LEVEL_NAMES, c.level_events):
        point[f"events_{k}"] = v
    return point


def run(n_clusters=16, size=64, steps=100, core_counts=(1, 2, 4, 8),
        quiet=False, out_json="BENCH_hiaer.json"):
    axons, neurons, outputs = clustered_net(n_clusters, size)
    n = len(neurons)
    rng = np.random.default_rng(1)
    ax_keys = list(axons)
    sched = [[k for k in rng.choice(ax_keys, 3, replace=False)]
             for _ in range(steps)]

    results = {"n_neurons": n, "n_clusters": n_clusters, "steps": steps,
               "by_cores": {}}
    failures = []
    for C in core_counts:
        s, f, k = HIER_SHAPES[C]
        hier = Hierarchy(s, f, k, -(-n // C))
        bfs = _run_point(axons, neurons, outputs, hier, None, sched,
                         steps)
        rnd_asg = random_assignment({k: None for k in neurons}, hier,
                                    seed=3)
        rnd = _run_point(axons, neurons, outputs, hier, rnd_asg, sched,
                         steps)
        entry = {"hierarchy": [s, f, k], "bfs": bfs, "random": rnd}
        if C > 1:
            ok = bfs["cross_level_events"] < rnd["cross_level_events"]
            entry["bfs_beats_random"] = ok
            if not ok:
                failures.append(C)
        results["by_cores"][str(C)] = entry
        if not quiet:
            print(f"hiaer_scaling,cores={C},"
                  f"bfs={bfs['events_per_sec']:.3e}ev/s,"
                  f"bfs_cross={bfs['cross_level_events']},"
                  f"rnd_cross={rnd['cross_level_events']}")

    if out_json:
        with open(out_json, "w") as fh:
            json.dump(results, fh, indent=2)
    if failures:
        raise SystemExit(
            f"BFS placement did not beat random placement on cross-level "
            f"traffic at core counts {failures} — partitioner regression")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (seconds, not minutes)")
    args = ap.parse_args()
    if args.smoke:
        run(n_clusters=8, size=16, steps=25, core_counts=(1, 2, 4))
    else:
        run()
