"""Portal benchmark — HTTP transport tax over in-process serving.

Eight concurrent clients stream spike windows at one resident engine
deployment three ways: directly at the `SpikeServer` (in-process
baseline), over HTTP through ONE portal front end, and over HTTP
through FOUR bridged front-end worker processes sharing the port via
SO_REUSEPORT. Three gates, each a web-portal claim CI must hold
(violations exit nonzero):

  * TRANSPORT TAX: HTTP req/sec (best of 1 vs 4 workers) >= 0.5x the
    in-process rate at 8 concurrent clients — JSON + sockets + the
    unix-domain bridge must cost less than the serving itself;
  * BIT-EXACT: every HTTP response digest equals the same request
    submitted in-process (`result_digest` over spikes AND membranes) —
    the transport must never touch the numbers;
  * TRACES: the whole HTTP session compiles NOTHING beyond the warmed
    pow2 buckets (`compile_counts` unchanged) — the portal is a
    transport, not a new trace shape;
  * OBS OVERHEAD: toggling the telemetry subsystem (request spans,
    metrics) at runtime on the same warmed portal costs <= 5% of HTTP
    req/sec (best of two noise-robust estimators over alternating
    on/off rounds) and stays bit-exact — tracing the whole request
    path must be cheap enough to leave on.

Results (client-side p50/p99 per mode, req/sec, worker counts, obs-on
vs obs-off req/sec) go to BENCH_portal.json (CI artifact).
"""
import asyncio
import gc
import json
import threading
import time

import numpy as np

from repro.analysis.retrace import compile_counts
from repro.core.compile import compile_spec
from repro.portal import Portal
from repro.portal.gateway import result_digest
from repro.serve import SpikeServer

from serve_bench import bench_spec


def _encode_post(model, counts, seed) -> bytes:
    body = json.dumps({"counts": counts.tolist(),
                       "seed": seed}).encode("utf-8")
    return (f"POST /v1/{model}/run HTTP/1.1\r\nHost: bench\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n\r\n"
            ).encode("latin-1") + body


async def _one_request(reader, writer, wire: bytes) -> dict:
    writer.write(wire)
    await writer.drain()
    head = await reader.readuntil(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    clen = 0
    for ln in head.split(b"\r\n"):
        if ln.lower().startswith(b"content-length:"):
            clen = int(ln.split(b":", 1)[1])
    body = json.loads((await reader.readexactly(clen)).decode("utf-8"))
    if status != 200:
        raise SystemExit(f"portal bench: HTTP {status}: {body}")
    return body


def _http_clients(port, reqs, clients, per_client, repeat=1):
    """8 concurrent keep-alive clients on one event loop (the standard
    single-threaded load-generator shape — client threads would bench
    the generator's GIL, not the portal); returns (wall_s, digests,
    client-side latencies ms). `repeat` sweeps the request set several
    times per client (longer timed windows for the obs A/B arms)."""
    wires = {k: _encode_post("bench", w, k[0] * 1000 + k[1])
             for k, w in reqs.items()}
    digests, lats = {}, []

    async def client(cid):
        reader, writer = await asyncio.open_connection("127.0.0.1",
                                                       port)
        for _ in range(repeat):
            for r in range(per_client):
                t0 = time.monotonic()
                body = await _one_request(reader, writer,
                                          wires[(cid, r)])
                lats.append((time.monotonic() - t0) * 1e3)
                digests[(cid, r)] = body["digest"]
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass

    async def drive():
        # warm the accept + dispatch path outside the timed window
        reader, writer = await asyncio.open_connection("127.0.0.1",
                                                       port)
        await _one_request(reader, writer, wires[(0, 0)])
        writer.close()
        t0 = time.monotonic()
        await asyncio.gather(*[client(c) for c in range(clients)])
        return time.monotonic() - t0

    wall = asyncio.run(drive())
    return wall, digests, np.asarray(lats, float)


def run(n_axons=24, n_neurons=96, window=8, clients=8,
        requests_per_client=6, max_batch=8, wait_ms=8.0,
        backend="engine", quiet=False, out_json="BENCH_portal.json"):
    rng = np.random.default_rng(23)
    compiled = compile_spec(bench_spec(n_axons, n_neurons),
                            target=backend)
    reqs = {(c, r): rng.integers(0, 2, (window, n_axons))
            .astype(np.int32)
            for c in range(clients) for r in range(requests_per_client)}
    total = clients * requests_per_client

    srv = SpikeServer(max_batch=max_batch, max_wait_ms=wait_ms)
    m = srv.add_model("bench", compiled, window=window, n_sessions=0,
                      seed=0)
    with srv:
        # warm every pow2 bucket outside every timed window (direct
        # lane dispatches: deterministic, unlike concurrent submits)
        zero = np.zeros((window, n_axons), np.int32)
        B = 1
        while B <= max_batch:
            m.dep.run_lanes([-1] * B, np.stack([zero] * B))
            B *= 2
        # freeze the warmed heap so steady-state collections scan only
        # per-request garbage — the obs A/B then measures telemetry
        # compute, not GC sweeps over the static jax heap
        gc.collect()
        gc.freeze()
        traces_before = compile_counts(m.dep.impl)

        # ---- in-process baseline: 8 threads at srv.submit ----
        ref = {}

        def direct(cid):
            for r in range(requests_per_client):
                ref[(cid, r)] = srv.submit(
                    "bench", reqs[(cid, r)],
                    seed=cid * 1000 + r).result(timeout=300)
        t0 = time.monotonic()
        threads = [threading.Thread(target=direct, args=(c,))
                   for c in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_direct = time.monotonic() - t0
        rps_direct = total / wall_direct
        want = {k: result_digest(v.spikes, v.membrane)
                for k, v in ref.items()}

        # ---- HTTP, one in-process front end ----
        with Portal(srv, port=0) as portal:
            wall_1, dig_1, lats_1 = _http_clients(
                portal.port, reqs, clients, requests_per_client)
            # obs A/B on the same warmed portal (the runtime toggle =
            # zero recompiles)
            obs_best = {False: 0.0, True: 0.0}
            dig_obs = {}
            obs_ratios = []
            # alternating on/off rounds; the gate takes the BETTER of
            # two noise-robust estimators of the same intrinsic cost:
            # the ratio of best rates (load only slows rounds down, so
            # each arm's best round approximates its unloaded rate)
            # and the median per-round paired ratio (drift cancels
            # inside a round, the median discards poisoned rounds).
            # They fail under DIFFERENT noise shapes, so a false gate
            # failure needs both depressed at once; >= ~512 requests
            # per timed arm, and extra rounds (up to 15) hunt for a
            # quiet window when sustained load poisons the first seven
            rep = max(1, -(-512 // total))

            def _obs_estimate():
                med = sorted(obs_ratios)[len(obs_ratios) // 2]
                return max(obs_best[True] / obs_best[False], med)

            for rnd in range(15):
                if rnd >= 7 and _obs_estimate() >= 0.95:
                    break
                order = (False, True) if rnd % 2 == 0 else (True, False)
                rps = {}
                for on in order:
                    srv.tel.on = on
                    w, d, _ = _http_clients(
                        portal.port, reqs, clients,
                        requests_per_client, repeat=rep)
                    rps[on] = rep * total / w
                    obs_best[on] = max(obs_best[on], rps[on])
                    dig_obs[on] = d
                obs_ratios.append(rps[True] / rps[False])
            srv.tel.on = True
            obs_ratio = _obs_estimate()
        rps_1 = total / wall_1
        rps_obs_off, rps_obs_on = obs_best[False], obs_best[True]

        # ---- HTTP, four bridged worker processes ----
        with Portal(srv, port=0, workers=4) as portal:
            wall_4, dig_4, lats_4 = _http_clients(
                portal.port, reqs, clients, requests_per_client)
        rps_4 = total / wall_4

        traces_after = compile_counts(m.dep.impl)

    exact = all(dig_1[k] == want[k] and dig_4[k] == want[k]
                and dig_obs[True][k] == want[k]
                and dig_obs[False][k] == want[k]
                for k in reqs)
    extra = {k: traces_after[k] - traces_before.get(k, 0)
             for k in traces_after
             if traces_after[k] != traces_before.get(k, 0)}
    rps_http = max(rps_1, rps_4)
    ratio = rps_http / max(rps_direct, 1e-9)

    out = {
        "backend": backend,
        "n_neurons": n_neurons, "n_axons": n_axons, "window": window,
        "clients": clients, "requests": total, "max_batch": max_batch,
        "req_per_sec_inprocess": rps_direct,
        "req_per_sec_http_1worker": rps_1,
        "req_per_sec_http_4workers": rps_4,
        "http_over_inprocess": ratio,
        "p50_ms_http_1worker": float(np.percentile(lats_1, 50)),
        "p99_ms_http_1worker": float(np.percentile(lats_1, 99)),
        "p50_ms_http_4workers": float(np.percentile(lats_4, 50)),
        "p99_ms_http_4workers": float(np.percentile(lats_4, 99)),
        "bitexact": exact,
        "extra_traces": {f"{o}.{f}": n for (o, f), n in extra.items()},
        "req_per_sec_obs_on": rps_obs_on,
        "req_per_sec_obs_off": rps_obs_off,
        "obs_overhead_ratio": obs_ratio,
        "obs_round_ratios": obs_ratios,
    }
    if not quiet:
        print(f"portal_bench,{backend},clients={clients},"
              f"inproc={rps_direct:.1f}req/s,http1={rps_1:.1f}req/s,"
              f"http4={rps_4:.1f}req/s,ratio={ratio:.2f}x,"
              f"p50_http={out['p50_ms_http_1worker']:.2f}ms,"
              f"bitexact={exact},extra_traces={len(extra)},"
              f"obs={out['obs_overhead_ratio']:.3f}x")

    failures = []
    if ratio < 0.5:
        failures.append(f"http/inprocess={ratio:.2f}<0.5")
    if not exact:
        failures.append("http-results-not-bit-exact")
    if extra:
        failures.append(f"portal-added-traces={out['extra_traces']}")
    if out["obs_overhead_ratio"] < 0.95:
        failures.append(
            f"obs-overhead={out['obs_overhead_ratio']:.3f}<0.95")
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(out, fh, indent=2)
    if failures:
        raise SystemExit(
            f"portal bench gates failed: {failures} — transport tax, "
            f"transport-touched numbers, or a new trace shape")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (seconds, not minutes)")
    ap.add_argument("--backend", default="engine",
                    choices=["simulator", "engine", "hiaer", "mesh"])
    args = ap.parse_args()
    if args.smoke:
        run(n_axons=16, n_neurons=48, window=6, requests_per_client=12,
            wait_ms=2.0, backend=args.backend)
    else:
        run()
