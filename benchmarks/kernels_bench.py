"""Kernel micro-benchmarks (interpret mode on CPU: correctness + relative
cost; Mosaic timings require real TPUs). Reports event-driven savings: the
spike kernel's gated-block fraction at representative activity levels —
the quantity that scales HBM traffic on hardware (paper §4/§6)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def run(quiet=False):
    key = jax.random.PRNGKey(0)
    rows = []
    for density in (0.01, 0.05, 0.2):
        spikes = jax.random.bernoulli(key, density, (2048,))
        w = jax.random.randint(key, (2048, 1024), -300, 300, jnp.int16)
        out = ops.spike_matmul(spikes, w)
        want = ref.spike_matmul_ref(spikes, w)
        assert np.array_equal(np.asarray(out), np.asarray(want))
        counts = np.asarray(spikes, np.int32).reshape(-1, 128).sum(1)
        live = float((counts > 0).mean())
        rows.append(("spike_matmul", density, live))
        if not quiet:
            print(f"kernel,spike_matmul,density={density},"
                  f"live_blocks={live:.2f}")
    q = jax.random.normal(key, (1, 2, 256, 64))
    t0 = time.time()
    o = ops.flash_attention(q, q, q, bq=128, bk=128)
    dt = (time.time() - t0) * 1e6
    err = float(jnp.max(jnp.abs(o - ref.flash_attention_ref(q, q, q))))
    assert err < 2e-5
    if not quiet:
        print(f"kernel,flash_attention,us={dt:.0f},maxerr={err:.2e}")
    return rows


if __name__ == "__main__":
    run()
