"""Kernel micro-benchmarks (interpret mode on CPU: correctness + relative
cost; Mosaic timings require real TPUs). Reports event-driven savings: the
spike kernel's gated-block fraction at representative activity levels —
the quantity that scales HBM traffic on hardware (paper §4/§6) — plus the
two-phase routing kernels (fan-in-gather vs CSR-segment vs segment-sum
accumulate, and the fused route+LIF Pallas step vs its unfused oracle).

`--smoke` runs one small size per kernel (the CI job).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _bench_routing(quiet=False, smoke=False):
    """Routing-path parity + relative cost on a random HBM image."""
    from repro.core import hbm
    from repro.kernels import route as route_k

    rng = np.random.default_rng(0)
    n = 256
    axon_syn = {a: [(int(p), int(rng.integers(-20, 20)) or 1)
                    for p in rng.choice(n, 16, replace=False)]
                for a in range(32)}
    neuron_syn = {i: [(int(p), int(rng.integers(-20, 20)) or 1)
                      for p in rng.choice(n, 8, replace=False)]
                  for i in range(n)}
    img = hbm.compile_network(axon_syn, neuron_syn,
                              {i: 0 for i in range(n)}, [0], n)
    tables = route_k.RouteTables.from_flat(img.flatten(), n)
    counts = np.zeros((len(img.axon_ptr),), np.int32)
    counts[rng.choice(len(counts), 4, replace=False)] = 1
    counts = jnp.asarray(counts)
    spikes = jnp.asarray(rng.random(n) < 0.05)

    gate, _, _ = route_k.route_event_counts(tables, counts, spikes)
    iters = 3 if smoke else 20
    rows = []
    for name, fn in (("fanin_gather", route_k.accumulate),
                     ("csr_segment", route_k.accumulate_csr),
                     ("segment_sum", route_k.accumulate_scatter)):
        f = jax.jit(lambda g, fn=fn: fn(tables, g, n))
        out = f(gate)
        out.block_until_ready()
        t0 = time.time()
        for _ in range(iters):
            out = f(gate)
        out.block_until_ready()
        us = (time.time() - t0) / iters * 1e6
        rows.append((f"route_{name}", us))
        if not quiet:
            print(f"kernel,route_{name},us={us:.0f}")
    a = jax.jit(lambda g: route_k.accumulate(tables, g, n))(gate)
    b = jax.jit(lambda g: route_k.accumulate_scatter(tables, g, n))(gate)
    c = jax.jit(lambda g: route_k.accumulate_csr(tables, g, n))(gate)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(a), np.asarray(c))

    # fused route+LIF Pallas step vs the unfused two-phase oracle
    from repro.core import neuron as nrn
    V = jnp.asarray(rng.integers(-1000, 1000, n), jnp.int32)
    u = jnp.asarray(rng.integers(-(2**16), 2**16, n), jnp.int32)
    theta = jnp.asarray(rng.integers(0, 50, n), jnp.int32)
    nu = jnp.full((n,), -32, jnp.int32)
    lam = jnp.asarray(rng.integers(0, 64, n), jnp.int32)
    is_lif = jnp.asarray(rng.random(n) < 0.7)
    V_f, spk_f, _, _ = route_k.fused_route_lif_step(
        tables, counts, V, u, theta, nu, lam, is_lif)
    # oracle: fire -> route -> integrate with materialized V_mid
    xi = nrn.noise_from_u(u, nu)
    spk = (V + xi) > theta
    V_mid = jnp.where(spk, 0, V + xi)
    V_mid = jnp.where(is_lif, nrn.leak(V_mid, lam), 0)
    syn, _, _ = route_k.route(tables, counts, spk, n)
    V_o = nrn.integrate_phase(V_mid, syn)
    assert np.array_equal(np.asarray(V_f), np.asarray(V_o))
    assert np.array_equal(np.asarray(spk_f), np.asarray(spk))
    if not quiet:
        print("kernel,fused_route_lif,parity=ok")
    return rows


def run(quiet=False, smoke=False):
    key = jax.random.PRNGKey(0)
    rows = []
    densities = (0.05,) if smoke else (0.01, 0.05, 0.2)
    for density in densities:
        spikes = jax.random.bernoulli(key, density, (2048,))
        w = jax.random.randint(key, (2048, 1024), -300, 300, jnp.int16)
        out = ops.spike_matmul(spikes, w)
        want = ref.spike_matmul_ref(spikes, w)
        assert np.array_equal(np.asarray(out), np.asarray(want))
        counts = np.asarray(spikes, np.int32).reshape(-1, 128).sum(1)
        live = float((counts > 0).mean())
        rows.append(("spike_matmul", density, live))
        if not quiet:
            print(f"kernel,spike_matmul,density={density},"
                  f"live_blocks={live:.2f}")
    S, bqk = (128, 64) if smoke else (256, 128)
    q = jax.random.normal(key, (1, 2, S, 64))
    t0 = time.time()
    o = ops.flash_attention(q, q, q, bq=bqk, bk=bqk)
    dt = (time.time() - t0) * 1e6
    err = float(jnp.max(jnp.abs(o - ref.flash_attention_ref(q, q, q))))
    assert err < 2e-5
    if not quiet:
        print(f"kernel,flash_attention,us={dt:.0f},maxerr={err:.2e}")
    rows += _bench_routing(quiet=quiet, smoke=smoke)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small size per kernel (CI)")
    args = ap.parse_args()
    run(smoke=args.smoke)
