"""Network CONSTRUCTION throughput — the staged-API PR's claim.

The paper's interface configures networks of up to 160M neurons / 40B
synapses; at that scale building the description must not be the
bottleneck. This benchmark times spec-build + compile (synapses/sec,
no deployment) through two front doors:

  * columnar — `NetworkSpec` bulk ops (`add_axons`/`add_neurons`/one
    array `connect`) -> `compile_spec`: pure NumPy, no per-synapse
    Python;
  * dict — the legacy per-key dict format through
    `NetworkSpec.from_dicts` -> `compile_spec`: the unavoidable
    per-synapse Python loop at the dict boundary, then the same
    vectorized compiler.

For reference it also times the seed-era per-synapse Fig. 7 mapper
(`hbm.compile_network`) at the sizes where that is bearable.

Results go to BENCH_build.json. `--min-ratio R` turns the
columnar-vs-dict throughput ratio at 1e5 synapses into a hard gate
(SystemExit) — CI runs `--smoke --min-ratio 5`, the PR's acceptance
bar (measured ~6x, with the dict path dominated by boundary Python, so
the ratio is stable across machine speeds).

    PYTHONPATH=src python -m benchmarks.build_bench [--smoke]
        [--min-ratio 5] [--out BENCH_build.json]
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import hbm
from repro.core.api import LIF_neuron
from repro.core.compile import compile_spec
from repro.core.spec import NetworkSpec

MODEL = LIF_neuron(threshold=50, nu=-32, lam=5)


def gen_columns(n_syn: int, seed: int = 0):
    """Random topology: N = n_syn/10 neurons, A = N/8 axons, 20% of
    synapses axon-sourced."""
    rng = np.random.default_rng(seed)
    N = max(n_syn // 10, 16)
    A = max(N // 8, 4)
    n_ax_syn = n_syn // 5
    pre = np.concatenate([
        -(rng.integers(0, A, n_ax_syn) + 1),          # encoded axon ids
        rng.integers(0, N, n_syn - n_ax_syn)])
    post = rng.integers(0, N, n_syn)
    w = rng.integers(-100, 100, n_syn)
    return A, N, pre, post, w


def dicts_from_columns(A, N, pre, post, w):
    """The same network in the legacy dict format (built outside the
    timed region — the dicts are the INPUT of the dict path)."""
    axons = {f"a{i}": [] for i in range(A)}
    neurons = {f"n{i}": ([], MODEL) for i in range(N)}
    for p, q, ww in zip(pre.tolist(), post.tolist(), w.tolist()):
        if p < 0:
            axons[f"a{-p - 1}"].append((f"n{q}", ww))
        else:
            neurons[f"n{p}"][0].append((f"n{q}", ww))
    return axons, neurons, [f"n{i}" for i in range(min(8, N))]


def _merge_best(best, t0, t1, t2):
    best["spec_build_s"] = min(best["spec_build_s"], t1 - t0)
    best["compile_s"] = min(best["compile_s"], t2 - t1)
    best["total_s"] = min(best["total_s"], t2 - t0)


def _one_columnar(A, N, pre, post, w, best):
    t0 = time.perf_counter()
    spec = NetworkSpec()
    spec.add_axons(A)
    nr = spec.add_neurons(N, MODEL)
    spec.connect(pre, post, w)
    spec.set_outputs(nr[:min(8, N)])
    t1 = time.perf_counter()
    compile_spec(spec, target="engine")
    _merge_best(best, t0, t1, time.perf_counter())


def _one_dict(axons, neurons, outputs, best):
    t0 = time.perf_counter()
    spec = NetworkSpec.from_dicts(axons, neurons, outputs)
    t1 = time.perf_counter()
    compile_spec(spec, target="engine")
    _merge_best(best, t0, t1, time.perf_counter())


def time_both(A, N, pre, post, w, reps=5):
    """Best-of-`reps`, with columnar and dict builds INTERLEAVED so a
    load spike on a shared runner degrades both paths rather than
    skewing the gated ratio."""
    inf = float("inf")
    col = {"spec_build_s": inf, "compile_s": inf, "total_s": inf}
    dic = {"spec_build_s": inf, "compile_s": inf, "total_s": inf}
    axons, neurons, outputs = dicts_from_columns(A, N, pre, post, w)
    for _ in range(reps):
        _one_columnar(A, N, pre, post, w, col)
        _one_dict(axons, neurons, outputs, dic)
    return col, dic, (axons, neurons, outputs)


def time_seed_mapper(axons, neurons, outputs):
    aid = {k: i for i, k in enumerate(axons)}
    nid = {k: i for i, k in enumerate(neurons)}
    axon_syn = {aid[k]: [(nid[p], int(ww)) for p, ww in axons[k]]
                for k in axons}
    neuron_syn = {nid[k]: [(nid[p], int(ww)) for p, ww in neurons[k][0]]
                  for k in neurons}
    t0 = time.perf_counter()
    hbm.compile_network(axon_syn, neuron_syn,
                        {i: 0 for i in range(len(neurons))},
                        [nid[k] for k in outputs], len(neurons))
    return time.perf_counter() - t0


def run(sizes=(10 ** 4, 10 ** 5, 10 ** 6), min_ratio=0.0, quiet=False,
        out_json="BENCH_build.json"):
    results = {"sizes": {}, "gate_size": 10 ** 5}
    # warm NumPy/allocator once so the first timed build is not paying
    # first-touch costs (stabilizes the gate ratio on loaded runners)
    time_both(*gen_columns(10 ** 4), reps=1)
    for n_syn in sizes:
        A, N, pre, post, w = gen_columns(n_syn)
        col, dic, (axons, neurons, outputs) = time_both(
            A, N, pre, post, w, reps=5 if n_syn <= 10 ** 5 else 2)
        entry = {
            "n_axons": A, "n_neurons": N, "n_synapses": n_syn,
            "columnar": {**col,
                         "syn_per_sec": n_syn / col["total_s"]},
            "dict": {**dic, "syn_per_sec": n_syn / dic["total_s"]},
            "ratio_columnar_over_dict":
                dic["total_s"] / col["total_s"],
        }
        if n_syn <= 10 ** 5:
            t_seed = time_seed_mapper(axons, neurons, outputs)
            entry["seed_mapper_s"] = t_seed
            entry["ratio_columnar_over_seed"] = t_seed / col["total_s"]
        results["sizes"][str(n_syn)] = entry
        if not quiet:
            print(f"n_syn={n_syn:>8}: columnar "
                  f"{entry['columnar']['syn_per_sec']:>12,.0f} syn/s   "
                  f"dict {entry['dict']['syn_per_sec']:>12,.0f} syn/s   "
                  f"ratio {entry['ratio_columnar_over_dict']:.1f}x")
    gate = results["sizes"].get(str(results["gate_size"]))
    if gate is not None:
        results["gate_ratio"] = gate["ratio_columnar_over_dict"]
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    if not quiet:
        print(f"wrote {out_json}")
    if min_ratio > 0:
        if gate is None:
            raise SystemExit("gate size 1e5 was not benchmarked")
        if gate["ratio_columnar_over_dict"] < min_ratio:
            raise SystemExit(
                f"columnar/dict ratio "
                f"{gate['ratio_columnar_over_dict']:.2f}x at 1e5 "
                f"synapses below the {min_ratio}x gate")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="1e4 + 1e5 only (CI)")
    ap.add_argument("--min-ratio", type=float, default=0.0)
    ap.add_argument("--out", default="BENCH_build.json")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    sizes = (10 ** 4, 10 ** 5) if args.smoke else \
        (10 ** 4, 10 ** 5, 10 ** 6)
    run(sizes=sizes, min_ratio=args.min_ratio, quiet=args.quiet,
        out_json=args.out)
