"""Serving-tier benchmark — micro-batched spike serving vs sequential
dispatch, on a resident mesh deployment.

Eight concurrent clients stream spike windows at a `SpikeServer`
(double-buffered ingestion, deadline + max-batch admission, pow2
batch-shape bucketing); the same request set then runs one-dispatch-
per-request on an identical deployment. Three gates, each a serving
claim CI must hold (violations exit nonzero):

  * THROUGHPUT: micro-batched req/sec >= 2x the sequential dispatch
    rate at 8 concurrent clients — the amortized-collective win that
    justifies an always-on batching tier at all;
  * BIT-EXACT: every served response (spikes AND final membranes)
    equals the same request run alone — micro-batching must never leak
    state or PRNG noise between clients;
  * TRACES: the whole serving session compiles the lane path at most
    log2(max_batch) + 1 times (the pow2 buckets), counted with
    `repro.analysis.retrace.compile_counts` — fluctuating client
    concurrency must not turn into unbounded XLA recompiles.

Results (p50/p99 latency, req/sec both ways, batch-size distribution)
go to BENCH_serve.json (CI artifact).
"""
import json
import math
import threading
import time

import numpy as np

from repro.analysis.retrace import compile_counts
from repro.core.api import LIF_neuron
from repro.core.compile import compile_spec
from repro.core.deploy import deploy
from repro.core.partition import Hierarchy
from repro.core.spec import NetworkSpec
from repro.serve import SpikeServer


def bench_spec(n_axons, n_neurons, fanout=6, seed=7) -> NetworkSpec:
    rng = np.random.default_rng(seed)
    spec = NetworkSpec()
    ax = spec.add_axons(n_axons)
    nid = spec.add_neurons(n_neurons,
                           LIF_neuron(threshold=6, nu=-32, lam=40))
    pre = np.concatenate([np.repeat(ax, fanout),
                          np.repeat(nid, fanout)])
    post = rng.integers(0, n_neurons, pre.shape[0])
    w = rng.integers(-3, 8, pre.shape[0])
    spec.connect(pre, post, w)
    spec.set_outputs(list(range(min(8, n_neurons))))
    return spec


def _client(srv, cid, n_requests, reqs, results):
    for r in range(n_requests):
        res = srv.submit("bench", reqs[(cid, r)], seed=cid * 1000 + r) \
            .result(timeout=300)
        results[(cid, r)] = res


def run(n_axons=24, n_neurons=96, window=8, clients=8,
        requests_per_client=6, max_batch=8, wait_ms=8.0,
        backend="mesh", quiet=False, out_json="BENCH_serve.json"):
    rng = np.random.default_rng(11)
    spec = bench_spec(n_axons, n_neurons)
    kw = {}
    if backend in ("hiaer", "mesh"):
        kw["hierarchy"] = Hierarchy(1, 2, 2, -(-n_neurons // 4))
    compiled = compile_spec(spec, target=backend, **kw)

    reqs = {(c, r): rng.integers(0, 2, (window, n_axons))
            .astype(np.int32)
            for c in range(clients) for r in range(requests_per_client)}
    total = clients * requests_per_client

    # ---- micro-batched serving: 8 concurrent clients, one server ----
    srv = SpikeServer(max_batch=max_batch, max_wait_ms=wait_ms)
    srv.add_model("bench", compiled, window=window, n_sessions=0,
                  seed=0)
    results = {}
    with srv:
        # warm every pow2 bucket outside the timed window (B=1 via a
        # lone request, then a full-width burst for the bigger buckets)
        srv.submit("bench", np.zeros((window, n_axons), np.int32)) \
            .result()
        warm = [srv.submit("bench",
                           np.zeros((window, n_axons), np.int32))
                for _ in range(max_batch)]
        for f in warm:
            f.result()
        srv.reset_stats()          # percentiles from serving, not tracing
        t0 = time.monotonic()
        threads = [threading.Thread(
            target=_client,
            args=(srv, c, requests_per_client, reqs, results))
            for c in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_b = time.monotonic() - t0
        stats = srv.stats()
    rps_b = total / wall_b

    # trace gate: pow2 bucketing bounds the whole session's compiles
    lane_traces = sum(
        n for (_, name), n in compile_counts(
            srv.models["bench"].dep.impl).items()
        if "lanes" in name)
    trace_bound = int(math.log2(max_batch)) + 1

    # ---- sequential baseline: same requests, one dispatch each ----
    dep = deploy(compiled, seed=0)
    dep.run_lanes([-1], [np.zeros((window, n_axons), np.int32)])  # warm
    t0 = time.monotonic()
    serial = {}
    for c in range(clients):
        for r in range(requests_per_client):
            spk, V = dep.run_lanes([-1], [reqs[(c, r)]],
                                   seeds=[c * 1000 + r])
            serial[(c, r)] = (spk[0], V[0])
    wall_s = time.monotonic() - t0
    rps_s = total / wall_s

    # bit-exactness: served response == the request run alone
    exact = all(
        np.array_equal(results[k].spikes, serial[k][0])
        and np.array_equal(results[k].membrane, serial[k][1])
        for k in reqs)

    out = {
        "backend": backend,
        "n_neurons": n_neurons, "n_axons": n_axons, "window": window,
        "clients": clients, "requests": total, "max_batch": max_batch,
        "max_wait_ms": wait_ms,
        "p50_ms": stats["p50_ms"], "p99_ms": stats["p99_ms"],
        "req_per_sec_batched": rps_b,
        "req_per_sec_sequential": rps_s,
        "speedup": rps_b / max(rps_s, 1e-9),
        "mean_batch_size": stats["mean_batch_size"],
        "batch_shapes": [list(s) for s in
                         stats["models"]["bench"]["batch_shapes"]],
        "buffer": stats["buffer"],
        "lane_traces": lane_traces, "trace_bound": trace_bound,
        "bitexact": exact,
    }
    if not quiet:
        print(f"serve_bench,{backend},clients={clients},"
              f"batched={rps_b:.1f}req/s,sequential={rps_s:.1f}req/s,"
              f"speedup={out['speedup']:.2f}x,p50={out['p50_ms']:.2f}ms,"
              f"p99={out['p99_ms']:.2f}ms,"
              f"traces={lane_traces}<={trace_bound},bitexact={exact}")

    failures = []
    if out["speedup"] < 2.0:
        failures.append(f"speedup={out['speedup']:.2f}<2.0")
    if not exact:
        failures.append("served-results-not-bit-exact")
    if lane_traces > trace_bound:
        failures.append(f"lane-traces={lane_traces}>{trace_bound}")
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(out, fh, indent=2)
    if failures:
        raise SystemExit(
            f"serve bench gates failed: {failures} — micro-batching "
            f"throughput, client isolation, or bucket regression")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (seconds, not minutes)")
    ap.add_argument("--backend", default="mesh",
                    choices=["simulator", "engine", "hiaer", "mesh"])
    args = ap.parse_args()
    if args.smoke:
        run(n_axons=16, n_neurons=48, window=6, requests_per_client=4,
            backend=args.backend)
    else:
        run()
