"""Serving-tier benchmark — micro-batched spike serving vs sequential
dispatch, on a resident mesh deployment.

Eight concurrent clients stream spike windows at a `SpikeServer`
(double-buffered ingestion, deadline + max-batch admission, pow2
batch-shape bucketing); the same request set then runs one-dispatch-
per-request on an identical deployment. Three gates, each a serving
claim CI must hold (violations exit nonzero):

  * THROUGHPUT: micro-batched req/sec >= 2x the sequential dispatch
    rate at 8 concurrent clients — the amortized-collective win that
    justifies an always-on batching tier at all;
  * BIT-EXACT: every served response (spikes AND final membranes)
    equals the same request run alone — micro-batching must never leak
    state or PRNG noise between clients;
  * TRACES: the whole serving session compiles the lane path at most
    log2(max_batch) + 1 times (the pow2 buckets), counted with
    `repro.analysis.retrace.compile_counts` — fluctuating client
    concurrency must not turn into unbounded XLA recompiles;
  * OBS OVERHEAD: the telemetry subsystem (spans + metrics), toggled
    at runtime on the SAME warmed server, costs <= 5% of req/sec
    (best of two noise-robust estimators over alternating on/off
    rounds), stays bit-exact, and adds ZERO compiles — observability
    must be cheap enough to leave on.

Results (p50/p99 latency, req/sec both ways, batch-size distribution,
obs-on vs obs-off req/sec) go to BENCH_serve.json (CI artifact).
"""
import gc
import json
import math
import threading
import time

import numpy as np

from repro.analysis.retrace import compile_counts
from repro.core.api import LIF_neuron
from repro.core.compile import compile_spec
from repro.core.deploy import deploy
from repro.core.partition import Hierarchy
from repro.core.spec import NetworkSpec
from repro.serve import SpikeServer


def bench_spec(n_axons, n_neurons, fanout=6, seed=7) -> NetworkSpec:
    rng = np.random.default_rng(seed)
    spec = NetworkSpec()
    ax = spec.add_axons(n_axons)
    nid = spec.add_neurons(n_neurons,
                           LIF_neuron(threshold=6, nu=-32, lam=40))
    pre = np.concatenate([np.repeat(ax, fanout),
                          np.repeat(nid, fanout)])
    post = rng.integers(0, n_neurons, pre.shape[0])
    w = rng.integers(-3, 8, pre.shape[0])
    spec.connect(pre, post, w)
    spec.set_outputs(list(range(min(8, n_neurons))))
    return spec


def _client(srv, cid, n_requests, reqs, results):
    for r in range(n_requests):
        res = srv.submit("bench", reqs[(cid, r)], seed=cid * 1000 + r) \
            .result(timeout=300)
        results[(cid, r)] = res


def _timed_pass(srv, clients, requests_per_client, reqs, repeat=1):
    """One full concurrent-client pass (`repeat` sweeps of the request
    set per client); returns (wall_s for ALL sweeps, last results)."""
    results = {}
    t0 = time.monotonic()
    for _ in range(repeat):
        threads = [threading.Thread(
            target=_client,
            args=(srv, c, requests_per_client, reqs, results))
            for c in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    return time.monotonic() - t0, results


def run(n_axons=24, n_neurons=96, window=8, clients=8,
        requests_per_client=6, max_batch=8, wait_ms=8.0,
        backend="mesh", quiet=False, out_json="BENCH_serve.json"):
    rng = np.random.default_rng(11)
    spec = bench_spec(n_axons, n_neurons)
    kw = {}
    if backend in ("hiaer", "mesh"):
        kw["hierarchy"] = Hierarchy(1, 2, 2, -(-n_neurons // 4))
    compiled = compile_spec(spec, target=backend, **kw)

    reqs = {(c, r): rng.integers(0, 2, (window, n_axons))
            .astype(np.int32)
            for c in range(clients) for r in range(requests_per_client)}
    total = clients * requests_per_client

    # ---- micro-batched serving: 8 concurrent clients, one server ----
    srv = SpikeServer(max_batch=max_batch, max_wait_ms=wait_ms)
    srv.add_model("bench", compiled, window=window, n_sessions=0,
                  seed=0)
    results = {}
    with srv:
        # warm every pow2 bucket outside the timed window (B=1 via a
        # lone request, then a full-width burst for the bigger buckets)
        srv.submit("bench", np.zeros((window, n_axons), np.int32)) \
            .result()
        warm = [srv.submit("bench",
                           np.zeros((window, n_axons), np.int32))
                for _ in range(max_batch)]
        for f in warm:
            f.result()
        # freeze the warmed heap (jax modules, compiled executables):
        # steady-state collections then scan only per-request garbage,
        # so the obs A/B below measures telemetry compute instead of
        # GC sweeps over a large static heap (and every timed arm gets
        # less jitter)
        gc.collect()
        gc.freeze()
        srv.reset_stats()          # percentiles from serving, not tracing
        wall_b, results = _timed_pass(srv, clients,
                                      requests_per_client, reqs)
        stats = srv.stats()
        rps_b = total / wall_b

        # ---- obs A/B on the SAME warmed server (the runtime toggle
        # means zero recompiles) ----
        traces_pre_obs = compile_counts(srv.models["bench"].dep.impl)
        obs_results = {}
        best = {False: 0.0, True: 0.0}
        ratios = []
        # alternating on/off rounds; the gate takes the BETTER of two
        # noise-robust estimators of the same intrinsic cost: the
        # ratio of best rates (ambient load only slows rounds down, so
        # each arm's best round approximates its unloaded rate) and
        # the median per-round paired ratio (load drift cancels inside
        # a round, the median discards spike-poisoned rounds). The two
        # fail under DIFFERENT noise shapes, so a false gate failure
        # needs both depressed at once; passes are long (>= ~512
        # requests) so scheduler jitter cannot fake 5%, and extra
        # rounds (up to 15) hunt for a quiet window when sustained
        # load poisons the first seven
        repeat = max(1, -(-512 // total))

        def _obs_estimate():
            med = sorted(ratios)[len(ratios) // 2]
            return max(best[True] / best[False], med)

        for rnd in range(15):
            if rnd >= 7 and _obs_estimate() >= 0.95:
                break
            order = (False, True) if rnd % 2 == 0 else (True, False)
            rps = {}
            for on in order:
                srv.tel.on = on
                wall, res = _timed_pass(srv, clients,
                                        requests_per_client, reqs,
                                        repeat=repeat)
                rps[on] = repeat * total / wall
                best[on] = max(best[on], rps[on])
                obs_results[on] = res
            ratios.append(rps[True] / rps[False])
        srv.tel.on = True
        rps_obs_off, rps_obs_on = best[False], best[True]
        obs_ratio = _obs_estimate()
        obs_extra = {
            k: n for k, n in
            compile_counts(srv.models["bench"].dep.impl).items()
            if n != traces_pre_obs.get(k, 0)}

    # trace gate: pow2 bucketing bounds the whole session's compiles
    lane_traces = sum(
        n for (_, name), n in compile_counts(
            srv.models["bench"].dep.impl).items()
        if "lanes" in name)
    trace_bound = int(math.log2(max_batch)) + 1

    # ---- sequential baseline: same requests, one dispatch each ----
    dep = deploy(compiled, seed=0)
    dep.run_lanes([-1], [np.zeros((window, n_axons), np.int32)])  # warm
    t0 = time.monotonic()
    serial = {}
    for c in range(clients):
        for r in range(requests_per_client):
            spk, V = dep.run_lanes([-1], [reqs[(c, r)]],
                                   seeds=[c * 1000 + r])
            serial[(c, r)] = (spk[0], V[0])
    wall_s = time.monotonic() - t0
    rps_s = total / wall_s

    # bit-exactness: served response == the request run alone, in the
    # main pass AND in both obs arms (telemetry never touches numbers)
    exact = all(
        np.array_equal(res[k].spikes, serial[k][0])
        and np.array_equal(res[k].membrane, serial[k][1])
        for res in (results, obs_results[True], obs_results[False])
        for k in reqs)

    out = {
        "backend": backend,
        "n_neurons": n_neurons, "n_axons": n_axons, "window": window,
        "clients": clients, "requests": total, "max_batch": max_batch,
        "max_wait_ms": wait_ms,
        "p50_ms": stats["p50_ms"], "p99_ms": stats["p99_ms"],
        "req_per_sec_batched": rps_b,
        "req_per_sec_sequential": rps_s,
        "speedup": rps_b / max(rps_s, 1e-9),
        "mean_batch_size": stats["mean_batch_size"],
        "batch_shapes": [list(s) for s in
                         stats["models"]["bench"]["batch_shapes"]],
        "buffer": stats["buffer"],
        "lane_traces": lane_traces, "trace_bound": trace_bound,
        "bitexact": exact,
        "req_per_sec_obs_on": rps_obs_on,
        "req_per_sec_obs_off": rps_obs_off,
        "obs_overhead_ratio": obs_ratio,
        "obs_round_ratios": ratios,
        "obs_extra_traces": {f"{o}.{f}": n
                             for (o, f), n in obs_extra.items()},
    }
    if not quiet:
        print(f"serve_bench,{backend},clients={clients},"
              f"batched={rps_b:.1f}req/s,sequential={rps_s:.1f}req/s,"
              f"speedup={out['speedup']:.2f}x,p50={out['p50_ms']:.2f}ms,"
              f"p99={out['p99_ms']:.2f}ms,"
              f"traces={lane_traces}<={trace_bound},bitexact={exact},"
              f"obs={out['obs_overhead_ratio']:.3f}x")

    failures = []
    if out["speedup"] < 2.0:
        failures.append(f"speedup={out['speedup']:.2f}<2.0")
    if not exact:
        failures.append("served-results-not-bit-exact")
    if lane_traces > trace_bound:
        failures.append(f"lane-traces={lane_traces}>{trace_bound}")
    if out["obs_overhead_ratio"] < 0.95:
        failures.append(
            f"obs-overhead={out['obs_overhead_ratio']:.3f}<0.95")
    if obs_extra:
        failures.append(f"obs-added-traces={out['obs_extra_traces']}")
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(out, fh, indent=2)
    if failures:
        raise SystemExit(
            f"serve bench gates failed: {failures} — micro-batching "
            f"throughput, client isolation, or bucket regression")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (seconds, not minutes)")
    ap.add_argument("--backend", default="mesh",
                    choices=["simulator", "engine", "hiaer", "mesh"])
    args = ap.parse_args()
    if args.smoke:
        run(n_axons=16, n_neurons=48, window=6, requests_per_client=4,
            backend=args.backend)
    else:
        run()
