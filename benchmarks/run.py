"""Benchmark orchestrator — one entry per paper table/figure plus the
kernel and roofline harnesses. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time


def _timed(name, fn, *a, **k):
    t0 = time.time()
    out = fn(*a, **k)
    dt = (time.time() - t0) * 1e6
    print(f"bench,{name},{dt:.0f},ok")
    return out


def main() -> None:
    print("name,us_per_call,derived")

    from benchmarks import table2_vision
    rows = _timed("table2_vision", table2_vision.run)

    from benchmarks import table3_table4_platforms
    _timed("table3_table4", table3_table4_platforms.run, table2_rows=rows)

    from benchmarks import fig10_scaling
    _timed("fig10_scaling", fig10_scaling.run)

    from benchmarks import sim_throughput
    _timed("sim_throughput", sim_throughput.run)

    from benchmarks import kernels_bench
    _timed("kernels", kernels_bench.run)

    # roofline over whatever dry-run artifacts exist (full table comes from
    # `python -m repro.launch.dryrun --all --mesh both`)
    from benchmarks import roofline
    try:
        cells = roofline.load_cells()
        if cells:
            _timed("roofline_report", roofline.report, mesh="pod16x16")
        else:
            print("bench,roofline_report,0,skipped(no artifacts)")
    except Exception as e:                       # pragma: no cover
        print(f"bench,roofline_report,0,error({e})")


if __name__ == "__main__":
    main()
