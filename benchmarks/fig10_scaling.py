"""Fig. 10 reproduction: per-inference HBM energy/latency scale linearly
with neuron count (paper: Energy = 0.0294x - 30.3, R^2 = 0.994;
Latency = 0.0658x - 53.0, R^2 = 0.995 for the DVS CNN family).

We sweep MLP widths on the engine and fit the same regressions; the claim
reproduced is the LINEARITY (R^2 > 0.97) and positive slope — absolute
slopes depend on fan-out structure, as the paper notes (MLP vs LeNet vs
CNN slopes differ by ~2-10x).
"""
from __future__ import annotations

import numpy as np

from repro.core.api import ANN_neuron, CRI_network


def _mlp(n_hidden, n_in=196, seed=0):
    rng = np.random.default_rng(seed)
    axons = {f"x{i}": [(f"h{j}", int(rng.integers(1, 9)))
                       for j in range(n_hidden)] for i in range(n_in)}
    neurons = {f"h{j}": ([(f"o{k}", int(rng.integers(1, 9)))
                          for k in range(10)],
                         ANN_neuron(threshold=n_in))
               for j in range(n_hidden)}
    for k in range(10):
        neurons[f"o{k}"] = ([], ANN_neuron(threshold=2 ** 30))
    return CRI_network(axons=axons, neurons=neurons,
                       outputs=[f"o{k}" for k in range(10)],
                       backend="engine", seed=seed), n_in


def run(sizes=(32, 64, 128, 256, 512), n_inf=5, quiet=False):
    rng = np.random.default_rng(3)
    es, ls, ns = [], [], []
    for nh in sizes:
        net, n_in = _mlp(nh)
        net.counter.reset()
        for _ in range(n_inf):
            net.reset()
            net.step([f"x{i}" for i in
                      rng.choice(n_in, n_in // 5, replace=False)])
            net.step([])
        ns.append(nh + 10)
        es.append(net.counter.energy_uJ() / n_inf)
        ls.append(net.counter.latency_us() / n_inf)
    x = np.array(ns, float)
    out = {}
    for label, ys in (("energy_uJ", np.array(es)),
                      ("latency_us", np.array(ls))):
        A = np.vstack([x, np.ones_like(x)]).T
        coef, res, *_ = np.linalg.lstsq(A, ys, rcond=None)
        ss = ((ys - ys.mean()) ** 2).sum()
        r2 = 1 - (res[0] / ss if len(res) else 0.0)
        out[label] = {"slope": float(coef[0]), "intercept": float(coef[1]),
                      "r2": float(r2)}
        if not quiet:
            print(f"fig10,{label},slope={coef[0]:.4f},"
                  f"intercept={coef[1]:.2f},r2={r2:.4f}")
    assert out["energy_uJ"]["r2"] > 0.97 and out["latency_us"]["r2"] > 0.97
    assert out["energy_uJ"]["slope"] > 0 and out["latency_us"]["slope"] > 0
    if not quiet:
        print("fig10,paper_energy,slope=0.0294,intercept=-30.29,r2=0.994")
        print("fig10,paper_latency,slope=0.0658,intercept=-53.03,r2=0.995")
    return out


if __name__ == "__main__":
    run()
