"""Fault-tolerant checkpointing (no orbax in this environment — built from
scratch).

Design (1000+ node deployment):
  * step-atomic directories: writes go to ``step_N.tmp/`` and are renamed to
    ``step_N/`` only after fsync — a node failure mid-save never corrupts
    the latest restorable step;
  * per-shard tensor files: each process saves only its addressable shards
    (``{leaf}.{shard_index}.npy``), so save bandwidth scales with the
    cluster and no host ever materializes a 405B-param tree;
  * an index (JSON) stores the treedef, global shapes/dtypes and shard
    grid, independent of the mesh — restoring onto a DIFFERENT mesh
    (elastic scale-up/down after node loss) reassembles global arrays and
    re-device_puts them to the new sharding (repro.distributed.elastic);
  * async save: the train loop hands off jax.device_get'd host copies to a
    writer thread (compute/IO overlap), with a barrier before the next
    save (at most one in flight);
  * data-pipeline cursors and PRNG state ride along in ``aux.json`` so
    restart is sample-exact.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't round-trip ml_dtypes (bf16/f8) through .npy: store raw bits
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _BITCAST:
        return arr.view(_BITCAST[name]), name
    return arr, name


def _from_saved(arr: np.ndarray, dtype_name: str):
    if dtype_name in _BITCAST:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


import re

_SLICE_RE = re.compile(r"slice\((\w+),\s*(\w+)(?:,\s*\w+)?\)")


def _parse_index(index_str: str, shape):
    """'(slice(0, 32, None), slice(None, None, None))' -> slice tuple."""
    slices = []
    for i, m in enumerate(_SLICE_RE.finditer(index_str)):
        a, b = m.group(1), m.group(2)
        slices.append(slice(None if a == "None" else int(a),
                            None if b == "None" else int(b)))
    if not slices:
        return tuple(slice(None) for _ in shape)
    while len(slices) < len(shape):
        slices.append(slice(None))
    return tuple(slices)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, jax.tree.structure(tree)


def save_tree(path: os.PathLike, tree, *, aux: Optional[Dict] = None):
    """Atomic save of a pytree of (possibly sharded) jax or numpy arrays."""
    path = Path(path)
    tmp = path.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = _flatten(tree)
    index = {"leaves": {}, "aux": aux or {}}
    for key, leaf in flat.items():
        arr = leaf
        fname = key.replace("/", "__")
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards") \
                and len(arr.addressable_shards) > 1:
            shards = []
            dtn = None
            for si, sh in enumerate(arr.addressable_shards):
                sf = f"{fname}.shard{si}.npy"
                data, dtn = _to_savable(np.asarray(sh.data))
                np.save(tmp / sf, data)
                shards.append({"file": sf, "index": str(sh.index)})
            index["leaves"][key] = {
                "shape": list(arr.shape), "dtype": dtn,
                "sharded": True, "shards": shards}
        else:
            data, dtn = _to_savable(np.asarray(arr))
            np.save(tmp / f"{fname}.npy", data)
            index["leaves"][key] = {
                "shape": list(np.shape(arr)), "dtype": dtn,
                "sharded": False, "file": f"{fname}.npy"}
    (tmp / "index.json").write_text(json.dumps(index))
    fd = os.open(tmp, os.O_RDONLY)
    os.fsync(fd)
    os.close(fd)
    if path.exists():
        shutil.rmtree(path)
    os.rename(tmp, path)


def restore_tree(path: os.PathLike, like, *, shardings=None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching pytree of
    NamedShardings for the (possibly different — elastic) target mesh."""
    path = Path(path)
    index = json.loads((path / "index.json").read_text())
    flat_like, _ = _flatten(like)
    flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out_flat = {}
    for key, meta in index["leaves"].items():
        if meta["sharded"]:
            # reassemble on host by each shard's saved global-slice index
            # (replicated copies simply overwrite with identical values)
            arr = None
            for s in meta["shards"]:
                part = _from_saved(np.load(path / s["file"]), meta["dtype"])
                if arr is None:
                    arr = np.empty(tuple(meta["shape"]), dtype=part.dtype)
                arr[_parse_index(s["index"], meta["shape"])] = part
        else:
            arr = _from_saved(np.load(path / meta["file"]), meta["dtype"])
        sh = flat_sh.get(key)
        out_flat[key] = jax.device_put(arr, sh) if sh is not None \
            else jax.numpy.asarray(arr)
    leaves, treedef = _flatten(like)
    missing = set(leaves) - set(out_flat)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    ordered = [out_flat[k] for k in leaves]
    return jax.tree.unflatten(jax.tree.structure(like), ordered), \
        index.get("aux", {})


class CheckpointManager:
    """Step-numbered checkpoints with retention, async save, and resume."""

    def __init__(self, root: os.PathLike, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def _dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def steps(self):
        return sorted(int(p.name.split("_")[1]) for p in
                      self.root.glob("step_*") if p.is_dir()
                      and not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, aux: Optional[Dict] = None,
             async_: bool = False):
        self.wait()                      # at most one save in flight
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)

        def work():
            save_tree(self._dir(step), host_tree,
                      aux={**(aux or {}), "step": step})
            self._gc()
        if async_:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, like, step: Optional[int] = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return restore_tree(self._dir(step), like, shardings=shardings)

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
