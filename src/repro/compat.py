"""Version-compat shims for the pinned toolchain.

The repo targets the container's jax 0.4.37, where `shard_map` still lives
in `jax.experimental.shard_map` and its replication-check kwarg is named
`check_rep`. Newer jax (>= 0.6) promotes it to `jax.shard_map` and renames
the kwarg to `check_vma`. Call sites import `shard_map` from here and may
pass `check_vma=...` uniformly; the shim forwards it under whichever name
the installed jax understands.
"""
from __future__ import annotations

import jax

try:                                    # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:                     # jax 0.4.x: experimental module
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"

__all__ = ["shard_map", "make_mesh"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """`jax.shard_map` with the modern keyword surface on any jax version."""
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """`jax.make_mesh` with explicit Auto axis types where supported.

    jax >= 0.6 takes `axis_types` (and `jax.sharding.AxisType` exists);
    jax 0.4.x has neither — every mesh axis is implicitly auto there, so
    dropping the kwarg is semantically identical."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=axis_types, devices=devices)
    except (AttributeError, TypeError):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             devices=devices)
