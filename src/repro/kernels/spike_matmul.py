"""Event-gated block-sparse spike SpMV — the TPU-native adaptation of the
paper's two-phase HBM synapse routing (DESIGN.md §2).

FPGA mechanism: for each fired neuron, fetch its synapse rows from HBM and
scatter-accumulate into membrane registers. TPUs have no efficient per-event
scatter, so the event-driven insight is lifted to BLOCK granularity:
synapses live in (BP x BN) int16 tiles (128-aligned, the MXU/VPU native
shape — the analogue of the 16-slot segment alignment); a scalar-prefetched
per-block spike count gates the whole tile with @pl.when, so presynaptic
blocks that carry no events are never multiplied — and with the block-count
vector known before the grid runs, the DMA pipeline skips their HBM reads,
which is precisely the paper's "energy ∝ HBM accesses touched by events".

Accumulation is int32 (exact, matches the fixed-point engine bit-for-bit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BP = 128     # presynaptic block
BN = 128     # postsynaptic block


def _kernel(counts_ref, spikes_ref, w_ref, out_ref):
    ip = pl.program_id(1)        # presynaptic block index (inner)

    @pl.when(ip == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(counts_ref[ip] > 0)
    def _accum():
        s = spikes_ref[...].astype(jnp.int32)          # (BP,)
        w = w_ref[...].astype(jnp.int32)               # (BP, BN)
        out_ref[...] += jnp.sum(s[:, None] * w, axis=0)


def spike_matmul(spikes, weights, *, interpret=None):
    """spikes: (Npre,) bool; weights: (Npre, Npost) int16.
    Returns (Npost,) int32. Npre/Npost must be multiples of 128
    (pad to segment boundaries — the compiler's alignment job)."""
    npre, npost = weights.shape
    assert npre % BP == 0 and npost % BN == 0, (npre, npost)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    s32 = spikes.astype(jnp.int32)
    counts = jnp.sum(s32.reshape(npre // BP, BP), axis=1)
    grid = (npost // BN, npre // BP)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),             # counts (SMEM-ish)
            pl.BlockSpec((BP,), lambda j, i: (i,)),        # spike block
            pl.BlockSpec((BP, BN), lambda j, i: (i, j)),   # weight tile
        ],
        out_specs=pl.BlockSpec((BN,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((npost,), jnp.int32),
        interpret=interpret,
    )(counts, s32, weights)
