"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spike_matmul_ref(spikes, weights):
    """Event-driven synaptic accumulation oracle.
    spikes: (Npre,) bool/int; weights: (Npre, Npost) int16.
    Returns (Npost,) int32 = Σ_pre spike * w."""
    return jnp.einsum("p,pn->n", spikes.astype(jnp.int32),
                      weights.astype(jnp.int32))


def lif_step_ref(V, syn_in, noise_u, theta, nu, lam, is_lif):
    """Fused LIF/ANN timestep oracle (Table 1 semantics; noise bits are
    pre-generated 17-bit draws, shift applied inside)."""
    from repro.core.neuron import leak, noise_from_u
    V = V + noise_from_u(noise_u, nu)
    spikes = V > theta
    V = jnp.where(spikes, 0, V)
    V = jnp.where(is_lif, leak(V, lam), 0)
    return V + syn_in, spikes


def flash_attention_ref(q, k, v, causal=True):
    """q,k,v: (B, H, S, D). fp32 softmax. Returns (B, H, S, D)."""
    S = q.shape[2]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
