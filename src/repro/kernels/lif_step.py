"""Fused LIF/ANN membrane-update kernel — the VMEM-resident analogue of the
paper's URAM membrane registers (DESIGN.md §2).

One pass over the neuron state vector does noise-shift, threshold/reset,
leak, and synaptic integration — V never round-trips to HBM between the
sub-steps (on the FPGA it never leaves URAM within a timestep). All math is
int32 and bit-exact against core.neuron (ref.lif_step_ref).

Noise bits are pre-generated 17-bit draws (uniform, from the host PRNG) so
the kernel is deterministic and byte-for-byte testable; on TPU the same
kernel can seed pltpu.prng_random_bits instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _kernel(V_ref, syn_ref, u_ref, theta_ref, nu_ref, lam_ref, lif_ref,
            Vout_ref, spike_ref):
    V = V_ref[...]
    u = u_ref[...] | 1
    nu = nu_ref[...]
    pos = jnp.minimum(jnp.maximum(nu, 0), 31)
    neg = jnp.minimum(jnp.maximum(-nu, 0), 31)
    mag = jnp.abs(u) >> neg
    xi = jnp.where(nu >= 0, u << pos, jnp.sign(u) * mag)
    V = V + xi
    spikes = V > theta_ref[...]
    V = jnp.where(spikes, 0, V)
    lam = lam_ref[...]
    pow2 = jnp.int32(1) << jnp.minimum(lam, 30)
    leaked = V - jnp.where(lam >= 31, V >> 31, V // pow2)
    V = jnp.where(lif_ref[...] != 0, leaked, 0)
    Vout_ref[...] = V + syn_ref[...]
    spike_ref[...] = spikes.astype(jnp.int32)


def lif_step(V, syn_in, noise_u, theta, nu, lam, is_lif, *, interpret=None):
    """All inputs (N,) int32 (is_lif: bool). Returns (V_next, spikes_bool).
    N must be a multiple of 256 (pad the membrane file)."""
    n = V.shape[0]
    assert n % BLOCK == 0, n
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    V_next, spikes = pl.pallas_call(
        _kernel,
        grid=(n // BLOCK,),
        in_specs=[spec] * 7,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
    )(V, syn_in, noise_u, theta, nu, lam, is_lif.astype(jnp.int32))
    return V_next, spikes.astype(bool)
