"""Hierarchical level-aware spike exchange — §3 / Fig. 1b in array form.

After every core's fire phase, the fired-neuron event vectors are
aggregated level by level up the deployment hierarchy — cores within an
FPGA over the NoC, FPGA aggregates within a server over FireFly, server
aggregates over Ethernet — until every core can see the global event
vector it needs to gate its white-matter tables. `hierarchical_gather`
expresses that as stacked per-level concatenations over the
(servers, fpgas, cores, neurons) axes; on one device each fold lowers to
a reshape inside the jit-compiled step, and the loop is the exact seam
where `shard_map` + `lax.all_gather` slot in when the core axis becomes
a real device mesh — `collective_stages` / `hierarchical_gather_collective`
realize that lowering for the mesh tier (core.mesh_runtime), one grouped
all-gather per hierarchy level (core.distributed_engine's pod-scale
dry-run consumes the same primitives).

The wire format is bit-packed by default: the fabric moves address-event
BITS, so fired flags pack to uint32 presence words (`pack_events`,
ceil(n_max/32) words per core) before any hop, and destinations read
their neurons' bits with one word gather + bit extract
(`kernels.route.packed_gather_counts` at `packed_positions`) — never a
full unpack. `exchange_packed` and
`hierarchical_gather_collective_packed` are the packed twins of the
int32-lane paths (`hierarchical_gather`'s folds are width-generic and
carry presence words as-is), bit-exact on counts and traffic since fired counts
are 0/1 by construction; `exchange_bytes_per_step` /
`event_vector_bytes` account the ~32x the packing buys per level and
per device.

The exchange also *measures* the traffic the partitioner's
`traffic_cost` only estimates: `build_dest_tables` precomputes, for
every source item, how many destination cores it reaches at each
hierarchy level (destination cores deduplicated per source — the HiAER
multicast granularity: one event per (source, destination core)
delivery). Per step, measured traffic is then the event counts dotted
with those static tables — the same gather-style bookkeeping as the
pointer/row access counts of `kernels.route`, and integer-identical to
`partition.level_event_counts` times the realized fire counts.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import LEVEL_NAMES
from repro.kernels import route as route_k

N_LEVELS = len(LEVEL_NAMES)    # local / NoC / FireFly / Ethernet
PACK_BITS = 32                 # presence bits per packed uint32 word


# ------------------------------------------------------ packed wire format
# The HiAER fabric moves address-event BITS, not int32 lanes: a fired
# flag is one bit on the wire. The packed representation stores each
# core's n_max presence bits as ceil(n_max / 32) uint32 words
# (LSB-first within a word), cutting every exchanged byte ~32x. Packing
# is lossless exactly because fired flags are 0/1; multi-event sources
# (axons driven k times per step) never ride the packed wire — their
# count vector is replicated input, not exchanged.

def packed_words(width: int) -> int:
    """Words per packed event vector of `width` presence bits."""
    # width is always a static shape, never a tracer
    return -(-max(int(width), 0) // PACK_BITS)  # tracelint: allow=host-scalar


def pack_events(bits):
    """(..., n) {0,1} flags -> (..., ceil(n/32)) uint32 presence words,
    bit i of word w = element w*32 + i (LSB-first). Ragged tails
    (n % 32 != 0) pad with zero bits; `unpack_events(_, n)` inverts
    exactly. jit/vmap/shard_map friendly (static shapes only)."""
    n = bits.shape[-1]
    W = packed_words(n)
    pad = [(0, 0)] * (bits.ndim - 1) + [(0, W * PACK_BITS - n)]
    b = jnp.pad(bits.astype(jnp.uint32), pad)
    b = b.reshape(bits.shape[:-1] + (W, PACK_BITS))
    shifts = jnp.arange(PACK_BITS, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def unpack_events(words, width: int):
    """Inverse of `pack_events`: (..., W) uint32 -> (..., width) int32
    presence flags (the first `width` bits, LSB-first per word)."""
    shifts = jnp.arange(PACK_BITS, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (-1,))
    return flat[..., :width].astype(jnp.int32)


def packed_positions(core, local, n_max: int):
    """Host-side word/bit coordinates of per-core slot (core, local) in
    the packed core-ordered wire vector: each core contributes
    `packed_words(n_max)` words, so slot (c, l) lives at bit l % 32 of
    word c * Wc + l // 32. These are the static gather tables the
    destination side uses to read presence bits without a full unpack
    (`kernels.route.packed_gather_counts`)."""
    Wc = packed_words(n_max)
    core = np.asarray(core, np.int64)
    local = np.asarray(local, np.int64)
    return ((core * Wc + local // PACK_BITS).astype(np.int32),
            (local % PACK_BITS).astype(np.int32))


class HierSpec(NamedTuple):
    """Static hierarchy shape: n_cores = servers * fpgas * cores."""
    servers: int
    fpgas: int          # per server
    cores: int          # per FPGA

    @classmethod
    def from_hierarchy(cls, hier) -> "HierSpec":
        return cls(hier.n_servers, hier.fpgas_per_server,
                   hier.cores_per_fpga)

    @property
    def n_cores(self) -> int:
        return self.servers * self.fpgas * self.cores


def hierarchical_gather(x_core, spec: HierSpec):
    """(C, n_max) per-core vectors -> (C * n_max,) core-ordered global
    vector, folded level by level: cores concatenate within their FPGA
    (NoC hop), FPGA blocks within their server (FireFly hop), server
    blocks globally (Ethernet hop). Single-device lowering of the
    hierarchical all-gather of Fig. 1b."""
    x = x_core.reshape(spec.servers, spec.fpgas, spec.cores, -1)
    x = x.reshape(spec.servers, spec.fpgas, -1)      # NoC: core -> FPGA
    x = x.reshape(spec.servers, -1)                  # FireFly: FPGA -> server
    return x.reshape(-1)                             # Ethernet: server -> all


def collective_stages(spec: HierSpec, n_dev: int) -> List[List[List[int]]]:
    """The device-mesh lowering plan for `hierarchical_gather`: one
    `axis_index_groups` list per hierarchy level, for a 1-D device mesh
    where each of `n_dev` devices owns C // n_dev consecutive cores.

    Stage l gathers the aggregates of the previous level's blocks within
    every level-l subtree (cores within an FPGA over the NoC, FPGA
    aggregates within a server over FireFly, server aggregates over
    Ethernet), so after all stages every device holds the global
    core-ordered vector — exactly `hierarchical_gather`'s folds, with
    each reshape replaced by a grouped `lax.all_gather`. Each group
    lists one representative per already-aggregated block (same offset r
    within the block, so the groups partition the devices); gathering in
    block order concatenates the aggregates in core order. Levels whose
    subtree is smaller than one device's core span fold into the next
    stage (their exchange is device-local); n_dev == 1 yields no stages
    at all."""
    C = spec.n_cores
    if n_dev < 1 or C % n_dev:
        raise ValueError(f"{n_dev} devices must evenly divide "
                         f"{C} cores")
    cpd = C // n_dev
    stages: List[List[List[int]]] = []
    b = 1                          # devices already aggregated per block
    for size in (spec.cores, spec.cores * spec.fpgas, C):
        if size % cpd:
            continue               # subtree not device-aligned: fold up
        L = size // cpd            # devices per level-l subtree
        if L <= b:
            continue               # subtree already within one block
        m = L // b                 # blocks to concatenate per subtree
        groups = []
        for blk in range(0, n_dev, L):
            for r in range(b):
                groups.append([blk + r + j * b for j in range(m)])
        stages.append(groups)
        b = L
    return stages


def hierarchical_gather_collective(x_local, stages, axis_name: str,
                                   axis: int = 0):
    """`hierarchical_gather` over a real device mesh: `x_local` is this
    device's flattened per-core block ((C // n_dev) * n_max,); each
    stage is one grouped tiled `lax.all_gather` along `axis_name` (the
    NoC / FireFly / Ethernet hop of Fig. 1b). Returns the (C * n_max,)
    core-ordered global vector, replicated on every device. Must run
    inside `shard_map` over the 1-D core/device mesh axis. `axis` is
    the array axis the gather concatenates along — leading axes before
    it (e.g. a folded sample batch) ride every hop unchanged, so B
    samples share one collective per level."""
    for groups in stages:
        x_local = jax.lax.all_gather(x_local, axis_name,
                                     axis_index_groups=groups,
                                     tiled=True, axis=axis)
    return x_local


def hierarchical_gather_collective_packed(words_local, stages,
                                          axis_name: str, axis: int = 0):
    """The packed-wire device-mesh exchange: every grouped
    `lax.all_gather` in `stages` runs over uint32 presence WORDS
    ((C // n_dev) * Wc per device) instead of int32 event lanes —
    per-level collective bytes and the replicated event-vector floor
    both drop ~32x. The hop plan is identical to the unpacked
    collective; only the payload dtype/width changes."""
    return hierarchical_gather_collective(words_local, stages, axis_name,
                                          axis=axis)


def exchange_bytes_per_step(spec: HierSpec, n_dev: int, n_max: int,
                            packed: bool = True) -> int:
    """Wire bytes one device RECEIVES per spike-exchange round under the
    `collective_stages` plan: at each stage every device gathers
    (group_size - 1) peer blocks of the current aggregate size, which
    then becomes the next stage's block. The packed wire carries
    `packed_words(n_max)` uint32 words per core; the unpacked wire one
    int32 lane per neuron slot — the ~32x the bitpacking buys. n_dev = 1
    has no collectives (0 wire bytes); see `event_vector_bytes` for the
    replicated per-device floor that shrinks even then."""
    per_core = packed_words(n_max) if packed else max(int(n_max), 0)
    block = (spec.n_cores // n_dev) * per_core * 4
    total = 0
    for groups in collective_stages(spec, n_dev):
        m = len(groups[0])
        total += (m - 1) * block
        block *= m
    return total


def event_vector_bytes(spec: HierSpec, n_max: int,
                       packed: bool = True) -> int:
    """Bytes of the replicated global event vector every device holds
    after the exchange — the per-device O(C * n_max) floor ROADMAP
    flags at 160M neurons. Packed: C * ceil(n_max/32) uint32 words."""
    per_core = packed_words(n_max) if packed else max(int(n_max), 0)
    return spec.n_cores * per_core * 4


def build_dest_tables(axon_syn: Dict[int, List[Tuple[int, int]]],
                      neuron_syn: Dict[int, List[Tuple[int, int]]],
                      axon_core: np.ndarray, neuron_core: np.ndarray,
                      hier, n_axon_slots: int,
                      n_neurons: int) -> Tuple[np.ndarray, np.ndarray]:
    """Static per-source destination tables: ndest[s, l] = number of
    distinct destination cores source s reaches at hierarchy level l
    (level of (home core of s, destination core), per
    `partition.Hierarchy.level`). Built from the user adjacency, not the
    packed image, so A.3 filler records never count as traffic."""
    def table(adjacency, src_core, width):
        nd = np.zeros((width, N_LEVELS), np.int32)
        for s, syns in adjacency.items():
            if not 0 <= s < width:
                continue
            dests = {int(neuron_core[p]) for p, _ in syns
                     if 0 <= p < n_neurons}
            for d in dests:
                nd[s, hier.level(int(src_core[s]), d)] += 1
        return nd

    return (table(axon_syn, np.asarray(axon_core), n_axon_slots),
            table(neuron_syn, np.asarray(neuron_core), n_neurons))


def levels_between(core_a, core_b, hier) -> np.ndarray:
    """Vectorized `partition.Hierarchy.level`: per-pair interconnect
    level (0 local, 1 NoC, 2 FireFly, 3 Ethernet)."""
    ca = np.asarray(core_a, np.int64)
    cb = np.asarray(core_b, np.int64)
    fa, fb = ca // hier.cores_per_fpga, cb // hier.cores_per_fpga
    sa, sb = fa // hier.fpgas_per_server, fb // hier.fpgas_per_server
    return np.where(ca == cb, 0,
                    np.where(fa == fb, 1, np.where(sa == sb, 2, 3)))


def build_dest_tables_columns(pre_item: np.ndarray, post: np.ndarray,
                              axon_core: np.ndarray,
                              neuron_core: np.ndarray, hier,
                              n_axon_slots: int, n_neurons: int
                              ) -> Tuple[np.ndarray, np.ndarray]:
    """Columnar twin of `build_dest_tables` (bit-identical output): one
    vectorized pass over the synapse columns instead of a per-synapse
    Python loop. `pre_item` is in engine item space (axon id, or
    n_axon_slots + neuron id); filler records must be excluded by the
    caller — the tables describe the user adjacency, not the packed
    image."""
    A, N = int(n_axon_slots), int(n_neurons)
    pre_item = np.asarray(pre_item, np.int64)
    post = np.asarray(post, np.int64)
    axon_ndest = np.zeros((A, N_LEVELS), np.int32)
    neuron_ndest = np.zeros((N, N_LEVELS), np.int32)
    if pre_item.size == 0 or N == 0:
        return axon_ndest, neuron_ndest
    core_of = np.asarray(neuron_core, np.int64)
    dest = core_of[post]
    # HiAER multicast granularity: one event per (source item,
    # destination core), so dedup the pairs before counting
    pair = np.unique(pre_item * max(hier.n_cores, 1) + dest)
    item = pair // max(hier.n_cores, 1)
    dcore = pair % max(hier.n_cores, 1)
    is_axon = item < A
    src = np.where(is_axon,
                   np.asarray(axon_core, np.int64)[
                       np.clip(item, 0, max(A - 1, 0))],
                   core_of[np.clip(item - A, 0, N - 1)])
    lvl = levels_between(src, dcore, hier)
    counts = np.bincount(item * N_LEVELS + lvl,
                         minlength=(A + N) * N_LEVELS) \
        .reshape(A + N, N_LEVELS).astype(np.int32)
    axon_ndest[:, :] = counts[:A]
    neuron_ndest[:, :] = counts[A:]
    return axon_ndest, neuron_ndest


class ExchangeTables(NamedTuple):
    """Device-resident exchange state (pytree — passed as a traced
    argument so placements/weights swap without recompiling).
    `pos_word`/`pos_bit` are the packed-wire coordinates of each neuron
    (`packed_positions` of its (core, local) slot) — the word-gather
    tables of the bit-packed exchange."""
    pos_of_neuron: jnp.ndarray     # (N,) flat (core * n_max + local) slot
    axon_ndest: jnp.ndarray        # (A, N_LEVELS) int32
    neuron_ndest: jnp.ndarray      # (N, N_LEVELS) int32
    pos_word: jnp.ndarray          # (N,) int32 packed-wire word index
    pos_bit: jnp.ndarray           # (N,) int32 bit within the word


def exchange(spikes_core, axon_counts, spec: HierSpec,
             tables: ExchangeTables):
    """One spike-exchange round: per-core fired flags (C, n_max) bool +
    driven-axon counts (A,) int32 -> (global fired-neuron counts (N,)
    int32 in global id order, measured per-level traffic (N_LEVELS,)
    int32). Driven axons are events too: an axon driven k times sends k
    events to each of its destination cores, matching the pointer-queue
    multiplicity of the routing phase."""
    flat = hierarchical_gather(spikes_core.astype(jnp.int32), spec)
    neuron_counts = flat[tables.pos_of_neuron]
    traffic = (axon_counts @ tables.axon_ndest
               + neuron_counts @ tables.neuron_ndest)
    return neuron_counts, traffic


def exchange_packed(spikes_core, axon_counts, spec: HierSpec,
                    tables: ExchangeTables):
    """Bit-exact twin of `exchange` over the packed uint32 wire format:
    fired flags are packed to presence words BEFORE the level folds, and
    each destination reads its neurons' bits with one word gather + bit
    extract (`kernels.route.packed_gather_counts`) — never a full
    unpack, since fired counts are 0/1 by construction. Traffic tallies
    are computed from the recovered counts against the same static ndest
    tables, so per-level traffic is integer-identical to the unpacked
    exchange."""
    words = pack_events(spikes_core)
    flat = hierarchical_gather(words, spec)
    neuron_counts = route_k.packed_gather_counts(flat, tables.pos_word,
                                                 tables.pos_bit)
    traffic = (axon_counts @ tables.axon_ndest
               + neuron_counts @ tables.neuron_ndest)
    return neuron_counts, traffic
