"""Hierarchical level-aware spike exchange — §3 / Fig. 1b in array form.

After every core's fire phase, the fired-neuron event vectors are
aggregated level by level up the deployment hierarchy — cores within an
FPGA over the NoC, FPGA aggregates within a server over FireFly, server
aggregates over Ethernet — until every core can see the global event
vector it needs to gate its white-matter tables. `hierarchical_gather`
expresses that as stacked per-level concatenations over the
(servers, fpgas, cores, neurons) axes; on one device each fold lowers to
a reshape inside the jit-compiled step, and the loop is the exact seam
where `shard_map` + `lax.all_gather` slot in when the core axis becomes
a real device mesh (cf. core.distributed_engine's dense dry-run).

The exchange also *measures* the traffic the partitioner's
`traffic_cost` only estimates: `build_dest_tables` precomputes, for
every source item, how many destination cores it reaches at each
hierarchy level (destination cores deduplicated per source — the HiAER
multicast granularity: one event per (source, destination core)
delivery). Per step, measured traffic is then the event counts dotted
with those static tables — the same gather-style bookkeeping as the
pointer/row access counts of `kernels.route`, and integer-identical to
`partition.level_event_counts` times the realized fire counts.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import LEVEL_NAMES

N_LEVELS = len(LEVEL_NAMES)    # local / NoC / FireFly / Ethernet


class HierSpec(NamedTuple):
    """Static hierarchy shape: n_cores = servers * fpgas * cores."""
    servers: int
    fpgas: int          # per server
    cores: int          # per FPGA

    @classmethod
    def from_hierarchy(cls, hier) -> "HierSpec":
        return cls(hier.n_servers, hier.fpgas_per_server,
                   hier.cores_per_fpga)

    @property
    def n_cores(self) -> int:
        return self.servers * self.fpgas * self.cores


def hierarchical_gather(x_core, spec: HierSpec):
    """(C, n_max) per-core vectors -> (C * n_max,) core-ordered global
    vector, folded level by level: cores concatenate within their FPGA
    (NoC hop), FPGA blocks within their server (FireFly hop), server
    blocks globally (Ethernet hop). Single-device lowering of the
    hierarchical all-gather of Fig. 1b."""
    x = x_core.reshape(spec.servers, spec.fpgas, spec.cores, -1)
    x = x.reshape(spec.servers, spec.fpgas, -1)      # NoC: core -> FPGA
    x = x.reshape(spec.servers, -1)                  # FireFly: FPGA -> server
    return x.reshape(-1)                             # Ethernet: server -> all


def build_dest_tables(axon_syn: Dict[int, List[Tuple[int, int]]],
                      neuron_syn: Dict[int, List[Tuple[int, int]]],
                      axon_core: np.ndarray, neuron_core: np.ndarray,
                      hier, n_axon_slots: int,
                      n_neurons: int) -> Tuple[np.ndarray, np.ndarray]:
    """Static per-source destination tables: ndest[s, l] = number of
    distinct destination cores source s reaches at hierarchy level l
    (level of (home core of s, destination core), per
    `partition.Hierarchy.level`). Built from the user adjacency, not the
    packed image, so A.3 filler records never count as traffic."""
    def table(adjacency, src_core, width):
        nd = np.zeros((width, N_LEVELS), np.int32)
        for s, syns in adjacency.items():
            if not 0 <= s < width:
                continue
            dests = {int(neuron_core[p]) for p, _ in syns
                     if 0 <= p < n_neurons}
            for d in dests:
                nd[s, hier.level(int(src_core[s]), d)] += 1
        return nd

    return (table(axon_syn, np.asarray(axon_core), n_axon_slots),
            table(neuron_syn, np.asarray(neuron_core), n_neurons))


class ExchangeTables(NamedTuple):
    """Device-resident exchange state (pytree — passed as a traced
    argument so placements/weights swap without recompiling)."""
    pos_of_neuron: jnp.ndarray     # (N,) flat (core * n_max + local) slot
    axon_ndest: jnp.ndarray        # (A, N_LEVELS) int32
    neuron_ndest: jnp.ndarray      # (N, N_LEVELS) int32


def exchange(spikes_core, axon_counts, spec: HierSpec,
             tables: ExchangeTables):
    """One spike-exchange round: per-core fired flags (C, n_max) bool +
    driven-axon counts (A,) int32 -> (global fired-neuron counts (N,)
    int32 in global id order, measured per-level traffic (N_LEVELS,)
    int32). Driven axons are events too: an axon driven k times sends k
    events to each of its destination cores, matching the pointer-queue
    multiplicity of the routing phase."""
    flat = hierarchical_gather(spikes_core.astype(jnp.int32), spec)
    neuron_counts = flat[tables.pos_of_neuron]
    traffic = (axon_counts @ tables.axon_ndest
               + neuron_counts @ tables.neuron_ndest)
    return neuron_counts, traffic
