"""Vectorized two-phase HiAER event routing — §4 / Fig. 2 in array form.

The seed engine walked the pointer queue in host Python, one pointer and
one synapse row at a time. Here both phases are data-parallel over the
whole (rows, 16-slot) HBM table:

  phase 1 (pointer fetch) becomes two gathers through the `FlatImage`
  inverse-pointer maps: a row is "live" iff its owning axon was driven or
  its owning neuron fired this step — `row_gate` is the per-row event
  count (axons may be driven multiple times per step, matching the seed
  queue semantics);

  phase 2 (synapse fetch + accumulate) becomes a masked gather +
  `segment_sum` over all (row, slot) lanes: every slot's weight is scaled
  by its row's gate and scattered to its postsynaptic neuron. Empty slots
  hold weight 0 and A.3 filler records are zero-weight by construction, so
  the dense formulation is bit-exact vs the event queue (int32 wraparound
  addition is associative and order-free).

Three accumulate formulations (all bit-exact vs the event queue — int32
wraparound addition is associative and order-free):

  * `accumulate` — per-neuron gathers through a padded fan-in transpose;
    the default when the padding stays economical (`fanin_is_economical`).
  * `accumulate_csr` — the synapse records sorted by postsynaptic neuron
    once at build time; a segment sum becomes cumsum + boundary gathers
    (`csr_segment_sum`), linear in synapses with no scatter anywhere.
    This is the hub-topology path (a power-law in-degree would blow up
    the fan-in padding), and — vmapped over the core axis — the per-core
    accumulate of the hierarchical engine (core.hiaer).
  * `accumulate_scatter` — the natural segment_sum/scatter form (fast on
    TPU, serial on CPU XLA); kept for benchmarks and as the formulation
    the other two are tested against.

Plus:

  * `route_event_counts` + `route` — pure jnp, jit/vmap/scan friendly;
    the production path (`EventEngine.step/run/run_batch`).
  * `fused_route_lif_step` — a Pallas kernel that folds the slot-lane
    accumulation into the `lif_step` membrane update: the grid walks row
    blocks accumulating per-lane partial sums in the output ref, and the
    final grid step applies noise/threshold/reset/leak/integrate in the
    same VMEM pass, so V is read and written exactly once per timestep
    (the URAM-resident membrane file of the FPGA; V never round-trips to
    HBM between the two phases).

Access statistics (`pointer_reads`, `row_reads`) are computed from the
same gathers and are integer-identical to the seed `AccessCounter`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import neuron as nrn
from repro.core.hbm import SLOTS, FlatImage

ROW_BLOCK = 32          # synapse rows per fused-kernel grid step


class RouteTables(NamedTuple):
    """Device-resident copy of `FlatImage` (int32/bool jnp arrays), plus a
    precomputed fan-in transpose of the synapse table.

    XLA's CPU scatter-add makes `segment_sum` the bottleneck (~10x slower
    than the rest of the step), so the default accumulate path inverts the
    table once at build time: for every postsynaptic neuron, `fanin_src`
    lists the flattened (row * SLOTS + slot) positions of all synapse
    records targeting it, padded to the max in-degree with a sentinel that
    points at an appended always-zero weight. Phase 2 is then pure
    gathers + a row-wise sum — no scatter anywhere. int32 wraparound
    addition is order-free, so this is bit-exact vs the event queue."""
    syn_post: jnp.ndarray          # (R, SLOTS)
    syn_weight: jnp.ndarray        # (R, SLOTS)
    axon_rows: jnp.ndarray         # (A,)
    axon_present: jnp.ndarray      # (A,) bool
    neuron_rows: jnp.ndarray       # (N,)
    neuron_present: jnp.ndarray    # (N,) bool
    row_owner_axon: jnp.ndarray    # (R,)
    row_owner_neuron: jnp.ndarray  # (R,)
    fanin_src: jnp.ndarray         # (n_neurons, max_indeg) int32
    fanin_row: jnp.ndarray         # (n_neurons, max_indeg) int32
    syn_weight_ext: jnp.ndarray    # (R * SLOTS + 1,) int32, [-1] == 0
    csr_pos: jnp.ndarray           # (nnz,) int32 flat (row*SLOTS+slot)
    csr_row: jnp.ndarray           # (nnz,) int32 owning synapse row
    csr_indptr: jnp.ndarray        # (n_neurons + 1,) int32, post-sorted

    @classmethod
    def from_flat(cls, flat: FlatImage, n_neurons: int,
                  build_fanin: bool = True) -> "RouteTables":
        """build_fanin=False skips the transpose (placeholder arrays) for
        topologies where max-in-degree padding would blow up — see
        `fanin_is_economical`; `route` then uses the CSR path. The CSR
        arrays (nnz-sized, cheap) are always built so any mode can run on
        any tables."""
        if build_fanin:
            src, row = _fanin_transpose(flat, n_neurons)
        else:
            # zero-size placeholders: a real transpose is never empty
            # (every neuron owns at least one filler synapse), so
            # `route(mode="fanin")` can reject these loudly.
            src = np.zeros((0, 1), np.int32)
            row = np.zeros((0, 1), np.int32)
        csr_pos, csr_row, csr_indptr = _csr_transpose(flat, n_neurons)
        w_ext = np.append(flat.syn_weight.reshape(-1), np.int32(0))
        return cls(
            syn_post=jnp.asarray(flat.syn_post),
            syn_weight=jnp.asarray(flat.syn_weight),
            axon_rows=jnp.asarray(flat.axon_rows),
            axon_present=jnp.asarray(flat.axon_present),
            neuron_rows=jnp.asarray(flat.neuron_rows),
            neuron_present=jnp.asarray(flat.neuron_present),
            row_owner_axon=jnp.asarray(flat.row_owner_axon),
            row_owner_neuron=jnp.asarray(flat.row_owner_neuron),
            fanin_src=jnp.asarray(src),
            fanin_row=jnp.asarray(row),
            syn_weight_ext=jnp.asarray(w_ext, jnp.int32),
            csr_pos=jnp.asarray(csr_pos),
            csr_row=jnp.asarray(csr_row),
            csr_indptr=jnp.asarray(csr_indptr),
        )

    def with_weights(self, syn_weight) -> "RouteTables":
        """Refresh after an in-place weight edit (same sparsity pattern)."""
        w = np.asarray(syn_weight, np.int32)
        w_ext = np.append(w.reshape(-1), np.int32(0))
        return self._replace(syn_weight=jnp.asarray(w),
                             syn_weight_ext=jnp.asarray(w_ext))


def fanin_is_economical(flat: FlatImage, n_neurons: int,
                        max_expand: float = 8.0) -> bool:
    """The fan-in transpose pads every neuron to the global max in-degree,
    so a single hub neuron can inflate it to N x max_indeg. Use it only
    when the padded size stays within `max_expand` x the actual synapse
    count; otherwise the engine routes through `accumulate_csr`
    (linear in synapses, scatter-free)."""
    flat_post = flat.syn_post.reshape(-1)
    valid = flat_post >= 0
    nnz = int(valid.sum())
    if nnz == 0:
        return True
    deg = np.bincount(np.clip(flat_post[valid], 0, max(n_neurons - 1, 0)),
                      minlength=max(n_neurons, 1))
    return n_neurons * int(deg.max()) <= max_expand * nnz + 1024


def _fanin_transpose(flat: FlatImage, n_neurons: int):
    """(N, max_indeg) source-position and source-row matrices. A.3 filler
    posts beyond n_neurons - 1 are clipped like the seed loop (their
    weight is 0 by construction); pad entries use the sentinel R * SLOTS
    (appended zero weight), so no separate mask is needed."""
    flat_post = flat.syn_post.reshape(-1)
    sentinel = flat_post.size
    pos = np.nonzero(flat_post >= 0)[0]
    tgt = np.clip(flat_post[pos], 0, max(n_neurons - 1, 0))
    order = np.argsort(tgt, kind="stable")
    pos, tgt = pos[order], tgt[order]
    deg = np.bincount(tgt, minlength=n_neurons)
    maxdeg = max(int(deg.max()) if deg.size else 0, 1)
    src = np.full((max(n_neurons, 1), maxdeg), sentinel, np.int32)
    ptr = np.zeros(n_neurons + 1, np.int64)
    np.cumsum(deg, out=ptr[1:])
    if pos.size:
        # pos is stably sorted by tgt, so each entry's column is its
        # global rank minus its neuron's group start — one scatter.
        col = np.arange(pos.size, dtype=np.int64) - ptr[tgt]
        src[tgt, col] = pos
    row = np.minimum(src // SLOTS, flat.syn_post.shape[0] - 1).astype(
        np.int32)
    return src, row


def _csr_transpose(flat: FlatImage, n_neurons: int):
    """Valid synapse positions sorted by postsynaptic neuron: returns
    (pos (nnz,), row (nnz,), indptr (n_neurons + 1,)). A.3 filler posts
    beyond n_neurons - 1 are clipped like the seed loop and the fan-in
    transpose (zero weight, numerically inert)."""
    flat_post = flat.syn_post.reshape(-1)
    pos = np.nonzero(flat_post >= 0)[0]
    tgt = np.clip(flat_post[pos], 0, max(n_neurons - 1, 0))
    order = np.argsort(tgt, kind="stable")
    pos, tgt = pos[order], tgt[order]
    indptr = np.zeros(n_neurons + 1, np.int32)
    np.cumsum(np.bincount(tgt, minlength=n_neurons), out=indptr[1:])
    row = (pos // SLOTS).astype(np.int32)
    return pos.astype(np.int32), row, indptr


def csr_segment_sum(vals, indptr):
    """Segment sums of `vals` (..., nnz) over the contiguous segments
    delimited by `indptr` (..., n_segments + 1): inclusive cumsum +
    boundary gathers — no scatter, linear in nnz, and exact under int32
    wraparound (cs[j] - cs[i] recovers the segment sum mod 2^32 no matter
    how the running sum wraps). Leading batch axes broadcast through, so
    a (C, nnz) per-core stack reduces in one call (core.hiaer)."""
    zero = jnp.zeros(vals.shape[:-1] + (1,), vals.dtype)
    cs = jnp.concatenate([zero, jnp.cumsum(vals, axis=-1)], axis=-1)
    return (jnp.take_along_axis(cs, indptr[..., 1:], axis=-1)
            - jnp.take_along_axis(cs, indptr[..., :-1], axis=-1))


def ragged_segment_sum(vals, indptr):
    """Segment sums of the FLAT `vals` (..., nnz) over segments
    delimited by ABSOLUTE offsets `indptr` (..., n_segments + 1): one
    inclusive cumsum + fancy boundary gathers. Unlike `csr_segment_sum`
    (which broadcasts a batched vals axis against matching indptr
    axes), the leading axes of `indptr` all index into the same flat
    value axis — the ragged per-core layout of `hbm.CoreShards`, where
    core c's segment offsets live in row c of `indptr` and shard memory
    stays linear in synapses. Leading axes of `vals` (a folded sample
    batch) broadcast through: (B, nnz) vals x (C, S + 1) indptr ->
    (B, C, S). Exact under int32 wraparound (cs[j] - cs[i] recovers the
    segment sum mod 2^32)."""
    zero = jnp.zeros(vals.shape[:-1] + (1,), vals.dtype)
    cs = jnp.concatenate([zero, jnp.cumsum(vals, axis=-1)], axis=-1)
    return cs[..., indptr[..., 1:]] - cs[..., indptr[..., :-1]]


def accumulate_csr(tables: RouteTables, row_gate, n_neurons: int):
    """Phase 2 via the post-sorted CSR: gather each record's weight and
    owning-row gate in post order, then `csr_segment_sum`. Linear in
    synapses regardless of the in-degree distribution — the hub-topology
    path where the fan-in padding is uneconomical. Bit-exact vs the
    other accumulate formulations and the seed event queue."""
    vals = (tables.syn_weight_ext[tables.csr_pos]
            * row_gate[tables.csr_row])
    return csr_segment_sum(vals, tables.csr_indptr)


def access_counts(axon_counts, neuron_counts, axon_rows, axon_present,
                  neuron_rows, neuron_present):
    """Exact HBM access tallies from per-item event counts and the
    pointer span tables: one pointer read per driven/fired item with a
    pointer, one row read per spanned synapse row per event — the seed
    `AccessCounter` semantics, shared by the monolithic engine
    (`route_event_counts`) and the sharded hiaer engine (which counts
    against the monolithic spans so its tallies stay bit-exact vs
    `backend="engine"`). Counts may carry leading batch axes (the
    batched mesh step): tallies reduce over the item axis only, one
    scalar pair per sample."""
    ax_ct = axon_counts * axon_present
    nr_ct = neuron_counts * neuron_present
    pointer_reads = ax_ct.sum(axis=-1) + nr_ct.sum(axis=-1)
    row_reads = ((ax_ct * axon_rows).sum(axis=-1)
                 + (nr_ct * neuron_rows).sum(axis=-1))
    return ax_ct, nr_ct, pointer_reads, row_reads


# --------------------------------------------------- packed-wire consume
def popcount32(x):
    """Per-word bit population count of uint32 presence words (SWAR —
    the FPGA's event-count reduction over a packed spike word). Returns
    int32; summing it over a packed event vector counts the fired
    events without ever unpacking."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def packed_gather_counts(words, word_idx, bit_idx):
    """Per-item 0/1 event counts straight off the packed wire: one word
    gather + bit extract per destination item — no full unpack of the
    global event vector. `words` (..., W) uint32 presence words (leading
    axes = folded sample batch); `word_idx`/`bit_idx` (N,) int32 from
    `kernels.exchange.packed_positions`. Returns (..., N) int32.

    Presence bits carry counts of 0/1 exactly — which fired-neuron
    events always are. Multi-event sources (axons driven k > 1 times
    per step) cannot ride a presence bit; their counts stay on the
    replicated int32 side (or fall back to `exchange.unpack_events` of
    a per-count bit plane), which is why only the spike vector is
    packed on the wire."""
    w = jnp.take(words, word_idx, axis=-1)
    return ((w >> bit_idx.astype(jnp.uint32)) & jnp.uint32(1)) \
        .astype(jnp.int32)


def route_event_counts(tables: RouteTables, axon_counts, spikes):
    """Phase-1 bookkeeping: per-row event gate + exact HBM access counts.

    axon_counts: (A,) int32 — how many times each axon was driven this
    step (seed queue enqueued one pointer per occurrence).
    spikes: (N,) bool — neurons that fired this step.

    Returns (row_gate (R,) int32, pointer_reads, row_reads) where the two
    scalars match the seed `AccessCounter` increments bit for bit."""
    ax_ct, nr_ct, pointer_reads, row_reads = access_counts(
        axon_counts, spikes.astype(jnp.int32),
        tables.axon_rows, tables.axon_present,
        tables.neuron_rows, tables.neuron_present)
    n_a = tables.axon_rows.shape[0]
    n_n = tables.neuron_rows.shape[0]
    gate_a = jnp.where(
        tables.row_owner_axon >= 0,
        ax_ct[jnp.clip(tables.row_owner_axon, 0, n_a - 1)], 0)
    gate_n = jnp.where(
        tables.row_owner_neuron >= 0,
        nr_ct[jnp.clip(tables.row_owner_neuron, 0, n_n - 1)], 0)
    return gate_a + gate_n, pointer_reads, row_reads


def accumulate_scatter(tables: RouteTables, row_gate, n_neurons: int):
    """Phase 2 as gated gather + segment_sum over the (R, SLOTS) lanes.
    Returns syn_in (n_neurons,) int32. A.3 filler posts may exceed
    n_neurons - 1; they are zero-weight, so the clip is numerically inert
    (same trick as the seed loop). Kept as the scatter formulation (the
    natural one on TPU); CPU XLA lowers it to a serial scatter-add, which
    is why the engine default is `accumulate` below."""
    w = tables.syn_weight * row_gate[:, None]
    idx = jnp.clip(tables.syn_post, 0, n_neurons - 1)
    w = jnp.where(tables.syn_post >= 0, w, 0)
    return jax.ops.segment_sum(w.reshape(-1), idx.reshape(-1),
                               num_segments=n_neurons)


def accumulate(tables: RouteTables, row_gate, n_neurons: int):
    """Phase 2 via the precomputed fan-in transpose: per-neuron gathers of
    (weight, owning-row gate) followed by a row-wise sum — scatter-free.
    Bit-exact vs `accumulate_scatter` and the seed event queue."""
    if tables.fanin_src.shape[0] == 0:
        raise ValueError("tables built with build_fanin=False; use "
                         "accumulate_csr (route(mode=\"csr\"))")
    w = tables.syn_weight_ext[tables.fanin_src]      # (N, D)
    g = row_gate[tables.fanin_row]                   # (N, D)
    return jnp.sum(w * g, axis=1)[:n_neurons]


ACCUMULATE_MODES = {
    "fanin": accumulate,
    "csr": accumulate_csr,
    "scatter": accumulate_scatter,
}


def route(tables: RouteTables, axon_counts, spikes, n_neurons: int,
          mode: str = "fanin"):
    """Full two-phase routing step. Returns (syn_in, ptr_reads, row_reads).
    `mode` is a trace-time switch between the accumulate formulations:
    "fanin" (padded transpose gathers), "csr" (post-sorted cumsum — the
    hub-topology fallback), "scatter" (segment_sum)."""
    gate, ptr_reads, row_reads = route_event_counts(tables, axon_counts,
                                                    spikes)
    acc = ACCUMULATE_MODES[mode]
    return acc(tables, gate, n_neurons), ptr_reads, row_reads


# ----------------------------------------------------- fused Pallas variant
def _pad_rows(x, mult):
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=0)


def _fused_kernel(post_ref, w_ref, V_ref, u_ref, theta_ref, nu_ref,
                  lam_ref, lif_ref, Vout_ref):
    pi = pl.program_id(0)
    nb = pl.num_programs(0)
    n16 = Vout_ref.shape[0]

    @pl.when(pi == 0)
    def _init():
        Vout_ref[...] = jnp.zeros_like(Vout_ref)

    # --- accumulate this row block's gated weights into the (n16, SLOTS)
    # lane accumulator (Vout doubles as the accumulator until the final
    # grid step). Slot alignment (slot == post % 16) means slot s only
    # ever feeds lane s, so the scatter is a per-lane one-hot reduction.
    post = post_ref[...]                         # (ROW_BLOCK, SLOTS)
    w = w_ref[...]                               # gated, 0 where inactive
    ids16 = jnp.maximum(post, 0) // SLOTS        # target row in the lane file
    onehot = (ids16[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32,
                                          (1, 1, n16), 2))
    contrib = jnp.sum(w[:, :, None] * onehot, axis=0)      # (SLOTS, n16)
    Vout_ref[...] += contrib.T

    # --- final grid step: the lif_step membrane pass, reading V once and
    # writing the integrated result over the accumulator in place.
    @pl.when(pi == nb - 1)
    def _membrane():
        V = V_ref[...]
        V = V + nrn.noise_from_u(u_ref[...], nu_ref[...])
        spikes = V > theta_ref[...]
        V = jnp.where(spikes, 0, V)
        V = jnp.where(lif_ref[...] != 0, nrn.leak(V, lam_ref[...]), 0)
        Vout_ref[...] = V + Vout_ref[...]


def fused_route_lif_step(tables: RouteTables, axon_counts, V, noise_u,
                         theta, nu, lam, is_lif, *, interpret=None):
    """One fused engine timestep: fire + route + integrate in one kernel.

    Spikes are derived twice from the same (V, noise) — once here in jnp to
    gate the synapse rows, once inside the kernel for the reset — which is
    cheaper than materializing V_mid between phases (the seed engine wrote
    V after fire_phase and read it back for integrate_phase).

    All neuron vectors are (N,) int32 (is_lif bool); returns
    (V_next (N,), spikes (N,) bool, ptr_reads, row_reads), bit-exact vs
    `core.neuron.fire_phase` + `route` + `integrate_phase`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = V.shape[0]
    spikes = (V + nrn.noise_from_u(noise_u, nu)) > theta
    gate, ptr_reads, row_reads = route_event_counts(tables, axon_counts,
                                                    spikes)
    w = tables.syn_weight * gate[:, None]
    w = jnp.where(tables.syn_post >= 0, w, 0)
    post = _pad_rows(tables.syn_post, ROW_BLOCK)
    w = _pad_rows(w, ROW_BLOCK)

    # membrane file as (n16, SLOTS) — neuron id n lives at (n // 16, n % 16),
    # the paper's 16-lane layout. Pad N to a whole number of lane rows; the
    # pad region only ever receives zero-weight filler contributions.
    n16 = max((n + SLOTS - 1) // SLOTS, 1)

    def to_lane(x):
        pad = n16 * SLOTS - n
        x = jnp.pad(x, (0, pad), constant_values=0)
        return x.reshape(n16, SLOTS)

    row_blocks = post.shape[0] // ROW_BLOCK
    rspec = pl.BlockSpec((ROW_BLOCK, SLOTS), lambda i: (i, 0))
    fspec = pl.BlockSpec((n16, SLOTS), lambda i: (0, 0))
    V_out = pl.pallas_call(
        _fused_kernel,
        grid=(row_blocks,),
        in_specs=[rspec, rspec] + [fspec] * 6,
        out_specs=fspec,
        out_shape=jax.ShapeDtypeStruct((n16, SLOTS), jnp.int32),
        interpret=interpret,
    )(post, w, to_lane(V), to_lane(noise_u), to_lane(theta), to_lane(nu),
      to_lane(lam), to_lane(is_lif.astype(jnp.int32)))
    return V_out.reshape(-1)[:n], spikes, ptr_reads, row_reads
