"""Blocked online-softmax (flash) attention forward — the TPU runtime path
for 32k-token prefill (models/attention.py uses the rematerialized-XLA
equivalent in dry-run lowering; this kernel is the hardware hot-spot).

Grid (B*H, nq, nk), kv innermost; VMEM scratch carries the running
(max, denom, accum) across kv blocks; causal block-skipping via @pl.when
(a query block never touches kv blocks in its future — the same
event-gating shape as spike_matmul, applied to the attention mask).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq, bk, causal, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                       # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _kernel_lse(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
                *, bq, bk, causal, scale):
    """Forward that additionally emits logsumexp rows (for the backward)."""
    _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            bq=bq, bk=bk, causal=causal, scale=scale)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == nk - 1)
    def _emit():
        lse_ref[0] = m_ref[...] + jnp.log(
            jnp.maximum(l_ref[...], 1e-30))


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, bq, bk, causal, scale):
    """Grid (bh, nk, nq): accumulate dK/dV for one kv block across q blocks.
    p recomputed from (q, k, lse); ds = p * (do v^T - delta)."""
    kj = pl.program_id(1)
    qi = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(qi == 0)
    def _init():
        dk_ref[0] = jnp.zeros_like(dk_ref[0])
        dv_ref[0] = jnp.zeros_like(dv_ref[0])

    run = (not causal) or (kj * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = q @ k.T                                    # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG)
        p = jnp.exp(s - lse_ref[0][:, None])           # softmax rows
        dv_ref[0] += (p.T @ do).astype(dv_ref.dtype)
        dp = do @ v.T                                  # (bq, bk)
        ds = p * (dp - delta_ref[0][:, None])
        dk_ref[0] += (ds.T @ q).astype(dk_ref.dtype)   # dK (scale folded in q)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, *, bq, bk, causal, scale):
    """Grid (bh, nq, nk): accumulate dQ for one q block across kv blocks."""
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        dq_ref[0] = jnp.zeros_like(dq_ref[0])

    run = (not causal) or (kj * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = q @ k.T
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG)
        p = jnp.exp(s - lse_ref[0][:, None])
        dp = do @ v.T
        ds = p * (dp - delta_ref[0][:, None])
        dq_ref[0] += (scale * (ds @ k)).astype(dq_ref.dtype)


def _flash_fwd_lse(q, k, v, causal, bq, bk, interpret):
    B, H, S, D = q.shape
    scale = D ** -0.5
    qq, kk, vv = (t.reshape(B * H, S, D) for t in (q, k, v))
    grid = (B * H, S // bq, S // bk)
    out, lse = pl.pallas_call(
        functools.partial(_kernel_lse, bq=bq, bk=bk, causal=causal,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
                   pl.BlockSpec((1, bq), lambda b, i, j: (b, i))],
        out_shape=[jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
                   jax.ShapeDtypeStruct((B * H, S), jnp.float32)],
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qq, kk, vv)
    return out.reshape(B, H, S, D), lse


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, bq=128,
                        bk=128, interpret=None):
    """Flash backward: returns (dq, dk, dv). delta = rowsum(do * o)."""
    B, H, S, D = q.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = D ** -0.5
    qq, kk, vv, oo, ddo = (t.reshape(B * H, S, D)
                           for t in (q, k, v, o, do))
    delta = jnp.sum(oo.astype(jnp.float32) * ddo.astype(jnp.float32),
                    axis=-1)                               # (BH, S)
    qspec = pl.BlockSpec((1, bq, D), lambda b, j, i: (b, i, 0))
    kspec = pl.BlockSpec((1, bk, D), lambda b, j, i: (b, j, 0))
    rowq = pl.BlockSpec((1, bq), lambda b, j, i: (b, i))
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, bq=bq, bk=bk, causal=causal,
                          scale=scale),
        grid=(B * H, S // bk, S // bq),
        in_specs=[qspec, kspec, kspec, qspec, rowq, rowq],
        out_specs=[kspec, kspec],
        out_shape=[jax.ShapeDtypeStruct((B * H, S, D), jnp.float32),
                   jax.ShapeDtypeStruct((B * H, S, D), jnp.float32)],
        interpret=interpret,
    )(qq, kk, vv, ddo, lse, delta)
    qspec2 = pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0))
    kspec2 = pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0))
    rowq2 = pl.BlockSpec((1, bq), lambda b, i, j: (b, i))
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, bq=bq, bk=bk, causal=causal,
                          scale=scale),
        grid=(B * H, S // bq, S // bk),
        in_specs=[qspec2, kspec2, kspec2, qspec2, rowq2, rowq2],
        out_specs=qspec2,
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), jnp.float32),
        interpret=interpret,
    )(qq, kk, vv, ddo, lse, delta)
    rs = lambda t: t.reshape(B, H, S, D)
    return rs(dq).astype(q.dtype), rs(dk).astype(k.dtype), \
        rs(dv).astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_trainable(q, k, v, causal=True, bq=128, bk=128,
                              interpret=None):
    """Differentiable flash attention (fwd + bwd both Pallas kernels)."""
    o, _ = _flash_fwd_lse(q, k, v, causal, bq, bk,
                          interpret if interpret is not None
                          else jax.default_backend() != "tpu")
    return o


def _fat_fwd(q, k, v, causal, bq, bk, interpret):
    interp = interpret if interpret is not None \
        else jax.default_backend() != "tpu"
    o, lse = _flash_fwd_lse(q, k, v, causal, bq, bk, interp)
    return o, (q, k, v, o, lse)


def _fat_bwd(causal, bq, bk, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                     bq=bq, bk=bk, interpret=interpret)
    return dq, dk, dv


flash_attention_trainable.defvjp(_fat_fwd, _fat_bwd)


def flash_attention(q, k, v, *, causal=True, bq=128, bk=128, interpret=None):
    """q,k,v: (B, H, S, D) — S % bq == 0, D <= VMEM tile. fp32 accumulate."""
    B, H, S, D = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = D ** -0.5
    qq = q.reshape(B * H, S, D)
    kk = k.reshape(B * H, S, D)
    vv = v.reshape(B * H, S, D)
    grid = (B * H, S // bq, S // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),     # acc
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denom
        ],
        interpret=interpret,
    )(qq, kk, vv)
    return out.reshape(B, H, S, D)
