"""Public jit'd wrappers for the Pallas kernels.

`interpret` resolves automatically: Python-interpret on CPU (correctness /
CI), compiled Mosaic on TPU. Padding to hardware tile boundaries (128-lane,
the segment-alignment analogue) happens here so callers keep natural sizes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.lif_step import BLOCK as _LIF_BLOCK
from repro.kernels.lif_step import lif_step as _lif
from repro.kernels.spike_matmul import BN, BP
from repro.kernels.spike_matmul import spike_matmul as _spmv


def _pad_to(x, mult, axis=0, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.jit, static_argnames=("interpret",))
def spike_matmul(spikes, weights, interpret=None):
    """Event-gated synaptic accumulation. spikes (Npre,) bool,
    weights (Npre, Npost) int16 -> (Npost,) int32."""
    npre, npost = weights.shape
    s = _pad_to(spikes.astype(jnp.int32), BP)
    w = _pad_to(_pad_to(weights, BP, 0), BN, 1)
    out = _spmv(s.astype(bool), w, interpret=interpret)
    return out[:npost]


@partial(jax.jit, static_argnames=("interpret",))
def lif_step(V, syn_in, noise_u, theta, nu, lam, is_lif, interpret=None):
    n = V.shape[0]
    args = [_pad_to(a.astype(jnp.int32), _LIF_BLOCK)
            for a in (V, syn_in, noise_u, theta, nu, lam)]
    lif = _pad_to(is_lif.astype(jnp.int32), _LIF_BLOCK).astype(bool)
    V2, s2 = _lif(*args, lif, interpret=interpret)
    return V2[:n], s2[:n]


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal=True, bq=128, bk=128, interpret=None):
    """(B, H, S, D) flash forward; S padded to block multiple (padded keys
    are masked out by causality when causal=True)."""
    S = q.shape[2]
    if S % bq or S % bk:
        qp = _pad_to(q, max(bq, bk), axis=2)
        kp = _pad_to(k, max(bq, bk), axis=2)
        vp = _pad_to(v, max(bq, bk), axis=2)
        out = _flash(qp, kp, vp, causal=True, bq=bq, bk=bk,
                     interpret=interpret)
        return out[:, :, :S]
    return _flash(q, k, v, causal=causal, bq=bq, bk=bk, interpret=interpret)
