"""Retrace detector — a regression gate for the jit compile cache.

Every performance result since the event-driven engine landed depends on
each backend's step/run/run_batch tracing ONCE per (topology,
batch-shape) and replaying the compiled executable afterwards. A stray
host-dependent value in a carry, a Python scalar that should be an
array, or a shape that varies call-to-call silently turns every call
into a fresh XLA compile — correct results, catastrophic throughput.

This harness reads the per-function compilation-cache entry count that
`jax.jit` exposes (`jitted._cache_size()`), so it counts exactly the
user-visible traces — no global monitoring hooks, no noise from XLA's
internal sub-compiles.

    eng = deploy(compiled).impl
    det = RetraceDetector.of(eng)          # finds _jit_step/_jit_run/...
    eng.run_batch(batches)                 # first call traces
    det.snapshot()
    eng.run_batch(batches)                 # same shapes: must replay
    det.assert_no_retrace()                # raises RetraceError if not

    with no_retrace(eng):                  # context-manager form — the
        eng.run_batch(batches)             # timed region of mesh_bench

`compile_counts(obj)` returns the raw {name: entries} map for asserting
the stronger "compiled exactly once" property in tests.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Tuple

__all__ = ["RetraceError", "RetraceDetector", "no_retrace",
           "compile_counts", "jit_functions"]


class RetraceError(RuntimeError):
    """A watched jitted function re-traced inside a no-retrace region."""


def jit_functions(obj) -> Dict[str, object]:
    """{attribute name: jitted function} for every attribute of `obj`
    exposing a jit compilation cache (`_cache_size`). A jitted function
    itself maps to {'<jit>': fn}; backend objects (EventEngine,
    HiAERNetwork, MeshNetwork, DenseSimulator) yield their
    `_jit_step`-style attributes."""
    if callable(getattr(obj, "_cache_size", None)):
        return {"<jit>": obj}
    out = {}
    for name, v in getattr(obj, "__dict__", {}).items():
        if callable(getattr(v, "_cache_size", None)):
            out[name] = v
    return out


def compile_counts(*objects) -> Dict[Tuple[str, str], int]:
    """{(object label, function name): cache entries} right now. One
    entry per distinct traced signature — "compiled exactly once per
    (topology, batch-shape)" is `count == number of distinct shapes
    fed`."""
    out = {}
    for obj in objects:
        label = type(obj).__name__
        for name, fn in jit_functions(obj).items():
            out[(label, name)] = int(fn._cache_size())
    return out


class RetraceDetector:
    """Snapshot/compare the compile caches of a set of jitted
    functions."""

    def __init__(self, fns: Dict[Tuple[str, str], object]):
        self._fns = fns
        self._base: Dict[Tuple[str, str], int] = {}
        self.snapshot()

    @classmethod
    def of(cls, *objects) -> "RetraceDetector":
        fns = {}
        for obj in objects:
            label = type(obj).__name__
            for name, fn in jit_functions(obj).items():
                fns[(label, name)] = fn
        if not fns:
            raise ValueError(
                f"no jitted functions found on "
                f"{[type(o).__name__ for o in objects]}")
        return cls(fns)

    def counts(self) -> Dict[Tuple[str, str], int]:
        return {k: int(fn._cache_size()) for k, fn in self._fns.items()}

    def snapshot(self) -> Dict[Tuple[str, str], int]:
        self._base = self.counts()
        return dict(self._base)

    def deltas(self) -> Dict[Tuple[str, str], int]:
        """Cache growth since the last snapshot (only nonzero entries)."""
        return {k: v - self._base[k] for k, v in self.counts().items()
                if v != self._base[k]}

    def assert_no_retrace(self) -> None:
        d = self.deltas()
        if d:
            grew = ", ".join(f"{label}.{name} (+{n})"
                             for (label, name), n in sorted(d.items()))
            raise RetraceError(
                f"jit retrace detected: {grew} recompiled inside a "
                f"no-retrace region — a traced shape or a host value in "
                f"the call signature is varying call-to-call")


@contextmanager
def no_retrace(*objects):
    """Assert that no watched jitted function re-traces inside the
    block (call once with warm caches — e.g. after the warmup run of a
    benchmark). Yields the detector for inspection."""
    det = RetraceDetector.of(*objects)
    yield det
    det.assert_no_retrace()
