"""CLI: analyze a saved compiled artifact.

    python -m repro.analysis <artifact.npz> [--max-events-per-source K]

Loads the `CompiledNetwork`, runs every validator pass, prints the
rendered `AnalysisReport` (the exact text `compile_spec(...,
validate=True)` raises with on the same network), and exits nonzero if
the report contains errors.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static network analysis of a compiled artifact.")
    ap.add_argument("artifact", help=".npz saved by CompiledNetwork.save")
    ap.add_argument("--max-events-per-source", type=int, default=1,
                    help="worst-case events per axon per timestep for "
                         "the accumulation bound (default 1)")
    args = ap.parse_args(argv)
    # import after argparse so `--help` works without jax/numpy warm-up
    from repro.core.compile import CompiledNetwork
    from repro.analysis.validate import validate_compiled
    compiled = CompiledNetwork.load(args.artifact)
    report = validate_compiled(
        compiled, max_events_per_source=args.max_events_per_source)
    print(report.render())
    return 1 if report.errors else 0


if __name__ == "__main__":
    sys.exit(main())
