"""Static network analysis — the compiler stage that makes "minimal
constraints in topology" safe: bad configurations fail loudly at compile
time instead of silently mis-routing spikes or overflowing accumulators
at runtime.

Three passes, one report format:

  * `validate` — a pure-numpy pass over `NetworkSpec`/`CompiledNetwork`
    columns: dangling/duplicate synapses, dead neurons and unreachable
    outputs, placement/hierarchy consistency, and accumulation-bound
    propagation against the int32 accumulate path (`repro.analysis
    .validate`). Wired into `compile_spec(..., validate=True)` (default
    on) and exposed as `python -m repro.analysis <artifact.npz>`.
  * `tracelint` — an AST pass over the source tree flagging host-Python
    hazards inside the jitted step paths (`repro.analysis.tracelint`;
    `python -m repro.analysis.tracelint src/repro`).
  * `retrace` — a jit-compilation counter asserting each backend
    compiles exactly once per (topology, batch-shape)
    (`repro.analysis.retrace`; used from tests and
    benchmarks/mesh_bench.py).
"""
from repro.analysis.retrace import (RetraceDetector, RetraceError,
                                    compile_counts, no_retrace)
from repro.analysis.validate import (AnalysisError, AnalysisReport,
                                     Finding, validate_compiled,
                                     validate_spec)

__all__ = ["AnalysisError", "AnalysisReport", "Finding",
           "validate_compiled", "validate_spec", "RetraceDetector",
           "RetraceError", "compile_counts", "no_retrace"]
