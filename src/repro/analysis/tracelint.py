"""Jit-hygiene lint — an AST pass over the source tree guarding the jit
boundary every performance result depends on.

The pass finds the **jit roots** of each module — functions decorated
with `@jax.jit`/`@partial(jax.jit, ...)`, functions handed to
`jax.jit(...)`, `shard_map(...)`, `jax.vmap(...)`, or a `jax.lax`
control-flow combinator (scan/cond/while_loop/...) — then takes the
transitive closure over module-local calls, `self.method(...)` calls,
and cross-module `repro.*` imports. Inside that closure it flags the
host-Python hazards that either fail at trace time or, worse, silently
retrace every call:

  * host-scalar — `.item()` / `.tolist()` anywhere, and
    `float(x)`/`int(x)`/`bool(x)` applied to a function parameter
    (a traced value in a jitted path): device syncs or concretization
    errors;
  * numpy-call — `np.*(...)` calls: at best trace-time constants that
    hide retraces, at worst a silent host round trip per call;
  * py-loop — Python `for`/`while` statements: a static unroll at best
    (linear trace growth), a retrace-per-iteration at worst;
  * dict-iter — `.items()`/`.keys()`/`.values()` iteration feeding the
    traced computation: closure contents silently baked into the trace.

Deliberate host-side builders are silenced per file via `ALLOWLIST`
below (path suffix -> rule names) or per line with an inline
`# tracelint: allow=<rule>[,<rule>]` comment.

CLI (CI gate):  python -m repro.analysis.tracelint src/repro
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

__all__ = ["LintFinding", "lint_paths", "lint_file", "main", "ALLOWLIST"]

# path suffix (posix) -> rules silenced for that file. Every entry is a
# deliberate design decision, documented where the code lives.
ALLOWLIST: Dict[str, Set[str]] = {
    # static unrolls over the (tiny, fixed) hierarchy collective
    # stages: the stage list is a compile-time schedule, one trace total
    "kernels/exchange.py": {"py-loop"},
    # per-mesh-axis all-gather chain: unrolls over the static axis
    # names of the device mesh, never over traced values
    "core/distributed_engine.py": {"py-loop"},
    # sharding-constraint resolution walks the static (dim, axis-spec)
    # zip of a shape — trace-time config, not data
    "distributed/context.py": {"py-loop"},
}

_WRAP_ATTRS = {"jit", "vmap", "pmap", "shard_map"}
_LAX_COMBINATORS = {"scan", "cond", "switch", "while_loop", "fori_loop",
                    "map", "associative_scan", "custom_root"}
_HOST_SCALAR_ATTRS = {"item", "tolist"}
_DICT_ITER_ATTRS = {"items", "keys", "values"}
_CAST_BUILTINS = {"float", "int", "bool"}


@dataclass
class LintFinding:
    path: str
    line: int
    rule: str
    qualname: str
    message: str

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] "
                f"{self.qualname}: {self.message}")


# ------------------------------------------------------------- indexing
class _ModuleIndex:
    """One parsed module: its function defs by qualname, import
    aliases, and raw source lines (for inline allow comments)."""

    def __init__(self, path: Path, dotted: str, tree: ast.Module,
                 lines: List[str]):
        self.path = path
        self.dotted = dotted
        self.tree = tree
        self.lines = lines
        self.funcs: Dict[str, ast.AST] = {}       # qualname -> def node
        self.mod_alias: Dict[str, str] = {}       # name -> dotted module
        self.obj_alias: Dict[str, Tuple[str, str]] = {}  # name ->
        #                                           (dotted module, attr)
        self.np_aliases: Set[str] = set()
        self._index()

    def _index(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name.split(".")[0]
                    target = a.name if a.asname else a.name.split(".")[0]
                    self.mod_alias[name] = target
                    if a.name == "numpy":
                        self.np_aliases.add(name)
            elif isinstance(node, ast.ImportFrom):
                if node.level or not node.module:
                    continue
                for a in node.names:
                    name = a.asname or a.name
                    self.obj_alias[name] = (node.module, a.name)
                    if node.module == "numpy":
                        self.np_aliases.add(name)

        def collect(body, prefix):
            for node in body:
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    self.funcs[prefix + node.name] = node
                elif isinstance(node, ast.ClassDef):
                    collect(node.body, prefix + node.name + ".")
        collect(self.tree.body, "")

    def allow_inline(self, line: int, rule: str) -> bool:
        if not 1 <= line <= len(self.lines):
            return False
        text = self.lines[line - 1]
        mark = "# tracelint: allow="
        i = text.find(mark)
        if i < 0:
            return False
        rules = text[i + len(mark):].split()[0]
        return rule in rules.split(",")


def _dotted_name(node) -> Optional[str]:
    """Attribute/Name chain -> 'a.b.c' (None for anything else)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_expr(node) -> bool:
    """Does this expression evaluate to a jit-like wrapper? Covers
    `jax.jit`, bare `jit`, `shard_map`, `partial(jax.jit, ...)`, and
    `jax.jit(...)` / `partial(...)` call results used as decorators."""
    d = _dotted_name(node)
    if d is not None:
        leaf = d.split(".")[-1]
        return leaf in _WRAP_ATTRS
    if isinstance(node, ast.Call):
        fd = _dotted_name(node.func)
        if fd is not None and fd.split(".")[-1] == "partial":
            return bool(node.args) and _is_jit_expr(node.args[0])
        return _is_jit_expr(node.func)
    return False


def _wrapper_fn_args(call: ast.Call) -> List[ast.AST]:
    """The function-valued operands of a jit/vmap/shard_map/lax call."""
    fd = _dotted_name(call.func)
    if fd is None:
        return []
    leaf = fd.split(".")[-1]
    if leaf in _WRAP_ATTRS:
        return call.args[:1]
    if leaf in _LAX_COMBINATORS and ("lax" in fd.split(".")[:-1]
                                     or fd.startswith("lax.")):
        # every positional arg that looks like a function reference
        return list(call.args)
    return []


# --------------------------------------------------------- root discovery
def _find_roots(idx: _ModuleIndex) -> List[Tuple[str, ast.AST]]:
    """(qualname, def node) for every jit root in the module."""
    roots: List[Tuple[str, ast.AST]] = []
    seen: Set[int] = set()

    def add(qualname, node):
        if id(node) not in seen:
            seen.add(id(node))
            roots.append((qualname, node))

    class V(ast.NodeVisitor):
        def __init__(self):
            self.stack: List[str] = []   # class/function name stack
            # nested function defs visible in each enclosing scope —
            # `shard_map(f, ...)`/`lax.scan(body, ...)` over a local
            # def must root that def, not silently skip it
            self.locals: List[Dict[str, ast.AST]] = []

        def qual(self, name):
            return ".".join(self.stack + [name])

        def visit_ClassDef(self, node):
            self.stack.append(node.name)
            self.generic_visit(node)
            self.stack.pop()

        def _visit_def(self, node):
            if any(_is_jit_expr(d) for d in node.decorator_list):
                add(self.qual(node.name), node)
            if self.locals:
                self.locals[-1][node.name] = node
            self.stack.append(node.name)
            self.locals.append({})
            self.generic_visit(node)
            self.locals.pop()
            self.stack.pop()

        visit_FunctionDef = _visit_def
        visit_AsyncFunctionDef = _visit_def

        def visit_Call(self, node):
            for fn_arg in _wrapper_fn_args(node):
                target = self._resolve_local(fn_arg)
                if target is not None:
                    add(*target)
            self.generic_visit(node)

        def _resolve_local(self, node):
            """A function-valued argument -> (qualname, def node):
            innermost local defs first, then module/class level."""
            if isinstance(node, ast.Name):
                for scope in reversed(self.locals):
                    if node.id in scope:
                        return self.qual(node.id), scope[node.id]
                if node.id in idx.funcs:
                    return node.id, idx.funcs[node.id]
            elif isinstance(node, ast.Attribute) and \
                    isinstance(node.value, ast.Name) and \
                    node.value.id == "self":
                for cls in reversed(self.stack):
                    q = f"{cls}.{node.attr}"
                    if q in idx.funcs:
                        return q, idx.funcs[q]
            return None

    V().visit(idx.tree)
    return roots


# ------------------------------------------------------------- violations
class _BodyScan(ast.NodeVisitor):
    """Scan one jit-reachable function subtree: record violations and
    the calls to chase for the transitive closure."""

    def __init__(self, idx: _ModuleIndex, qualname: str, node):
        self.idx = idx
        self.qualname = qualname
        self.findings: List[LintFinding] = []
        self.callees: List[Tuple[str, str]] = []   # (dotted mod, name)
        self.params: List[Set[str]] = [_param_names(node)]
        self.cls = qualname.rsplit(".", 1)[0] if "." in qualname else None
        for child in ast.iter_child_nodes(node):
            self.visit(child)

    # ---- scope tracking: nested defs/lambdas add their params
    def _visit_def(self, node):
        self.params.append(_param_names(node))
        self.generic_visit(node)
        self.params.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def
    visit_Lambda = _visit_def

    def _traced(self, name: str) -> bool:
        return any(name in p for p in self.params)

    def _flag(self, node, rule, message):
        if rule in _file_allow(self.idx.path):
            return
        if self.idx.allow_inline(node.lineno, rule):
            return
        self.findings.append(LintFinding(
            str(self.idx.path), node.lineno, rule, self.qualname,
            message))

    # ---- rules
    def visit_For(self, node):
        self._flag(node, "py-loop",
                   "Python for-loop in a jit-reachable path — a static "
                   "unroll at best (trace grows with the bound), a "
                   "retrace per call at worst; use lax.scan/fori_loop "
                   "or allowlist a deliberate host-side builder")
        self.generic_visit(node)

    def visit_While(self, node):
        self._flag(node, "py-loop",
                   "Python while-loop in a jit-reachable path — cannot "
                   "depend on traced values; use lax.while_loop")
        self.generic_visit(node)

    def visit_Call(self, node):
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr in _HOST_SCALAR_ATTRS:
                self._flag(node, "host-scalar",
                           f".{f.attr}() forces a host sync and fails "
                           f"on tracers")
            elif f.attr in _DICT_ITER_ATTRS:
                self._flag(node, "dict-iter",
                           f".{f.attr}() iteration inside a jitted "
                           f"path bakes dict contents into the trace")
            elif isinstance(f.value, ast.Name) and \
                    f.value.id in self.idx.np_aliases:
                self._flag(node, "numpy-call",
                           f"{f.value.id}.{f.attr}(...) is host numpy "
                           f"— a trace-time constant or a concretization "
                           f"error; use jnp")
        elif isinstance(f, ast.Name) and f.id in _CAST_BUILTINS:
            if node.args and isinstance(node.args[0], ast.Name) and \
                    self._traced(node.args[0].id):
                self._flag(node, "host-scalar",
                           f"{f.id}() on a function parameter "
                           f"concretizes a traced value")
        self._chase(f)
        self.generic_visit(node)

    # ---- closure edges
    def _chase(self, f):
        idx = self.idx
        if isinstance(f, ast.Name):
            if f.id in idx.funcs:
                self.callees.append((idx.dotted, f.id))
            elif f.id in idx.obj_alias:
                mod, attr = idx.obj_alias[f.id]
                self.callees.append((mod, attr))
        elif isinstance(f, ast.Attribute) and \
                isinstance(f.value, ast.Name):
            if f.value.id == "self" and self.cls is not None:
                self.callees.append((idx.dotted,
                                     f"{self.cls}.{f.attr}"))
            elif f.value.id in idx.mod_alias:
                self.callees.append((idx.mod_alias[f.value.id], f.attr))
            elif f.value.id in idx.obj_alias:
                mod, attr = idx.obj_alias[f.value.id]
                self.callees.append((f"{mod}.{attr}", f.attr))


def _param_names(node) -> Set[str]:
    a = node.args
    names = [p.arg for p in
             a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n not in ("self", "cls")}


def _file_allow(path: Path) -> Set[str]:
    posix = path.as_posix()
    out: Set[str] = set()
    for suffix, rules in ALLOWLIST.items():
        if posix.endswith(suffix):
            out |= rules
    return out


# --------------------------------------------------------------- driver
def _load_modules(root: Path) -> Dict[str, _ModuleIndex]:
    """Parse every .py under `root`, keyed by dotted module name (the
    package name is `root`'s basename — lint `src/repro` and modules
    are `repro.*`, matching how the code imports itself)."""
    root = root.resolve()
    pkg = root.name
    modules: Dict[str, _ModuleIndex] = {}
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root)
        parts = (pkg,) + rel.with_suffix("").parts
        if parts[-1] == "__init__":
            parts = parts[:-1]
        dotted = ".".join(parts)
        text = path.read_text()
        try:
            tree = ast.parse(text)
        except SyntaxError as e:
            raise SyntaxError(f"{path}: {e}") from e
        modules[dotted] = _ModuleIndex(path, dotted, tree,
                                       text.splitlines())
    return modules


def lint_paths(root) -> List[LintFinding]:
    """Lint a source tree: discover jit roots in every module, close
    over their callees (within the tree), and return the findings,
    sorted by (path, line)."""
    modules = _load_modules(Path(root))
    queue: List[Tuple[str, str, ast.AST]] = []
    for dotted, idx in modules.items():
        for qualname, node in _find_roots(idx):
            queue.append((dotted, qualname, node))
    visited: Set[Tuple[str, str]] = set()
    findings: List[LintFinding] = []
    while queue:
        dotted, qualname, node = queue.pop()
        if (dotted, qualname) in visited or dotted not in modules:
            continue
        visited.add((dotted, qualname))
        scan = _BodyScan(modules[dotted], qualname, node)
        findings.extend(scan.findings)
        for mod, name in scan.callees:
            target = modules.get(mod)
            if target is not None and name in target.funcs:
                queue.append((mod, name, target.funcs[name]))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_file(path) -> List[LintFinding]:
    """Lint one module in isolation (no cross-module closure)."""
    p = Path(path)
    return lint_paths(p.parent) if p.is_dir() else \
        _lint_single(p)


def _lint_single(path: Path) -> List[LintFinding]:
    text = path.read_text()
    idx = _ModuleIndex(path, path.stem, ast.parse(text),
                       text.splitlines())
    findings: List[LintFinding] = []
    seen: Set[str] = set()
    queue = list(_find_roots(idx))
    while queue:
        qualname, node = queue.pop()
        if qualname in seen:
            continue
        seen.add(qualname)
        scan = _BodyScan(idx, qualname, node)
        findings.extend(scan.findings)
        for mod, name in scan.callees:
            if mod == idx.dotted and name in idx.funcs:
                queue.append((name, idx.funcs[name]))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def main(argv=None) -> int:
    args = sys.argv[1:] if argv is None else argv
    if not args:
        print("usage: python -m repro.analysis.tracelint <src-root> "
              "[<src-root> ...]", file=sys.stderr)
        return 2
    findings: List[LintFinding] = []
    for root in args:
        p = Path(root)
        findings.extend(lint_paths(p) if p.is_dir() else _lint_single(p))
    for f in findings:
        print(f.render())
    print(f"tracelint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
