"""Network validator — a pure-numpy static-analysis pass over
`NetworkSpec`/`CompiledNetwork`.

The paper's interface claim ("shields the user from complexity ... with
minimal constraints in topology") only holds if a bad configuration
fails loudly at compile time. This pass consolidates the scattered
ad-hoc checks of the build pipeline into one structured report:

  * synapses      — dangling pre/post ids, duplicate (pre, post) pairs;
  * reachability  — dead neurons (no fan-in) and output neurons no axon
                    can reach (noise-driven neurons excepted: nu > -17
                    fires without input, Table 1);
  * placement     — hierarchy consistency: every neuron placed, core
                    ids in range, per-core load against
                    `Hierarchy.neurons_per_core`, axon homing in range,
                    shard/placement agreement, per-FPGA HBM footprint
                    against `hbm.HBM_BYTES`;
  * accumulation  — worst-case membrane accumulate bounds: given each
                    neuron's fan-in and the stored int16 weights, bound
                    the one-step synaptic sum and flag any neuron that
                    can overflow the int32 accumulate path
                    (`kernels.route` segment sums, `costmodel.ACC_MIN/
                    ACC_MAX`), reporting neuron AND core ids.

Every finding is a structured `Finding` (severity, code, pass name,
message, ids); `AnalysisReport.render()` is the single text format, so
`compile_spec(..., validate=True)` raising `AnalysisError` and
`python -m repro.analysis artifact.npz` print identical diagnostics.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.costmodel import ACC_MAX, ACC_MIN
from repro.core.hbm import HBM_BYTES, SLOT_BYTES, W_MAX, W_MIN
from repro.core.neuron import NOISE_BITS

__all__ = ["Finding", "AnalysisReport", "AnalysisError",
           "validate_compiled", "validate_spec", "structural_error",
           "accumulation_bounds", "synapse_findings",
           "placement_findings"]

_ID_CAP = 100           # ids stored per finding (full count kept separately)
NOISELESS_NU = -NOISE_BITS  # nu <= -17 disables noise (Table 1)


@dataclass
class Finding:
    """One analysis result: `severity` ('error' | 'warning'), a stable
    `code` (E_*/W_*), the `pass_name` that produced it, a rendered
    `message`, structured `ids` (e.g. {'neurons': [...], 'cores': [...]})
    and `count` (total offenders; `ids` lists at most the first 100)."""
    severity: str
    code: str
    pass_name: str
    message: str
    ids: Dict[str, List[int]] = field(default_factory=dict)
    count: int = 1

    def render(self) -> str:
        return f"[{self.severity}] {self.code} ({self.pass_name}): " \
               f"{self.message}"

    def to_dict(self) -> dict:
        """JSON-serializable form (the portal's structured error
        bodies)."""
        return {"severity": self.severity, "code": self.code,
                "pass": self.pass_name, "message": self.message,
                "ids": self.ids, "count": self.count}


class AnalysisError(ValueError):
    """Raised when an `AnalysisReport` contains errors. Subclasses
    ValueError so pre-analyzer callers catching the old ad-hoc raises
    keep working; `.report` carries the structured findings and the
    message is exactly `report.render()` — the same text the CLI
    prints."""

    def __init__(self, report: "AnalysisReport"):
        super().__init__(report.render())
        self.report = report


@dataclass
class AnalysisReport:
    findings: List[Finding] = field(default_factory=list)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def add(self, severity, code, pass_name, message, ids=None,
            count=None) -> Finding:
        ids = {k: [int(i) for i in np.asarray(v).reshape(-1)[:_ID_CAP]]
               for k, v in (ids or {}).items()}
        n = count if count is not None else \
            max([len(v) for v in ids.values()] or [1])
        f = Finding(severity, code, pass_name, message, ids, int(n))
        self.findings.append(f)
        return f

    def render(self) -> str:
        head = (f"network analysis: {len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s)")
        return "\n".join([head] + ["  " + f.render()
                                   for f in self.findings])

    def raise_if_errors(self) -> None:
        if self.errors:
            raise AnalysisError(self)

    def by_code(self, code: str) -> List[Finding]:
        return [f for f in self.findings if f.code == code]

    def to_dict(self) -> dict:
        """JSON-serializable form: the portal ships this under the
        `findings` key of a 400 body, next to a `message` that is
        exactly `render()`."""
        return {"errors": len(self.errors),
                "warnings": len(self.warnings),
                "findings": [f.to_dict() for f in self.findings]}


def structural_error(pass_name: str, code: str, message: str,
                     **ids) -> AnalysisError:
    """A single-finding error report for structural build failures (bad
    placement dicts, unknown targets) — same rendering as the validator
    passes, so every compile diagnostic speaks one format."""
    r = AnalysisReport()
    r.add("error", code, pass_name, message,
          ids={k: np.atleast_1d(v) for k, v in ids.items()})
    return AnalysisError(r)


def _fmt_ids(arr, cap: int = 8) -> str:
    a = np.asarray(arr).reshape(-1)
    body = ", ".join(str(int(i)) for i in a[:cap])
    return body + (f", ... ({a.size} total)" if a.size > cap else "")


# ------------------------------------------------------------------ passes
def _check_synapses(rep, item, post, A_slots, N):
    bad_post = np.nonzero((post < 0) | (post >= N))[0]
    if bad_post.size:
        rep.add("error", "E_SYN_POST_RANGE", "synapses",
                f"dangling postsynaptic id(s): synapse(s) "
                f"{_fmt_ids(bad_post)} target neuron(s) "
                f"{_fmt_ids(post[bad_post])} outside [0, {N})",
                ids={"synapses": bad_post, "neurons": post[bad_post]})
    n_items = A_slots + N
    bad_pre = np.nonzero((item < 0) | (item >= max(n_items, 1)))[0]
    if bad_pre.size:
        rep.add("error", "E_SYN_PRE_RANGE", "synapses",
                f"dangling source item(s): synapse(s) "
                f"{_fmt_ids(bad_pre)} source from item(s) "
                f"{_fmt_ids(item[bad_pre])} outside [0, {n_items}) "
                f"(axons [0, {A_slots}), neurons [{A_slots}, {n_items}))",
                ids={"synapses": bad_pre, "items": item[bad_pre]})
    if bad_post.size or bad_pre.size:
        return                       # duplicates need in-range keys
    if item.size:
        key = item * max(N, 1) + post
        uniq, counts = np.unique(key, return_counts=True)
        dup = uniq[counts > 1]
        if dup.size:
            rep.add("warning", "W_SYN_DUPLICATE", "synapses",
                    f"{dup.size} duplicate (pre, post) pair(s) — e.g. "
                    f"item {int(dup[0] // max(N, 1))} -> neuron "
                    f"{int(dup[0] % max(N, 1))}; duplicate records sum "
                    f"at integrate time",
                    ids={"items": dup // max(N, 1),
                         "neurons": dup % max(N, 1)},
                    count=int(dup.size))


def _check_reachability(rep, item, post, A_slots, N, outputs, nu):
    if N == 0:
        return
    indeg = np.bincount(post, minlength=N) if item.size else \
        np.zeros((N,), np.int64)
    dead = np.nonzero(indeg == 0)[0]
    noisy = np.asarray(nu) > NOISELESS_NU      # can self-fire from noise
    dead_quiet = dead[~noisy[dead]] if dead.size else dead
    if dead_quiet.size:
        rep.add("warning", "W_DEAD_NEURON", "reachability",
                f"neuron(s) {_fmt_ids(dead_quiet)} have no incoming "
                f"synapses and noise disabled (nu <= {NOISELESS_NU}) — "
                f"they can never fire",
                ids={"neurons": dead_quiet})
    # forward BFS from all axons over the synapse columns
    is_axon_src = item < A_slots
    reach = np.zeros((N,), bool)
    frontier = np.unique(post[is_axon_src]) if item.size else \
        np.zeros((0,), np.int64)
    src = item[~is_axon_src] - A_slots
    dst = post[~is_axon_src]
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    indptr = np.zeros((N + 1,), np.int64)
    np.cumsum(np.bincount(src_s, minlength=N), out=indptr[1:])
    while frontier.size:
        reach[frontier] = True
        starts, ends = indptr[frontier], indptr[frontier + 1]
        spans = [dst_s[s:e] for s, e in zip(starts, ends) if e > s]
        nxt = np.unique(np.concatenate(spans)) if spans else \
            np.zeros((0,), np.int64)
        frontier = nxt[~reach[nxt]]
    out = np.asarray(outputs, np.int64).reshape(-1)
    out = out[(out >= 0) & (out < N)]
    unreachable = out[~reach[out] & ~noisy[out]]
    if unreachable.size:
        rep.add("warning", "W_UNREACHABLE_OUTPUT", "reachability",
                f"output neuron(s) {_fmt_ids(unreachable)} are not "
                f"reachable from any axon and have noise disabled — "
                f"they will never report a spike",
                ids={"neurons": unreachable})


def _check_placement(rep, neuron_core, axon_core, hier, N, shards=None):
    if hier is None:
        return
    core = np.asarray(neuron_core, np.int64).reshape(-1)
    if N > hier.capacity:
        rep.add("error", "E_HIER_CAPACITY", "placement",
                f"network has {N} neurons > hierarchy capacity "
                f"{hier.capacity} ({hier.n_cores} cores x "
                f"{hier.neurons_per_core} neurons_per_core)",
                ids={"neurons": np.asarray([N])}, count=1)
    missing = np.nonzero(core < 0)[0]
    if missing.size:
        rep.add("error", "E_PLACE_MISSING", "placement",
                f"placement missing neuron(s) {_fmt_ids(missing)} "
                f"(no core assigned)",
                ids={"neurons": missing})
    oob = np.nonzero(core >= hier.n_cores)[0]
    if oob.size:
        rep.add("error", "E_PLACE_CORE_RANGE", "placement",
                f"neuron(s) {_fmt_ids(oob)} placed on core(s) "
                f"{_fmt_ids(core[oob])}, hierarchy has only "
                f"{hier.n_cores} cores",
                ids={"neurons": oob, "cores": core[oob]})
    valid = core[(core >= 0) & (core < hier.n_cores)]
    load = np.bincount(valid, minlength=hier.n_cores) if valid.size \
        else np.zeros((hier.n_cores,), np.int64)
    over = np.nonzero(load > hier.neurons_per_core)[0]
    if over.size:
        rep.add("error", "E_PLACE_OVERFULL", "placement",
                f"core(s) {_fmt_ids(over)} hold "
                f"{_fmt_ids(load[over])} neurons > configured limit "
                f"neurons_per_core={hier.neurons_per_core}",
                ids={"cores": over, "loads": load[over]})
    if axon_core is not None:
        ac = np.asarray(axon_core, np.int64).reshape(-1)
        bad = np.nonzero((ac < 0) | (ac >= hier.n_cores))[0]
        if bad.size:
            rep.add("error", "E_PLACE_AXON_RANGE", "placement",
                    f"axon(s) {_fmt_ids(bad)} homed on core(s) "
                    f"{_fmt_ids(ac[bad])}, hierarchy has only "
                    f"{hier.n_cores} cores",
                    ids={"axons": bad, "cores": ac[bad]})
    if shards is not None:
        mism = np.nonzero(np.asarray(shards.core_of_neuron, np.int64)
                          != core[:shards.core_of_neuron.shape[0]])[0]
        if mism.size:
            rep.add("error", "E_SHARD_MISMATCH", "placement",
                    f"shard tables disagree with the placement for "
                    f"neuron(s) {_fmt_ids(mism)} — stale or corrupted "
                    f"artifact",
                    ids={"neurons": mism})
        # per-FPGA HBM footprint: each FPGA card (8 GB, hbm.HBM_BYTES)
        # carries its cores' synapse entries
        per_core = np.diff(shards.core_offsets)
        cpf = hier.cores_per_fpga
        n_fpga = max(-(-hier.n_cores // cpf), 1)
        pad = n_fpga * cpf - per_core.shape[0]
        per_fpga = np.pad(per_core, (0, pad)).reshape(n_fpga, cpf) \
            .sum(axis=1) * SLOT_BYTES
        hot = np.nonzero(per_fpga > HBM_BYTES)[0]
        if hot.size:
            rep.add("warning", "W_HBM_CAPACITY", "placement",
                    f"FPGA(s) {_fmt_ids(hot)} carry "
                    f"{_fmt_ids(per_fpga[hot])} synapse-table bytes > "
                    f"HBM capacity {HBM_BYTES}",
                    ids={"fpgas": hot, "bytes": per_fpga[hot]})


def accumulation_bounds(item, post, weight, A_slots, N,
                        max_events_per_source: int = 1):
    """Per-neuron worst-case one-step synaptic accumulate (lo, hi), in
    exact int64: hi = sum of positive fan-in weights, lo = sum of
    negative ones, each axon-sourced weight counted
    `max_events_per_source` times (an axon may be driven multiple times
    per timestep; neurons fire at most once). This bounds the int32
    segment-sum accumulate of `kernels.route` — `csr_segment_sum`'s
    running cumsum may wrap (differences are exact mod 2^32), but a
    per-neuron sum outside int32 wraps the delivered synaptic input
    itself."""
    w = np.asarray(weight, np.int64)
    mult = np.where(np.asarray(item) < A_slots,
                    int(max_events_per_source), 1)
    contrib = w * mult
    hi = np.zeros((max(N, 1),), np.int64)
    lo = np.zeros((max(N, 1),), np.int64)
    p = np.asarray(post)
    sel = contrib > 0
    np.add.at(hi, p[sel], contrib[sel])
    np.add.at(lo, p[~sel], contrib[~sel])
    return lo[:N], hi[:N]


def _check_accumulation(rep, item, post, weight, A_slots, N, neuron_core,
                        max_events_per_source):
    if N == 0 or not len(item):
        return
    lo, hi = accumulation_bounds(item, post, weight, A_slots, N,
                                 max_events_per_source)
    bound = np.maximum(hi, -lo)

    def cores_of(ids):
        if neuron_core is None:
            return {}
        return {"cores": np.asarray(neuron_core, np.int64)[ids]}

    over = np.nonzero((hi > ACC_MAX) | (lo < ACC_MIN))[0]
    if over.size:
        core_txt = ""
        if neuron_core is not None:
            core_txt = f" on core(s) " \
                       f"{_fmt_ids(np.asarray(neuron_core)[over])}"
        rep.add("error", "E_ACC_OVERFLOW", "accumulation",
                f"neuron(s) {_fmt_ids(over)}{core_txt}: worst-case "
                f"one-step accumulate {_fmt_ids(bound[over])} exceeds "
                f"the int32 accumulate range [{ACC_MIN}, {ACC_MAX}] "
                f"(fan-in x int16 weights, axons counted "
                f"x{max_events_per_source})",
                ids={"neurons": over, "bounds": bound[over],
                     **cores_of(over)})
        return
    near = np.nonzero(bound > ACC_MAX // 2)[0]
    if near.size:
        rep.add("warning", "W_ACC_HEADROOM", "accumulation",
                f"neuron(s) {_fmt_ids(near)}: worst-case one-step "
                f"accumulate {_fmt_ids(bound[near])} uses more than "
                f"half the int32 range [{ACC_MIN}, {ACC_MAX}] — "
                f"repeated axon events or weight growth can overflow",
                ids={"neurons": near, "bounds": bound[near],
                     **cores_of(near)})


# public pass entry points (core.compile runs them piecemeal: the
# synapse pass before lowering — a dangling post id would crash the
# lowering itself — and the structural placement subset always)
synapse_findings = _check_synapses
placement_findings = _check_placement


# ------------------------------------------------------------ entry points
def validate_compiled(compiled, *, max_events_per_source: int = 1
                      ) -> AnalysisReport:
    """Run every pass over a `CompiledNetwork` (any target). Pure
    analysis: never raises on findings — call `.raise_if_errors()` (or
    let `compile_spec(..., validate=True)` do it)."""
    rep = AnalysisReport()
    c = compiled
    A_slots = c.item_base
    item = np.asarray(c.syn_item, np.int64)
    post = np.asarray(c.syn_post, np.int64)
    w = np.asarray(c.syn_weight, np.int64)
    _check_synapses(rep, item, post, A_slots, c.n_neurons)
    if not rep.errors:               # downstream passes need sane ids
        _check_reachability(rep, item, post, A_slots, c.n_neurons,
                            c.outputs, c.nu)
        _check_placement(rep, c.neuron_core, c.axon_core, c.hierarchy,
                         c.n_neurons, shards=c.shards)
        _check_accumulation(rep, item, post, w, A_slots, c.n_neurons,
                            c.neuron_core, max_events_per_source)
    return rep


def validate_spec(spec, *, max_events_per_source: int = 1
                  ) -> AnalysisReport:
    """Pre-compile validation of a `NetworkSpec`: the synapse,
    reachability, and accumulation passes over the raw columns
    (placement does not exist yet — compile with a hierarchy to check
    it). Weights are taken as stored, clipped to the int16 record range
    like the compiler does."""
    rep = AnalysisReport()
    pre, post, w = spec.columns()
    A_slots = max(spec.n_axons, 1)
    item = np.where(pre < 0, -pre - 1, A_slots + pre)
    post = np.asarray(post, np.int64)
    w16 = np.clip(np.asarray(w, np.int64), W_MIN, W_MAX)
    _, nu, _, _, _ = spec.model_tables()
    _check_synapses(rep, item, post, A_slots, spec.n_neurons)
    if not rep.errors:
        _check_reachability(rep, item, post, A_slots, spec.n_neurons,
                            spec.outputs, nu)
        _check_accumulation(rep, item, post, w16, A_slots,
                            spec.n_neurons, None, max_events_per_source)
    return rep
