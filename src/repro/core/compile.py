"""Stage 2 of the staged API: lower a columnar `NetworkSpec` to a
compiled, deployable artifact.

    compiled = compile_spec(spec, target="engine")   # or simulator/hiaer
    compiled.save("net.npz"); compiled = CompiledNetwork.load("net.npz")
    dep = deploy(compiled)                           # core.deploy

Per target the compiler lowers the same columns to the backend's native
storage — no intermediate per-key dicts, no per-synapse Python:

  * simulator — dense (A, N)/(N, N) int32 weight matrices (one
    `np.add.at` scatter);
  * engine — the packed §4 HBM routing table via the vectorized Fig. 7
    mapper (`hbm.build_image_columnar`), bit-identical to the legacy
    `hbm.compile_network` walk;
  * hiaer / mesh — the HBM image PLUS the ragged per-core
    grey/white-matter shards built *directly from the columns*
    (`hbm.shard_entries`, each core carrying its own weight storage so
    the runtime never gathers through a monolithic dense `w_ext`),
    together with the placement (vectorized BFS,
    `partition.partition_arrays`), axon homing, and the exchange
    destination tables (`kernels.exchange.build_dest_tables_columns`);
    the two targets share the artifact — "mesh" deploys it over a real
    device mesh (core.mesh_runtime).

`CompiledNetwork` also carries the synapse columns in engine item space
plus each record's flat position in the packed table: that is the
runtime (pre, post) -> (row, slot) index `core.deploy` uses for batched
`read_synapses`/`write_synapses`, replacing the legacy per-call list
scans. `save`/`load` round-trip the whole artifact bit for bit
(tests/test_staged_api.py).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.analysis.validate import (AnalysisReport, placement_findings,
                                     structural_error, synapse_findings,
                                     validate_compiled)
from repro.core import hbm
from repro.core.hbm import CoreShards, FlatImage, HBMImage, Pointer
from repro.core.partition import Hierarchy, partition_arrays
from repro.core.spec import NetworkSpec, decode_pre
from repro.kernels import exchange as exch_k

__all__ = ["CompiledNetwork", "compile_spec", "TARGETS"]

TARGETS = ("simulator", "engine", "hiaer", "mesh")


@dataclass
class CompiledNetwork:
    """The compiled artifact: everything a `Deployment` needs, and
    nothing tied to the Python objects that built it."""
    target: str
    dense_pack: bool
    n_axons: int
    n_neurons: int
    axon_keys: List
    neuron_keys: List
    outputs: np.ndarray            # (n_out,) neuron ids, monitor order
    theta: np.ndarray              # (N,) int32 packed model tables
    nu: np.ndarray
    lam: np.ndarray
    is_lif: np.ndarray
    model_gid: np.ndarray          # (N,) int32 HBM model group
    # synapse columns, append order; item space: axon id in [0, A'),
    # neuron id + A' with A' = item_base = max(n_axons, 1)
    syn_item: np.ndarray           # (S,) int64
    syn_post: np.ndarray           # (S,) int64
    syn_weight: np.ndarray         # (S,) int32 CURRENT weights (the
    #                                authoritative read_synapses source)
    syn_pos: Optional[np.ndarray] = None   # (S,) flat row*SLOTS+slot
    #                                        (engine/hiaer targets)
    image: Optional[HBMImage] = None
    flat: Optional[FlatImage] = None
    axonW: Optional[np.ndarray] = None     # simulator target
    neuronW: Optional[np.ndarray] = None
    # hiaer / mesh targets
    hierarchy: Optional[Hierarchy] = None
    neuron_core: Optional[np.ndarray] = None
    axon_core: Optional[np.ndarray] = None
    shards: Optional[CoreShards] = None
    axon_ndest: Optional[np.ndarray] = None
    neuron_ndest: Optional[np.ndarray] = None
    # the AnalysisReport of the compile-time validation run (None when
    # compiled with validate=False or loaded from disk — run
    # `repro.analysis.validate_compiled` to regenerate); not persisted
    report: Optional[AnalysisReport] = None

    @property
    def item_base(self) -> int:
        """Neuron offset in item space (the engine's axon-table width)."""
        return max(self.n_axons, 1)

    @property
    def n_synapses(self) -> int:
        return int(self.syn_item.shape[0])

    def stats(self) -> Dict[str, float]:
        out = {"target": self.target, "n_axons": self.n_axons,
               "n_neurons": self.n_neurons, "n_synapses": self.n_synapses}
        if self.image is not None:
            out.update(self.image.stats())
        if self.shards is not None:
            out.update({f"shard_{k}": v
                        for k, v in self.shards.stats().items()})
        return out

    # ------------------------------------------------------------ persist
    def save(self, path) -> None:
        """Serialize to one .npz artifact (arrays verbatim; keys via a
        pickled object payload). `load` restores it bit-identically."""
        arrays = {
            "outputs": self.outputs, "theta": self.theta, "nu": self.nu,
            "lam": self.lam, "is_lif": self.is_lif,
            "model_gid": self.model_gid, "syn_item": self.syn_item,
            "syn_post": self.syn_post, "syn_weight": self.syn_weight,
        }
        meta = {"version": 2, "target": self.target,
                "dense_pack": bool(self.dense_pack),
                "n_axons": self.n_axons, "n_neurons": self.n_neurons,
                "axon_keys": self.axon_keys,
                "neuron_keys": self.neuron_keys}
        if self.syn_pos is not None:
            arrays["syn_pos"] = self.syn_pos
        if self.image is not None:
            img = self.image
            arrays.update(
                img_post=img.syn_post, img_weight=img.syn_weight,
                img_outflag=img.syn_outflag,
                axon_base=self.flat.axon_base,
                axon_rows=self.flat.axon_rows,
                axon_present=self.flat.axon_present,
                neuron_base=self.flat.neuron_base,
                neuron_rows=self.flat.neuron_rows,
                neuron_present=self.flat.neuron_present)
        if self.axonW is not None:
            arrays.update(axonW=self.axonW, neuronW=self.neuronW)
        if self.hierarchy is not None:
            h = self.hierarchy
            meta["hierarchy"] = (h.n_servers, h.fpgas_per_server,
                                 h.cores_per_fpga, h.neurons_per_core)
            sh = self.shards
            arrays.update(
                neuron_core=self.neuron_core, axon_core=self.axon_core,
                axon_ndest=self.axon_ndest,
                neuron_ndest=self.neuron_ndest,
                sh_core_nids=sh.core_nids,
                sh_core_of_neuron=sh.core_of_neuron,
                sh_local_id=sh.local_id, sh_entry_pos=sh.entry_pos,
                sh_entry_item=sh.entry_item, sh_entry_w=sh.entry_w,
                sh_csr_indptr=sh.csr_indptr,
                sh_grey=sh.grey_entries, sh_white=sh.white_entries,
                sh_white_sources=sh.white_sources)
            meta["shard_dims"] = (sh.n_cores, sh.n_max)
        # JSON, not pickle: a loaded artifact must never execute code.
        # Keys therefore have to be JSON-serializable (str/int/...);
        # dumps raises a clear TypeError otherwise.
        arrays["meta_json"] = np.frombuffer(
            json.dumps(meta).encode("utf-8"), dtype=np.uint8)
        np.savez_compressed(path, **arrays)

    @classmethod
    def load(cls, path) -> "CompiledNetwork":
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(z["meta_json"].tobytes().decode("utf-8"))
            version = meta.get("version")
            if version not in (1, 2):
                raise ValueError(
                    f"unsupported artifact version {version}")
            if version == 1 and "shard_dims" in meta:
                # only the hiaer shard arrays changed layout in v2
                # (padded csr_src/csr_item -> ragged entry_*); plain
                # simulator/engine v1 artifacts load unchanged
                raise ValueError(
                    "version-1 hiaer artifacts predate the ragged "
                    "shard layout; recompile the spec and re-save")
            c = cls(
                target=meta["target"], dense_pack=meta["dense_pack"],
                n_axons=meta["n_axons"], n_neurons=meta["n_neurons"],
                axon_keys=meta["axon_keys"],
                neuron_keys=meta["neuron_keys"],
                outputs=z["outputs"], theta=z["theta"], nu=z["nu"],
                lam=z["lam"], is_lif=z["is_lif"],
                model_gid=z["model_gid"], syn_item=z["syn_item"],
                syn_post=z["syn_post"],
                syn_weight=np.array(z["syn_weight"]))
            if "syn_pos" in z:
                c.syn_pos = z["syn_pos"]
            if "img_post" in z:
                c.image, c.flat = _rebuild_image(
                    np.array(z["img_post"]), np.array(z["img_weight"]),
                    np.array(z["img_outflag"]), z["axon_base"],
                    z["axon_rows"], z["axon_present"], z["neuron_base"],
                    z["neuron_rows"], z["neuron_present"], c.model_gid,
                    c.n_axons, c.n_neurons)
            if "axonW" in z:
                c.axonW = np.array(z["axonW"])
                c.neuronW = np.array(z["neuronW"])
            if "hierarchy" in meta:
                c.hierarchy = Hierarchy(*meta["hierarchy"])
                c.neuron_core = z["neuron_core"]
                c.axon_core = z["axon_core"]
                c.axon_ndest = z["axon_ndest"]
                c.neuron_ndest = z["neuron_ndest"]
                n_cores, n_max = meta["shard_dims"]
                c.shards = CoreShards(
                    n_cores=n_cores, n_max=n_max,
                    core_nids=z["sh_core_nids"],
                    core_of_neuron=z["sh_core_of_neuron"],
                    local_id=z["sh_local_id"],
                    entry_pos=z["sh_entry_pos"],
                    entry_item=z["sh_entry_item"],
                    entry_w=np.array(z["sh_entry_w"]),
                    csr_indptr=z["sh_csr_indptr"],
                    grey_entries=z["sh_grey"],
                    white_entries=z["sh_white"],
                    white_sources=z["sh_white_sources"])
        return c


def _rebuild_image(post, weight, outflag, a_base, a_rows, a_present,
                   n_base, n_rows, n_present, model_gid, A, N):
    """Reconstruct (HBMImage, FlatImage) from saved arrays — the pointer
    dicts and inverse maps are pure functions of the id-indexed tables,
    so the round trip is bit-identical."""
    def mk_ptrs(base, rows, present, n):
        return {i: Pointer(int(base[i]), int(rows[i]))
                for i in range(n) if present[i]}

    def mk_groups():
        groups: Dict[int, List[int]] = {}
        for nid in range(N):
            groups.setdefault(int(model_gid[nid]), []).append(nid)
        return {g: sorted(m) for g, m in groups.items()}

    image = HBMImage(
        post, weight, outflag,
        axon_ptr=lambda: mk_ptrs(a_base, a_rows, a_present, A),
        neuron_ptr=lambda: mk_ptrs(n_base, n_rows, n_present, N),
        model_groups=mk_groups)
    R = post.shape[0]
    ab, ar, ap, aown, a_indptr, aidx = hbm._flatten_arrays(
        a_base, a_rows, a_present, R)
    nb, nr, npr, nown, n_indptr, nidx = hbm._flatten_arrays(
        n_base, n_rows, n_present, R)
    flat = FlatImage(
        syn_post=np.ascontiguousarray(post, np.int32),
        syn_weight=np.ascontiguousarray(weight, np.int32),
        axon_base=ab, axon_rows=ar, axon_present=ap,
        neuron_base=nb, neuron_rows=nr, neuron_present=npr,
        row_owner_axon=aown, row_owner_neuron=nown,
        axon_row_indptr=a_indptr, axon_row_indices=aidx,
        neuron_row_indptr=n_indptr, neuron_row_indices=nidx)
    return image, flat


# ---------------------------------------------------------------- lowering
def _finish(c: CompiledNetwork, validate: bool) -> CompiledNetwork:
    """Post-lowering analysis: run the full validator over the artifact
    when `validate`, attach the report, raise on errors (the message is
    the rendered report — bit-identical to the CLI on the same
    network)."""
    if validate:
        c.report = validate_compiled(c)
        c.report.raise_if_errors()
    return c


def _axon_majority(raw_pre, post, is_axon, neuron_core, n_axons,
                   n_cores) -> np.ndarray:
    """Vectorized majority-target axon homing (ties to the lowest core
    id; axons with no targets home on core 0) — bit-identical to
    `core.hiaer._axon_majority_placement`."""
    core = np.zeros((max(n_axons, 1),), np.int32)
    sel = is_axon
    if sel.any() and n_cores > 0:
        aid = raw_pre[sel]
        tgt_core = np.asarray(neuron_core, np.int64)[post[sel]]
        counts = np.bincount(aid * n_cores + tgt_core,
                             minlength=max(n_axons, 1) * n_cores) \
            .reshape(max(n_axons, 1), n_cores)
        core[:] = counts.argmax(axis=1).astype(np.int32)
    return core[:max(n_axons, 1)]


def _check_placement(core: np.ndarray, hier: Hierarchy, n: int):
    """Structural placement validation, phrased by the analyzer's
    placement pass so `compile_spec` and the CLI speak one diagnostic
    format. Only the findings that break the shard build itself
    (missing/out-of-range placements) raise here — an overfull core is
    left to the full post-lowering validation, so a validate=False
    compile still produces an artifact `python -m repro.analysis` can
    diagnose with the identical report."""
    rep = AnalysisReport()
    placement_findings(rep, core, None, hier, n)
    structural = ("E_PLACE_MISSING", "E_PLACE_CORE_RANGE")
    rep.findings = [f for f in rep.findings if f.code in structural]
    rep.raise_if_errors()


def compile_spec(spec: NetworkSpec, target: str = "engine", *,
                 dense_pack: bool = True,
                 hierarchy: Optional[Hierarchy] = None,
                 placement: Optional[Dict[int, int]] = None,
                 axon_placement: Optional[Dict[int, int]] = None,
                 validate: bool = True) -> CompiledNetwork:
    """Lower a `NetworkSpec` to a `CompiledNetwork` for one target.
    `placement`/`axon_placement` map neuron/axon IDS to cores (the
    `CRI_network` facade translates keys). See the module docstring for
    what each target materializes.

    `validate=True` (default) runs the static analyzer
    (`repro.analysis.validate_compiled`) over the artifact: errors raise
    `AnalysisError` (a ValueError whose message is the rendered report —
    identical to `python -m repro.analysis <artifact>` on the same
    network); warnings land on `compiled.report`. `validate=False`
    skips the analyzer; only the structural checks that the lowering
    itself cannot survive still raise."""
    if target not in TARGETS:
        raise structural_error(
            "compile", "E_BAD_TARGET",
            f"unknown target {target!r} (one of {TARGETS})")
    pre, post, w = spec.columns()
    A, N = spec.n_axons, spec.n_neurons
    A_eng = max(A, 1)
    theta, nu, lam, is_lif, model_gid = spec.model_tables()
    outputs = spec.outputs
    # item spaces in two fused passes (decode_pre folded in): the
    # mapper's (neurons at A + id) and the engine's (neurons at A_eng)
    mapper_item = np.where(pre < 0, -pre - 1, A + pre)
    syn_item = mapper_item if A == A_eng else \
        np.where(pre < 0, -pre - 1, A_eng + pre)
    if validate:
        # the synapse pass runs before lowering: a dangling post id
        # would crash the scatter/mapper below, not report cleanly
        rep0 = AnalysisReport()
        synapse_findings(rep0, syn_item, np.asarray(post, np.int64),
                         A_eng, N)
        rep0.raise_if_errors()

    # every stored record is int16 (the paper's weight width): clip once
    # here so the readable column, the packed image, and the dense
    # simulator matrices can never disagree on a record's value
    w16 = np.clip(w, hbm.W_MIN, hbm.W_MAX)
    c = CompiledNetwork(
        target=target, dense_pack=bool(dense_pack), n_axons=A,
        n_neurons=N, axon_keys=spec.axon_keys,
        neuron_keys=spec.neuron_keys, outputs=outputs, theta=theta,
        nu=nu, lam=lam, is_lif=is_lif, model_gid=model_gid,
        syn_item=syn_item, syn_post=post.copy(),
        syn_weight=w16.astype(np.int32))

    if target == "simulator":
        is_axon, raw = decode_pre(pre)
        axonW = np.zeros((A, N), np.int32)
        neuronW = np.zeros((N, N), np.int32)
        sel = is_axon
        np.add.at(axonW, (raw[sel], post[sel]),
                  w16[sel].astype(np.int32))
        np.add.at(neuronW, (raw[~sel], post[~sel]),
                  w16[~sel].astype(np.int32))
        c.axonW, c.neuronW = axonW, neuronW
        return _finish(c, validate)

    # shared engine/hiaer/mesh lowering: the packed HBM image from columns
    ci = hbm.build_image_columnar(mapper_item, post, w, A, N, model_gid,
                                  outputs, dense_pack=dense_pack)
    c.image, c.flat, c.syn_pos = ci.image, ci.flat, ci.syn_pos
    if target == "engine":
        return _finish(c, validate)

    # hiaer/mesh: placement + axon homing + per-core shards from columns
    is_axon, raw = decode_pre(pre)
    hier = hierarchy if hierarchy is not None else \
        Hierarchy(1, 1, 1, max(N, 1))
    if N > hier.capacity:
        raise structural_error(
            "placement", "E_HIER_CAPACITY",
            f"network has {N} neurons > hierarchy capacity "
            f"{hier.capacity} ({hier.n_cores} cores x "
            f"{hier.neurons_per_core} neurons_per_core)", neurons=N)
    if placement is not None:
        neuron_core = np.full((N,), -1, np.int64)
        for nid, cc in placement.items():
            if not 0 <= nid < N:
                raise structural_error(
                    "placement", "E_PLACE_UNKNOWN_ID",
                    f"placement has unknown neuron id {nid} (network "
                    f"has {N} neurons)", neurons=nid)
            if not 0 <= cc < hier.n_cores:
                raise structural_error(
                    "placement", "E_PLACE_CORE_RANGE",
                    f"neuron {nid} placed on core {cc}, hierarchy has "
                    f"only {hier.n_cores} cores", neurons=nid, cores=cc)
            neuron_core[nid] = cc
        _check_placement(neuron_core, hier, N)
        neuron_core = neuron_core.astype(np.int32)
    elif hier.n_cores == 1:
        # the BFS partitioner provably assigns everything to core 0
        # when there is only one core — skip it entirely
        neuron_core = np.zeros((N,), np.int32)
    else:
        # vectorized locality-first BFS straight from the columns — no
        # per-synapse adjacency dict on the construction path
        sel = ~is_axon
        neuron_core = partition_arrays(raw[sel], post[sel], w[sel], N,
                                       hier)
        _check_placement(neuron_core, hier, N)
    axon_core = _axon_majority(raw, post, is_axon, neuron_core, A,
                               hier.n_cores)
    if axon_placement is not None:
        for a, cc in axon_placement.items():
            if not 0 <= a < A_eng:
                raise structural_error(
                    "placement", "E_PLACE_AXON_UNKNOWN",
                    f"axon_placement has unknown axon id {a} (network "
                    f"has {A} axons)", axons=a)
            if not 0 <= cc < hier.n_cores:
                raise structural_error(
                    "placement", "E_PLACE_AXON_RANGE",
                    f"axon {a} placed on core {cc}, hierarchy has only "
                    f"{hier.n_cores} cores", axons=a, cores=cc)
            axon_core[a] = cc

    # build-time sharding straight from the columns (plus in-range A.3
    # fillers, which shard_image would also keep) — no dense-table scan;
    # each core's shard carries its own weight storage (w16, the stored
    # int16 record values), so the runtime never gathers through the
    # dense image
    keep_fill = ci.filler_post < N
    pos_all = np.concatenate([ci.syn_pos, ci.filler_pos[keep_fill]])
    item_all = np.concatenate([syn_item, ci.filler_item[keep_fill]])
    post_all = np.concatenate([post, ci.filler_post[keep_fill]])
    w_all = np.concatenate([w16.astype(np.int32),
                            np.zeros((int(keep_fill.sum()),), np.int32)])
    if N == 0:
        pos_all = pos_all[:0]
        item_all = item_all[:0]
        post_all = post_all[:0]
        w_all = w_all[:0]
    c.hierarchy = hier
    c.neuron_core, c.axon_core = neuron_core, axon_core
    c.shards = hbm.shard_entries(pos_all, item_all, post_all, w_all,
                                 neuron_core, axon_core, hier.n_cores,
                                 N, A_eng)
    c.axon_ndest, c.neuron_ndest = exch_k.build_dest_tables_columns(
        syn_item, post, axon_core, neuron_core, hier, A_eng, N)
    return _finish(c, validate)
