"""Spiking CNN pipeline — the DVS-Gesture rows of Table 2 (§6, second
experiment family).

The paper trains spiking CNNs in SpikingJelly with a modified LIFNode that
matches HiAER-Spike's semantics — strict `>` threshold, hard reset to 0,
inputs integrated at the END of the timestep, membrane time constant 2^63
(i.e. IF, no leak) — using an ATan surrogate gradient, then quantizes to
int16 and converts. This module is that pipeline natively in JAX:

  * `SpikingModel.apply` — T-timestep IF dynamics with exactly the engine's
    phase order (threshold/reset on carried V, then integrate this step's
    inputs), ATan surrogate for the spike nonlinearity;
  * rate decoding: output spike counts / T (the paper's gesture rule);
  * `spiking_to_network` — conversion to LIF_neuron(λ=63) adjacency run on
    the event-driven engine, frame events in → output spikes out;
  * `simulate_quantized` — the integer oracle the engine must match
    bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import CRI_network, LIF_neuron
from repro.core.convert import LayerSpec, W_MAX, quantize


@jax.custom_vjp
def atan_spike(v):
    """Strict > 0 spike with ATan surrogate (the paper's training setup)."""
    return (v > 0).astype(v.dtype)


def _as_fwd(v):
    return atan_spike(v), v


def _as_bwd(v, g):
    alpha = 2.0
    return (g * alpha / 2.0 / (1.0 + (jnp.pi / 2.0 * alpha * v) ** 2),)


atan_spike.defvjp(_as_fwd, _as_bwd)


@dataclass
class SpikingModel:
    """IF spiking CNN: conv/dense feature layers + linear readout whose
    spike counts over T steps are the class scores."""
    input_shape: Tuple[int, ...]            # (C, H, W) per frame
    layers: List[LayerSpec] = field(default_factory=list)
    n_classes: int = 11

    def init(self, key):
        # reuse the QAT initializer (same layer geometry)
        from repro.core.convert import QATModel
        self._qat = QATModel(self.input_shape, self.layers, self.n_classes)
        return self._qat.init(key)

    def _layer_pre(self, spec, p, h):
        if spec.kind == "conv":
            z = jax.lax.conv_general_dilated(
                h, p["w"], (spec.stride, spec.stride), "VALID",
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
            return z + p["b"][None, :, None, None]
        h = h.reshape(h.shape[0], -1)
        return h @ p["w"] + p["b"]

    def apply(self, params, frames):
        """frames: (B, T, C, H, W) float 0/1 events. Returns rate logits
        (B, n_classes) = output spike counts / T.

        Engine-faithful step order per layer: carried V is thresholded
        (strict >, from LAST step's integration), spiking entries reset,
        then this step's input is integrated — i.e. a spike emitted at step
        t reflects inputs up to t-1, reaching layer l at step t+l."""
        B, T = frames.shape[:2]
        Vs = [None] * (len(self.layers) + 1)
        counts = jnp.zeros((B, self.n_classes))
        for t in range(T):
            x = frames[:, t]
            for li, (spec, p) in enumerate(zip(self.layers, params[:-1])):
                z = self._layer_pre(spec, p, x)
                if Vs[li] is None:
                    Vs[li] = jnp.zeros_like(z)
                s = atan_spike(Vs[li])              # spike on carried V
                Vs[li] = Vs[li] * (1.0 - s) + z     # reset then integrate
                x = s if spec.kind == "dense" else s.reshape(z.shape)
            zo = self._layer_pre(LayerSpec("dense"), params[-1], x)
            if Vs[-1] is None:
                Vs[-1] = jnp.zeros_like(zo)
            so = atan_spike(Vs[-1])
            Vs[-1] = Vs[-1] * (1.0 - so) + zo
            counts = counts + so
        return counts / T


def train_spiking(model: SpikingModel, frames, labels, *, epochs=6, lr=1e-3,
                  batch=32, seed=0, verbose=False):
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, xb, yb):
        rates = model.apply(p, xb)
        logp = jax.nn.log_softmax(rates * 4.0)   # rate-coded logits
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    @jax.jit
    def step(p, m, v, t, xb, yb):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        p = jax.tree.map(
            lambda pp, mm, vv: pp - lr * (mm / (1 - 0.9 ** t))
            / (jnp.sqrt(vv / (1 - 0.999 ** t)) + 1e-8), p, m, v)
        return p, m, v, l

    n = frames.shape[0]
    rng = np.random.default_rng(seed)
    t = 0
    for ep in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            t += 1
            params, m, v, l = step(params, m, v, jnp.float32(t),
                                   jnp.asarray(frames[idx]),
                                   jnp.asarray(labels[idx]))
        if verbose:
            print(f"epoch {ep}: loss {float(l):.4f}")
    return params


# ------------------------------------------------------- integer reference
def _if_leak(V):
    """Engine-exact λ=63 'leak': V -= floor(V / 2^63) — a +1/step drift for
    negative membranes under the published floor-division semantics,
    positive membranes untouched. For int64 V the floor quotient is just
    the sign bit, so compute it as an arithmetic shift (V >> 63 is 0 for
    V >= 0, -1 for V < 0); `core.neuron.leak` does the same with V >> 31
    on its int32 membranes, and tests/test_leak_exact.py pins all three
    implementations (neuron.leak, kernels lif_step, this) to each other."""
    return V - (V >> 63)


def simulate_quantized(model: SpikingModel, qparams, frames) -> np.ndarray:
    """Integer IF simulation (numpy oracle, bit-exact vs the engine):
    returns output spike counts (B, n_classes). Engine step order per
    layer: threshold carried V (strict >0), reset, λ=63 leak, integrate.
    Runs T + depth steps (zero frames appended) so the layer pipeline
    drains — spikes caused by frame T-1 reach the readout."""
    B, T = frames.shape[:2]
    depth = len(model.layers) + 1
    Vs = [None] * (len(model.layers) + 1)
    counts = np.zeros((B, model.n_classes), np.int64)
    zero = np.zeros_like(frames[:, 0])
    for t in range(T + depth):
        x = (frames[:, t] if t < T else zero).astype(np.int64)
        for li, (spec, p) in enumerate(zip(model.layers, qparams[:-1])):
            z = _int_layer(spec, p, x, model, li)
            if Vs[li] is None:
                Vs[li] = np.zeros_like(z)
            s = (Vs[li] > 0).astype(np.int64)
            Vs[li] = _if_leak(Vs[li] * (1 - s)) + z
            x = s
        zo = x.reshape(B, -1) @ qparams[-1]["w"] + qparams[-1]["b"]
        if Vs[-1] is None:
            Vs[-1] = np.zeros_like(zo)
        so = (Vs[-1] > 0).astype(np.int64)
        Vs[-1] = _if_leak(Vs[-1] * (1 - so)) + zo
        counts += so
    return counts


def _int_layer(spec, p, h, model, li):
    if spec.kind == "conv":
        Bn, C, H, W = h.shape
        K, st = spec.kernel, spec.stride
        Ho, Wo = (H - K) // st + 1, (W - K) // st + 1
        z = np.zeros((Bn, spec.channels, Ho, Wo), np.int64)
        for dy in range(K):
            for dx in range(K):
                patch = h[:, :, dy:dy + st * Ho:st, dx:dx + st * Wo:st]
                z += np.einsum("bchw,oc->bohw", patch, p["w"][:, :, dy, dx])
        return z + p["b"][None, :, None, None]
    h = h.reshape(h.shape[0], -1)
    return h @ p["w"] + p["b"]


# ----------------------------------------------------------- conversion
def spiking_to_network(model: SpikingModel, qparams, backend="engine",
                       seed=0):
    """Convert to LIF_neuron(λ=63 ≈ IF, θ=0 strict >) adjacency through
    the staged columnar path (the same `build_conversion_spec` as the
    ANN pipeline, with LIF models — no intermediate throwaway network).
    Biases use per-layer always-on axons fired EVERY step (spiking nets
    integrate biases each timestep, unlike the one-shot ANN case).
    Output neurons are ordinary spiking LIF neurons whose spikes are
    counted."""
    from repro.core.convert import QATModel, build_conversion_spec
    qm = QATModel(model.input_shape, model.layers, model.n_classes)
    lif = LIF_neuron(threshold=0, nu=-32, lam=63)
    spec, out_keys = build_conversion_spec(qm, qparams,
                                           hidden_model=lif,
                                           output_model=lif)
    net = CRI_network.from_spec(spec, backend=backend, seed=seed)
    return net, out_keys


def infer_frames(net: CRI_network, frames_one, model: SpikingModel,
                 out_keys: Sequence[str]):
    """Run one sample's (T, C, H, W) event frames on the engine; returns
    (pred, spike_counts). Bias axons fire every step; each step feeds that
    frame's active pixels; outputs spike-counted for T + depth steps (to
    drain the pipeline, matching the depth-latency of the layered IF
    dynamics)."""
    net.reset()
    T = frames_one.shape[0]
    depth = len(model.layers) + 1
    counts = np.zeros((len(out_keys),), np.int64)
    out_index = {k: i for i, k in enumerate(out_keys)}
    bias_keys = [f"bias_l{i}" for i in range(depth)]
    for t in range(T + depth):
        active = list(bias_keys)
        if t < T:
            flat = np.asarray(frames_one[t]).reshape(-1)
            active += [f"x{i}" for i in np.nonzero(flat)[0]]
        fired = net.step(active)
        for k in fired:
            counts[out_index[k]] += 1
    return int(np.argmax(counts)), counts


def infer_frames_batch(net: CRI_network, frames, model: SpikingModel,
                       out_keys: Sequence[str]):
    """Table-2-style evaluation, B samples per dispatch: encode all
    samples' frames into one (B, T + depth, A) axon-count tensor and run it
    through `CRI_network.run_batch` (one vmapped lax.scan on the engine).
    Returns (preds (B,), spike_counts (B, n_outputs)) — per sample exactly
    what `infer_frames` computes (the converted nets disable noise, so
    batch PRNG streams cannot introduce divergence)."""
    frames = np.asarray(frames)
    B, T = frames.shape[:2]
    depth = len(model.layers) + 1
    A = len(net.axon_keys)
    sched = np.zeros((B, T + depth, A), np.int32)
    bias_ids = [net._aid[f"bias_l{i}"] for i in range(depth)]
    sched[:, :, bias_ids] = 1                      # biases fire every step
    flat = frames.reshape(B, T, -1) != 0
    pix_ids = np.asarray([net._aid[f"x{i}"]
                          for i in range(flat.shape[-1])])
    sched[:, :T, pix_ids] = flat
    out_spikes = net.run_batch(sched)              # (B, T+depth, n_out)
    # run_batch orders columns by net.outputs; reorder to out_keys
    col = {k: i for i, k in enumerate(net.outputs)}
    order = np.asarray([col[k] for k in out_keys])
    counts = out_spikes.sum(axis=1).astype(np.int64)[:, order]
    return counts.argmax(axis=1), counts
