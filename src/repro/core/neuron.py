"""Neuron models — Table 1 of the paper, bit-exact fixed-point semantics.

Two model classes:
  LIF  (θ, ν, λ): leaky integrate-and-fire, int32 membrane
  ANN  (θ, ν):    binary/memoryless ("spike or not each step")

Within-timestep order (§5.1 + Fig. 8 simulator excerpt):
  1. noise update   V += ξ,  ξ = (u | 1) << ν  (>> -ν if ν < 0), where
                    u ~ U{-2^16 .. 2^16-1} (17-bit signed), LSB forced to 1
                    to balance the distribution around zero
  2. spike update   S = (V > θ)  (strict >), spiking neurons reset V ← 0
  3. membrane update
       LIF: V ← V - V // 2^λ + Σ_j w_ij S_j   (floor division, exactly
            Fig. 8's `V - V // np.power(2, λ)`)
       ANN: V ← Σ_j w_ij S_j                  (no carry-over)

The synaptic input Σ_j w_ij S_j integrates the spikes detected in THIS
timestep (phase split below mirrors the two-phase HBM routing of §4).
λ = 63 approximates an IF neuron; ν > -17 on an ANN neuron makes it a
Boltzmann-like stochastic binary neuron. ν is a 6-bit signed integer.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NOISE_BITS = 17
MAX_LAMBDA = 63
_NU_MIN, _NU_MAX = -32, 31      # 6-bit signed


@dataclass(frozen=True)
class LIF_neuron:
    threshold: int
    nu: int = -32               # noise shift (<= -17 disables noise)
    lam: int = MAX_LAMBDA       # leak: V -= V // 2^lam

    def __post_init__(self):
        if not _NU_MIN <= self.nu <= _NU_MAX:
            raise ValueError(f"nu must be 6-bit signed, got {self.nu}")
        if not 0 <= self.lam <= MAX_LAMBDA:
            raise ValueError(f"lambda in [0,63], got {self.lam}")

    @property
    def kind(self):
        return "LIF"


@dataclass(frozen=True)
class ANN_neuron:
    threshold: int
    nu: int = -32

    def __post_init__(self):
        if not _NU_MIN <= self.nu <= _NU_MAX:
            raise ValueError(f"nu must be 6-bit signed, got {self.nu}")

    @property
    def kind(self):
        return "ANN"

    @property
    def lam(self):
        return MAX_LAMBDA       # unused; uniform param layout


def noise_from_u(u, nu):
    """ξ from pre-drawn 17-bit signed uniforms u: LSB forced to 1, then
    shifted by ν — (u | 1) << ν for ν >= 0, sign-magnitude >> -ν for
    ν < 0. Right shift truncates toward zero: ν <= -17 must yield exactly
    0 so that "noise disabled" neurons are bit-exact deterministic
    (Table 1 note: ν > -17 makes an ANN neuron stochastic). The single
    definition of the fixed-point noise formula — the Pallas kernels and
    benchmark oracles call this rather than re-deriving it."""
    u = u | 1
    pos = jnp.minimum(jnp.maximum(nu, 0), 31)
    neg = jnp.minimum(jnp.maximum(-nu, 0), 31)
    mag = jnp.abs(u) >> neg
    right = jnp.sign(u) * mag
    return jnp.where(nu >= 0, u << pos, right)


def noise_draw(key, n):
    """The raw 17-bit signed uniform draw feeding `noise_from_u` — the
    single definition of the noise distribution (the fused-kernel engine
    path draws through this too, keeping its PRNG stream bit-identical
    to `noise_sample`)."""
    return jax.random.randint(key, (n,), -(2 ** (NOISE_BITS - 1)),
                              2 ** (NOISE_BITS - 1), dtype=jnp.int32)


def noise_sample(key, n, nu):
    """ξ per neuron: 17-bit signed uniform, LSB set to 1, shifted by ν.
    nu: (n,) int32 per-neuron shift. Matches Fig. 8's
    (randint | 1) << ν  /  >> -ν."""
    return noise_from_u(noise_draw(key, n), nu)


def leak(V, lam):
    """V - V // 2^lam with floor semantics (Fig. 8 numpy floor division).
    |V| < 2^31, so for lam >= 31 the floor quotient is 0 (V >= 0) or -1
    (V < 0) — computed as an arithmetic shift, avoiding int64 entirely."""
    pow2 = jnp.int32(1) << jnp.minimum(lam, 30)
    small = V // pow2          # floor division, exact for lam <= 30
    big = V >> 31              # 0 or -1: floor(V / 2^lam) for lam >= 31
    return V - jnp.where(lam >= 31, big, small)


def fire_phase_from_u(V, theta, nu, lam, is_lif, u):
    """Phase 1 of a timestep from pre-drawn raw uniforms u (see
    `noise_draw`): noise, threshold, reset, leak/zero. Returns
    (V_mid, spikes); V_mid still lacks this step's synaptic input.
    Separated from the draw so engines that reorganize neurons (the
    per-core layout of core.hiaer) can draw once in global id order —
    the PRNG-parity requirement — and apply the elementwise phase in
    any layout."""
    V = V + noise_from_u(u, nu)
    spikes = V > theta
    V = jnp.where(spikes, 0, V)
    V = jnp.where(is_lif, leak(V, lam), 0)
    return V, spikes


def fire_phase(V, theta, nu, lam, is_lif, key):
    """Phase 1 of a timestep: noise, threshold, reset, leak/zero.
    Returns (V_mid, spikes). V_mid still lacks this step's synaptic input."""
    return fire_phase_from_u(V, theta, nu, lam, is_lif,
                             noise_draw(key, V.shape[0]))


def integrate_phase(V_mid, syn_in):
    """Phase 2: integrate Σ_j w_ij S_j (this step's spikes + axon events)."""
    return V_mid + syn_in


def pack_models(models):
    """Stack per-neuron model params into dense vectors.
    models: list of LIF_neuron/ANN_neuron, one per neuron id."""
    theta = jnp.array([m.threshold for m in models], jnp.int32)
    nu = jnp.array([m.nu for m in models], jnp.int32)
    lam = jnp.array([m.lam for m in models], jnp.int32)
    is_lif = jnp.array([m.kind == "LIF" for m in models], bool)
    return theta, nu, lam, is_lif
