"""Mesh-scale SNN execution with hierarchical HiAER spike routing.

This is the paper's scaling story mapped to the TPU pod: 160M neurons /
40B synapses sharded over the production mesh, with spike bit-vectors
multicast level-by-level (Fig. 1b):

  'model' axis = 32 cores within an FPGA  -> NoC        (fastest, first)
  'data'  axis = 8 FPGA boards per server -> FireFly
  'pod'   axis = servers                  -> Ethernet   (slowest, last)

Postsynaptic neurons are sharded over ('data','model') [+pod]; each device
owns a (neurons_global x neurons_local) stripe of synapses stored as dense
int8-occupancy-tagged 128x128 blocks (block-CSR in spirit; block-dense in
the XLA dry-run — the event-gated skipping is the Pallas kernel's job on
real TPUs, kernels/spike_matmul.py).

The spike exchange is a hierarchical all-gather of 1-bit spike vectors:
exactly the paper's "keep most event traffic on fast local links" — the
slow cross-pod hop carries only the pod-boundary summary once. The wire
format is the shared bit-packed representation of `kernels.exchange`
(`pack_events`/`unpack_events`, uint32 presence words): this module no
longer hand-rolls its own 1-bit packing — it is a thin consumer of the
same primitives the production mesh tier (core.mesh_runtime) exchanges
with, and `small_reference_step` remains the dense single-device oracle
the packed path is tested against (tests/test_system.py). Shards whose
local bit count is not word-aligned (n_loc % 32 != 0, impossible at the
paper's scale) fall back to the dense bool gather.

`step` is pjit-compatible; `hiaer_snn_40b` dry-runs it at full scale
(160e6 neurons, 40e9 synapses => 2.4e5 synapses/neuron avg fan-in 250,
int16 weights: 80 GB sharded, 312 MB/device at 256 devices).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import neuron as nrn
from repro.distributed.context import batch_axes, get_mesh, tp_axis
from repro.kernels import exchange as exch_k


@dataclass(frozen=True)
class SNNShardConfig:
    n_neurons: int = 160_000_000
    avg_fan_in: int = 250            # 40e9 / 160e6
    block: int = 128
    # synapses stored as (n_blocks_in, block, n_loc) int16 stripes where
    # n_blocks_in = ceil(fan_in_window / block): each neuron's inputs come
    # from a bounded window of presynaptic blocks (sparse 'grey matter'
    # locality the paper's partitioner [10] optimizes for).
    fan_window_blocks: int = 4       # 4*128 = 512-wide presynaptic window

    @property
    def n_synapses(self) -> int:
        return self.n_neurons * self.avg_fan_in


def snn_state_shapes(cfg: SNNShardConfig, mesh):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    n_axes = [a for a in ("data", "model") if a in mesh.axis_names]
    shard = 1
    for a in n_axes:
        shard *= mesh.shape[a]
    if "pod" in mesh.axis_names:
        shard *= mesh.shape["pod"]
    n_loc = cfg.n_neurons // shard
    W = cfg.fan_window_blocks * cfg.block
    spec = {
        "V": jax.ShapeDtypeStruct((cfg.n_neurons,), jnp.int32),
        "theta": jax.ShapeDtypeStruct((cfg.n_neurons,), jnp.int32),
        "lam": jax.ShapeDtypeStruct((cfg.n_neurons,), jnp.int32),
        # per-device synapse stripe: (window_pre, n_loc) int16, stored
        # globally as (n_neurons_global_window..., n) — represented as the
        # full sharded matrix (W, n_neurons) with W the presyn window
        "weights": jax.ShapeDtypeStruct((W, cfg.n_neurons), jnp.int16),
        "spikes": jax.ShapeDtypeStruct((cfg.n_neurons,), jnp.bool_),
    }
    return spec


def snn_shardings(cfg: SNNShardConfig, mesh):
    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    vec = NamedSharding(mesh, P(all_axes))
    return {
        "V": vec, "theta": vec, "lam": vec, "spikes": vec,
        "weights": NamedSharding(mesh, P(None, all_axes)),
    }


def make_snn_step(cfg: SNNShardConfig, mesh):
    """One simulation timestep at pod scale.

    state: dict of sharded arrays (see snn_state_shapes). The windowed
    synapse model: neuron i's presynaptic sources are spikes[w(i) : w(i)+W]
    where w(i) is its window start — here fixed strided windows so the
    gather is a reshape (the partitioner's locality assumption)."""
    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)

    def step(state, key):
        V, theta, lam = state["V"], state["theta"], state["lam"]
        spikes_prev = state["spikes"]
        W = cfg.fan_window_blocks * cfg.block

        def local(V, theta, lam, spikes_prev, weights, key):
            # --- phase 1 (fire): local neuron update
            n_loc = V.shape[0]
            V_mid, spikes = nrn.fire_phase(
                V, theta, jnp.full_like(theta, -32), lam,
                jnp.ones((n_loc,), bool), key)
            # --- HiAER multicast: hierarchical all-gather of spike bits,
            # fast axis first (NoC -> FireFly -> Ethernet), over the
            # shared packed wire format: each shard's bool vector packs
            # to uint32 presence words (kernels.exchange.pack_events),
            # the hops gather WORDS (32x fewer bytes per link), and the
            # global vector unpacks once at the destination. Word
            # packing commutes with concatenation only when every
            # shard's bit count is word-aligned; otherwise fall back to
            # the dense bool gather (same values, wide wire).
            if spikes_prev.shape[0] % exch_k.PACK_BITS == 0:
                words = exch_k.pack_events(spikes_prev)
                for ax in reversed(all_axes):  # model, data, pod
                    words = jax.lax.all_gather(words, ax, tiled=True)
                bits = exch_k.unpack_events(
                    words, words.shape[0] * exch_k.PACK_BITS)
            else:
                bits = spikes_prev
                for ax in reversed(all_axes):  # model, data, pod
                    bits = jax.lax.all_gather(bits, ax, tiled=True)
            # --- phase 2 (integrate): windowed event-driven synaptic sum.
            # Local connectivity ("grey matter"): this device's neurons see
            # the presynaptic window anchored at their own global offset —
            # the locality the partitioning algorithm [10] optimizes for.
            n_glob = bits.shape[0]
            lin = jnp.int32(0)
            for ax in all_axes:
                lin = lin * get_mesh().shape[ax] + jax.lax.axis_index(ax)
            base = jnp.minimum(lin * n_loc, n_glob - W)
            win = jax.lax.dynamic_slice_in_dim(bits, base, W)
            syn = jnp.einsum("w,wn->n", win.astype(jnp.int32),
                             weights.astype(jnp.int32))
            V_next = nrn.integrate_phase(V_mid, syn)
            return V_next, spikes

        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(all_axes), P(all_axes), P(all_axes), P(all_axes),
                      P(None, all_axes), P()),
            out_specs=(P(all_axes), P(all_axes)),
            check_vma=False)
        V_next, spikes = fn(V, theta, lam, spikes_prev, state["weights"],
                            key)
        return {**state, "V": V_next, "spikes": spikes}

    return step


def small_reference_step(V, theta, lam, spikes_prev, weights, key):
    """Single-device oracle for tests: same windowed semantics."""
    V_mid, spikes = nrn.fire_phase(V, theta, jnp.full_like(theta, -32), lam,
                                   jnp.ones(V.shape, bool), key)
    W = weights.shape[0]
    win = spikes_prev[:W]
    syn = jnp.einsum("w,wn->n", win.astype(jnp.int32),
                     weights.astype(jnp.int32))
    return nrn.integrate_phase(V_mid, syn), spikes
