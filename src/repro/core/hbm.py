"""HBM synaptic-routing-table layout — §4, Fig. 2, Fig. 7, Appendix A.3.

Memory model (8 GB HBM per FPGA card):
  * memory is divided into SEGMENTS of 16 SLOTS spanning two HBM rows;
    each slot stores one pointer or one synapse record;
  * four regions: neuron-model definitions, axon pointers, neuron pointers,
    synapses;
  * a pointer = (base address, n_rows) delimiting where its item's outgoing
    synapses live — relative row counts rather than absolute addresses save
    bits (§4);
  * ALIGNMENT: a synapse must occupy the same slot number (id mod 16) as its
    POSTSYNAPTIC neuron, so that the 16-lane parallel membrane-update units
    each read their own slot (Fig. 2b);
  * neuron pointers are grouped by neuron model;
  * output neurons are designated by a flag in their synapse records; a
    neuron with no outgoing synapses still gets 16 zero-weight synapses so
    that every neuron has a synapse-region entry (A.3);
  * the compiler packs synapses for maximum density (it may reorder
    axon/neuron placement to reduce padding), which lowers execution latency.

This module reproduces the mapping algorithm of Fig. 7 and reports the
packing/access statistics that drive the paper's energy & latency model
(costmodel.py). The event-driven engine (engine.py) executes directly from
this table; `HBMImage.flatten()` lowers the pointer dicts to dense
id-indexed arrays + row-owner/CSR inverse maps (`FlatImage`) for the
vectorized routing path (kernels/route.py); `shard_image()` splits the
packed table into per-core destination shards (`CoreShards`) for the
hierarchical multi-core tier (core.hiaer).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

SLOTS = 16                 # slots per segment (Fig. 2)
ROWS_PER_SEGMENT = 2       # a segment spans two HBM rows
HBM_BYTES = 8 << 30        # 8 GB per FPGA card
SLOT_BYTES = 8             # one 64-bit record per slot (weight+addr+flags)
W_MIN = -32768             # int16 synapse-record weight range (Fig. 7);
W_MAX = 32767              # the single definition every clip/check uses


@dataclass
class Pointer:
    base_row: int          # starting row in the synapse region
    n_rows: int            # rows spanned by this item's synapses


@dataclass
class Synapse:
    post: int              # postsynaptic neuron id
    weight: int            # int16
    output_flag: bool = False


@dataclass
class FlatImage:
    """`HBMImage` lowered to dense arrays for the vectorized engine.

    The `Dict[int, Pointer]` tables become id-indexed int32 vectors plus two
    inverse maps over the synapse rows, so phase-1 (pointer fetch) and
    phase-2 (row fetch + 16-lane accumulate) are pure gathers:

      * `axon_base/axon_rows/axon_present`  — (A,) pointer table, A =
        max axon id + 1 (present=False marks ids with no pointer);
      * `neuron_base/neuron_rows/neuron_present` — (N,) likewise;
      * `row_owner_axon/row_owner_neuron`   — (R,) inverse pointer maps:
        the item id whose span covers row r, or -1.  The Fig. 7 mapper
        gives every row at most one owner (items occupy disjoint ranges),
        which is what makes the dense row-gate formulation exact;
      * `axon_row_indptr/axon_row_indices` (and the neuron pair) — the
        per-item row-span CSR: rows of item i are
        `indices[indptr[i]:indptr[i+1]]`, for gather-style routing of only
        the fired items (sparse dispatch; the dense engine path uses the
        owner maps instead).

    `syn_weight` is widened to int32 once here so the accumulate path never
    re-casts per step."""
    syn_post: np.ndarray           # (R, SLOTS) int32, -1 = empty
    syn_weight: np.ndarray         # (R, SLOTS) int32 (widened from int16)
    axon_base: np.ndarray          # (A,) int32
    axon_rows: np.ndarray          # (A,) int32
    axon_present: np.ndarray       # (A,) bool
    neuron_base: np.ndarray        # (N,) int32
    neuron_rows: np.ndarray        # (N,) int32
    neuron_present: np.ndarray     # (N,) bool
    row_owner_axon: np.ndarray     # (R,) int32, -1 = unowned
    row_owner_neuron: np.ndarray   # (R,) int32, -1 = unowned
    axon_row_indptr: np.ndarray    # (A + 1,) int32
    axon_row_indices: np.ndarray   # (sum axon_rows,) int32
    neuron_row_indptr: np.ndarray  # (N + 1,) int32
    neuron_row_indices: np.ndarray  # (sum neuron_rows,) int32


def _ranges(counts: np.ndarray) -> np.ndarray:
    """Concatenated aranges: [0..c0), [0..c1), ... as one vector."""
    counts = np.asarray(counts, np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros((0,), np.int64)
    starts = np.cumsum(counts) - counts
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)


def _flatten_arrays(base: np.ndarray, rows: np.ndarray,
                    present: np.ndarray, n_rows: int):
    """Vectorized twin of `_flatten_ptr_table` over id-indexed pointer
    arrays (base/rows/present, length max(n_items, 1)): returns the same
    (base, rows, present, owner, indptr, indices) tuple, bit for bit."""
    base = np.asarray(base, np.int32)
    rows = np.asarray(rows, np.int32)
    present = np.asarray(present, bool)
    eff = np.where(present, rows, 0).astype(np.int64)
    owner = np.full((n_rows,), -1, np.int32)
    idx = (np.repeat(base.astype(np.int64), eff) + _ranges(eff))
    owner[idx] = np.repeat(np.arange(base.shape[0], dtype=np.int32), eff)
    indptr = np.zeros((base.shape[0] + 1,), np.int32)
    np.cumsum(eff, out=indptr[1:])
    return (base, np.where(present, rows, 0).astype(np.int32), present,
            owner, indptr, idx.astype(np.int32))


def _flatten_ptr_table(ptr: Dict[int, Pointer], n_rows: int):
    """Lower one pointer dict to (base, rows, present, owner, CSR)."""
    n = max(ptr.keys(), default=-1) + 1
    n = max(n, 1)                  # keep zero-item tables indexable
    base = np.zeros((n,), np.int32)
    rows = np.zeros((n,), np.int32)
    present = np.zeros((n,), bool)
    owner = np.full((n_rows,), -1, np.int32)
    indptr = np.zeros((n + 1,), np.int32)
    indices: List[int] = []
    for i in range(n):
        p = ptr.get(i)
        if p is not None:
            base[i], rows[i], present[i] = p.base_row, p.n_rows, True
            owner[p.base_row:p.base_row + p.n_rows] = i
            indices.extend(range(p.base_row, p.base_row + p.n_rows))
        indptr[i + 1] = len(indices)
    return (base, rows, present, owner, indptr,
            np.asarray(indices, np.int32))


class HBMImage:
    """The packed routing table: a dense (rows, SLOTS) record array.

    The pointer tables may be passed as dicts (the legacy mapper) or as
    zero-argument thunks (the columnar compiler): the staged execution
    paths never touch the per-item dicts — they run off `FlatImage` —
    so thunks defer the O(items) dict materialization until a
    reference-path consumer (e.g. `EventEngine._route_reference`)
    actually asks for `axon_ptr`/`neuron_ptr`/`model_groups`."""

    def __init__(self, syn_post, syn_weight, syn_outflag,
                 axon_ptr=None, neuron_ptr=None, model_groups=None):
        self.syn_post = syn_post
        self.syn_weight = syn_weight
        self.syn_outflag = syn_outflag
        self._axon_ptr = {} if axon_ptr is None else axon_ptr
        self._neuron_ptr = {} if neuron_ptr is None else neuron_ptr
        self._model_groups = {} if model_groups is None else model_groups

    @staticmethod
    def _force(v):
        return v() if callable(v) else v

    @property
    def axon_ptr(self) -> Dict[int, Pointer]:
        self._axon_ptr = self._force(self._axon_ptr)
        return self._axon_ptr

    @axon_ptr.setter
    def axon_ptr(self, v):
        self._axon_ptr = v

    @property
    def neuron_ptr(self) -> Dict[int, Pointer]:
        self._neuron_ptr = self._force(self._neuron_ptr)
        return self._neuron_ptr

    @neuron_ptr.setter
    def neuron_ptr(self, v):
        self._neuron_ptr = v

    @property
    def model_groups(self) -> Dict[int, List[int]]:
        self._model_groups = self._force(self._model_groups)
        return self._model_groups

    @model_groups.setter
    def model_groups(self, v):
        self._model_groups = v

    @property
    def n_rows(self) -> int:
        return self.syn_post.shape[0]

    def flatten(self) -> FlatImage:
        """Lower the pointer dicts to dense id-indexed arrays (see
        `FlatImage`). Call again after in-place `syn_weight` edits if a
        consumer snapshotted the weights."""
        ab, ar, ap, aown, a_indptr, aidx = _flatten_ptr_table(
            self.axon_ptr, self.n_rows)
        nb, nr, npr, nown, n_indptr, nidx = _flatten_ptr_table(
            self.neuron_ptr, self.n_rows)
        return FlatImage(
            syn_post=np.ascontiguousarray(self.syn_post, np.int32),
            syn_weight=np.ascontiguousarray(self.syn_weight, np.int32),
            axon_base=ab, axon_rows=ar, axon_present=ap,
            neuron_base=nb, neuron_rows=nr, neuron_present=npr,
            row_owner_axon=aown, row_owner_neuron=nown,
            axon_row_indptr=a_indptr, axon_row_indices=aidx,
            neuron_row_indptr=n_indptr, neuron_row_indices=nidx)

    def stats(self) -> Dict[str, float]:
        used = int((self.syn_post >= 0).sum())
        total = self.syn_post.size
        ptr_slots = len(self.axon_ptr) + len(self.neuron_ptr)
        return {
            "synapse_slots_used": used,
            "synapse_slots_total": total,
            "packing_density": used / max(total, 1),
            "pointer_slots": ptr_slots,
            "hbm_bytes": (total + ptr_slots) * SLOT_BYTES,
            "hbm_rows": self.n_rows,
        }


@dataclass
class CoreShards:
    """`HBMImage` split into per-core shards for the hierarchical
    multi-core engines (core.hiaer, core.mesh_runtime) — §3's HiAER tier
    over the §4 tables.

    The split is by DESTINATION: core c stores every synapse record whose
    postsynaptic neuron is placed on c, because the 16-lane membrane
    units that consume a record live next to the postsynaptic neuron
    (Fig. 2b). Records sourced from items homed on c form its core-local
    ('grey matter') table; records sourced from remote items form its
    cross-core fan-in ('white matter') table — the rows a HiAER event
    from another core activates after the spike exchange delivers it.

    The layout is RAGGED: all cores' entries live in one flat array
    sorted by (core, local post id, monolithic position), and
    `csr_indptr` holds ABSOLUTE offsets into it — core c's span is
    `[csr_indptr[c, 0], csr_indptr[c, -1])` and local neuron l's records
    are `entries[csr_indptr[c, l]:csr_indptr[c, l + 1]]`. Shard memory
    is therefore linear in synapses no matter how skewed the placement
    (the padded-to-max (C, E) layout this replaces multiplied it by up
    to n_cores). Phase 2 on every core is still one scatter-free cumsum
    reduction (`kernels.route.ragged_segment_sum`).

    Each core owns its own weight storage: `entry_w` carries the record
    weights in entry order, so the execution tiers never gather through
    a monolithic dense `w_ext` image — a weight edit updates only the
    touched cores' spans (`entry_pos` keeps each record's monolithic
    flat position as the host-side edit index). The sharded sum reduces
    exactly the monolithic multiset of (weight x event-count) terms —
    int32 wraparound addition is order-free, which is what makes the
    sharded engines bit-exact vs the single-image `EventEngine`."""
    n_cores: int
    n_max: int                     # padded neurons per core
    core_nids: np.ndarray          # (C, n_max) int32 global id, -1 pad
    core_of_neuron: np.ndarray     # (N,) int32
    local_id: np.ndarray           # (N,) int32 slot within home core
    entry_pos: np.ndarray          # (nnz,) int64 flat monolithic
    #                                position row*SLOTS+slot (host-side
    #                                weight-edit index, never a gather
    #                                source on device)
    entry_item: np.ndarray         # (nnz,) int32 source item (axon id,
    #                                or A + neuron id)
    entry_w: np.ndarray            # (nnz,) int32 per-core weight storage
    csr_indptr: np.ndarray         # (C, n_max + 1) int64 ABSOLUTE
    #                                offsets into the entry arrays
    grey_entries: np.ndarray       # (C,) int64 core-local records
    white_entries: np.ndarray      # (C,) int64 cross-core records
    white_sources: np.ndarray      # (C,) int64 distinct remote source items

    @property
    def n_entries(self) -> int:
        return int(self.entry_pos.shape[0])

    @property
    def core_offsets(self) -> np.ndarray:
        """(C + 1,) int64: core c's entries span
        [core_offsets[c], core_offsets[c + 1])."""
        return np.append(self.csr_indptr[:, 0],
                         self.csr_indptr[-1, -1]).astype(np.int64)

    def entries_of_positions(self, positions: np.ndarray) -> np.ndarray:
        """Flat monolithic positions -> indices into the entry arrays
        (one lazy argsort of `entry_pos`, then searchsorted; raises
        KeyError on a position no entry carries). Shared by the
        hiaer/mesh weight-update paths."""
        order = getattr(self, "_pos_order", None)
        if order is None:
            order = np.argsort(self.entry_pos, kind="stable")
            self._pos_order = order
            self._pos_sorted = self.entry_pos[order]
        i = np.searchsorted(self._pos_sorted, positions)
        if positions.size and not np.array_equal(
                self._pos_sorted[np.minimum(
                    i, self._pos_sorted.shape[0] - 1)], positions):
            raise KeyError("position not present in shard tables")
        return order[i]

    def apply_entry_updates(self, positions, weights) -> np.ndarray:
        """Write `weights` at the entries carrying the given monolithic
        positions (in place) and return the SORTED UNIQUE core ids whose
        shards changed — the engines re-upload exactly those."""
        positions = np.asarray(positions, np.int64).reshape(-1)
        w = np.asarray(weights, np.int32).reshape(-1)
        if positions.size == 0:
            return np.zeros((0,), np.int64)
        idx = self.entries_of_positions(positions)
        self.entry_w[idx] = w
        return np.unique(np.searchsorted(self.core_offsets, idx,
                                         side="right") - 1)

    def padded(self, sentinel_pos: int = -1, sentinel_item: int = -1):
        """Expand the ragged layout to the padded-to-max (C, E) view
        (pos, item, w, per-core-relative indptr) — the legacy shard
        image. Kept for the ragged-vs-padded identity property tests
        and per-device padding in the mesh tier; the execution tiers
        never materialize the full (C, E) expansion."""
        off = self.core_offsets
        per_core = np.diff(off)
        E = max(int(per_core.max()) if per_core.size else 0, 1)
        C = self.n_cores
        pos = np.full((C, E), sentinel_pos, np.int64)
        item = np.full((C, E), sentinel_item, np.int64)
        w = np.zeros((C, E), np.int32)
        rows = np.repeat(np.arange(C), per_core)
        cols = _ranges(per_core)
        pos[rows, cols] = self.entry_pos
        item[rows, cols] = self.entry_item
        w[rows, cols] = self.entry_w
        indptr_rel = self.csr_indptr - off[:-1, None]
        return pos, item, w, indptr_rel

    def stats(self) -> Dict[str, float]:
        total = int(self.grey_entries.sum() + self.white_entries.sum())
        return {
            "n_cores": self.n_cores,
            "neurons_per_core_max": self.n_max,
            "synapse_entries": total,
            "grey_entries": int(self.grey_entries.sum()),
            "white_entries": int(self.white_entries.sum()),
            "white_frac": int(self.white_entries.sum()) / max(total, 1),
            "white_pointer_slots": int(self.white_sources.sum()),
        }


def shard_entries(pos: np.ndarray, item: np.ndarray, post: np.ndarray,
                  weight: np.ndarray, neuron_core: np.ndarray,
                  axon_core: np.ndarray, n_cores: int, n_neurons: int,
                  n_axon_slots: int) -> CoreShards:
    """Build ragged `CoreShards` from flat synapse entries: `pos` (flat
    position into the monolithic R*SLOTS table), `item` (source in
    engine item space: axon id, or n_axon_slots + neuron id), `post`
    (neuron id in [0, n_neurons)) and `weight` (the record's weight —
    each core's own copy of its synapse memory). Entries are sorted by
    (destination core, local post id) with flat position as the
    tie-break — identical to scanning the dense table in position order
    (`shard_image`), so both construction routes produce bit-identical
    shards. Entries need not arrive pre-sorted."""
    C, N, A = n_cores, n_neurons, n_axon_slots
    core_of = np.asarray(neuron_core, np.int32)
    counts = np.bincount(core_of, minlength=C) if N else np.zeros(C, int)
    n_max = max(int(counts.max()) if N else 0, 1)
    core_nids = np.full((C, n_max), -1, np.int32)
    local_id = np.zeros((N,), np.int32)
    # one stable sort by home core gives every neuron's slot: rank within
    # its core = global rank - core start (no per-core scans; the build
    # stays O(N log N + nnz log nnz) at deployment-scale core counts)
    order = np.argsort(core_of, kind="stable")
    core_sorted = core_of[order]
    nrn_start = np.zeros(C + 1, np.int64)
    np.cumsum(counts, out=nrn_start[1:])
    ranks = np.arange(N, dtype=np.int64) - nrn_start[core_sorted]
    core_nids[core_sorted, ranks] = order
    local_id[order] = ranks

    pos = np.asarray(pos, np.int64)
    item = np.asarray(item, np.int64)
    post = np.asarray(post, np.int64)
    weight = np.asarray(weight, np.int32)
    if pos.size >= 2 ** 31:
        # the engines index entries with device int32; past that a
        # network must shard across hosts, never silently wrap
        raise ValueError(f"{pos.size} shard entries exceed int32 "
                         f"indexing; split the network across hosts")
    dest = core_of[post] if pos.size else np.zeros((0,), np.int32)
    lpost = local_id[post] if pos.size else np.zeros((0,), np.int32)
    is_axon_src = item < A
    src_core = np.where(
        is_axon_src,
        np.asarray(axon_core, np.int32)[
            np.clip(item, 0, max(A - 1, 0))],
        core_of[np.clip(item - A, 0, max(N - 1, 0))]) \
        if pos.size else np.zeros((0,), np.int32)
    is_white = src_core != dest

    per_core = np.bincount(dest, minlength=C) if pos.size else \
        np.zeros(C, int)
    # one global stable sort by (dest core, local post) replaces the
    # per-core argsorts; the trailing position key keeps equal-(core,
    # post) records in monolithic table order (deterministic builds)
    ord_e = np.lexsort((pos, lpost, dest))
    ent_start = np.zeros(C + 1, np.int64)
    np.cumsum(per_core, out=ent_start[1:])
    seg = np.bincount(dest.astype(np.int64) * n_max + lpost,
                      minlength=C * n_max).reshape(C, n_max)
    csr_indptr = np.zeros((C, n_max + 1), np.int64)
    np.cumsum(seg, axis=1, out=csr_indptr[:, 1:])
    csr_indptr += ent_start[:-1, None]
    white = np.bincount(dest[is_white], minlength=C).astype(np.int64)
    grey = per_core.astype(np.int64) - white
    if is_white.any():
        wpairs = np.unique(np.stack([dest[is_white], item[is_white]]),
                           axis=1)
        white_sources = np.bincount(wpairs[0], minlength=C) \
            .astype(np.int64)
    else:
        white_sources = np.zeros((C,), np.int64)
    return CoreShards(n_cores=C, n_max=n_max, core_nids=core_nids,
                      core_of_neuron=core_of, local_id=local_id,
                      entry_pos=pos[ord_e],
                      entry_item=item[ord_e].astype(np.int32),
                      entry_w=weight[ord_e],
                      csr_indptr=csr_indptr, grey_entries=grey,
                      white_entries=white, white_sources=white_sources)


def gather_to_cores(values, core_nids_idx, pad):
    """Gather a global (N,) vector into the (C, n_max) per-core layout
    (pad slots read the appended `pad` value) — shared by the hiaer and
    mesh engines."""
    v = np.asarray(values)
    ext = np.append(v, np.asarray(pad, v.dtype))
    return ext[np.asarray(core_nids_idx)]


def shard_image(image: HBMImage, flat: FlatImage, neuron_core: np.ndarray,
                axon_core: np.ndarray, n_cores: int,
                n_neurons: int) -> CoreShards:
    """Split the packed table into per-core destination shards (see
    `CoreShards`) by scanning the dense table. `neuron_core` (N,) /
    `axon_core` (A,) give each item's home core under the deployment
    hierarchy. A.3 filler records whose post id exceeds n_neurons - 1
    are dropped (zero weight by construction, so the sharded sum stays
    bit-exact); in-range filler records are kept so later weight edits
    flow through unchanged. The staged compiler (core.compile) builds
    the same shards directly from the columnar spec via `shard_entries`
    without this dense scan."""
    N = n_neurons
    post_flat = image.syn_post.reshape(-1)
    A = int(flat.axon_rows.shape[0])
    pos = np.nonzero((post_flat >= 0) & (post_flat < max(N, 1)))[0]
    if N == 0:
        pos = pos[:0]
    rows = pos // SLOTS
    aid = flat.row_owner_axon[rows]
    nid = flat.row_owner_neuron[rows]
    owned = (aid >= 0) | (nid >= 0)
    pos, aid, nid = pos[owned], aid[owned], nid[owned]
    item = np.where(aid >= 0, aid, A + nid).astype(np.int64)
    post = post_flat[pos]
    weight = np.asarray(image.syn_weight, np.int32).reshape(-1)[pos]
    return shard_entries(pos, item, post, weight, neuron_core, axon_core,
                         n_cores, N, A)


class HBMMapper:
    """Fig. 7 mapping: iterate items (axons then neurons, neurons grouped by
    model), place each item's synapses contiguously, respecting the
    slot-alignment constraint (slot == post % 16); then write the pointer."""

    def __init__(self, n_neurons: int):
        self.n_neurons = n_neurons
        self.rows: List[List[Optional[Synapse]]] = []

    def _ensure(self, row: int):
        while len(self.rows) <= row:
            self.rows.append([None] * SLOTS)

    def place_item(self, synapses: Sequence[Synapse], start_row: int) -> Pointer:
        """Place one axon/neuron's synapses contiguously from start_row.
        Within the region each synapse goes to the first free row whose
        aligned slot (post % 16) is empty."""
        if not synapses:               # empty axon: zero-span pointer
            return Pointer(base_row=start_row, n_rows=0)
        row = start_row
        self._ensure(row)
        placed_rows = set()
        for syn in synapses:
            slot = syn.post % SLOTS
            r = row
            while True:
                self._ensure(r)
                if self.rows[r][slot] is None:
                    self.rows[r][slot] = syn
                    placed_rows.add(r)
                    break
                r += 1
        end_row = max(placed_rows) if placed_rows else row
        return Pointer(base_row=row, n_rows=end_row - row + 1)

    def finalize(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = max(len(self.rows), 1)
        # round up to whole segments
        n = ((n + ROWS_PER_SEGMENT - 1) // ROWS_PER_SEGMENT) * ROWS_PER_SEGMENT
        post = np.full((n, SLOTS), -1, np.int32)
        w = np.zeros((n, SLOTS), np.int16)
        flag = np.zeros((n, SLOTS), bool)
        for r, row in enumerate(self.rows):
            for s, syn in enumerate(row):
                if syn is not None:
                    post[r, s] = syn.post
                    w[r, s] = np.int16(np.clip(syn.weight, W_MIN, W_MAX))
                    flag[r, s] = syn.output_flag
        return post, w, flag


def compile_network(axon_syn: Dict[int, List[Tuple[int, int]]],
                    neuron_syn: Dict[int, List[Tuple[int, int]]],
                    neuron_model_ids: Dict[int, int],
                    outputs: Sequence[int],
                    n_neurons: int,
                    dense_pack: bool = True) -> HBMImage:
    """Build the HBM image.

    axon_syn / neuron_syn: item id -> [(post_neuron, weight), ...]
    neuron_model_ids: neuron id -> model group id (pointers grouped by model)
    dense_pack: start each item's search at the current frontier (the
    compiler's density optimization); False = segment-aligned placement
    (each item starts on a fresh segment — the naive baseline the paper's
    compiler improves on).
    """
    out_set = set(outputs)
    mapper = HBMMapper(n_neurons)
    img_axon_ptr: Dict[int, Pointer] = {}
    img_neuron_ptr: Dict[int, Pointer] = {}
    frontier = 0

    def mk(syns, is_out_src=False):
        return [Synapse(post=p, weight=w,
                        output_flag=(p in out_set)) for p, w in syns]

    def advance():
        # items own disjoint row ranges (phase-2 reads a pointer's rows in
        # full); dense packing starts the next item on the very next row,
        # the naive baseline pads to a segment boundary.
        f = len(mapper.rows)
        if not dense_pack:
            f += (-f) % ROWS_PER_SEGMENT
        return f

    # Fig. 7: axons first
    for aid in sorted(axon_syn):
        ptr = mapper.place_item(mk(axon_syn[aid]), frontier)
        img_axon_ptr[aid] = ptr
        frontier = advance()
    # neurons grouped by model (§A.3 step 1)
    groups: Dict[int, List[int]] = {}
    for nid, mid in neuron_model_ids.items():
        groups.setdefault(mid, []).append(nid)
    for mid in sorted(groups):
        for nid in sorted(groups[mid]):
            syns = mk(neuron_syn.get(nid, []))
            if not syns:
                # A.3: a zero-fanout neuron still gets a full segment of 16
                # zero-weight synapses; if it is an output neuron the filler
                # records carry its output flag.
                syns = [Synapse(post=s, weight=0,
                                output_flag=(nid in out_set))
                        for s in range(SLOTS)]
            ptr = mapper.place_item(syns, frontier)
            img_neuron_ptr[nid] = ptr
            frontier = advance()
    post, w, flag = mapper.finalize()
    return HBMImage(post, w, flag, img_axon_ptr, img_neuron_ptr,
                    {m: sorted(g) for m, g in groups.items()})


def ptr_dict(base: np.ndarray, rows: np.ndarray) -> Dict[int, Pointer]:
    """Id-indexed pointer arrays -> the legacy {id: Pointer} dict."""
    return {i: Pointer(b, r)
            for i, (b, r) in enumerate(zip(np.asarray(base).tolist(),
                                           np.asarray(rows).tolist()))}


def _model_groups_of(model_gid: np.ndarray, nperm: np.ndarray,
                     n_neurons: int) -> Dict[int, List[int]]:
    """{group id: sorted neuron ids} from the per-neuron group vector
    (nperm is the (gid, id) lexsort, so each split is already sorted)."""
    if not n_neurons:
        return {}
    gid_sorted = model_gid[nperm]
    bounds = np.nonzero(np.diff(gid_sorted))[0] + 1
    return {int(model_gid[g[0]]): [int(i) for i in g]
            for g in np.split(nperm, bounds)}


class ColumnarImage(NamedTuple):
    """`build_image_columnar` result: the packed image plus the lowered
    `FlatImage` and per-synapse placement columns the staged compiler
    threads through to the runtime (synapse index, delta weight uploads,
    direct shard construction)."""
    image: HBMImage
    flat: FlatImage
    syn_pos: np.ndarray        # (S,) int64 flat position row*SLOTS+slot,
    #                            aligned with the input columns
    filler_pos: np.ndarray     # (F*SLOTS,) int64 positions of A.3 fillers
    filler_item: np.ndarray    # (F*SLOTS,) int64 source item (A' + nid)
    filler_post: np.ndarray    # (F*SLOTS,) int64 post id (= slot)


def build_image_columnar(pre_item: np.ndarray, post: np.ndarray,
                         weight: np.ndarray, n_axons: int, n_neurons: int,
                         model_gid: np.ndarray, outputs: Sequence[int],
                         dense_pack: bool = True) -> ColumnarImage:
    """Vectorized Fig. 7 mapping from synapse columns — bit-identical to
    `compile_network` on the equivalent per-item adjacency (pinned in
    tests/test_staged_api.py), but O(S log S) NumPy instead of a
    per-synapse Python loop.

    pre_item: (S,) source in item space — axon id a in [0, A), or
    A + neuron id; per-item synapse order is the column order (the order
    the legacy mapper walks each item's list). model_gid: (N,) model
    group of each neuron (pointers grouped by model, §A.3 step 1).

    The closed form of `HBMMapper.place_item` under disjoint item
    ranges: within one item the k-th synapse aimed at slot s lands on
    row base + k, so an item spans max-slot-multiplicity rows and bases
    are a cumulative sum over items in processing order (axons by id,
    then neurons by (model group, id); the naive non-dense layout rounds
    every span up to a segment boundary)."""
    A, N = int(n_axons), int(n_neurons)
    S = int(np.asarray(post).shape[0])
    pre_item = np.asarray(pre_item, np.int64)
    post = np.asarray(post, np.int64)
    weight = np.asarray(weight, np.int64)
    model_gid = np.asarray(model_gid, np.int64)
    n_items = A + N

    # processing rank: axons in id order, then neurons by (gid, nid)
    rank = np.empty((max(n_items, 1),), np.int64)
    rank[:A] = np.arange(A)
    nperm = np.lexsort((np.arange(N), model_gid))   # gid, then id
    rank[A + nperm] = A + np.arange(N)

    # occurrence index within (item, slot): stable sort by the pair key
    # keeps column order within each group = legacy list order
    slot = post % SLOTS
    r = rank[pre_item] if S else np.zeros((0,), np.int64)
    g = r * SLOTS + slot
    if S and (n_items * SLOTS + 1) < (2 ** 62) // (S + 1):
        # stable order via an unsorted-tie-free composite key + default
        # quicksort — ~4x faster than numpy's stable argsort here
        sidx = np.argsort(g * S + np.arange(S, dtype=np.int64))
    else:
        sidx = np.argsort(g, kind="stable")
    gs = g[sidx]
    is_start = np.ones((S,), bool)
    if S:
        is_start[1:] = gs[1:] != gs[:-1]
    group_of = np.cumsum(is_start) - 1
    group_start = np.nonzero(is_start)[0]
    occ = np.empty((S,), np.int64)
    occ[sidx] = np.arange(S) - group_start[group_of]

    # rows spanned per item (by processing rank): max slot multiplicity;
    # zero-fanout neurons get one A.3 filler segment row, empty axons 0
    rows_by_rank = np.zeros((max(n_items, 1),), np.int64)
    if S:
        gi = gs[group_start] // SLOTS               # item of each group
        gcount = np.diff(np.append(group_start, S))
        # groups of one item are contiguous in gi (gs is sorted), so a
        # segmented max via maximum.reduceat beats np.maximum.at
        item_start = np.nonzero(np.concatenate(
            [[True], gi[1:] != gi[:-1]]))[0]
        rows_by_rank[gi[item_start]] = np.maximum.reduceat(
            gcount, item_start)
    deg = np.bincount(pre_item, minlength=max(n_items, 1)) if S \
        else np.zeros((max(n_items, 1),), np.int64)
    empty_nrn = np.nonzero(deg[A:A + N] == 0)[0]
    rows_by_rank[rank[A + empty_nrn]] = 1

    step = rows_by_rank if dense_pack else \
        -(-rows_by_rank // ROWS_PER_SEGMENT) * ROWS_PER_SEGMENT
    base_by_rank = np.zeros_like(step)
    np.cumsum(step[:-1], out=base_by_rank[1:])
    used = int((base_by_rank + rows_by_rank).max()) if n_items else 0
    n_rows = -(-max(used, 1) // ROWS_PER_SEGMENT) * ROWS_PER_SEGMENT

    out_mask = np.zeros((max(N, 1),), bool)
    out_ids = np.asarray(list(outputs), np.int64)
    out_mask[out_ids] = True

    syn_post = np.full((n_rows, SLOTS), -1, np.int32)
    syn_weight = np.zeros((n_rows, SLOTS), np.int16)
    syn_outflag = np.zeros((n_rows, SLOTS), bool)
    syn_pos = (base_by_rank[r] + occ) * SLOTS + slot
    pf = syn_post.reshape(-1)
    wf = syn_weight.reshape(-1)
    ff = syn_outflag.reshape(-1)
    pf[syn_pos] = post
    wf[syn_pos] = np.clip(weight, W_MIN, W_MAX).astype(np.int16)
    ff[syn_pos] = out_mask[post]
    # A.3 filler segments: 16 zero-weight records carrying the SOURCE
    # neuron's output flag (post id = slot)
    F = int(empty_nrn.shape[0])
    filler_pos = (base_by_rank[rank[A + empty_nrn]][:, None] * SLOTS
                  + np.arange(SLOTS)[None, :]).reshape(-1)
    filler_post = np.tile(np.arange(SLOTS, dtype=np.int64), F)
    pf[filler_pos] = filler_post
    ff[filler_pos] = np.repeat(out_mask[empty_nrn], SLOTS)

    # id-indexed pointer tables (axons, then neurons via their rank)
    a_base = base_by_rank[:A].astype(np.int32)
    a_rows = rows_by_rank[:A].astype(np.int32)
    n_rank = rank[A:A + N]
    nb = base_by_rank[n_rank].astype(np.int32)
    nr = rows_by_rank[n_rank].astype(np.int32)
    image = HBMImage(syn_post, syn_weight, syn_outflag,
                     axon_ptr=lambda: ptr_dict(a_base, a_rows),
                     neuron_ptr=lambda: ptr_dict(nb, nr),
                     model_groups=lambda: _model_groups_of(model_gid,
                                                           nperm, N))

    def pad1(a, dtype, fill=0):
        return a if a.shape[0] else np.full((1,), fill, dtype)

    ab, ar, ap, aown, a_indptr, aidx = _flatten_arrays(
        pad1(a_base, np.int32), pad1(a_rows, np.int32),
        np.ones((max(A, 1),), bool) if A else np.zeros((1,), bool),
        n_rows)
    nb_, nr_, npr, nown, n_indptr, nidx = _flatten_arrays(
        pad1(nb, np.int32), pad1(nr, np.int32),
        np.ones((max(N, 1),), bool) if N else np.zeros((1,), bool),
        n_rows)
    flat = FlatImage(
        syn_post=np.ascontiguousarray(syn_post, np.int32),
        syn_weight=np.ascontiguousarray(syn_weight, np.int32),
        axon_base=ab, axon_rows=ar, axon_present=ap,
        neuron_base=nb_, neuron_rows=nr_, neuron_present=npr,
        row_owner_axon=aown, row_owner_neuron=nown,
        axon_row_indptr=a_indptr, axon_row_indices=aidx,
        neuron_row_indptr=n_indptr, neuron_row_indices=nidx)
    A_eng = int(ar.shape[0])            # engine item space offset
    filler_item = A_eng + empty_nrn.repeat(SLOTS).astype(np.int64)
    return ColumnarImage(image=image, flat=flat, syn_pos=syn_pos,
                         filler_pos=filler_pos.astype(np.int64),
                         filler_item=filler_item,
                         filler_post=filler_post)
