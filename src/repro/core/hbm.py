"""HBM synaptic-routing-table layout — §4, Fig. 2, Fig. 7, Appendix A.3.

Memory model (8 GB HBM per FPGA card):
  * memory is divided into SEGMENTS of 16 SLOTS spanning two HBM rows;
    each slot stores one pointer or one synapse record;
  * four regions: neuron-model definitions, axon pointers, neuron pointers,
    synapses;
  * a pointer = (base address, n_rows) delimiting where its item's outgoing
    synapses live — relative row counts rather than absolute addresses save
    bits (§4);
  * ALIGNMENT: a synapse must occupy the same slot number (id mod 16) as its
    POSTSYNAPTIC neuron, so that the 16-lane parallel membrane-update units
    each read their own slot (Fig. 2b);
  * neuron pointers are grouped by neuron model;
  * output neurons are designated by a flag in their synapse records; a
    neuron with no outgoing synapses still gets 16 zero-weight synapses so
    that every neuron has a synapse-region entry (A.3);
  * the compiler packs synapses for maximum density (it may reorder
    axon/neuron placement to reduce padding), which lowers execution latency.

This module reproduces the mapping algorithm of Fig. 7 and reports the
packing/access statistics that drive the paper's energy & latency model
(costmodel.py). The event-driven engine (engine.py) executes directly from
this table.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

SLOTS = 16                 # slots per segment (Fig. 2)
ROWS_PER_SEGMENT = 2       # a segment spans two HBM rows
HBM_BYTES = 8 << 30        # 8 GB per FPGA card
SLOT_BYTES = 8             # one 64-bit record per slot (weight+addr+flags)


@dataclass
class Pointer:
    base_row: int          # starting row in the synapse region
    n_rows: int            # rows spanned by this item's synapses


@dataclass
class Synapse:
    post: int              # postsynaptic neuron id
    weight: int            # int16
    output_flag: bool = False


@dataclass
class HBMImage:
    """The packed routing table: a dense (rows, SLOTS) record array."""
    syn_post: np.ndarray       # (rows, SLOTS) int32, -1 = empty
    syn_weight: np.ndarray     # (rows, SLOTS) int16
    syn_outflag: np.ndarray    # (rows, SLOTS) bool
    axon_ptr: Dict[int, Pointer] = field(default_factory=dict)
    neuron_ptr: Dict[int, Pointer] = field(default_factory=dict)
    model_groups: Dict[int, List[int]] = field(default_factory=dict)

    @property
    def n_rows(self) -> int:
        return self.syn_post.shape[0]

    def stats(self) -> Dict[str, float]:
        used = int((self.syn_post >= 0).sum())
        total = self.syn_post.size
        ptr_slots = len(self.axon_ptr) + len(self.neuron_ptr)
        return {
            "synapse_slots_used": used,
            "synapse_slots_total": total,
            "packing_density": used / max(total, 1),
            "pointer_slots": ptr_slots,
            "hbm_bytes": (total + ptr_slots) * SLOT_BYTES,
            "hbm_rows": self.n_rows,
        }


class HBMMapper:
    """Fig. 7 mapping: iterate items (axons then neurons, neurons grouped by
    model), place each item's synapses contiguously, respecting the
    slot-alignment constraint (slot == post % 16); then write the pointer."""

    def __init__(self, n_neurons: int):
        self.n_neurons = n_neurons
        self.rows: List[List[Optional[Synapse]]] = []

    def _ensure(self, row: int):
        while len(self.rows) <= row:
            self.rows.append([None] * SLOTS)

    def place_item(self, synapses: Sequence[Synapse], start_row: int) -> Pointer:
        """Place one axon/neuron's synapses contiguously from start_row.
        Within the region each synapse goes to the first free row whose
        aligned slot (post % 16) is empty."""
        if not synapses:               # empty axon: zero-span pointer
            return Pointer(base_row=start_row, n_rows=0)
        row = start_row
        self._ensure(row)
        placed_rows = set()
        for syn in synapses:
            slot = syn.post % SLOTS
            r = row
            while True:
                self._ensure(r)
                if self.rows[r][slot] is None:
                    self.rows[r][slot] = syn
                    placed_rows.add(r)
                    break
                r += 1
        end_row = max(placed_rows) if placed_rows else row
        return Pointer(base_row=row, n_rows=end_row - row + 1)

    def finalize(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = max(len(self.rows), 1)
        # round up to whole segments
        n = ((n + ROWS_PER_SEGMENT - 1) // ROWS_PER_SEGMENT) * ROWS_PER_SEGMENT
        post = np.full((n, SLOTS), -1, np.int32)
        w = np.zeros((n, SLOTS), np.int16)
        flag = np.zeros((n, SLOTS), bool)
        for r, row in enumerate(self.rows):
            for s, syn in enumerate(row):
                if syn is not None:
                    post[r, s] = syn.post
                    w[r, s] = np.int16(np.clip(syn.weight, -32768, 32767))
                    flag[r, s] = syn.output_flag
        return post, w, flag


def compile_network(axon_syn: Dict[int, List[Tuple[int, int]]],
                    neuron_syn: Dict[int, List[Tuple[int, int]]],
                    neuron_model_ids: Dict[int, int],
                    outputs: Sequence[int],
                    n_neurons: int,
                    dense_pack: bool = True) -> HBMImage:
    """Build the HBM image.

    axon_syn / neuron_syn: item id -> [(post_neuron, weight), ...]
    neuron_model_ids: neuron id -> model group id (pointers grouped by model)
    dense_pack: start each item's search at the current frontier (the
    compiler's density optimization); False = segment-aligned placement
    (each item starts on a fresh segment — the naive baseline the paper's
    compiler improves on).
    """
    out_set = set(outputs)
    mapper = HBMMapper(n_neurons)
    img_axon_ptr: Dict[int, Pointer] = {}
    img_neuron_ptr: Dict[int, Pointer] = {}
    frontier = 0

    def mk(syns, is_out_src=False):
        return [Synapse(post=p, weight=w,
                        output_flag=(p in out_set)) for p, w in syns]

    def advance():
        # items own disjoint row ranges (phase-2 reads a pointer's rows in
        # full); dense packing starts the next item on the very next row,
        # the naive baseline pads to a segment boundary.
        f = len(mapper.rows)
        if not dense_pack:
            f += (-f) % ROWS_PER_SEGMENT
        return f

    # Fig. 7: axons first
    for aid in sorted(axon_syn):
        ptr = mapper.place_item(mk(axon_syn[aid]), frontier)
        img_axon_ptr[aid] = ptr
        frontier = advance()
    # neurons grouped by model (§A.3 step 1)
    groups: Dict[int, List[int]] = {}
    for nid, mid in neuron_model_ids.items():
        groups.setdefault(mid, []).append(nid)
    for mid in sorted(groups):
        for nid in sorted(groups[mid]):
            syns = mk(neuron_syn.get(nid, []))
            if not syns:
                # A.3: a zero-fanout neuron still gets a full segment of 16
                # zero-weight synapses; if it is an output neuron the filler
                # records carry its output flag.
                syns = [Synapse(post=s, weight=0,
                                output_flag=(nid in out_set))
                        for s in range(SLOTS)]
            ptr = mapper.place_item(syns, frontier)
            img_neuron_ptr[nid] = ptr
            frontier = advance()
    post, w, flag = mapper.finalize()
    return HBMImage(post, w, flag, img_axon_ptr, img_neuron_ptr,
                    {m: sorted(g) for m, g in groups.items()})
