"""HBM synaptic-routing-table layout — §4, Fig. 2, Fig. 7, Appendix A.3.

Memory model (8 GB HBM per FPGA card):
  * memory is divided into SEGMENTS of 16 SLOTS spanning two HBM rows;
    each slot stores one pointer or one synapse record;
  * four regions: neuron-model definitions, axon pointers, neuron pointers,
    synapses;
  * a pointer = (base address, n_rows) delimiting where its item's outgoing
    synapses live — relative row counts rather than absolute addresses save
    bits (§4);
  * ALIGNMENT: a synapse must occupy the same slot number (id mod 16) as its
    POSTSYNAPTIC neuron, so that the 16-lane parallel membrane-update units
    each read their own slot (Fig. 2b);
  * neuron pointers are grouped by neuron model;
  * output neurons are designated by a flag in their synapse records; a
    neuron with no outgoing synapses still gets 16 zero-weight synapses so
    that every neuron has a synapse-region entry (A.3);
  * the compiler packs synapses for maximum density (it may reorder
    axon/neuron placement to reduce padding), which lowers execution latency.

This module reproduces the mapping algorithm of Fig. 7 and reports the
packing/access statistics that drive the paper's energy & latency model
(costmodel.py). The event-driven engine (engine.py) executes directly from
this table; `HBMImage.flatten()` lowers the pointer dicts to dense
id-indexed arrays + row-owner/CSR inverse maps (`FlatImage`) for the
vectorized routing path (kernels/route.py); `shard_image()` splits the
packed table into per-core destination shards (`CoreShards`) for the
hierarchical multi-core tier (core.hiaer).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

SLOTS = 16                 # slots per segment (Fig. 2)
ROWS_PER_SEGMENT = 2       # a segment spans two HBM rows
HBM_BYTES = 8 << 30        # 8 GB per FPGA card
SLOT_BYTES = 8             # one 64-bit record per slot (weight+addr+flags)


@dataclass
class Pointer:
    base_row: int          # starting row in the synapse region
    n_rows: int            # rows spanned by this item's synapses


@dataclass
class Synapse:
    post: int              # postsynaptic neuron id
    weight: int            # int16
    output_flag: bool = False


@dataclass
class FlatImage:
    """`HBMImage` lowered to dense arrays for the vectorized engine.

    The `Dict[int, Pointer]` tables become id-indexed int32 vectors plus two
    inverse maps over the synapse rows, so phase-1 (pointer fetch) and
    phase-2 (row fetch + 16-lane accumulate) are pure gathers:

      * `axon_base/axon_rows/axon_present`  — (A,) pointer table, A =
        max axon id + 1 (present=False marks ids with no pointer);
      * `neuron_base/neuron_rows/neuron_present` — (N,) likewise;
      * `row_owner_axon/row_owner_neuron`   — (R,) inverse pointer maps:
        the item id whose span covers row r, or -1.  The Fig. 7 mapper
        gives every row at most one owner (items occupy disjoint ranges),
        which is what makes the dense row-gate formulation exact;
      * `axon_row_indptr/axon_row_indices` (and the neuron pair) — the
        per-item row-span CSR: rows of item i are
        `indices[indptr[i]:indptr[i+1]]`, for gather-style routing of only
        the fired items (sparse dispatch; the dense engine path uses the
        owner maps instead).

    `syn_weight` is widened to int32 once here so the accumulate path never
    re-casts per step."""
    syn_post: np.ndarray           # (R, SLOTS) int32, -1 = empty
    syn_weight: np.ndarray         # (R, SLOTS) int32 (widened from int16)
    axon_base: np.ndarray          # (A,) int32
    axon_rows: np.ndarray          # (A,) int32
    axon_present: np.ndarray       # (A,) bool
    neuron_base: np.ndarray        # (N,) int32
    neuron_rows: np.ndarray        # (N,) int32
    neuron_present: np.ndarray     # (N,) bool
    row_owner_axon: np.ndarray     # (R,) int32, -1 = unowned
    row_owner_neuron: np.ndarray   # (R,) int32, -1 = unowned
    axon_row_indptr: np.ndarray    # (A + 1,) int32
    axon_row_indices: np.ndarray   # (sum axon_rows,) int32
    neuron_row_indptr: np.ndarray  # (N + 1,) int32
    neuron_row_indices: np.ndarray  # (sum neuron_rows,) int32


def _flatten_ptr_table(ptr: Dict[int, Pointer], n_rows: int):
    """Lower one pointer dict to (base, rows, present, owner, CSR)."""
    n = max(ptr.keys(), default=-1) + 1
    n = max(n, 1)                  # keep zero-item tables indexable
    base = np.zeros((n,), np.int32)
    rows = np.zeros((n,), np.int32)
    present = np.zeros((n,), bool)
    owner = np.full((n_rows,), -1, np.int32)
    indptr = np.zeros((n + 1,), np.int32)
    indices: List[int] = []
    for i in range(n):
        p = ptr.get(i)
        if p is not None:
            base[i], rows[i], present[i] = p.base_row, p.n_rows, True
            owner[p.base_row:p.base_row + p.n_rows] = i
            indices.extend(range(p.base_row, p.base_row + p.n_rows))
        indptr[i + 1] = len(indices)
    return (base, rows, present, owner, indptr,
            np.asarray(indices, np.int32))


@dataclass
class HBMImage:
    """The packed routing table: a dense (rows, SLOTS) record array."""
    syn_post: np.ndarray       # (rows, SLOTS) int32, -1 = empty
    syn_weight: np.ndarray     # (rows, SLOTS) int16
    syn_outflag: np.ndarray    # (rows, SLOTS) bool
    axon_ptr: Dict[int, Pointer] = field(default_factory=dict)
    neuron_ptr: Dict[int, Pointer] = field(default_factory=dict)
    model_groups: Dict[int, List[int]] = field(default_factory=dict)

    @property
    def n_rows(self) -> int:
        return self.syn_post.shape[0]

    def flatten(self) -> FlatImage:
        """Lower the pointer dicts to dense id-indexed arrays (see
        `FlatImage`). Call again after in-place `syn_weight` edits if a
        consumer snapshotted the weights."""
        ab, ar, ap, aown, a_indptr, aidx = _flatten_ptr_table(
            self.axon_ptr, self.n_rows)
        nb, nr, npr, nown, n_indptr, nidx = _flatten_ptr_table(
            self.neuron_ptr, self.n_rows)
        return FlatImage(
            syn_post=np.ascontiguousarray(self.syn_post, np.int32),
            syn_weight=np.ascontiguousarray(self.syn_weight, np.int32),
            axon_base=ab, axon_rows=ar, axon_present=ap,
            neuron_base=nb, neuron_rows=nr, neuron_present=npr,
            row_owner_axon=aown, row_owner_neuron=nown,
            axon_row_indptr=a_indptr, axon_row_indices=aidx,
            neuron_row_indptr=n_indptr, neuron_row_indices=nidx)

    def stats(self) -> Dict[str, float]:
        used = int((self.syn_post >= 0).sum())
        total = self.syn_post.size
        ptr_slots = len(self.axon_ptr) + len(self.neuron_ptr)
        return {
            "synapse_slots_used": used,
            "synapse_slots_total": total,
            "packing_density": used / max(total, 1),
            "pointer_slots": ptr_slots,
            "hbm_bytes": (total + ptr_slots) * SLOT_BYTES,
            "hbm_rows": self.n_rows,
        }


@dataclass
class CoreShards:
    """`HBMImage` split into per-core shards for the hierarchical
    multi-core engine (core.hiaer) — §3's HiAER tier over the §4 tables.

    The split is by DESTINATION: core c stores every synapse record whose
    postsynaptic neuron is placed on c, because the 16-lane membrane
    units that consume a record live next to the postsynaptic neuron
    (Fig. 2b). Records sourced from items homed on c form its core-local
    ('grey matter') table; records sourced from remote items form its
    cross-core fan-in ('white matter') table — the rows a HiAER event
    from another core activates after the spike exchange delivers it.

    Physically both tables are one per-core CSR sorted by local
    postsynaptic id, so phase 2 on every core is the same scatter-free
    cumsum reduction (`kernels.route.csr_segment_sum`) batched over the
    core axis. Entries reference the monolithic image by flattened
    position (`csr_src`), so a weight edit is a pure gather refresh and
    the sharded sum reduces exactly the monolithic multiset of
    (weight x event-count) terms — int32 wraparound addition is
    order-free, which is what makes the sharded engine bit-exact vs the
    single-image `EventEngine`."""
    n_cores: int
    n_max: int                     # padded neurons per core
    core_nids: np.ndarray          # (C, n_max) int32 global id, -1 pad
    core_of_neuron: np.ndarray     # (N,) int32
    local_id: np.ndarray           # (N,) int32 slot within home core
    csr_src: np.ndarray            # (C, E) int32 into flat R*SLOTS;
    #                                sentinel R*SLOTS = appended zero weight
    csr_item: np.ndarray           # (C, E) int32 source item (axon id,
    #                                or A + neuron id); sentinel A + N
    csr_indptr: np.ndarray         # (C, n_max + 1) int32
    grey_entries: np.ndarray       # (C,) int64 core-local records
    white_entries: np.ndarray      # (C,) int64 cross-core records
    white_sources: np.ndarray      # (C,) int64 distinct remote source items

    def stats(self) -> Dict[str, float]:
        total = int(self.grey_entries.sum() + self.white_entries.sum())
        return {
            "n_cores": self.n_cores,
            "neurons_per_core_max": self.n_max,
            "synapse_entries": total,
            "grey_entries": int(self.grey_entries.sum()),
            "white_entries": int(self.white_entries.sum()),
            "white_frac": int(self.white_entries.sum()) / max(total, 1),
            "white_pointer_slots": int(self.white_sources.sum()),
        }


def shard_image(image: HBMImage, flat: FlatImage, neuron_core: np.ndarray,
                axon_core: np.ndarray, n_cores: int,
                n_neurons: int) -> CoreShards:
    """Split the packed table into per-core destination shards (see
    `CoreShards`). `neuron_core` (N,) / `axon_core` (A,) give each item's
    home core under the deployment hierarchy. A.3 filler records whose
    post id exceeds n_neurons - 1 are dropped (zero weight by
    construction, so the sharded sum stays bit-exact); in-range filler
    records are kept so later weight edits flow through unchanged."""
    C, N = n_cores, n_neurons
    core_of = np.asarray(neuron_core, np.int32)
    A = int(flat.axon_rows.shape[0])
    counts = np.bincount(core_of, minlength=C) if N else np.zeros(C, int)
    n_max = max(int(counts.max()) if N else 0, 1)
    core_nids = np.full((C, n_max), -1, np.int32)
    local_id = np.zeros((N,), np.int32)
    # one stable sort by home core gives every neuron's slot: rank within
    # its core = global rank - core start (no per-core scans; the build
    # stays O(N log N + nnz log nnz) at deployment-scale core counts)
    order = np.argsort(core_of, kind="stable")
    core_sorted = core_of[order]
    nrn_start = np.zeros(C + 1, np.int64)
    np.cumsum(counts, out=nrn_start[1:])
    ranks = np.arange(N, dtype=np.int64) - nrn_start[core_sorted]
    core_nids[core_sorted, ranks] = order
    local_id[order] = ranks

    post_flat = image.syn_post.reshape(-1)
    sentinel_src = post_flat.size
    pos = np.nonzero((post_flat >= 0) & (post_flat < max(N, 1)))[0]
    if N == 0:
        pos = pos[:0]
    rows = pos // SLOTS
    aid = flat.row_owner_axon[rows]
    nid = flat.row_owner_neuron[rows]
    owned = (aid >= 0) | (nid >= 0)
    pos, aid, nid = pos[owned], aid[owned], nid[owned]
    item = np.where(aid >= 0, aid, A + nid).astype(np.int32)
    post = post_flat[pos]
    dest = core_of[post]
    lpost = local_id[post]
    src_core = np.where(
        aid >= 0,
        np.asarray(axon_core, np.int32)[np.clip(aid, 0, max(A - 1, 0))],
        core_of[np.clip(nid, 0, max(N - 1, 0))])
    is_white = src_core != dest

    per_core = np.bincount(dest, minlength=C) if pos.size else \
        np.zeros(C, int)
    E = max(int(per_core.max()) if pos.size else 0, 1)
    csr_src = np.full((C, E), sentinel_src, np.int32)
    csr_item = np.full((C, E), A + N, np.int32)
    csr_indptr = np.zeros((C, n_max + 1), np.int32)
    # one global stable sort by (dest core, local post) replaces the
    # per-core argsorts; the trailing arange key keeps equal-(core, post)
    # records in original table order (deterministic builds)
    ord_e = np.lexsort((np.arange(pos.size), lpost, dest))
    dest_s = dest[ord_e]
    ent_start = np.zeros(C + 1, np.int64)
    np.cumsum(per_core, out=ent_start[1:])
    col = np.arange(pos.size, dtype=np.int64) - ent_start[dest_s]
    csr_src[dest_s, col] = pos[ord_e]
    csr_item[dest_s, col] = item[ord_e]
    seg = np.bincount(dest.astype(np.int64) * n_max + lpost,
                      minlength=C * n_max).reshape(C, n_max)
    csr_indptr[:, 1:] = np.cumsum(seg, axis=1)
    white = np.bincount(dest[is_white], minlength=C).astype(np.int64)
    grey = per_core.astype(np.int64) - white
    if is_white.any():
        wpairs = np.unique(np.stack([dest[is_white], item[is_white]]),
                           axis=1)
        white_sources = np.bincount(wpairs[0], minlength=C) \
            .astype(np.int64)
    else:
        white_sources = np.zeros((C,), np.int64)
    return CoreShards(n_cores=C, n_max=n_max, core_nids=core_nids,
                      core_of_neuron=core_of, local_id=local_id,
                      csr_src=csr_src, csr_item=csr_item,
                      csr_indptr=csr_indptr, grey_entries=grey,
                      white_entries=white, white_sources=white_sources)


class HBMMapper:
    """Fig. 7 mapping: iterate items (axons then neurons, neurons grouped by
    model), place each item's synapses contiguously, respecting the
    slot-alignment constraint (slot == post % 16); then write the pointer."""

    def __init__(self, n_neurons: int):
        self.n_neurons = n_neurons
        self.rows: List[List[Optional[Synapse]]] = []

    def _ensure(self, row: int):
        while len(self.rows) <= row:
            self.rows.append([None] * SLOTS)

    def place_item(self, synapses: Sequence[Synapse], start_row: int) -> Pointer:
        """Place one axon/neuron's synapses contiguously from start_row.
        Within the region each synapse goes to the first free row whose
        aligned slot (post % 16) is empty."""
        if not synapses:               # empty axon: zero-span pointer
            return Pointer(base_row=start_row, n_rows=0)
        row = start_row
        self._ensure(row)
        placed_rows = set()
        for syn in synapses:
            slot = syn.post % SLOTS
            r = row
            while True:
                self._ensure(r)
                if self.rows[r][slot] is None:
                    self.rows[r][slot] = syn
                    placed_rows.add(r)
                    break
                r += 1
        end_row = max(placed_rows) if placed_rows else row
        return Pointer(base_row=row, n_rows=end_row - row + 1)

    def finalize(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = max(len(self.rows), 1)
        # round up to whole segments
        n = ((n + ROWS_PER_SEGMENT - 1) // ROWS_PER_SEGMENT) * ROWS_PER_SEGMENT
        post = np.full((n, SLOTS), -1, np.int32)
        w = np.zeros((n, SLOTS), np.int16)
        flag = np.zeros((n, SLOTS), bool)
        for r, row in enumerate(self.rows):
            for s, syn in enumerate(row):
                if syn is not None:
                    post[r, s] = syn.post
                    w[r, s] = np.int16(np.clip(syn.weight, -32768, 32767))
                    flag[r, s] = syn.output_flag
        return post, w, flag


def compile_network(axon_syn: Dict[int, List[Tuple[int, int]]],
                    neuron_syn: Dict[int, List[Tuple[int, int]]],
                    neuron_model_ids: Dict[int, int],
                    outputs: Sequence[int],
                    n_neurons: int,
                    dense_pack: bool = True) -> HBMImage:
    """Build the HBM image.

    axon_syn / neuron_syn: item id -> [(post_neuron, weight), ...]
    neuron_model_ids: neuron id -> model group id (pointers grouped by model)
    dense_pack: start each item's search at the current frontier (the
    compiler's density optimization); False = segment-aligned placement
    (each item starts on a fresh segment — the naive baseline the paper's
    compiler improves on).
    """
    out_set = set(outputs)
    mapper = HBMMapper(n_neurons)
    img_axon_ptr: Dict[int, Pointer] = {}
    img_neuron_ptr: Dict[int, Pointer] = {}
    frontier = 0

    def mk(syns, is_out_src=False):
        return [Synapse(post=p, weight=w,
                        output_flag=(p in out_set)) for p, w in syns]

    def advance():
        # items own disjoint row ranges (phase-2 reads a pointer's rows in
        # full); dense packing starts the next item on the very next row,
        # the naive baseline pads to a segment boundary.
        f = len(mapper.rows)
        if not dense_pack:
            f += (-f) % ROWS_PER_SEGMENT
        return f

    # Fig. 7: axons first
    for aid in sorted(axon_syn):
        ptr = mapper.place_item(mk(axon_syn[aid]), frontier)
        img_axon_ptr[aid] = ptr
        frontier = advance()
    # neurons grouped by model (§A.3 step 1)
    groups: Dict[int, List[int]] = {}
    for nid, mid in neuron_model_ids.items():
        groups.setdefault(mid, []).append(nid)
    for mid in sorted(groups):
        for nid in sorted(groups[mid]):
            syns = mk(neuron_syn.get(nid, []))
            if not syns:
                # A.3: a zero-fanout neuron still gets a full segment of 16
                # zero-weight synapses; if it is an output neuron the filler
                # records carry its output flag.
                syns = [Synapse(post=s, weight=0,
                                output_flag=(nid in out_set))
                        for s in range(SLOTS)]
            ptr = mapper.place_item(syns, frontier)
            img_neuron_ptr[nid] = ptr
            frontier = advance()
    post, w, flag = mapper.finalize()
    return HBMImage(post, w, flag, img_axon_ptr, img_neuron_ptr,
                    {m: sorted(g) for m, g in groups.items()})
