"""ANN -> HiAER-Spike conversion pipeline — §6 + Appendix A.2.

The paper trains MLP / LeNet-5 / spiking-CNN models in PyTorch/SpikingJelly
with quantization-aware training (binarized sigmoidal activations, int16
weights) and converts them to axon/neuron adjacency structures. This module
implements the same pipeline natively in JAX:

  1. `QATModel` — small MLP/CNN trainer with binary activations
     (straight-through estimator, z > 0 spike rule) — the QAT stage;
  2. `quantize` — int16 weight quantization with a power-of-two scale,
     biases folded into thresholds (A.2 bias method 1: θ_i = -b_i);
  3. `to_network` — adjacency construction: one axon per input pixel
     (row-major), conv layers mapped by the A.2 sliding-window technique,
     FC layers fully connected, output neurons listed last;
  4. exactness check — the quantized JAX forward and the CRI_network
     (simulator or HBM engine) produce identical predictions, reproducing
     Table 2's "Software Acc == HiAER Acc" column.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import ANN_neuron, CRI_network

W_BITS = 16
W_MAX = 2 ** (W_BITS - 1) - 1


# ------------------------------------------------------------------ QAT nets
@jax.custom_vjp
def binary_act(z):
    return (z > 0).astype(z.dtype)


def _ba_fwd(z):
    return binary_act(z), z


def _ba_bwd(z, g):
    # straight-through with sigmoid surrogate slope (binarized sigmoid QAT)
    s = jax.nn.sigmoid(4.0 * z)
    return (g * 4.0 * s * (1 - s),)


binary_act.defvjp(_ba_fwd, _ba_bwd)


@dataclass
class LayerSpec:
    kind: str                   # 'dense' | 'conv'
    out_features: int = 0       # dense
    channels: int = 0           # conv
    kernel: int = 5
    stride: int = 2


@dataclass
class QATModel:
    """MLP / small CNN with binary activations; last layer linear (logits =
    membrane potentials of output neurons)."""
    input_shape: Tuple[int, ...]          # (C, H, W) or (D,)
    layers: List[LayerSpec] = field(default_factory=list)
    n_classes: int = 10

    def init(self, key):
        params = []
        shape = self.input_shape
        for spec in self.layers:
            key, k = jax.random.split(key)
            if spec.kind == "conv":
                C = shape[0]
                w = jax.random.normal(k, (spec.channels, C, spec.kernel,
                                          spec.kernel)) * (1.0 / np.sqrt(
                                              C * spec.kernel ** 2))
                b = jnp.zeros((spec.channels,))
                H = (shape[1] - spec.kernel) // spec.stride + 1
                W = (shape[2] - spec.kernel) // spec.stride + 1
                shape = (spec.channels, H, W)
            else:
                D = int(np.prod(shape))
                w = jax.random.normal(k, (D, spec.out_features)) \
                    * (1.0 / np.sqrt(D))
                b = jnp.zeros((spec.out_features,))
                shape = (spec.out_features,)
            params.append({"w": w, "b": b})
        key, k = jax.random.split(key)
        D = int(np.prod(shape))
        params.append({"w": jax.random.normal(k, (D, self.n_classes))
                       * (1.0 / np.sqrt(D)),
                       "b": jnp.zeros((self.n_classes,))})
        return params

    def apply(self, params, x, quantized=False):
        """x: (B, *input_shape) float (0/1). Returns logits (B, n_classes)."""
        h = x
        for spec, p in zip(self.layers, params[:-1]):
            if spec.kind == "conv":
                z = jax.lax.conv_general_dilated(
                    h, p["w"], (spec.stride, spec.stride), "VALID",
                    dimension_numbers=("NCHW", "OIHW", "NCHW"))
                z = z + p["b"][None, :, None, None]
            else:
                h = h.reshape(h.shape[0], -1)
                z = h @ p["w"] + p["b"]
            h = binary_act(z) if not quantized else (z > 0).astype(z.dtype)
        h = h.reshape(h.shape[0], -1)
        p = params[-1]
        return h @ p["w"] + p["b"]


def train_qat(model: QATModel, X, y, *, epochs=10, lr=1e-3, batch=64,
              seed=0, verbose=False):
    """Adam training with binary activations (QAT). X: (n, *shape) float."""
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, xb, yb):
        logits = model.apply(p, xb)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    @jax.jit
    def step(p, m, v, t, xb, yb):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        bc1 = 1 - 0.9 ** t
        bc2 = 1 - 0.999 ** t
        p = jax.tree.map(
            lambda pp, mm, vv: pp - lr * (mm / bc1)
            / (jnp.sqrt(vv / bc2) + 1e-8), p, m, v)
        return p, m, v, l

    n = X.shape[0]
    rng = np.random.default_rng(seed)
    t = 0
    for ep in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            t += 1
            params, m, v, l = step(params, m, v, jnp.float32(t),
                                   jnp.asarray(X[idx]), jnp.asarray(y[idx]))
        if verbose:
            print(f"epoch {ep}: loss {float(l):.4f}")
    return params


# ------------------------------------------------------------- quantization
def quantize(params, w_scale_bits: Optional[int] = None):
    """int16 weights with a shared power-of-two scale; biases folded into
    thresholds downstream. Returns (int_params, scale_bits)."""
    wmax = max(float(jnp.max(jnp.abs(p["w"]))) for p in params)
    bmax = max(float(jnp.max(jnp.abs(p["b"]))) for p in params)
    amax = max(wmax, bmax, 1e-9)
    if w_scale_bits is None:
        w_scale_bits = int(np.floor(np.log2(W_MAX / amax)))
        w_scale_bits = min(w_scale_bits, 14)
    s = 2 ** w_scale_bits
    out = []
    for p in params:
        out.append({
            "w": np.clip(np.round(np.asarray(p["w"], np.float64) * s),
                         -W_MAX, W_MAX).astype(np.int32),
            "b": np.clip(np.round(np.asarray(p["b"], np.float64) * s),
                         -W_MAX, W_MAX).astype(np.int32),
        })
    return out, w_scale_bits


def apply_quantized(model: QATModel, qparams, X) -> np.ndarray:
    """Integer forward (reference for the converted network): returns final
    membrane potentials (B, n_classes) int."""
    h = np.asarray(X).reshape(X.shape[0], *model.input_shape).astype(np.int64)
    shape = model.input_shape
    for spec, p in zip(model.layers, qparams[:-1]):
        if spec.kind == "conv":
            B, C, H, W = h.shape
            K, st = spec.kernel, spec.stride
            Ho = (H - K) // st + 1
            Wo = (W - K) // st + 1
            z = np.zeros((B, spec.channels, Ho, Wo), np.int64)
            for dy in range(K):
                for dx in range(K):
                    patch = h[:, :, dy:dy + st * Ho:st, dx:dx + st * Wo:st]
                    z += np.einsum("bchw,oc->bohw", patch,
                                   p["w"][:, :, dy, dx])
            z += p["b"][None, :, None, None]
            h = (z > 0).astype(np.int64)
        else:
            h = h.reshape(h.shape[0], -1)
            z = h @ p["w"] + p["b"]
            h = (z > 0).astype(np.int64)
    h = h.reshape(h.shape[0], -1)
    return h @ qparams[-1]["w"] + qparams[-1]["b"]


# --------------------------------------------------------------- conversion
def build_conversion_spec(model: QATModel, qparams, hidden_model,
                          output_model):
    """A.2 adjacency construction as a columnar `NetworkSpec` (the
    staged front end): sliding conv windows and dense fan-ins become
    broadcast index arrays + one bulk `connect` per layer — no
    per-synapse Python. Returns (spec, out_keys).

    Axons: one per input element, row-major keys "x{i}"; plus one bias
    axon per layer ("bias_l{i}", A.2 bias method 2) carrying that
    layer's folded biases. `hidden_model`/`output_model` parameterize
    the neuron models so the ANN (convert) and spiking-IF (spiking)
    pipelines share the construction."""
    from repro.core.spec import NetworkSpec

    spec = NetworkSpec()
    n_inputs = int(np.prod(model.input_shape))
    in_ids = spec.add_axons(n_inputs,
                            keys=[f"x{i}" for i in range(n_inputs)])
    depth = len(model.layers) + 1
    bias_ids = spec.add_axons(depth,
                              keys=[f"bias_l{i}" for i in range(depth)])

    prev_ids = in_ids.reshape(model.input_shape)
    pre_parts: List[np.ndarray] = []
    post_parts: List[np.ndarray] = []
    w_parts: List[np.ndarray] = []

    def emit(pre, post, w):
        """Queue nonzero synapses (legacy `add_syn` skips w == 0)."""
        pre = np.asarray(pre, np.int64).reshape(-1)
        post = np.asarray(post, np.int64).reshape(-1)
        w = np.asarray(w, np.int64).reshape(-1)
        nz = w != 0
        pre_parts.append(pre[nz])
        post_parts.append(post[nz])
        w_parts.append(w[nz])

    layer_idx = 0
    for lspec, p in zip(model.layers, qparams[:-1]):
        if lspec.kind == "conv":
            C, H, W = prev_ids.shape
            K, st = lspec.kernel, lspec.stride
            O = lspec.channels
            Ho = (H - K) // st + 1
            Wo = (W - K) // st + 1
            keys = [f"l{layer_idx}_f{o}_{yy}_{xx}"
                    for o in range(O) for yy in range(Ho)
                    for xx in range(Wo)]
            new_ids = spec.add_neurons(O * Ho * Wo, hidden_model,
                                       keys=keys).reshape(O, Ho, Wo)
            # bias axon fan-out: one synapse per map position (b != 0)
            emit(np.broadcast_to(bias_ids[layer_idx], (O, Ho, Wo)),
                 new_ids,
                 np.broadcast_to(np.asarray(p["b"], np.int64)
                                 [:, None, None], (O, Ho, Wo)))
            # sliding window (A.2) as one gather: window (c, dy, dx) of
            # output position (yy, xx) reads prev[(yy*st+dy, xx*st+dx)]
            wy = (np.arange(Ho) * st)[:, None] + np.arange(K)[None, :]
            wx = (np.arange(Wo) * st)[:, None] + np.arange(K)[None, :]
            # pre_win: (C, Ho, K, Wo, K) -> (Ho, Wo, C, K, K)
            pre_win = prev_ids[:, wy][:, :, :, wx] \
                .transpose(1, 3, 0, 2, 4)
            pre_full = np.broadcast_to(pre_win[None],
                                       (O,) + pre_win.shape)
            post_full = np.broadcast_to(
                new_ids[:, :, :, None, None, None],
                (O, Ho, Wo, C, K, K))
            w_full = np.broadcast_to(
                np.asarray(p["w"], np.int64)[:, None, None, :, :, :],
                (O, Ho, Wo, C, K, K))
            emit(pre_full, post_full, w_full)
            prev_ids = new_ids
        else:
            flat = prev_ids.reshape(-1)
            F = lspec.out_features
            keys = [f"l{layer_idx}_u{j}" for j in range(F)]
            new_ids = spec.add_neurons(F, hidden_model, keys=keys)
            emit(np.broadcast_to(bias_ids[layer_idx], (F,)), new_ids,
                 np.asarray(p["b"], np.int64))
            emit(np.broadcast_to(flat[:, None], (flat.size, F)),
                 np.broadcast_to(new_ids[None, :], (flat.size, F)),
                 np.asarray(p["w"], np.int64))
            prev_ids = new_ids
        layer_idx += 1

    # output layer
    p = qparams[-1]
    flat = prev_ids.reshape(-1)
    out_keys = [f"out{j}" for j in range(model.n_classes)]
    out_ids = spec.add_neurons(model.n_classes, output_model,
                               keys=out_keys)
    emit(np.broadcast_to(bias_ids[-1], (model.n_classes,)), out_ids,
         np.asarray(p["b"], np.int64))
    emit(np.broadcast_to(flat[:, None], (flat.size, model.n_classes)),
         np.broadcast_to(out_ids[None, :], (flat.size, model.n_classes)),
         np.asarray(p["w"], np.int64))
    if pre_parts:
        spec.connect(np.concatenate(pre_parts),
                     np.concatenate(post_parts),
                     np.concatenate(w_parts))
    spec.set_outputs(out_ids)
    return spec, out_keys


def to_network(model: QATModel, qparams, backend="engine",
               seed=0) -> Tuple[CRI_network, List[str]]:
    """Build the CRI_network per A.2 through the staged columnar path
    (`build_conversion_spec` -> `CRI_network.from_spec`). Returns
    (network, output_keys).

    Each bias axon is fired at the timestep its layer integrates
    (infer_image), so ANN neurons — which are memoryless and would
    otherwise re-fire every step under the threshold-shift method when
    b_i > 0 — stay bit-exact with the integer reference forward. The
    output layer gets a huge threshold so outputs never fire/reset:
    their membrane potential after the final step IS the integer
    logit."""
    spec, out_keys = build_conversion_spec(
        model, qparams, hidden_model=ANN_neuron(threshold=0),
        output_model=ANN_neuron(threshold=2 ** 30))
    net = CRI_network.from_spec(spec, backend=backend, seed=seed)
    return net, out_keys


def infer_image(net: CRI_network, img, model: QATModel,
                out_keys: Sequence[str]) -> Tuple[int, List[int]]:
    """Run one image: activate its pixel axons for one timestep, then let
    the signal propagate layer-by-layer, firing each layer's bias axon at
    its integration step; predict argmax output membrane potential
    (§6 MLP/LeNet protocol)."""
    net.reset()
    flat = np.asarray(img).reshape(-1)
    depth = len(model.layers) + 1
    net.step([f"x{i}" for i in np.nonzero(flat)[0]] + ["bias_l0"])
    for t in range(1, depth):
        net.step([f"bias_l{t}"])
    pots = net.read_membrane(*out_keys)
    return int(np.argmax(pots)), pots
