"""Event-driven execution engine over the HBM routing table — §4 two-phase
spike routing, with exact HBM access counting for the energy/latency model.

Per timestep:
  phase 1 — for every neuron that fired and every externally driven axon,
            read its pointer (base row + row count) into the event queue;
  phase 2 — for each enqueued pointer, fetch the spanned synapse rows from
            the (rows × 16-slot) table and apply the weights to the
            postsynaptic membrane potentials (16 parallel lanes = the slot
            alignment constraint's purpose).

Two interchangeable execution paths, bit-exact against each other:

  * vectorized (default) — the pointer dicts are lowered once to dense
    arrays (`HBMImage.flatten`) and both phases run as gathers +
    `segment_sum` inside a single jit-compiled step (`kernels/route.py`);
    `run(schedule)` folds T timesteps into one `lax.scan` dispatch and
    `run_batch(schedules)` vmaps that scan over B independent samples
    (per-sample PRNG stream = fold_in(key, sample), fresh V = 0).
  * reference — the seed per-pointer host loop, kept as the oracle the
    vectorized path is property-tested against (and as the "before" side
    of benchmarks/sim_throughput.py).

Neuron state dynamics are shared with the dense simulator (core.neuron), so
engine-vs-simulator equivalence is bit-exact given the same PRNG stream —
that parity is the reproduction of the paper's claim that hs_api networks
run identically on the local simulator and the accelerator.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuron as nrn
from repro.core import schedule as sched
from repro.core.costmodel import AccessCounter
from repro.core.hbm import HBMImage
from repro.kernels import route as route_k

# canonical definition moved to core.schedule; kept under the old name for
# existing importers (core.simulator, downstream code)
_check_count_dtype = sched.check_count_dtype


class EventEngine:
    def __init__(self, image: HBMImage, theta, nu, lam, is_lif,
                 n_neurons: int, outputs: Sequence[int], seed: int = 0,
                 vectorized: bool = True, use_pallas: bool = False,
                 flat=None):
        self.image = image
        self.theta = jnp.asarray(theta, jnp.int32)
        self.nu = jnp.asarray(nu, jnp.int32)
        self.lam = jnp.asarray(lam, jnp.int32)
        self.is_lif = jnp.asarray(is_lif, bool)
        self.n = n_neurons
        self.outputs = list(outputs)
        self.V = jnp.zeros((n_neurons,), jnp.int32)
        self.key = jax.random.PRNGKey(seed)
        self.counter = AccessCounter()
        # `vectorized` and `use_pallas` are trace-time constants: they are
        # baked into the jit caches on the first step/run call, so set
        # them at construction (or before the first call) — toggling
        # afterwards is not supported for `use_pallas` (the cached
        # executable keeps its original path).
        self.vectorized = vectorized
        self.use_pallas = use_pallas
        self._spikes = np.zeros((n_neurons,), bool)
        # numpy views of the table for the host-side reference routing
        self._post = np.asarray(image.syn_post)
        self._w = np.asarray(image.syn_weight, np.int32)
        # dense pointer tables (cheap, O(rows), or handed in pre-lowered
        # by the staged compiler); the fan-in transpose is built lazily
        # on the first vectorized dispatch so reference-only engines
        # never pay for it.
        self.flat = flat if flat is not None else image.flatten()
        self.n_axon_slots = int(self.flat.axon_rows.shape[0])
        self._tables = None
        self._use_fanin = True
        if vectorized:
            self._build_tables()
        self._jit_step = jax.jit(self._step_impl)
        self._jit_run = jax.jit(self._run_impl)
        self._jit_run_batch = jax.jit(self._run_batch_impl)
        self._jit_run_lanes = jax.jit(self._run_lanes_impl)

    def _build_tables(self):
        # hub topologies fall back from the padded fan-in transpose to the
        # post-sorted CSR accumulate (linear in synapses, scatter-free)
        self._use_fanin = route_k.fanin_is_economical(self.flat, self.n)
        self._tables = route_k.RouteTables.from_flat(
            self.flat, self.n, build_fanin=self._use_fanin)

    @property
    def _acc_mode(self) -> str:
        return "fanin" if self._use_fanin else "csr"

    @property
    def tables(self) -> route_k.RouteTables:
        if self._tables is None:
            self._build_tables()
        return self._tables

    # ------------------------------------------------------------- state
    def reset(self):
        self.V = jnp.zeros((self.n,), jnp.int32)
        self._spikes = np.zeros((self.n,), bool)

    def update_weights(self, syn_weight) -> None:
        """Refresh both routing paths after an in-place `syn_weight` edit
        (CRI_network.write_synapse). The routing tables are traced
        arguments of the jitted paths, so this is a pure data swap — no
        retrace/recompile."""
        self._w = np.asarray(syn_weight, np.int32)
        self.flat.syn_weight = np.ascontiguousarray(self._w)
        if self._tables is not None:
            self._tables = self._tables.with_weights(self._w)

    # -------------------------------------------------- vectorized core
    # `tables` is passed as a (pytree) argument rather than captured, so
    # weight edits swap arrays under the same compiled executable.
    def _step_impl(self, V, key, axon_counts, tables):
        """One timestep as pure jax: returns (V', key', spikes, ptr, rows)."""
        key, sub = jax.random.split(key)
        if self.use_pallas:
            u = nrn.noise_draw(sub, self.n)
            V_next, spikes, pr, rr = route_k.fused_route_lif_step(
                tables, axon_counts, V, u, self.theta, self.nu,
                self.lam, self.is_lif)
        else:
            V_mid, spikes = nrn.fire_phase(V, self.theta, self.nu, self.lam,
                                           self.is_lif, sub)
            syn, pr, rr = route_k.route(tables, axon_counts, spikes,
                                        self.n, mode=self._acc_mode)
            V_next = nrn.integrate_phase(V_mid, syn)
        return V_next, key, spikes, pr, rr

    def _run_impl(self, V, key, counts, tables):
        """T timesteps under one lax.scan. counts: (T, A) int32. The
        access tallies come back per step (int32 is safe within a step);
        callers sum them host-side in exact Python ints so long runs
        cannot wrap the counter."""
        def body(carry, c):
            V, key = carry
            V, key, spikes, pr, rr = self._step_impl(V, key, c, tables)
            return (V, key), (spikes, pr, rr)

        (V, key), (spikes, prs, rrs) = jax.lax.scan(body, (V, key), counts)
        return V, key, spikes, prs, rrs

    def _run_batch_impl(self, key, counts, tables):
        """B independent samples per dispatch. counts: (B, T, A) int32.
        Sample b runs from V = 0 under PRNG stream fold_in(key, b)."""
        B = counts.shape[0]
        keys = jax.vmap(lambda b: jax.random.fold_in(key, b))(jnp.arange(B))
        V0 = jnp.zeros((B, self.n), jnp.int32)
        _, _, spikes, prs, rrs = jax.vmap(
            self._run_impl, in_axes=(0, 0, 0, None))(V0, keys, counts,
                                                     tables)
        return spikes, prs, rrs

    def _run_lanes_impl(self, V0, keys, counts, tables):
        """The serving-tier stateful batch: B lanes, each carrying ITS
        OWN membrane state and PRNG key through the dispatch (unlike
        `_run_batch_impl`, which derives both). Lane b is bit-identical
        to running its (V0[b], keys[b], counts[b]) alone — every
        per-lane op is elementwise in the lane axis — which is what
        makes micro-batched serving results independent of how requests
        were batched together."""
        return jax.vmap(self._run_impl, in_axes=(0, 0, 0, None))(
            V0, keys, counts, tables)

    def run_lanes(self, V0, keys, counts):
        """Stateful batched run for the serving tier. V0: (B, n) int32
        membranes, keys: (B,) PRNG keys, counts: (B, T, A) int32.
        Returns (V_final, keys_final, spikes (B, T, n) bool); the
        engine's own sequential state (V, key) is untouched."""
        B, T = counts.shape[0], counts.shape[1]
        self.counter.timesteps += B * T
        V, keys, spikes, prs, rrs = self._jit_run_lanes(
            jnp.asarray(V0, jnp.int32), keys, jnp.asarray(counts),
            self.tables)
        self.counter.tally(prs, rrs)
        return V, keys, np.asarray(spikes, bool)

    def lanes_membrane(self, V_lanes) -> np.ndarray:
        """Per-lane membrane state -> (B, n) in global neuron-id order
        (identity on the monolithic engine)."""
        return np.asarray(V_lanes)

    def lane_state_zeros(self, B: int) -> np.ndarray:
        """Fresh per-lane membrane state, (B,) + the backend's state
        shape — the V = 0 a `run_batch` sample starts from."""
        return np.zeros((B, self.n), np.int32)

    # -------------------------------------------------- schedule encoding
    # the shared core.schedule helpers at the engine's axon-table width
    def encode_axons(self, axon_inputs: Iterable[int]) -> np.ndarray:
        """Axon id sequence -> (A,) occurrence counts. Unknown ids are
        dropped, matching the reference path's `dict.get` skip."""
        return sched.encode_ids(axon_inputs, self.n_axon_slots)

    def _encode_schedule(self, schedule) -> np.ndarray:
        return sched.encode_schedule(schedule, self.n_axon_slots)

    # ------------------------------------------------------ reference path
    def _route_reference(self, fired_axons: Iterable[int],
                         fired_neurons: np.ndarray) -> np.ndarray:
        """Seed two-phase routing: host loop over pointers. Returns int32
        syn_in (n,). Counts accesses."""
        syn = np.zeros((self.n,), np.int64)
        queue = []                                   # phase 1: pointer fetch
        for a in fired_axons:
            ptr = self.image.axon_ptr.get(a)
            if ptr is not None:
                queue.append(ptr)
        for nid in np.nonzero(fired_neurons)[0]:
            ptr = self.image.neuron_ptr.get(int(nid))
            if ptr is not None:
                queue.append(ptr)
        self.counter.pointer_reads += len(queue)
        for ptr in queue:                            # phase 2: synapse rows
            rows = slice(ptr.base_row, ptr.base_row + ptr.n_rows)
            self.counter.row_reads += ptr.n_rows
            post = self._post[rows].ravel()
            w = self._w[rows].ravel()
            valid = post >= 0
            # A.3 filler synapses may carry out-of-range post ids; they are
            # zero-weight by construction, so clip is a no-op numerically.
            np.add.at(syn, np.clip(post[valid], 0, self.n - 1), w[valid])
        return syn.astype(np.int32)

    def _step_reference(self, axon_inputs: Sequence[int]) -> np.ndarray:
        self.key, sub = jax.random.split(self.key)
        V_mid, spikes = nrn.fire_phase(self.V, self.theta, self.nu, self.lam,
                                       self.is_lif, sub)
        spikes_np = np.asarray(spikes)
        syn = self._route_reference(axon_inputs, spikes_np)
        self.V = nrn.integrate_phase(V_mid, jnp.asarray(syn))
        return spikes_np

    # ----------------------------------------------------------- stepping
    def step(self, axon_inputs: Sequence[int]) -> np.ndarray:
        """One timestep; returns bool (n,) spikes fired this step."""
        self.counter.timesteps += 1
        if not self.vectorized:
            self._spikes = self._step_reference(axon_inputs)
            return self._spikes
        counts = jnp.asarray(self.encode_axons(axon_inputs))
        self.V, self.key, spikes, pr, rr = self._jit_step(
            self.V, self.key, counts, self.tables)
        self.counter.tally(pr, rr)
        self._spikes = np.asarray(spikes, bool)
        return self._spikes

    def run(self, schedule) -> np.ndarray:
        """T timesteps in one dispatch. schedule: (T, A) int count array or
        a length-T sequence of axon-id sequences. Returns (T, n) bool
        spikes; engine state (V, key, counter) advances exactly as T
        `step` calls would."""
        counts = self._encode_schedule(schedule)
        T = counts.shape[0]
        self.counter.timesteps += T
        if not self.vectorized:
            out = np.zeros((T, self.n), bool)
            for t in range(T):
                ids = np.repeat(np.arange(self.n_axon_slots), counts[t])
                out[t] = self._step_reference(ids)
            self._spikes = out[-1] if T else self._spikes
            return out
        self.V, self.key, spikes, prs, rrs = self._jit_run(
            self.V, self.key, jnp.asarray(counts), self.tables)
        self.counter.tally(prs, rrs)
        spikes = np.asarray(spikes, bool)
        if T:
            self._spikes = spikes[-1]
        return spikes

    def run_batch(self, schedules) -> np.ndarray:
        """B samples × T timesteps per dispatch. schedules: (B, T, A) int
        count array or nested per-sample schedules. Every sample starts
        from V = 0 with PRNG stream fold_in(key, sample); the engine's own
        sequential state (V, last spikes) is left untouched, but its key
        is advanced once so a later batch draws fresh streams — noisy
        sequential stepping after a run_batch continues from a different
        stream than it would otherwise. Returns (B, T, n) bool spikes;
        the access counter accumulates the whole batch."""
        if len(schedules) == 0:
            return np.zeros((0, 0, self.n), bool)
        counts = sched.encode_batch(schedules, self.n_axon_slots)
        B, T = counts.shape[0], counts.shape[1]
        self.counter.timesteps += B * T
        if not self.vectorized:
            saveV, saveS, saveK = self.V, self._spikes, self.key
            out = np.zeros((B, T, self.n), bool)
            for b in range(B):
                self.V = jnp.zeros((self.n,), jnp.int32)
                self.key = jax.random.fold_in(saveK, b)
                for t in range(T):
                    ids = np.repeat(np.arange(self.n_axon_slots),
                                    counts[b, t])
                    out[b, t] = self._step_reference(ids)
            self.V, self._spikes = saveV, saveS
            self.key, _ = jax.random.split(saveK)
            return out
        spikes, prs, rrs = self._jit_run_batch(self.key, jnp.asarray(counts),
                                               self.tables)
        self.counter.tally(prs, rrs)
        self.key, _ = jax.random.split(self.key)
        return np.asarray(spikes, bool)

    def read_membrane(self, ids: Sequence[int]) -> List[int]:
        V = np.asarray(self.V)
        return [int(V[i]) for i in ids]
