"""Event-driven execution engine over the HBM routing table — §4 two-phase
spike routing, with exact HBM access counting for the energy/latency model.

Per timestep:
  phase 1 — for every neuron that fired and every externally driven axon,
            read its pointer (base row + row count) into the event queue;
  phase 2 — for each enqueued pointer, fetch the spanned synapse rows from
            the (rows × 16-slot) table and apply the weights to the
            postsynaptic membrane potentials (16 parallel lanes = the slot
            alignment constraint's purpose).

Neuron state dynamics are shared with the dense simulator (core.neuron), so
engine-vs-simulator equivalence is bit-exact given the same PRNG stream —
that parity is the reproduction of the paper's claim that hs_api networks
run identically on the local simulator and the accelerator.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuron as nrn
from repro.core.costmodel import AccessCounter
from repro.core.hbm import HBMImage


class EventEngine:
    def __init__(self, image: HBMImage, theta, nu, lam, is_lif,
                 n_neurons: int, outputs: Sequence[int], seed: int = 0):
        self.image = image
        self.theta = jnp.asarray(theta, jnp.int32)
        self.nu = jnp.asarray(nu, jnp.int32)
        self.lam = jnp.asarray(lam, jnp.int32)
        self.is_lif = jnp.asarray(is_lif, bool)
        self.n = n_neurons
        self.outputs = list(outputs)
        self.V = jnp.zeros((n_neurons,), jnp.int32)
        self.key = jax.random.PRNGKey(seed)
        self.counter = AccessCounter()
        self._spikes = np.zeros((n_neurons,), bool)
        # numpy views of the table for host-side routing
        self._post = np.asarray(image.syn_post)
        self._w = np.asarray(image.syn_weight, np.int32)

    def reset(self):
        self.V = jnp.zeros((self.n,), jnp.int32)
        self._spikes = np.zeros((self.n,), bool)

    def _route(self, fired_axons: Iterable[int],
               fired_neurons: np.ndarray) -> np.ndarray:
        """Two-phase routing; returns int32 syn_in (n,). Counts accesses."""
        syn = np.zeros((self.n,), np.int64)
        queue = []                                   # phase 1: pointer fetch
        for a in fired_axons:
            ptr = self.image.axon_ptr.get(a)
            if ptr is not None:
                queue.append(ptr)
        for nid in np.nonzero(fired_neurons)[0]:
            ptr = self.image.neuron_ptr.get(int(nid))
            if ptr is not None:
                queue.append(ptr)
        self.counter.pointer_reads += len(queue)
        for ptr in queue:                            # phase 2: synapse rows
            rows = slice(ptr.base_row, ptr.base_row + ptr.n_rows)
            self.counter.row_reads += ptr.n_rows
            post = self._post[rows].ravel()
            w = self._w[rows].ravel()
            valid = post >= 0
            # A.3 filler synapses may carry out-of-range post ids; they are
            # zero-weight by construction, so clip is a no-op numerically.
            np.add.at(syn, np.clip(post[valid], 0, self.n - 1), w[valid])
        return syn.astype(np.int32)

    def step(self, axon_inputs: Sequence[int]) -> np.ndarray:
        """One timestep; returns bool (n,) spikes fired this step."""
        self.counter.timesteps += 1
        self.key, sub = jax.random.split(self.key)
        V_mid, spikes = nrn.fire_phase(self.V, self.theta, self.nu, self.lam,
                                       self.is_lif, sub)
        spikes_np = np.asarray(spikes)
        syn = self._route(axon_inputs, spikes_np)
        self.V = nrn.integrate_phase(V_mid, jnp.asarray(syn))
        self._spikes = spikes_np
        return spikes_np

    def read_membrane(self, ids: Sequence[int]) -> List[int]:
        V = np.asarray(self.V)
        return [int(V[i]) for i in ids]
