"""Stage 3 of the staged API: deploy a compiled artifact and run it.

    dep = deploy(compiled, seed=0)
    spikes = dep.step([0, 3])            # axon ids
    out = dep.run(schedule); batch = dep.run_batch(schedules)
    w = dep.read_synapses(pre, post)     # arrays, one gather
    dep.write_synapses(pre, post, w + 1) # ONE delta upload per batch

    dep.alloc_lanes(8)                   # resident serving lanes
    spk, V = dep.run_lanes([0, 3, -1], windows, seeds=[0, 0, 7])
    dep.reset(lanes=[3])                 # one session, others untouched

One `Deployment` class fronts all four backends (dense simulator, HBM
event engine, hierarchical multi-core hiaer, and the device-mesh
`mesh` tier running each core's shard on its own jax device) with the
id-space runtime surface; `CRI_network` (core.api) remains the
key-space facade on top.

Synapse access replaces the legacy per-call O(fan-out) list scans with
a precomputed (pre, post) -> column index (one lexsort at first use,
then `searchsorted` lookups). `pre` uses the spec's encoded source ids
(negative = axon -(a+1), non-negative = neuron id), so an axon and a
neuron with the same raw index never collide. Duplicate (pre, post)
synapses resolve to the FIRST record — the legacy scan order.

`write_synapses` applies a whole batch as ONE backend update: edit the
packed table in place at the precomputed flat positions, then a single
`update_weights` swap (engine) / shard-local `update_entry_weights`
touching only the changed cores' weight storage (hiaer/mesh) / one
scatter-add pair (simulator) — instead of one full upload per synapse.
That is what makes host-side plasticity loops (learning.STDP) practical
on every backend; tests assert a 1000-synapse batch triggers exactly
one upload, and that a batch confined to one core rebuilds exactly one
shard.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.validate import structural_error
from repro.core import schedule as sched
from repro.core.compile import CompiledNetwork
from repro.core.engine import EventEngine
from repro.core.hbm import W_MAX, W_MIN
from repro.core.hiaer import HiAERNetwork
from repro.core.mesh_runtime import MeshNetwork
from repro.core.simulator import DenseSimulator
from repro.core.spec import decode_pre

__all__ = ["Deployment", "deploy"]


class MissingSynapseError(KeyError):
    """KeyError subclass carrying the index of the first missing pair,
    so key-space facades can re-raise with user keys."""

    def __init__(self, message: str, index: int):
        super().__init__(message)
        self.index = index


class Deployment:
    """Uniform runtime handle over one compiled network."""

    def __init__(self, compiled: CompiledNetwork, *, seed: int = 0,
                 vectorized: bool = True, use_pallas: bool = False,
                 n_devices: Optional[int] = None, packed: bool = True):
        self.compiled = compiled
        c = compiled
        out_ids = [int(i) for i in c.outputs]
        if c.target == "simulator":
            self.impl = DenseSimulator(c.axonW, c.neuronW, c.theta, c.nu,
                                       c.lam, c.is_lif, seed=seed)
            self.counter = None
        elif c.target == "engine":
            self.impl = EventEngine(c.image, c.theta, c.nu, c.lam,
                                    c.is_lif, c.n_neurons, out_ids,
                                    seed=seed, vectorized=vectorized,
                                    use_pallas=use_pallas, flat=c.flat)
            self.counter = self.impl.counter
        elif c.target == "hiaer":
            self.impl = HiAERNetwork(c.image, c.theta, c.nu, c.lam,
                                     c.is_lif, c.n_neurons, out_ids,
                                     hierarchy=c.hierarchy,
                                     seed=seed, flat=c.flat,
                                     neuron_core=c.neuron_core,
                                     axon_core=c.axon_core,
                                     shards=c.shards,
                                     axon_ndest=c.axon_ndest,
                                     neuron_ndest=c.neuron_ndest,
                                     packed=packed)
            self.counter = self.impl.counter
        elif c.target == "mesh":
            self.impl = MeshNetwork(c.theta, c.nu, c.lam, c.is_lif,
                                    c.n_neurons, out_ids,
                                    hierarchy=c.hierarchy, seed=seed,
                                    flat=c.flat,
                                    neuron_core=c.neuron_core,
                                    axon_core=c.axon_core,
                                    shards=c.shards,
                                    axon_ndest=c.axon_ndest,
                                    neuron_ndest=c.neuron_ndest,
                                    n_devices=n_devices, packed=packed)
            self.counter = self.impl.counter
        else:
            raise ValueError(f"unknown target {c.target!r}")
        self.n_axon_slots = getattr(self.impl, "n_axon_slots",
                                    c.n_axons)
        self.seed = seed
        self.weight_uploads = 0         # batches applied, not synapses
        self._ikeys: Optional[np.ndarray] = None
        self._iorder: Optional[np.ndarray] = None
        # persistent batch-lane state (the serving tier's sessions):
        # allocated on demand by alloc_lanes(); lane l's PRNG stream is
        # fold_in(PRNGKey(seed), l) — identical to run_batch sample l on
        # a fresh deployment, so a new lane's first window is
        # bit-reproducible outside the server
        self._lane_V: Optional[np.ndarray] = None
        self._lane_keys: Optional[np.ndarray] = None
        self._lane_root = jax.random.PRNGKey(seed)
        # stateless (scratch) requests draw from a stream folded at the
        # int32 ceiling — no real lane id can collide with it
        self._scratch_root = jax.random.fold_in(self._lane_root,
                                                2**31 - 1)

    # ------------------------------------------------------------ running
    @property
    def V(self):
        return self.impl.V

    def step(self, axon_ids: Sequence[int] = ()):
        """One timestep from raw axon ids; returns (N,) bool spikes."""
        return self.impl.step(list(axon_ids))

    def run(self, schedule) -> np.ndarray:
        return self.impl.run(self._pad(sched.encode_schedule(
            schedule, self.compiled.n_axons)))

    def run_batch(self, schedules) -> np.ndarray:
        if len(schedules) == 0:
            return np.zeros((0, 0, self.compiled.n_neurons), bool)
        return self.impl.run_batch(self._pad(sched.encode_batch(
            schedules, self.compiled.n_axons)))

    def _pad(self, counts: np.ndarray) -> np.ndarray:
        """Zero-pad the axon axis up to the deployed slot count. A
        schedule WIDER than the axon table raises a structured
        `AnalysisError` (code E_SCHED_WIDTH): the extra columns used to
        pass straight through, where the routing gathers would clip
        their indices and silently mis-route the trailing axons."""
        if counts.shape[-1] > self.n_axon_slots:
            raise structural_error(
                "schedule", "E_SCHED_WIDTH",
                f"schedule drives {counts.shape[-1]} axon slots but "
                f"the deployed network has {self.n_axon_slots}; the "
                f"trailing columns would be silently dropped or "
                f"mis-routed",
                schedule_width=counts.shape[-1],
                axon_slots=self.n_axon_slots)
        return sched.pad_width(counts, self.n_axon_slots)

    def reset(self, lanes: Optional[Sequence[int]] = None):
        """Reset runtime state. `lanes=None` resets everything — the
        backend's sequential state AND every allocated lane (each lane
        back to V = 0 with its construction-seed PRNG stream).
        `lanes=[...]` resets ONLY those batch lanes, leaving the other
        lanes' membranes and streams untouched — the per-client reset
        the serving tier uses so one session's restart never perturbs
        its batch neighbours."""
        if lanes is None:
            self.impl.reset()
            if self._lane_V is not None:
                self._lane_V[:] = 0
                self._lane_keys[:] = self._initial_keys(
                    np.arange(self._lane_V.shape[0]))
            return
        ids = self._check_lane_ids(np.asarray(lanes, np.int64))
        self._lane_V[ids] = 0
        self._lane_keys[ids] = self._initial_keys(ids)

    # ------------------------------------------------------ batch lanes
    @property
    def n_lanes(self) -> int:
        return 0 if self._lane_V is None else self._lane_V.shape[0]

    def _initial_keys(self, lanes) -> np.ndarray:
        """Construction-seed PRNG keys for the given lane ids (a
        writable host copy — lane key storage is mutated in place)."""
        return np.array(jax.vmap(
            lambda i: jax.random.fold_in(self._lane_root, i))(
            jnp.asarray(lanes, jnp.int32)))

    def _check_lane_ids(self, ids: np.ndarray) -> np.ndarray:
        if ids.size and (self._lane_V is None
                         or ids.min() < 0
                         or ids.max() >= self._lane_V.shape[0]):
            raise IndexError(
                f"lane ids {ids.tolist()} outside the "
                f"{self.n_lanes} allocated lanes (alloc_lanes first)")
        return ids

    def alloc_lanes(self, n_lanes: int) -> None:
        """Allocate (or grow to) `n_lanes` persistent batch lanes. A
        lane is a resident session slot: membrane state plus a PRNG
        stream that persist ACROSS `run_lanes` dispatches, so a client
        can stream spike windows through the deployment and observe
        exactly the dynamics of one uninterrupted run. Growing never
        disturbs existing lanes."""
        have = self.n_lanes
        if n_lanes <= have:
            return
        V = self.impl.lane_state_zeros(n_lanes)
        new = self._initial_keys(np.arange(have, n_lanes))
        if have:
            V[:have] = self._lane_V
            new = np.concatenate([self._lane_keys, new])
        self._lane_V, self._lane_keys = V, new

    def run_lanes(self, lane_ids: Sequence[int], schedules,
                  seeds: Optional[Sequence[int]] = None):
        """Stateful micro-batched run — the serving tier's dispatch
        primitive. Each entry b runs `schedules[b]` (all the same T) on
        lane `lane_ids[b]`: a real lane (>= 0) continues from its
        persistent membranes/stream and writes its final state back; a
        SCRATCH entry (-1) runs stateless from V = 0 under the
        deterministic stream fold_in(scratch_root, seeds[b]) and leaves
        no trace. Entry b's results are bit-identical to running it in
        a batch of ONE (the lane axis is elementwise on every backend),
        so micro-batching never leaks state — or noise — between
        clients. Returns (spikes (B, T, n) bool, membranes (B, n) int32
        final per-lane potentials in global neuron order)."""
        if len(schedules) == 0:
            return (np.zeros((0, 0, self.compiled.n_neurons), bool),
                    np.zeros((0, self.compiled.n_neurons), np.int32))
        counts = self._pad(sched.encode_batch(schedules,
                                              self.compiled.n_axons))
        ids = np.asarray(list(lane_ids), np.int64)
        B = counts.shape[0]
        if ids.shape[0] != B:
            raise ValueError(f"{ids.shape[0]} lane ids for {B} "
                             f"schedules")
        live = ids >= 0
        live_ids = self._check_lane_ids(ids[live])
        uniq, cnt = np.unique(live_ids, return_counts=True)
        if uniq.size and cnt.max() > 1:
            raise ValueError(
                f"lane(s) {uniq[cnt > 1].tolist()} appear twice in one "
                f"batch — a session cannot run two windows in one "
                f"dispatch")
        if seeds is None:
            seeds = np.zeros((B,), np.int64)
        seeds = np.asarray(list(seeds), np.int64)
        keys = np.array(jax.vmap(
            lambda s: jax.random.fold_in(self._scratch_root, s))(
            jnp.asarray(seeds, jnp.int32)))
        V0 = self.impl.lane_state_zeros(B)
        if live.any():
            keys[live] = self._lane_keys[live_ids]
            V0[live] = self._lane_V[live_ids]
        Vf, kf, spikes = self.impl.run_lanes(V0, jnp.asarray(keys),
                                             counts)
        Vf = np.asarray(Vf)
        if live.any():
            self._lane_V[live_ids] = Vf[live]
            self._lane_keys[live_ids] = np.asarray(kf)[live]
        return spikes, self.impl.lanes_membrane(Vf)

    def lane_membrane(self, lane: int) -> np.ndarray:
        """Current (n,) membrane potentials of one allocated lane, in
        global neuron-id order."""
        ids = self._check_lane_ids(np.asarray([lane], np.int64))
        return self.impl.lanes_membrane(self._lane_V[ids])[0]

    def lane_snapshot(self, lanes: Sequence[int]):
        """Host copies of the given lanes' state: (V (k, ...), keys
        (k, 2)). Lane state is host numpy on every backend, so this is
        O(k) array copies — cheap enough to take per micro-batch. The
        serving tier's undo log snapshots session lanes before each
        dispatch so a crashed batch can be rolled back and retried
        bit-exactly."""
        ids = self._check_lane_ids(np.asarray(list(lanes), np.int64))
        return self._lane_V[ids].copy(), self._lane_keys[ids].copy()

    def lane_restore(self, lanes: Sequence[int], V: np.ndarray,
                     keys: np.ndarray) -> None:
        """Write `lane_snapshot` state back into the given lanes."""
        ids = self._check_lane_ids(np.asarray(list(lanes), np.int64))
        self._lane_V[ids] = V
        self._lane_keys[ids] = keys

    def lane_state(self) -> Optional[dict]:
        """Full resident-lane state — {"V", "keys"} host arrays, or
        None before `alloc_lanes`. The checkpointable half of a
        deployment's runtime state (weights are the other half)."""
        if self._lane_V is None:
            return None
        return {"V": self._lane_V.copy(),
                "keys": self._lane_keys.copy()}

    def load_lane_state(self, V: np.ndarray, keys: np.ndarray) -> None:
        """Restore `lane_state()` output; lane count and state shape
        must match this deployment's allocation (same compiled
        artifact, same `alloc_lanes`)."""
        if self._lane_V is None or V.shape != self._lane_V.shape \
                or keys.shape != self._lane_keys.shape:
            have = None if self._lane_V is None else self._lane_V.shape
            raise ValueError(
                f"lane state shape {V.shape} does not match the "
                f"allocated lanes {have} — restore onto a deployment "
                f"of the same artifact with the same alloc_lanes")
        self._lane_V[:] = V
        self._lane_keys[:] = keys

    def read_membrane(self, ids: Sequence[int]) -> List[int]:
        V = np.asarray(self.impl.V)
        return [int(V[i]) for i in ids]

    # ----------------------------------------------------- synapse access
    def _index(self):
        """(pre item, post) -> first column, via one lexsort (stable:
        duplicate pairs keep their first record, the legacy scan
        result)."""
        if self._ikeys is None:
            c = self.compiled
            key = (c.syn_item * max(c.n_neurons, 1) + c.syn_post) \
                .astype(np.int64)
            order = np.lexsort((np.arange(key.shape[0]), key))
            self._ikeys = key[order]
            self._iorder = order
        return self._ikeys, self._iorder

    def _lookup(self, pre, post) -> np.ndarray:
        """Column index of each (pre, post) pair; raises
        `MissingSynapseError` (a KeyError) on the first missing pair."""
        c = self.compiled
        pre = np.asarray(pre, np.int64).reshape(-1)
        post = np.asarray(post, np.int64).reshape(-1)
        pre, post = np.broadcast_arrays(pre, post)
        is_axon, raw = decode_pre(pre)
        # validate before the key encoding so an out-of-range axon id
        # can never alias a neuron item (and vice versa)
        ok = np.where(is_axon, raw < c.n_axons, raw < c.n_neurons)
        ok &= (post >= 0) & (post < max(c.n_neurons, 1))
        item = np.where(is_axon, raw, c.item_base + raw)
        ikeys, iorder = self._index()
        q = item * max(c.n_neurons, 1) + post
        if ikeys.size:
            idx = np.minimum(np.searchsorted(ikeys, q),
                             ikeys.shape[0] - 1)
            ok &= ikeys[idx] == q
        else:
            idx = np.zeros_like(q)
            ok &= False
        if not np.all(ok):
            i = int(np.nonzero(~ok)[0][0])
            raise MissingSynapseError(
                f"no synapse {int(pre[i])}->{int(post[i])}", i)
        return iorder[idx]

    def read_synapses(self, pre, post) -> np.ndarray:
        """Batched weight read: current weights of each (pre, post)
        pair, as one gather. pre: encoded source ids (negative = axon)."""
        return self.compiled.syn_weight[self._lookup(pre, post)].copy()

    def write_synapses(self, pre, post, weight) -> None:
        """Batched weight write, applied as ONE backend update. All
        pairs are validated before anything mutates; duplicate pairs in
        one batch resolve last-wins (sequential-write semantics)."""
        c = self.compiled
        cols = self._lookup(pre, post)
        if cols.size == 0:
            return
        w = np.asarray(weight)
        if not (np.issubdtype(w.dtype, np.integer)
                or w.dtype == np.bool_):
            raise TypeError(f"weights must be integers, got {w.dtype}")
        w = np.broadcast_to(np.atleast_1d(w.astype(np.int64)).reshape(-1)
                            if w.ndim <= 1 else w.astype(np.int64),
                            cols.shape)
        # last-wins dedup: first occurrence in the reversed batch
        _, rev_first = np.unique(cols[::-1], return_index=True)
        keep = cols.shape[0] - 1 - rev_first
        # records are int16 (clipped like compile_spec), so the read
        # column, the packed image, and the dense matrices agree even
        # for out-of-range requests
        self._write_cols(cols[keep], np.clip(w[keep], W_MIN, W_MAX))
        self.weight_uploads += 1

    def _write_cols(self, cols_u: np.ndarray, w_u: np.ndarray) -> None:
        """Apply already-validated, deduped column writes as one
        backend update (the shared tail of `write_synapses` and
        `load_weights`)."""
        c = self.compiled
        old = c.syn_weight[cols_u].copy()
        c.syn_weight[cols_u] = w_u.astype(np.int32)
        if c.target == "simulator":
            delta = c.syn_weight[cols_u] - old          # int32 wrap
            item = c.syn_item[cols_u]
            posts = c.syn_post[cols_u]
            ax = item < c.item_base
            self.impl.axonW = self.impl.axonW.at[
                item[ax], posts[ax]].add(delta[ax])
            self.impl.neuronW = self.impl.neuronW.at[
                item[~ax] - c.item_base, posts[~ax]].add(delta[~ax])
        elif c.target == "engine":
            flat_w = c.image.syn_weight.reshape(-1)
            flat_w[c.syn_pos[cols_u]] = w_u.astype(np.int16)
            self.impl.update_weights(c.image.syn_weight)
        else:
            # hiaer/mesh: shard-local update — only the shards whose
            # entries changed are rebuilt (per-core weight storage);
            # the host image stays authoritative for save()
            if c.image is not None:
                flat_w = c.image.syn_weight.reshape(-1)
                flat_w[c.syn_pos[cols_u]] = w_u.astype(np.int16)
            self.impl.update_entry_weights(c.syn_pos[cols_u],
                                           w_u.astype(np.int32))

    def load_weights(self, syn_weight: np.ndarray) -> None:
        """Restore a full synapse-weight column (the checkpointed
        `compiled.syn_weight`): diff against the current column and
        upload only the changed entries as ONE backend update — a
        restore that changes nothing uploads nothing."""
        w = np.asarray(syn_weight)
        c = self.compiled
        if w.shape != c.syn_weight.shape:
            raise ValueError(
                f"weight column of {w.shape} does not match the "
                f"{c.syn_weight.shape} deployed synapses — restore "
                f"onto a deployment of the same compiled artifact")
        cols = np.nonzero(w != c.syn_weight)[0]
        if cols.size == 0:
            return
        self._write_cols(cols, np.clip(w[cols].astype(np.int64),
                                       W_MIN, W_MAX))
        self.weight_uploads += 1

    def read_synapse(self, pre: int, post: int) -> int:
        return int(self.read_synapses([pre], [post])[0])

    def write_synapse(self, pre: int, post: int, weight: int) -> None:
        self.write_synapses([pre], [post], [int(weight)])


def deploy(compiled: CompiledNetwork, *, seed: int = 0,
           vectorized: bool = True, use_pallas: bool = False,
           n_devices: Optional[int] = None,
           packed: bool = True) -> Deployment:
    """Bring a compiled network up on its target backend. `n_devices`
    (mesh target only) picks the device-mesh width; default is the
    largest available device count that evenly divides the core count.
    `packed` (hiaer/mesh) selects the bit-packed spike wire format —
    uint32 presence words instead of int32 event lanes, bit-exact
    either way; default on (the 32x-narrower exchange)."""
    return Deployment(compiled, seed=seed, vectorized=vectorized,
                      use_pallas=use_pallas, n_devices=n_devices,
                      packed=packed)
