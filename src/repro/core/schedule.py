"""Shared schedule encoding: axon id sequences -> dense event-count
matrices.

Every execution path (CRI_network, EventEngine, DenseSimulator,
HiAERNetwork) drives timesteps from the same representation — a
(T, width) int32 matrix of per-axon event COUNTS, where an axon listed
twice in a step is driven twice (the event-queue semantics of §4's
two-phase routing). This module is the single definition of that
encoding; it used to live in five near-identical copies
(api.CRI_network._encode_schedule/_pad_axons, EventEngine.encode_axons/
_encode_schedule, DenseSimulator._encode), whose drift would have
silently broken the documented cross-backend bit-exactness.

Conventions shared by all callers:
  * out-of-range ids are silently dropped (the seed engine's `dict.get`
    skip — tests/test_routing_vectorized.py pins this on every backend);
  * an ndarray/jnp array is taken as an already-encoded count matrix and
    only validated (width + integer dtype), never re-interpreted — a
    plain Python list of id lists is always per-element events;
  * float count matrices are rejected loudly: truncating spike
    probabilities to int32 would drop events.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np


def check_count_dtype(a) -> None:
    """Reject non-integer count matrices: silently truncating a float
    schedule (e.g. spike probabilities) to int32 would drop events."""
    if not (np.issubdtype(a.dtype, np.integer) or a.dtype == np.bool_):
        raise ValueError(
            f"count schedules must be integer or bool, got {a.dtype}")


def encode_ids(ids: Iterable[int], width: int) -> np.ndarray:
    """Axon id sequence -> (width,) int32 occurrence counts. Ids outside
    [0, width) are dropped, matching the seed engine's `dict.get` skip."""
    arr = np.asarray(list(ids), np.int64).reshape(-1)
    arr = arr[(arr >= 0) & (arr < width)]
    return np.bincount(arr, minlength=width).astype(np.int32)


def encode_schedule(schedule, width: int) -> np.ndarray:
    """Length-T sequence of id sequences -> (T, width) int32 counts.
    An ndarray/jnp array with ndim >= 2 passes through as pre-encoded
    (..., width) counts after width/dtype validation (so (B, T, width)
    batches validate through the same door)."""
    if isinstance(schedule, (np.ndarray, jnp.ndarray)) and schedule.ndim >= 2:
        if schedule.shape[-1] != width:
            raise ValueError(
                f"schedule width {schedule.shape[-1]} != expected width "
                f"{width}")
        check_count_dtype(schedule)
        return np.asarray(schedule, np.int32)
    if len(schedule) == 0:
        return np.zeros((0, width), np.int32)
    return np.stack([encode_ids(s, width) for s in schedule])


def encode_batch(schedules, width: int) -> np.ndarray:
    """Length-B sequence of `encode_schedule` inputs (or a (B, T, width)
    count array) -> (B, T, width) int32 counts."""
    if isinstance(schedules, (np.ndarray, jnp.ndarray)) \
            and schedules.ndim == 3:
        return encode_schedule(np.asarray(schedules), width)
    return np.stack([encode_schedule(s, width) for s in schedules])


def pad_width(counts: np.ndarray, want: int) -> np.ndarray:
    """Zero-pad the trailing axis up to `want` columns (the engine's
    flattened axon table is never narrower than 1 slot, so an empty
    network's (T, 0) schedule widens to (T, 1))."""
    if counts.shape[-1] >= want:
        return counts
    pad = [(0, 0)] * (counts.ndim - 1) + [(0, want - counts.shape[-1])]
    return np.pad(counts, pad)
