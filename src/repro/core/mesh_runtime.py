"""Device-mesh HiAER execution tier — the §3 hierarchy on a REAL jax
device mesh (`CRI_network(..., backend="mesh")`).

The hiaer tier (core.hiaer) already structures every timestep as
per-core blocks plus a level-by-level spike exchange, but folds all of
it onto one device. Here the same per-core data model runs under
`compat.shard_map` over a 1-D mesh of D devices, each owning
C // D consecutive cores:

  * per-core STATE is sharded: membranes, model tables, and — the part
    that actually scales — each core's ragged synapse shard with its own
    weight storage (`hbm.CoreShards.entry_w`). A device holds only its
    cores' entries padded to the largest per-device span; the monolithic
    dense `w_ext` weight image of the original hiaer tier exists
    NOWHERE, so total weight memory per device shrinks with D — the
    paper's per-core HBM model (each FPGA core owns its synapse memory,
    only spikes cross the boundary; cf. SpiNNaker2's chip-local SRAM);
  * the spike exchange is the hierarchical all-gather of Fig. 1b lowered
    to real collectives: `kernels.exchange.collective_stages` plans one
    grouped `lax.all_gather` per hierarchy level (NoC -> FireFly ->
    Ethernet) and `hierarchical_gather_collective[_packed]` runs them
    inside the shard_mapped step, reproducing `hierarchical_gather`'s
    core-ordered global vector on every device. The wire format is
    BIT-PACKED by default (`packed=True`): fired flags travel as uint32
    presence words (`pack_events`, ceil(n_max/32) words per core) and
    destinations read their neurons' bits with one word gather + bit
    extract (`route.packed_gather_counts`) — per-level collective bytes
    and the replicated event-vector floor both drop ~32x, the
    address-event-bits wire of the paper's fabric;
  * `run_batch` folds the sample batch INTO the device-local state
    inside shard_map (rank-stable on jax 0.4.x, unlike
    vmap-of-shard_map): B samples share one collective per hierarchy
    level per timestep, recovering the monolithic engine's batched
    throughput at mesh scale;
  * phase 2 is the same scatter-free ragged segment sum as hiaer, run on
    the device-local entries with device-rebased CSR offsets.

Bit-exactness vs `backend="engine"`/`backend="hiaer"` (spikes,
membranes, AccessCounter pointer/row stats AND per-level traffic) holds
by the same three invariants as the hiaer tier — the noise draw stays in
global neuron-id order (drawn replicated outside the shard_map), the
sharded entries are the same monolithic multiset of (weight x
event-count) terms under order-free int32 addition, and access/traffic
tallies are computed from the replicated global event counts against the
monolithic pointer-span/ndest tables.

Multi-device execution on CPU comes from forcing XLA host devices
(`XLA_FLAGS=--xla_force_host_platform_device_count=8`, the
launch/dryrun.py pattern — the flag must precede the first jax import);
tests/test_mesh_runtime.py drives the 8-device parity suite through a
subprocess. Multi-host `jax.distributed` initialization is the one seam
left open: the step itself is already expressed entirely in collectives.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import hbm
from repro.core import neuron as nrn
from repro.core import schedule as sched
from repro.core.costmodel import AccessCounter
from repro.core.hbm import CoreShards
from repro.core.partition import Hierarchy
from repro.kernels import exchange as exch_k
from repro.kernels import route as route_k

_INT32_MAX = np.iinfo(np.int32).max
AXIS = "cores"                     # the 1-D mesh axis name
_to_cores = hbm.gather_to_cores


class MeshTables(NamedTuple):
    """Device-resident state. The first group is sharded over the mesh
    axis (leading dim = C or D); the second is replicated — replicated
    arrays are O(A + N) vectors (pointer spans, destination tables, the
    global noise draw), never synapse-sized."""
    # sharded, P(AXIS): per-device rows / per-core rows
    entry_w: jnp.ndarray           # (D, Epad) int32 per-device weight
    #                                storage, pad 0
    entry_item: jnp.ndarray        # (D, Epad) int32, pad = A + N
    csr_indptr: jnp.ndarray        # (C, n_max + 1) int32 DEVICE-rebased
    #                                offsets into the device's entries
    core_nids_idx: jnp.ndarray     # (C, n_max) int32 global id, pad -> N
    theta: jnp.ndarray             # (C, n_max) int32, pad = INT32_MAX
    nu: jnp.ndarray                # (C, n_max) int32, pad = -32
    lam: jnp.ndarray               # (C, n_max) int32
    is_lif: jnp.ndarray            # (C, n_max) bool, pad = False
    # replicated, P()
    pos_of_neuron: jnp.ndarray     # (N,) flat core * n_max + local slot
    pos_word: jnp.ndarray          # (N,) int32 packed-wire word index
    pos_bit: jnp.ndarray           # (N,) int32 bit within the word
    axon_ndest: jnp.ndarray        # (A, N_LEVELS) int32
    neuron_ndest: jnp.ndarray      # (N, N_LEVELS) int32
    axon_rows: jnp.ndarray         # (A,) int32 monolithic pointer spans
    axon_present: jnp.ndarray      # (A,) bool
    neuron_rows: jnp.ndarray       # (N,) int32
    neuron_present: jnp.ndarray    # (N,) bool


def default_device_count(n_cores: int,
                         available: Optional[int] = None) -> int:
    """Largest device count <= available that evenly divides the core
    count (each device owns the same number of whole cores)."""
    if available is None:
        available = len(jax.devices())
    return max(d for d in range(1, min(available, n_cores) + 1)
               if n_cores % d == 0)


class MeshNetwork:
    """Multi-device HiAER engine; mirrors `HiAERNetwork`'s interface
    (step/run/run_batch/reset/V/counter/update_entry_weights) so
    `CRI_network(..., backend="mesh")` drops in unchanged. Built only
    from the compiler's prebuilt pieces (the staged path — there is no
    per-dict legacy door at mesh scale)."""

    def __init__(self, theta, nu, lam, is_lif, n_neurons: int,
                 outputs: Sequence[int], *, hierarchy: Hierarchy,
                 flat, neuron_core, axon_core, shards: CoreShards,
                 axon_ndest, neuron_ndest, seed: int = 0,
                 n_devices: Optional[int] = None, packed: bool = True):
        self.n = n_neurons
        self.packed = bool(packed)
        self.outputs = list(outputs)
        self.flat = flat
        self.n_axon_slots = int(flat.axon_rows.shape[0])
        self.hier = hierarchy if hierarchy is not None else \
            Hierarchy(1, 1, 1, max(n_neurons, 1))
        self.spec = exch_k.HierSpec.from_hierarchy(self.hier)
        self.neuron_core = np.asarray(neuron_core, np.int32)
        self.axon_core = np.asarray(axon_core, np.int32)
        self.shards = shards

        C = self.hier.n_cores
        if n_devices is None:
            n_devices = default_device_count(C)
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        if C % n_devices:
            raise ValueError(f"n_devices={n_devices} must evenly "
                             f"divide {C} cores")
        if n_devices > len(jax.devices()):
            raise ValueError(f"n_devices={n_devices} > "
                             f"{len(jax.devices())} available devices")
        self.n_devices = n_devices
        self.cores_per_device = C // n_devices
        self.mesh = make_mesh((n_devices,), (AXIS,),
                              devices=np.asarray(
                                  jax.devices()[:n_devices]))
        self._stages = exch_k.collective_stages(self.spec, n_devices)
        self._shard = NamedSharding(self.mesh, P(AXIS))
        self._repl = NamedSharding(self.mesh, P())

        sh = shards
        core_nids_idx = np.where(sh.core_nids >= 0, sh.core_nids,
                                 n_neurons).astype(np.int32)
        pos_of_neuron = (sh.core_of_neuron.astype(np.int64) * sh.n_max
                         + sh.local_id).astype(np.int32)
        pos_word, pos_bit = exch_k.packed_positions(
            sh.core_of_neuron, sh.local_id, sh.n_max)

        # ---- per-device entry shards: each device's cores' ragged
        # entries concatenated, padded to the largest per-device span
        # (pad item = A + N gathers an appended zero event count)
        off = sh.core_offsets
        self._dev_off = off[::self.cores_per_device].copy()  # (D + 1,)
        dev_counts = np.diff(self._dev_off)
        Epad = max(int(dev_counts.max()) if dev_counts.size else 0, 1)
        self._Epad = Epad
        self._n_items = self.n_axon_slots + n_neurons
        ew, ei = self._device_entry_rows(range(n_devices))
        # CSR offsets rebased to each core's DEVICE entry array
        dev_of_core = np.repeat(np.arange(n_devices),
                                self.cores_per_device)
        indptr_rebased = (sh.csr_indptr
                          - self._dev_off[dev_of_core][:, None]) \
            .astype(np.int32)

        def shd(x):
            return jax.device_put(np.asarray(x), self._shard)

        def rep(x):
            return jax.device_put(np.asarray(x), self._repl)

        self._tables = MeshTables(
            entry_w=shd(ew), entry_item=shd(ei),
            csr_indptr=shd(indptr_rebased),
            core_nids_idx=shd(core_nids_idx),
            theta=shd(_to_cores(np.asarray(theta, np.int32),
                                core_nids_idx, _INT32_MAX)),
            nu=shd(_to_cores(np.asarray(nu, np.int32), core_nids_idx,
                             -32)),
            lam=shd(_to_cores(np.asarray(lam, np.int32), core_nids_idx,
                              63)),
            is_lif=shd(_to_cores(np.asarray(is_lif, bool),
                                 core_nids_idx, False)),
            pos_of_neuron=rep(pos_of_neuron),
            pos_word=rep(pos_word), pos_bit=rep(pos_bit),
            axon_ndest=rep(axon_ndest), neuron_ndest=rep(neuron_ndest),
            axon_rows=rep(flat.axon_rows),
            axon_present=rep(flat.axon_present),
            neuron_rows=rep(flat.neuron_rows),
            neuron_present=rep(flat.neuron_present),
        )

        self.Vc = jax.device_put(np.zeros((C, sh.n_max), np.int32),
                                 self._shard)
        # commit the key to the replicated sharding up front: the jit
        # cache keys on input shardings, and an uncommitted fresh key
        # vs the committed key a run returns would cost one silent
        # retrace on the second dispatch (caught by analysis.retrace)
        self.key = jax.device_put(jax.random.PRNGKey(seed), self._repl)
        self.counter = AccessCounter()
        self.shard_rebuilds = 0        # per-DEVICE weight-shard uploads
        self._spikes = np.zeros((n_neurons,), bool)

        table_specs = MeshTables(*([P(AXIS)] * 8 + [P()] * 9))
        self._smapped = shard_map(
            self._device_step, mesh=self.mesh,
            in_specs=(P(AXIS), P(), P(), table_specs),
            out_specs=(P(AXIS), P()), check_vma=False)
        # the batched step: B samples folded into the device-local state
        # (leading axis of Vc is the batch, the CORE axis stays the
        # sharded one) — rank-stable on jax 0.4.x, unlike
        # vmap-of-shard_map, and all B samples share one collective per
        # hierarchy level per timestep.
        self._smapped_batch = shard_map(
            self._device_step, mesh=self.mesh,
            in_specs=(P(None, AXIS), P(), P(), table_specs),
            out_specs=(P(None, AXIS), P()), check_vma=False)
        self._jit_step = jax.jit(self._step_impl)
        self._jit_run = jax.jit(self._run_impl)
        self._jit_run_batch = jax.jit(self._run_batch_impl)
        self._jit_run_lanes = jax.jit(self._run_lanes_impl)

    # ------------------------------------------------------------ helpers
    def _device_entry_rows(self, devices):
        """Host-side (len(devices), Epad) padded weight/item rows from
        the ragged shard arrays."""
        sh = self.shards
        ew = np.zeros((len(list(devices)), self._Epad), np.int32)
        ei = np.full_like(ew, self._n_items)
        for r, d in enumerate(devices):
            s, e = int(self._dev_off[d]), int(self._dev_off[d + 1])
            ew[r, :e - s] = sh.entry_w[s:e]
            ei[r, :e - s] = sh.entry_item[s:e]
        return ew, ei

    def device_shard_bytes(self) -> List[int]:
        """Per-device synapse-shard memory: padded weight + item entries
        plus the device's CSR offsets — the arrays `MeshTables` actually
        puts on each device (state vectors excluded). The monolithic
        comparison point is `w_ext` = (R * SLOTS + 1) * 4 bytes, the
        dense weight image the hiaer tier used to replicate."""
        ip = self.cores_per_device * (self.shards.n_max + 1) * 4
        return [self._Epad * (4 + 4) + ip] * self.n_devices

    def exchange_bytes_per_step(self, packed: Optional[bool] = None) -> int:
        """Wire bytes one device receives per spike-exchange round under
        this mesh's collective plan (`exch_k.exchange_bytes_per_step`);
        `packed=None` reports the deployed wire format."""
        return exch_k.exchange_bytes_per_step(
            self.spec, self.n_devices, self.shards.n_max,
            self.packed if packed is None else packed)

    def event_vector_bytes(self, packed: Optional[bool] = None) -> int:
        """Replicated global event-vector bytes per device — the
        O(C * n_max) per-device floor the bitpacking cuts ~32x."""
        return exch_k.event_vector_bytes(
            self.spec, self.shards.n_max,
            self.packed if packed is None else packed)

    # ------------------------------------------------------------- state
    @property
    def V(self):
        """Membrane potentials in global neuron-id order."""
        flat = np.asarray(self.Vc).reshape(-1)
        return flat[np.asarray(self._tables.pos_of_neuron)]

    def reset(self):
        self.Vc = jax.device_put(
            np.zeros(self.Vc.shape, np.int32), self._shard)
        self._spikes = np.zeros((self.n,), bool)

    # -------------------------------------------------- weight updates
    def update_entry_weights(self, positions, weights) -> None:
        """Batched weight edit at flat monolithic positions: re-uploads
        ONLY the device shards whose entries changed — the untouched
        devices' buffers are reused verbatim
        (`jax.make_array_from_single_device_arrays`)."""
        cores = self.shards.apply_entry_updates(positions, weights)
        if cores.size:
            self._refresh_devices(
                np.unique(cores // self.cores_per_device).tolist())

    def update_weights(self, syn_weight) -> None:
        """Full refresh from a dense `syn_weight` edit (legacy whole-
        image surface); batched runtime edits go through
        `update_entry_weights`."""
        w = np.asarray(syn_weight, np.int32)
        self.flat.syn_weight = np.ascontiguousarray(w)
        self.shards.entry_w[:] = w.reshape(-1)[self.shards.entry_pos]
        self._refresh_devices(range(self.n_devices))

    def _refresh_devices(self, devices) -> None:
        """Swap in fresh weight rows for the given device shards; every
        other device's buffer is reused verbatim."""
        devices = list(devices)
        ew_new, _ = self._device_entry_rows(devices)
        old = self._tables.entry_w
        # addressable shard of device d covers global row d
        parts = {int(s.index[0].start or 0): s.data
                 for s in old.addressable_shards}
        for r, d in enumerate(devices):
            parts[d] = jax.device_put(ew_new[r][None],
                                      self.mesh.devices.flat[d])
        buf = [parts[d] for d in sorted(parts)]
        self._tables = self._tables._replace(
            entry_w=jax.make_array_from_single_device_arrays(
                old.shape, self._shard, buf))
        self.shard_rebuilds += len(devices)

    # -------------------------------------------------- vectorized core
    def _device_step(self, Vc, u_ext, axon_counts, t: MeshTables):
        """The shard_mapped body: one device's cores for one timestep.
        Vc (cpd, n_max) — or (B, cpd, n_max) with a folded sample batch,
        in which case u_ext/axon_counts carry a matching leading B and
        all samples ride the SAME per-level collectives; sharded table
        rows are this device's blocks; u_ext/axon_counts and the
        replicated tables arrive whole."""
        uc = jnp.take(u_ext, t.core_nids_idx, axis=-1)
        Vc_mid, spikes_c = nrn.fire_phase_from_u(
            Vc, t.theta, t.nu, t.lam, t.is_lif, uc)
        lead = spikes_c.shape[:-2]         # () or (B,)
        if self.packed:
            # bit-packed wire: pack fired flags to uint32 presence words
            # BEFORE the hops, gather words, read bits at the
            # destination — per-level bytes drop ~32x
            words = exch_k.pack_events(spikes_c)
            flat = exch_k.hierarchical_gather_collective_packed(
                words.reshape(lead + (-1,)), self._stages, AXIS,
                axis=len(lead))
            neuron_counts = route_k.packed_gather_counts(
                flat, t.pos_word, t.pos_bit)           # (..., N)
        else:
            flat = exch_k.hierarchical_gather_collective(
                spikes_c.astype(jnp.int32).reshape(lead + (-1,)),
                self._stages, AXIS, axis=len(lead))
            neuron_counts = jnp.take(flat, t.pos_of_neuron, axis=-1)
        # phase 2 on the device-local ragged entries (pad item -> 0)
        item_counts = jnp.concatenate(
            [axon_counts, neuron_counts,
             jnp.zeros(lead + (1,), jnp.int32)], axis=-1)
        vals = t.entry_w[0] * jnp.take(item_counts, t.entry_item[0],
                                       axis=-1)
        syn_c = route_k.ragged_segment_sum(vals, t.csr_indptr)
        Vc_next = nrn.integrate_phase(Vc_mid, syn_c)
        return Vc_next, neuron_counts

    def _step_impl(self, Vc, key, axon_counts, tables: MeshTables):
        """One timestep: sharded fire/route/integrate + replicated
        access & traffic tallies. Returns (Vc', key', spikes (N,),
        ptr_reads, row_reads, traffic (4,))."""
        key, sub = jax.random.split(key)
        # global-order noise draw (PRNG parity with engine/hiaer),
        # replicated then gathered into each device's core layout
        u = nrn.noise_draw(sub, self.n)
        u_ext = jnp.concatenate([u, jnp.zeros((1,), jnp.int32)])
        Vc_next, neuron_counts = self._smapped(Vc, u_ext, axon_counts,
                                               tables)
        _, _, pr, rr = route_k.access_counts(
            axon_counts, neuron_counts, tables.axon_rows,
            tables.axon_present, tables.neuron_rows,
            tables.neuron_present)
        traffic = (axon_counts @ tables.axon_ndest
                   + neuron_counts @ tables.neuron_ndest)
        return (Vc_next, key, neuron_counts.astype(bool), pr, rr,
                traffic)

    def _run_impl(self, Vc, key, counts, tables):
        """T timesteps under one lax.scan; counts: (T, A) int32."""
        def body(carry, c):
            Vc, key = carry
            Vc, key, spikes, pr, rr, tr = self._step_impl(Vc, key, c,
                                                          tables)
            return (Vc, key), (spikes, pr, rr, tr)

        (Vc, key), outs = jax.lax.scan(body, (Vc, key), counts)
        return (Vc, key) + outs

    def _run_batch_impl(self, key, counts, tables):
        """B independent samples in ONE sharded stream; counts:
        (B, T, A) int32. Sample b runs from V = 0 under stream
        fold_in(key, b) — identical to EventEngine.run_batch. The batch
        axis is FOLDED into the device-local state arrays inside
        shard_map (`_smapped_batch`; rank-stable on jax 0.4.x, unlike
        vmap-of-shard_map), so the scan is over T only and all B
        samples share one grouped all_gather per hierarchy level per
        timestep instead of B of them. Output-identical to the retired
        per-sample sequential scan: samples are independent and every
        per-sample op is elementwise in the batch axis."""
        B = counts.shape[0]
        keys = jax.vmap(lambda b: jax.random.fold_in(key, b))(
            jnp.arange(B))
        V0 = jnp.zeros((B,) + self.Vc.shape, jnp.int32)
        _, _, spikes, prs, rrs, trs = self._run_lanes_impl(
            V0, keys, counts, tables)
        return spikes, prs, rrs, trs

    def _run_lanes_impl(self, V0, keys, counts, tables):
        """The stateful-lane core both batched paths share: B lanes,
        each carrying ITS OWN (C, n_max) membrane state and PRNG key
        through the dispatch; the lane axis is FOLDED into the
        device-local state inside shard_map exactly like
        `_run_batch_impl` (one collective per level per step for all B
        lanes). Lane b is bit-identical to running its
        (V0[b], keys[b], counts[b]) alone — every per-lane op is
        elementwise in the lane axis — the invariant micro-batched
        serving rests on. Returns (V_final, keys_final, spikes, prs,
        rrs, traffic)."""
        B = counts.shape[0]

        def body(carry, c):                # c: (B, A) — step for all B
            Vc, keys = carry
            ks = jax.vmap(jax.random.split)(keys)     # (B, 2, key)
            keys_next, subs = ks[:, 0], ks[:, 1]
            # per-sample global-order noise draws (PRNG parity), stacked
            u = jax.vmap(lambda s: nrn.noise_draw(s, self.n))(subs)
            u_ext = jnp.concatenate(
                [u, jnp.zeros((B, 1), jnp.int32)], axis=1)
            Vc, neuron_counts = self._smapped_batch(Vc, u_ext, c,
                                                    tables)
            _, _, pr, rr = route_k.access_counts(
                c, neuron_counts, tables.axon_rows, tables.axon_present,
                tables.neuron_rows, tables.neuron_present)   # (B,) each
            traffic = (c @ tables.axon_ndest
                       + neuron_counts @ tables.neuron_ndest)
            return (Vc, keys_next), (neuron_counts.astype(bool), pr,
                                     rr, traffic)

        (Vc, keys), (spikes, prs, rrs, trs) = jax.lax.scan(
            body, (V0, keys), jnp.swapaxes(counts, 0, 1))
        # scan stacks per-timestep leading axes: (T, B, ...) -> (B, T, ...)
        return (Vc, keys, jnp.swapaxes(spikes, 0, 1), prs, rrs,
                jnp.swapaxes(trs, 0, 1))

    def run_lanes(self, V0, keys, counts):
        """Stateful batched run for the serving tier. V0: (B, C, n_max)
        int32 per-core membranes, keys: (B,) PRNG keys, counts:
        (B, T, A) int32. All B lanes share one collective per hierarchy
        level per timestep (the lane axis rides inside shard_map).
        Returns (V_final, keys_final, spikes (B, T, n) bool); the
        engine's own sequential state is untouched."""
        B, T = counts.shape[0], counts.shape[1]
        self.counter.timesteps += B * T
        Vc, keys, spikes, prs, rrs, trs = self._jit_run_lanes(
            jnp.asarray(V0, jnp.int32), keys, jnp.asarray(counts),
            self._tables)
        self.counter.tally(prs, rrs, trs)
        return Vc, keys, np.asarray(spikes, bool)

    def lanes_membrane(self, V_lanes) -> np.ndarray:
        """Per-lane (C, n_max) state -> (B, n) membranes in global
        neuron-id order."""
        V = np.asarray(V_lanes)
        pos = np.asarray(self._tables.pos_of_neuron)
        return V.reshape(V.shape[0], -1)[:, pos]

    def lane_state_zeros(self, B: int) -> np.ndarray:
        """Fresh per-lane membrane state, (B,) + the backend's state
        shape — the V = 0 a `run_batch` sample starts from."""
        return np.zeros((B,) + tuple(self.Vc.shape), np.int32)

    # ----------------------------------------------------------- stepping
    def step(self, axon_inputs: Sequence[int]) -> np.ndarray:
        """One timestep; returns bool (n,) spikes fired this step."""
        self.counter.timesteps += 1
        counts = jnp.asarray(sched.encode_ids(axon_inputs,
                                              self.n_axon_slots))
        self.Vc, self.key, spikes, pr, rr, tr = self._jit_step(
            self.Vc, self.key, counts, self._tables)
        self.counter.tally(pr, rr, tr)
        self._spikes = np.asarray(spikes, bool)
        return self._spikes

    def run(self, schedule) -> np.ndarray:
        """T timesteps in one dispatch; returns (T, n) bool spikes."""
        counts = sched.encode_schedule(schedule, self.n_axon_slots)
        T = counts.shape[0]
        self.counter.timesteps += T
        self.Vc, self.key, spikes, prs, rrs, trs = self._jit_run(
            self.Vc, self.key, jnp.asarray(counts), self._tables)
        self.counter.tally(prs, rrs, trs)
        spikes = np.asarray(spikes, bool)
        if T:
            self._spikes = spikes[-1]
        return spikes

    def run_batch(self, schedules) -> np.ndarray:
        """B samples x T timesteps in ONE batched sharded dispatch;
        same contract as EventEngine.run_batch. Returns (B, T, n) bool
        spikes — the wire between devices carries packed uint32
        presence words (packed=True), never int32 event lanes."""
        if len(schedules) == 0:
            return np.zeros((0, 0, self.n), bool)
        counts = sched.encode_batch(schedules, self.n_axon_slots)
        B, T = counts.shape[0], counts.shape[1]
        self.counter.timesteps += B * T
        spikes, prs, rrs, trs = self._jit_run_batch(
            self.key, jnp.asarray(counts), self._tables)
        self.counter.tally(prs, rrs, trs)
        self.key, _ = jax.random.split(self.key)
        return np.asarray(spikes, bool)

    def read_membrane(self, ids: Sequence[int]) -> List[int]:
        V = np.asarray(self.V)
        return [int(V[i]) for i in ids]
