"""On-line synaptic learning — §3: "support synaptic learning algorithms
that require careful accounting for time differences between pre- and
postsynaptic spikes, such as variations of spike-timing-dependent
plasticity (STDP)". Weight updates execute host-side (the paper's server
CPUs program updates over PCIe) against the same synapse tables.

Trace-based STDP with 1 ms-resolution exponential traces:
    pre-trace  x_j += 1 on pre spike,  decays by 2^-tau_shift each step
    post-trace y_i += 1 on post spike, same decay (integer shift decay,
    matching the platform's fixed-point arithmetic)
    Δw_ij = A_plus * x_j  on a postsynaptic spike   (potentiation)
            -A_minus * y_i on a presynaptic spike   (depression)
Weights clip to int16.

The update engine is columnar: traces are int arrays over the network's
item space, each step's candidate synapses are gathered through a
per-item CSR over the compiled synapse columns, and every phase lands
on the backend as ONE batched `write_synapses` delta upload
(core.deploy) instead of one PCIe round trip per synapse — which is
what makes STDP practical on the hiaer backend, where a weight write
re-shards the per-core tables. Same-direction updates within a phase
commute with the int16 clip, so the batch is bit-identical to the
legacy sequential read_synapse/write_synapse loop
(tests/test_learning.py pins hiaer == engine on spikes, weights, and
traces).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.hbm import W_MAX, _ranges


@dataclass
class STDPConfig:
    a_plus: int = 8
    a_minus: int = 6
    tau_shift: int = 2          # trace decay: t -= t >> tau_shift
    w_min: int = -W_MAX
    w_max: int = W_MAX


class STDP:
    """Operates on a `CRI_network` (any backend) by replaying its spike
    history through the batched read/write_synapses path — the PCIe
    batch. Traces live in item space: `pre_trace[item]` with axons at
    [0, item_base) and neurons at item_base + id; `post_trace[nid]`."""

    def __init__(self, net, cfg: STDPConfig = STDPConfig()):
        self.net = net
        self.cfg = cfg
        c = net.compiled
        self._base = c.item_base
        self._n = c.n_neurons
        size = self._base + self._n
        self.pre_trace = np.zeros((size,), np.int64)
        self.post_trace = np.zeros((self._n,), np.int64)
        # per-item CSR over the synapse columns (candidate gathers)
        item = np.asarray(c.syn_item, np.int64)
        order = np.argsort(item, kind="stable")
        self._csr_post = np.asarray(c.syn_post, np.int64)[order]
        self._csr_item = item[order]
        counts = np.bincount(item, minlength=size)
        self._indptr = np.zeros((size + 1,), np.int64)
        np.cumsum(counts, out=self._indptr[1:])

    # --------------------------------------------------------- utilities
    def _decay(self):
        sh = self.cfg.tau_shift
        self.pre_trace -= self.pre_trace >> sh
        self.post_trace -= self.post_trace >> sh

    def _item_of(self, key):
        """Key -> item id (axon keys win the shared namespace, the
        legacy read/write_synapse resolution order); None if unknown
        (legacy tolerated unknown keys as trace-only entries)."""
        aid = self.net._aid.get(key)
        if aid is not None:
            return aid
        nid = self.net._nid.get(key)
        return None if nid is None else self._base + nid

    def _encode(self, items: np.ndarray) -> np.ndarray:
        """Item ids -> the deployment's encoded pre ids."""
        return np.where(items < self._base, -(items + 1),
                        items - self._base)

    def _apply(self, items: np.ndarray, deltas: np.ndarray,
               posts: np.ndarray):
        """One phase: aggregate per-(pre, post) deltas (same-direction,
        so summing commutes with the sequential clip), then one batched
        read + one batched write of the changed weights."""
        if items.size == 0:
            return
        key = items * max(self._n, 1) + posts
        uniq, inv = np.unique(key, return_inverse=True)
        dsum = np.zeros((uniq.shape[0],), np.int64)
        np.add.at(dsum, inv, deltas)
        u_item = uniq // max(self._n, 1)
        u_post = uniq % max(self._n, 1)
        pre = self._encode(u_item)
        dep = self.net._dep
        w = dep.read_synapses(pre, u_post).astype(np.int64)
        w2 = np.clip(w + dsum, self.cfg.w_min, self.cfg.w_max)
        chg = w2 != w
        if chg.any():
            dep.write_synapses(pre[chg], u_post[chg], w2[chg])
            self.net._syn_cache = None

    # -------------------------------------------------------------- step
    def step(self, inputs, fired_keys):
        """Call after each net.step: inputs = axon keys driven this step
        (an axon listed twice is a double event, doubling its trace bump
        and depression), fired_keys = neuron keys that spiked."""
        cfg = self.cfg
        self._decay()
        fired = list(dict.fromkeys(fired_keys))      # set semantics,
        #                                              deterministic order
        pres = [self._item_of(k) for k in list(inputs) + fired]
        pres = np.asarray([p for p in pres if p is not None], np.int64)
        p_items, mult = (np.unique(pres, return_counts=True)
                         if pres.size else
                         (np.zeros((0,), np.int64),) * 2)

        # depression: every synapse of a driven/fired pre against the
        # existing post traces
        start = self._indptr[p_items]
        cnt = self._indptr[p_items + 1] - start
        gather = np.repeat(start, cnt) + _ranges(cnt)
        d_item = self._csr_item[gather]
        d_post = self._csr_post[gather]
        d_mult = np.repeat(mult, cnt)
        yt = self.post_trace[d_post]
        sel = yt > 0
        self._apply(d_item[sel],
                    -cfg.a_minus * yt[sel] * d_mult[sel], d_post[sel])

        # potentiation: every synapse with a live pre trace into a
        # neuron that fired this step (skipped entirely on quiet steps
        # so sparse activity never pays the full-column gather)
        fired_ids = np.asarray([self.net._nid[k] for k in fired
                                if k in self.net._nid], np.int64)
        if fired_ids.size:
            fired_mask = np.zeros((max(self._n, 1),), bool)
            fired_mask[fired_ids] = True
            xt_all = self.pre_trace[self._csr_item]
            sel = (xt_all > 0) & fired_mask[self._csr_post]
            self._apply(self._csr_item[sel],
                        cfg.a_plus * xt_all[sel], self._csr_post[sel])

        # bump traces after applying (classic trace ordering)
        if pres.size:
            np.add.at(self.pre_trace, pres, 1)
        self.post_trace[fired_ids] += 1
