"""On-line synaptic learning — §3: "support synaptic learning algorithms
that require careful accounting for time differences between pre- and
postsynaptic spikes, such as variations of spike-timing-dependent
plasticity (STDP)". Weight updates execute host-side (the paper's server
CPUs program updates over PCIe) against the same synapse tables.

Trace-based STDP with 1 ms-resolution exponential traces:
    pre-trace  x_j += 1 on pre spike,  decays by 2^-tau_shift each step
    post-trace y_i += 1 on post spike, same decay (integer shift decay,
    matching the platform's fixed-point arithmetic)
    Δw_ij = A_plus * x_j  on a postsynaptic spike   (potentiation)
            -A_minus * y_i on a presynaptic spike   (depression)
Weights clip to int16.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

W_MAX = 32767


@dataclass
class STDPConfig:
    a_plus: int = 8
    a_minus: int = 6
    tau_shift: int = 2          # trace decay: t -= t >> tau_shift
    w_min: int = -W_MAX
    w_max: int = W_MAX


class STDP:
    """Operates on a CRI_network (simulator or engine backend) by replaying
    its spike history through read/write_synapse — the PCIe path."""

    def __init__(self, net, cfg: STDPConfig = STDPConfig()):
        self.net = net
        self.cfg = cfg
        self.pre_trace = {k: 0 for k in
                          list(net.axon_keys) + list(net.neuron_keys)}
        self.post_trace = {k: 0 for k in net.neuron_keys}
        # pre -> [(post, ...)] adjacency in key space
        ids = {i: k for k, i in net._nid.items()}
        self.adj = {}
        for k in net.axon_keys:
            self.adj[k] = [ids[p] for p, _ in net._axon_syn[net._aid[k]]]
        for k in net.neuron_keys:
            self.adj[k] = [ids[p] for p, _ in net._neuron_syn[net._nid[k]]]

    def _decay(self):
        sh = self.cfg.tau_shift
        for d in (self.pre_trace, self.post_trace):
            for k in d:
                d[k] -= d[k] >> sh

    def step(self, inputs, fired_keys):
        """Call after each net.step: inputs = axon keys driven this step,
        fired_keys = neuron keys that spiked this step."""
        cfg = self.cfg
        self._decay()
        fired = set(fired_keys)
        pres = list(inputs) + list(fired)
        # depression: pre spike against existing post trace
        for pre in pres:
            for post in self.adj.get(pre, ()):
                yt = self.post_trace.get(post, 0)
                if yt:
                    w = self.net.read_synapse(pre, post)
                    w2 = int(np.clip(w - cfg.a_minus * yt,
                                     cfg.w_min, cfg.w_max))
                    if w2 != w:
                        self.net.write_synapse(pre, post, w2)
        # potentiation: post spike against pre traces
        for pre, posts in self.adj.items():
            xt = self.pre_trace.get(pre, 0)
            if not xt:
                continue
            for post in posts:
                if post in fired:
                    w = self.net.read_synapse(pre, post)
                    w2 = int(np.clip(w + cfg.a_plus * xt,
                                     cfg.w_min, cfg.w_max))
                    if w2 != w:
                        self.net.write_synapse(pre, post, w2)
        # bump traces after applying (classic trace ordering)
        for pre in pres:
            self.pre_trace[pre] = self.pre_trace.get(pre, 0) + 1
        for post in fired:
            self.post_trace[post] = self.post_trace.get(post, 0) + 1
