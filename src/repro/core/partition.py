"""Hierarchical network partitioning & resource allocation — §3:
"a network partitioning and resource allocation algorithm that assigns SNN
simulation jobs to servers, FPGA boards, and cores as required" [10].

The objective mirrors the paper's scaling argument (§6): spikes crossing
higher hierarchy levels cost more (on-chip NoC < FireFly between FPGAs <
Ethernet between servers), so the partitioner keeps densely-connected
'grey matter' together and lets only sparse 'white matter' cross levels.

Algorithm: locality-first BFS growth (a light multilevel scheme):
  1. build the undirected connectivity graph weighted by |w| (a proxy for
     expected spike traffic along the synapse);
  2. repeatedly seed from the highest-degree unassigned neuron and grow a
     BFS region until the current core is full, preferring frontier
     neurons with the most edges INTO the current core (greedy modularity);
  3. cores fill FPGAs in order, FPGAs fill servers — so BFS locality at
     core level automatically concentrates traffic at the cheapest levels.

`partition_arrays` is the vectorized production implementation (NumPy
frontier expansion over the CSR adjacency, O(E + N log N) plus frontier
scans instead of the reference loop's O(N · frontier) Python pass per
pick); `partition` is the dict front door over it, and `partition_loop`
keeps the original per-node Python walk as the parity oracle — both
produce identical assignments (ties broken by lowest node index, the
deterministic order the reference's max-over-set realizes).

`traffic_cost` evaluates an assignment under per-level costs; tests verify
BFS beats random placement on clustered topologies and that capacity
constraints hold. `allocate` maps whole jobs (networks) onto the cluster
bin-packing style (the NSG scheduling layer).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, Hashable, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Hierarchy:
    """The paper's deployment: 5 servers x 8 FPGAs x 32 cores; 4M neurons
    per FPGA => 125k per core."""
    n_servers: int = 5
    fpgas_per_server: int = 8
    cores_per_fpga: int = 32
    neurons_per_core: int = 125_000

    @property
    def n_cores(self) -> int:
        return self.n_servers * self.fpgas_per_server * self.cores_per_fpga

    @property
    def capacity(self) -> int:
        return self.n_cores * self.neurons_per_core

    def level(self, core_a: int, core_b: int) -> int:
        """0 = same core, 1 = same FPGA (NoC), 2 = same server (FireFly),
        3 = cross-server (Ethernet)."""
        if core_a == core_b:
            return 0
        fa, fb = core_a // self.cores_per_fpga, core_b // self.cores_per_fpga
        if fa == fb:
            return 1
        sa = fa // self.fpgas_per_server
        sb = fb // self.fpgas_per_server
        return 2 if sa == sb else 3


LEVEL_COST = (0.0, 1.0, 10.0, 100.0)    # relative spike-hop costs


def _graph(adjacency: Dict[Hashable, List[Tuple[Hashable, int]]]):
    nodes = list(adjacency)
    idx = {k: i for i, k in enumerate(nodes)}
    edges: Dict[Tuple[int, int], float] = {}
    for pre, posts in adjacency.items():
        for post, w in posts:
            if post not in idx or post == pre:
                continue
            a, b = sorted((idx[pre], idx[post]))
            edges[(a, b)] = edges.get((a, b), 0.0) + abs(w)
    nbrs: List[Dict[int, float]] = [dict() for _ in nodes]
    for (a, b), w in edges.items():
        nbrs[a][b] = nbrs[a].get(b, 0.0) + w
        nbrs[b][a] = nbrs[b].get(a, 0.0) + w
    return nodes, idx, nbrs


def partition_loop(adjacency, hier: Hierarchy) -> Dict[Hashable, int]:
    """Reference implementation: the original per-node Python walk,
    O(N · frontier) per pick. Kept as the parity oracle for
    `partition_arrays` (ties in (gain, degree) resolve to the lowest
    node index — what max-over-an-int-set realizes)."""
    nodes, idx, nbrs = _graph(adjacency)
    n = len(nodes)
    if n > hier.capacity:
        raise ValueError(f"network ({n}) exceeds capacity "
                         f"({hier.capacity})")
    assign = np.full(n, -1, np.int64)
    degree = np.array([sum(d.values()) for d in nbrs])
    core = 0
    filled = 0
    # gain[i] = edge weight into the current core
    gain = np.zeros(n)
    unassigned = set(range(n))
    while unassigned:
        if filled >= hier.neurons_per_core:
            core += 1
            filled = 0
            gain[:] = 0.0
        # pick the best frontier node (max gain, tie-break by degree)
        cand = max(unassigned,
                   key=lambda i: (gain[i], degree[i]))
        assign[cand] = core
        unassigned.discard(cand)
        filled += 1
        for j, w in nbrs[cand].items():
            if j in unassigned:
                gain[j] += w
    return {nodes[i]: int(assign[i]) for i in range(n)}


def partition_arrays(pre: np.ndarray, post: np.ndarray, w: np.ndarray,
                     n: int, hier: Hierarchy) -> np.ndarray:
    """Vectorized locality-first BFS over synapse COLUMNS: `pre`/`post`
    are neuron indices in [0, n) and `w` the synapse weights (axon
    sources must be filtered out by the caller). Returns the (n,) int32
    core assignment — identical to `partition_loop` on the equivalent
    adjacency.

    The frontier expansion is NumPy over a symmetric CSR of the
    deduplicated undirected |w|-graph: assigning a node updates its
    neighbours' gains with one sliced add. Candidate selection is a
    lazy max-heap over (gain, degree, -index) — gains only grow within
    a core epoch, so stale heap entries are discarded on pop — compared
    against the single best zero-gain seed (a degree-presorted cursor),
    instead of the reference's scan of every unassigned node per pick."""
    if n > hier.capacity:
        raise ValueError(f"network ({n}) exceeds capacity "
                         f"({hier.capacity})")
    if n == 0:
        return np.zeros((0,), np.int32)
    pre = np.asarray(pre, np.int64)
    post = np.asarray(post, np.int64)
    w = np.abs(np.asarray(w, np.float64))
    # undirected dedup: accumulate |w| per unordered pair, no self-loops
    a = np.minimum(pre, post)
    b = np.maximum(pre, post)
    keep = a != b
    key = a[keep] * n + b[keep]
    uk, inv = (np.unique(key, return_inverse=True) if key.size
               else (np.zeros((0,), np.int64), np.zeros((0,), np.int64)))
    ew = np.bincount(inv, weights=w[keep],
                     minlength=uk.shape[0]) if key.size else uk * 0.0
    ua, ub = uk // n, uk % n
    # symmetric CSR adjacency (both directions of every undirected edge)
    src = np.concatenate([ua, ub])
    dst = np.concatenate([ub, ua])
    eww = np.concatenate([ew, ew])
    order = np.argsort(src, kind="stable")
    nbr = dst[order]
    nbw = eww[order]
    indptr = np.zeros((n + 1,), np.int64)
    np.cumsum(np.bincount(src, minlength=n), out=indptr[1:])
    degree = np.bincount(src, weights=eww, minlength=n)

    assign = np.full(n, -1, np.int64)
    gain = np.zeros(n)
    heap: List[Tuple[float, float, int]] = []   # (-gain, -degree, i)
    # zero-gain seeds in (max degree, lowest index) order with a cursor
    seed_order = np.lexsort((np.arange(n), -degree))
    cursor = 0
    core = 0
    filled = 0
    for _ in range(n):
        if filled >= hier.neurons_per_core:
            core += 1
            filled = 0
            gain[:] = 0.0
            heap = []
        while cursor < n and assign[seed_order[cursor]] >= 0:
            cursor += 1
        cand = int(seed_order[cursor])  # best (0, degree, -i) candidate
        # drop stale heap tops (assigned, or superseded by a later
        # push with a larger gain — gains only grow within an epoch)
        while heap and (assign[heap[0][2]] >= 0
                        or -heap[0][0] != gain[heap[0][2]]):
            heapq.heappop(heap)
        if heap:
            g, d, i = heap[0]
            # frontier gains are > 0, so the seed only wins on a
            # genuine (gain, degree, -index) comparison
            if (-g, -d, -i) > (gain[cand], degree[cand], -cand):
                cand = i
                heapq.heappop(heap)
        assign[cand] = core
        filled += 1
        s, e = indptr[cand], indptr[cand + 1]
        js, ws = nbr[s:e], nbw[s:e]
        sel = assign[js] < 0
        js, ws = js[sel], ws[sel]
        gain[js] += ws                  # CSR rows are deduplicated
        live = js[gain[js] > 0.0]
        gl = gain[live]
        dl = degree[live]
        for k in range(live.shape[0]):
            heapq.heappush(heap, (-gl[k], -dl[k], int(live[k])))
    return assign.astype(np.int32)


def partition(adjacency, hier: Hierarchy) -> Dict[Hashable, int]:
    """neuron key -> core id, locality-first BFS growth (vectorized
    implementation; see `partition_arrays`)."""
    nodes = list(adjacency)
    idx = {k: i for i, k in enumerate(nodes)}
    pre: List[int] = []
    post: List[int] = []
    w: List[int] = []
    for p, posts in adjacency.items():
        i = idx[p]
        for q, ww in posts:
            j = idx.get(q)
            if j is None:
                continue
            pre.append(i)
            post.append(j)
            w.append(ww)
    assign = partition_arrays(np.asarray(pre, np.int64),
                              np.asarray(post, np.int64),
                              np.asarray(w, np.float64), len(nodes),
                              hier)
    return {nodes[i]: int(assign[i]) for i in range(len(nodes))}


def level_event_counts(adjacency, src_assignment: Dict[Hashable, int],
                       dst_assignment: Dict[Hashable, int],
                       hier: Hierarchy) -> List[int]:
    """Per-level (source item -> destination core) delivery counts for ONE
    firing of every source in `adjacency`: source s homed on core c with
    synapses into destination core d is one event at level(c, d) —
    destination cores deduplicated per source, exactly the HiAER
    multicast granularity the hiaer engine's AccessCounter measures
    (kernels/exchange.py builds its static destination tables with the
    same rule, so measured == predicted x fire counts, bit for bit).
    `src_assignment` maps sources to cores (pass the axon placement for
    axon adjacencies), `dst_assignment` maps postsynaptic neurons."""
    per_level = [0] * len(LEVEL_COST)
    for pre, posts in adjacency.items():
        if pre not in src_assignment:
            continue
        ca = src_assignment[pre]
        dests = {dst_assignment[post] for post, _ in posts
                 if post in dst_assignment}
        for d in dests:
            per_level[hier.level(ca, d)] += 1
    return per_level


def traffic_cost(adjacency, assignment: Dict[Hashable, int],
                 hier: Hierarchy) -> Dict[str, float]:
    """Expected per-spike-event routing cost + per-level breakdown.
    `per_level` is the |w|-weighted synapse traffic; `events` is the
    deduplicated (source, destination-core) delivery count per single
    fire of every neuron — the static twin of the hiaer engine's
    measured AccessCounter.level_events."""
    per_level = [0.0, 0.0, 0.0, 0.0]
    for pre, posts in adjacency.items():
        if pre not in assignment:
            continue
        ca = assignment[pre]
        for post, w in posts:
            if post not in assignment:
                continue
            per_level[hier.level(ca, assignment[post])] += abs(w)
    total = sum(per_level) or 1.0
    return {
        "cost": sum(c * LEVEL_COST[l] for l, c in enumerate(per_level)),
        "local_frac": per_level[0] / total,
        "noc_frac": per_level[1] / total,
        "firefly_frac": per_level[2] / total,
        "ethernet_frac": per_level[3] / total,
        "per_level": per_level,
        "events": level_event_counts(adjacency, assignment, assignment,
                                     hier),
    }


def random_assignment(adjacency, hier: Hierarchy, seed=0):
    rng = np.random.default_rng(seed)
    keys = list(adjacency)
    cores = np.repeat(np.arange(hier.n_cores), hier.neurons_per_core)
    perm = rng.permutation(len(cores))[:len(keys)]
    return {k: int(cores[p]) for k, p in zip(keys, perm)}


@dataclass
class Job:
    name: str
    n_neurons: int


def allocate(jobs: Sequence[Job], hier: Hierarchy) -> Dict[str, List[int]]:
    """First-fit-decreasing allocation of jobs to contiguous core ranges
    (the NSG scheduling layer: a job never shares a core)."""
    per_core = hier.neurons_per_core
    free = list(range(hier.n_cores))
    out: Dict[str, List[int]] = {}
    for job in sorted(jobs, key=lambda j: -j.n_neurons):
        need = -(-job.n_neurons // per_core)
        if need > len(free):
            raise ValueError(f"job {job.name} needs {need} cores, "
                             f"{len(free)} free")
        out[job.name] = free[:need]
        free = free[need:]
    return out
