"""Columnar network description — stage 1 of the build→compile→deploy API.

The paper's headline interface claim is a Python front end "agnostic to
hardware-level detail" that configures networks of up to 160M neurons /
40B synapses. At that scale the per-key dict format of `CRI_network`
(one Python tuple per synapse) makes *construction* the bottleneck, so
the staged API starts from a columnar spec: synapses are three parallel
int arrays (pre, post, weight) grown by bulk NumPy appends, and neuron
models are packed parameter tables — a 1e6-synapse network is described
with a handful of array ops and no per-synapse Python.

    spec = NetworkSpec()
    ax = spec.add_axons(64)                      # -> encoded source ids
    nr = spec.add_neurons(1024, LIF_neuron(threshold=60, lam=3))
    spec.connect(ax[pre_idx], nr[post_idx], weights)   # arrays, one call
    spec.connect(nr[src], nr[dst], w2)                 # neuron->neuron
    spec.set_outputs(nr[:8])
    compiled = compile_spec(spec, target="engine")     # core.compile
    dep = deploy(compiled)                             # core.deploy

Source-id encoding: `add_axons` returns *encoded* ids (negative:
axon a ↦ -(a+1)) and `add_neurons` returns plain neuron ids (>= 0), so
one `pre` column can mix axon and neuron sources unambiguously and
`connect` never needs a flag argument. `encode_axon`/`decode` expose
the mapping for tools that work with raw axon indices.

`from_dicts` ingests the legacy `CRI_network(axons=..., neurons=...)`
format (one pass over the dicts — the unavoidable O(synapses) Python,
paid once at the boundary); everything downstream is columnar. The
compiled artifact is bit-identical between the two construction routes
whenever the per-item synapse order matches (tests/test_staged_api.py).
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hbm import W_MAX, W_MIN
from repro.core.neuron import ANN_neuron, LIF_neuron

__all__ = ["NetworkSpec", "encode_axon", "decode_pre"]


def encode_axon(axon_ids):
    """Raw axon index a -> encoded source id -(a+1) (vectorized)."""
    a = np.asarray(axon_ids, np.int64)
    return -(a + 1)


def decode_pre(pre):
    """Encoded source ids -> (is_axon, raw index): axon -(a+1) ↦ a,
    neuron id passes through."""
    p = np.asarray(pre, np.int64)
    is_axon = p < 0
    return is_axon, np.where(is_axon, -p - 1, p)


def _model_sig(model) -> Tuple:
    """The HBM grouping signature — distinct tuples define the model
    groups, in first-appearance order (exactly the legacy
    CRI_network rule, so images stay bit-identical)."""
    return (model.kind, model.threshold, model.nu, model.lam)


class NetworkSpec:
    """Growable columnar description of an axons+neurons network."""

    def __init__(self):
        self.n_axons = 0
        self.n_neurons = 0
        # keys are optional (default: the integer id); stored per item
        self._axon_keys: List[Hashable] = []
        self._neuron_keys: List[Hashable] = []
        # packed per-neuron model tables, grown per add_neurons call
        self._theta: List[np.ndarray] = []
        self._nu: List[np.ndarray] = []
        self._lam: List[np.ndarray] = []
        self._is_lif: List[np.ndarray] = []
        self._model_gid: List[np.ndarray] = []
        self._sig_gid: Dict[Tuple, int] = {}
        self._models_by_gid: List = []
        # synapse columns, appended per connect call
        self._pre: List[np.ndarray] = []
        self._post: List[np.ndarray] = []
        self._w: List[np.ndarray] = []
        self._outputs: Optional[np.ndarray] = None
        self._cols = None               # frozen (pre, post, w) cache

    # ------------------------------------------------------------ builders
    def add_axons(self, n: int, keys: Optional[Sequence] = None
                  ) -> np.ndarray:
        """Append n axons; returns their ENCODED source ids (negative),
        ready to use as `connect` pre entries."""
        n = int(n)
        if n < 0:
            raise ValueError(f"add_axons(n={n})")
        ids = np.arange(self.n_axons, self.n_axons + n, dtype=np.int64)
        if keys is None:
            self._axon_keys.extend(ids.tolist())
        else:
            keys = list(keys)
            if len(keys) != n:
                raise ValueError(f"{len(keys)} keys for {n} axons")
            self._axon_keys.extend(keys)
        self.n_axons += n
        return encode_axon(ids)

    def add_neurons(self, n: int, model, keys: Optional[Sequence] = None
                    ) -> np.ndarray:
        """Append n neurons sharing one model (call once per model run);
        returns their neuron ids."""
        n = int(n)
        if n < 0:
            raise ValueError(f"add_neurons(n={n})")
        if not isinstance(model, (LIF_neuron, ANN_neuron)):
            raise TypeError(f"model must be LIF_neuron/ANN_neuron, "
                            f"got {type(model).__name__}")
        ids = np.arange(self.n_neurons, self.n_neurons + n, dtype=np.int64)
        if keys is None:
            self._neuron_keys.extend(ids.tolist())
        else:
            keys = list(keys)
            if len(keys) != n:
                raise ValueError(f"{len(keys)} keys for {n} neurons")
            self._neuron_keys.extend(keys)
        sig = _model_sig(model)
        gid = self._sig_gid.setdefault(sig, len(self._sig_gid))
        if gid == len(self._models_by_gid):
            self._models_by_gid.append(model)
        self._theta.append(np.full((n,), model.threshold, np.int32))
        self._nu.append(np.full((n,), model.nu, np.int32))
        self._lam.append(np.full((n,), model.lam, np.int32))
        self._is_lif.append(np.full((n,), model.kind == "LIF", bool))
        self._model_gid.append(np.full((n,), gid, np.int32))
        self.n_neurons += n
        return ids

    def connect(self, pre, post, weight) -> None:
        """Bulk synapse append: pre (encoded source ids — negative for
        axons), post (neuron ids), weight (ints), all broadcastable to a
        common 1-D shape. Per-item synapse order is the append order —
        the order the HBM mapper places records in."""
        pre = np.asarray(pre, np.int64).reshape(-1)
        post = np.asarray(post, np.int64).reshape(-1)
        w = np.asarray(weight)
        if not (np.issubdtype(w.dtype, np.integer)
                or w.dtype == np.bool_):
            raise TypeError(f"weights must be integers, got {w.dtype}")
        w = w.astype(np.int64).reshape(-1)
        # synapse records are int16 (Fig. 7 HBM layout): reject rather
        # than clip, so a weight never silently changes value between
        # the spec and the compiled artifact
        if w.size and (w.min() < W_MIN or w.max() > W_MAX):
            bad = w[(w < W_MIN) | (w > W_MAX)][0]
            raise ValueError(
                f"connect: weight {int(bad)} outside the int16 synapse "
                f"record range [{W_MIN}, {W_MAX}]")
        pre, post, w = np.broadcast_arrays(pre, post, w)
        if pre.size == 0:
            return
        is_axon, raw = decode_pre(pre)
        bad_a = is_axon & (raw >= self.n_axons)
        bad_n = (~is_axon) & (raw >= self.n_neurons)
        if bad_a.any() or bad_n.any():
            i = int(np.nonzero(bad_a | bad_n)[0][0])
            raise ValueError(f"connect: unknown pre id {int(pre[i])} "
                             f"(n_axons={self.n_axons}, "
                             f"n_neurons={self.n_neurons})")
        if post.size and (post.min() < 0 or post.max() >= self.n_neurons):
            bad = post[(post < 0) | (post >= self.n_neurons)][0]
            raise ValueError(f"connect: unknown post neuron {int(bad)} "
                             f"(n_neurons={self.n_neurons})")
        self._pre.append(np.ascontiguousarray(pre))
        self._post.append(np.ascontiguousarray(post))
        self._w.append(np.ascontiguousarray(w))
        self._cols = None

    def set_outputs(self, outputs) -> None:
        """Designate output neurons (ids, in monitor order)."""
        out = np.asarray(outputs, np.int64).reshape(-1)
        if out.size and (out.min() < 0 or out.max() >= self.n_neurons):
            bad = out[(out < 0) | (out >= self.n_neurons)][0]
            raise KeyError(f"output {int(bad)} is not a neuron")
        self._outputs = out

    # ------------------------------------------------------------- frozen
    def columns(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(pre, post, weight) as three flat arrays (append order)."""
        if self._cols is None:
            if self._pre:
                self._cols = (np.concatenate(self._pre),
                              np.concatenate(self._post),
                              np.concatenate(self._w))
            else:
                z = np.zeros((0,), np.int64)
                self._cols = (z, z.copy(), z.copy())
        return self._cols

    @property
    def n_synapses(self) -> int:
        return int(self.columns()[0].shape[0])

    @property
    def axon_keys(self) -> List[Hashable]:
        return list(self._axon_keys)

    @property
    def neuron_keys(self) -> List[Hashable]:
        return list(self._neuron_keys)

    @property
    def outputs(self) -> np.ndarray:
        return (np.zeros((0,), np.int64) if self._outputs is None
                else self._outputs.copy())

    def model_tables(self):
        """(theta, nu, lam, is_lif, model_gid) — (N,) packed arrays."""
        def cat(parts, dtype):
            return (np.concatenate(parts) if parts
                    else np.zeros((0,), dtype))
        return (cat(self._theta, np.int32), cat(self._nu, np.int32),
                cat(self._lam, np.int32), cat(self._is_lif, bool),
                cat(self._model_gid, np.int32))

    @property
    def models_by_gid(self) -> List:
        return list(self._models_by_gid)

    # -------------------------------------------------------- legacy door
    @classmethod
    def from_dicts(cls, axons: Dict, neurons: Dict, outputs: Sequence
                   ) -> "NetworkSpec":
        """Ingest the legacy dict format:

            axons   = {key: [(post_key, w), ...]}
            neurons = {key: ([(post_key, w), ...], model)}
            outputs = [neuron_key, ...]

        Ids follow dict insertion order (the legacy rule); per-item
        synapse order follows the per-key lists, so compiling this spec
        reproduces the legacy `CRI_network` HBM image bit for bit."""
        spec = cls()
        axon_keys = list(axons.keys())
        neuron_keys = list(neurons.keys())
        nid = {k: i for i, k in enumerate(neuron_keys)}
        ax_ids = spec.add_axons(len(axon_keys), keys=axon_keys)
        # group consecutive same-model neurons into one bulk add
        run_start = 0
        models = [neurons[k][1] for k in neuron_keys]
        for i in range(1, len(neuron_keys) + 1):
            if i == len(neuron_keys) or models[i] != models[run_start]:
                spec.add_neurons(i - run_start, models[run_start],
                                 keys=neuron_keys[run_start:i])
                run_start = i
        pre_parts: List[np.ndarray] = []
        post_parts: List[np.ndarray] = []
        w_parts: List[np.ndarray] = []

        def ingest(pre_id, syns):
            if not syns:
                return
            pre_parts.append(np.full((len(syns),), pre_id, np.int64))
            post_parts.append(np.asarray([nid[p] for p, _ in syns],
                                         np.int64))
            w_parts.append(np.asarray([int(w) for _, w in syns], np.int64))

        for i, k in enumerate(axon_keys):
            ingest(int(ax_ids[i]), axons[k])
        for i, k in enumerate(neuron_keys):
            ingest(i, neurons[k][0])
        if pre_parts:
            spec.connect(np.concatenate(pre_parts),
                         np.concatenate(post_parts),
                         np.concatenate(w_parts))
        out_ids = []
        for k in outputs:
            if k not in nid:
                raise KeyError(f"output {k!r} is not a neuron")
            out_ids.append(nid[k])
        spec.set_outputs(out_ids)
        return spec
