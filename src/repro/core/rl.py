"""Reinforcement-learning pipeline — the DVS Pong row of Table 2 (§6,
fourth experiment family).

The paper trains a DQN on Atari Pong with a DVS-style input representation
(frame differencing into ON/OFF event channels), converts it to an SNN and
deploys it on the hardware, reporting the mean score over 50 episodes.
Atari is not available offline, so the environment is a DVS-style *catch*
game with the same observation construction (2-channel ON/OFF pixel-change
events between consecutive frames) and the same pipeline:

  DQN (replay buffer, target network, ε-greedy)  →  int16 quantization
  →  A.2 conversion  →  event-driven engine  →  greedy policy from output
  membrane potentials  →  mean episode score, engine vs software (exact).

The Q-network uses binary activations (QAT) so the conversion is bit-exact
single-step — the deterministic counterpart of the paper's rate-coded
IF conversion (rate coding itself is exercised by core/spiking.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convert import (LayerSpec, QATModel, apply_quantized,
                                infer_image, quantize)


@dataclass
class CatchEnv:
    """Ball falls; paddle catches. Observation: 2-channel ON/OFF event
    frame (pixel-change between consecutive raw frames, threshold-style —
    the paper's DVS construction)."""
    W: int = 9
    H: int = 9

    def reset(self, rng):
        self.ball_x = int(rng.integers(0, self.W))
        self.ball_y = 0
        self.pad_x = self.W // 2
        self.t = 0
        self.prev = self._raw()
        return self._obs()

    def _raw(self):
        # the paddle pixel blinks every frame (DVS sensors see flicker), so
        # a stationary paddle still emits events — without this, pure
        # frame-difference observations make the task unobservable
        f = np.zeros((self.H, self.W), bool)
        f[self.ball_y, self.ball_x] = True
        if self.t % 2 == 0:
            f[self.H - 1, self.pad_x] = True
        return f

    def _obs(self):
        cur = self._raw()
        on = cur & ~self.prev
        off = self.prev & ~cur
        self.prev = cur
        return np.stack([on, off]).astype(np.float32)   # (2, H, W)

    def step(self, action: int):
        self.pad_x = int(np.clip(self.pad_x + (action - 1), 0, self.W - 1))
        self.ball_y += 1
        self.t += 1
        done = self.ball_y >= self.H - 1
        reward = 0.0
        if done:
            reward = 1.0 if self.pad_x == self.ball_x else -1.0
        return self._obs(), reward, done

    @property
    def n_actions(self):
        return 3


def make_qnet(env: CatchEnv) -> QATModel:
    return QATModel(input_shape=(2, env.H, env.W),
                    layers=[LayerSpec("dense", out_features=64)],
                    n_classes=env.n_actions)


def train_dqn(env: CatchEnv, *, episodes=400, gamma=0.9, lr=1e-3,
              batch=64, buffer_cap=5000, target_sync=100, seed=0,
              verbose=False):
    """Standard DQN (the paper's §6 protocol, scaled down)."""
    rng = np.random.default_rng(seed)
    model = make_qnet(env)
    params = model.init(jax.random.PRNGKey(seed))
    target = jax.tree.map(lambda a: a, params)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def q_loss(p, tp, s, a, r, s2, done):
        q = model.apply(p, s)
        qa = jnp.take_along_axis(q, a[:, None], 1)[:, 0]
        q2 = jnp.max(model.apply(tp, s2), axis=1)
        tgt = r + gamma * q2 * (1.0 - done)
        return jnp.mean((qa - jax.lax.stop_gradient(tgt)) ** 2)

    @jax.jit
    def update(p, tp, m, v, t, s, a, r, s2, done):
        l, g = jax.value_and_grad(q_loss)(p, tp, s, a, r, s2, done)
        m = jax.tree.map(lambda x, y: 0.9 * x + 0.1 * y, m, g)
        v = jax.tree.map(lambda x, y: 0.999 * x + 0.001 * y * y, v, g)
        p = jax.tree.map(
            lambda pp, mm, vv: pp - lr * (mm / (1 - 0.9 ** t))
            / (jnp.sqrt(vv / (1 - 0.999 ** t)) + 1e-8), p, m, v)
        return p, m, v, l

    @jax.jit
    def act_q(p, s):
        return model.apply(p, s[None])[0]

    buf = []
    t = 0
    for ep in range(episodes):
        s = env.reset(rng)
        done = False
        eps = max(0.05, 1.0 - ep / (episodes * 0.6))
        while not done:
            if rng.random() < eps:
                a = int(rng.integers(0, env.n_actions))
            else:
                a = int(np.argmax(np.asarray(act_q(params,
                                                   jnp.asarray(s)))))
            s2, r, done = env.step(a)
            buf.append((s, a, r, s2, float(done)))
            if len(buf) > buffer_cap:
                buf.pop(0)
            s = s2
            if len(buf) >= batch:
                idx = rng.integers(0, len(buf), batch)
                bs, ba, br, bs2, bd = map(np.stack,
                                          zip(*[buf[i] for i in idx]))
                t += 1
                params, m, v, l = update(
                    params, target, m, v, jnp.float32(t),
                    jnp.asarray(bs), jnp.asarray(ba), jnp.asarray(br),
                    jnp.asarray(bs2), jnp.asarray(bd))
                if t % target_sync == 0:
                    target = jax.tree.map(lambda a_: a_, params)
        if verbose and ep % 100 == 0:
            print(f"ep {ep}: eps={eps:.2f} buffer={len(buf)}")
    return model, params


def evaluate(env, policy, episodes=50, seed=100):
    rng = np.random.default_rng(seed)
    total = 0.0
    for _ in range(episodes):
        s = env.reset(rng)
        done = False
        while not done:
            a = policy(s)
            s, r, done = env.step(a)
        total += r
    return total / episodes


def software_policy(model, qparams):
    def policy(s):
        q = apply_quantized(model, qparams, s[None].astype(np.int64))[0]
        return int(np.argmax(q))
    return policy


def engine_policy(net, out_keys, model):
    def policy(s):
        pred, _ = infer_image(net, s, model, out_keys)
        return pred
    return policy
