"""hs_api-compatible user interface — §5.2 and Appendix A.1.

    from repro.core.api import CRI_network, LIF_neuron, ANN_neuron

    lif = LIF_neuron(threshold=3, nu=-32, lam=60)
    axons   = {"alpha": [("a", 3), ("c", 2)], "beta": [("b", 3)]}
    neurons = {"a": ([("b", 1), ("a", 2)], lif),
               "b": ([], lif),
               "c": ([], LIF_neuron(threshold=4, nu=-32, lam=2)),
               "d": ([("c", 1)], ANN_neuron(threshold=5, nu=0))}
    outputs = ["a", "b"]
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs)
    fired = net.step(["alpha", "beta"])

`CRI_network` is now a thin key-space facade over the staged
build→compile→deploy pipeline:

    spec     = NetworkSpec.from_dicts(axons, neurons, outputs)  # stage 1
    compiled = compile_spec(spec, target=backend, ...)          # stage 2
    dep      = deploy(compiled, seed=...)                       # stage 3

so the dict constructor, `CRI_network.from_spec(spec)` (columnar bulk
construction — the scalable path), and `CRI_network.from_compiled(...)`
(a saved artifact) all produce bit-identical networks. The same API
runs on the dense software simulator (local development), the
event-driven HBM engine (the accelerator path, with energy/latency
accounting), the hierarchical multi-core HiAER tier (per-core HBM
shards with level-aware spike exchange and measured NoC/FireFly/
Ethernet traffic), or the device-mesh tier (the same per-core shards
executed under shard_map with each jax device owning only its cores'
state and weights, spike exchange as hierarchical all_gather
collectives over bit-packed uint32 presence words — `packed=False`
falls back to int32 event lanes, bit-exact either way; `n_devices`
picks the mesh width) — backend="simulator" | "engine" | "hiaer" |
"mesh". Results are bit-identical across all four
(tests/test_api.py, tests/test_hiaer.py, tests/test_staged_api.py,
tests/test_mesh_runtime.py); this mirrors the paper's seamless
local-to-cluster transition.

The hiaer backend takes a `partition.Hierarchy` (`hierarchy=...`) plus
optional explicit placements (`placement={neuron_key: core_id}`,
`axon_placement={axon_key: core_id}`; id-keyed when constructing from a
spec/compiled artifact); by default neurons are placed by the
locality-first BFS partitioner and axons home with the majority of
their targets.

Batched execution (all backends, bit-exact vs the per-step loop):

    fired_per_step = net.run(schedule)        # T steps, one lax.scan
    spikes = net.run_batch(batch_schedules)   # (B, T, n_outputs) bool

`run` takes a length-T sequence of axon-key lists (or a (T, A) int32
event-count array) and advances the network exactly as T `step` calls
would, counter included. `run_batch` evaluates B independent samples per
dispatch (each from V = 0 under PRNG stream fold_in(key, sample)) — the
Table-2 evaluation path (core.spiking.infer_frames_batch).

Synapse access is indexed, not scanned: scalar `read_synapse`/
`write_synapse` keep the A.1 signatures, and the batched
`read_synapses`/`write_synapses` apply a whole update set as ONE
backend upload (core.deploy) — the practical path for host-side
plasticity (learning.STDP) on every backend including hiaer.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched
from repro.core.compile import CompiledNetwork, compile_spec
from repro.core.costmodel import AccessCounter
from repro.core.deploy import Deployment, MissingSynapseError, deploy
from repro.core.neuron import ANN_neuron, LIF_neuron
from repro.core.partition import Hierarchy
from repro.core.spec import NetworkSpec, encode_axon

__all__ = ["CRI_network", "LIF_neuron", "ANN_neuron", "Hierarchy",
           "NetworkSpec"]


class CRI_network:
    def __init__(self, axons: Optional[Dict] = None,
                 neurons: Optional[Dict] = None,
                 outputs: Optional[Sequence] = None,
                 backend: str = "engine", seed: int = 0,
                 dense_pack: bool = True, vectorized: bool = True,
                 use_pallas: bool = False,
                 hierarchy: Optional[Hierarchy] = None,
                 placement: Optional[Dict] = None,
                 axon_placement: Optional[Dict] = None,
                 spec: Optional[NetworkSpec] = None,
                 compiled: Optional[CompiledNetwork] = None,
                 n_devices: Optional[int] = None,
                 packed: bool = True):
        if compiled is None:
            if spec is None:
                if axons is None or neurons is None or outputs is None:
                    raise TypeError("CRI_network needs either "
                                    "axons/neurons/outputs dicts, "
                                    "spec=..., or compiled=...")
                spec = NetworkSpec.from_dicts(axons, neurons, outputs)
                # legacy placement dicts are key-space; translate here
                nid = {k: i for i, k in enumerate(spec.neuron_keys)}
                aid = {k: i for i, k in enumerate(spec.axon_keys)}
                if placement is not None:
                    placement = {nid[k]: int(c)
                                 for k, c in placement.items()}
                if axon_placement is not None:
                    axon_placement = {aid[k]: int(c)
                                      for k, c in axon_placement.items()}
            compiled = compile_spec(spec, target=backend,
                                    dense_pack=dense_pack,
                                    hierarchy=hierarchy,
                                    placement=placement,
                                    axon_placement=axon_placement)
        # a prebuilt artifact fixes the backend (its target)
        self.backend = compiled.target
        self.compiled = compiled
        self._dep: Deployment = deploy(compiled, seed=seed,
                                       vectorized=vectorized,
                                       use_pallas=use_pallas,
                                       n_devices=n_devices,
                                       packed=packed)
        self._impl = self._dep.impl
        self.counter: Optional[AccessCounter] = self._dep.counter
        self.image = compiled.image
        self.axon_keys = list(compiled.axon_keys)
        self.neuron_keys = list(compiled.neuron_keys)
        self._aid = {k: i for i, k in enumerate(self.axon_keys)}
        self._nid = {k: i for i, k in enumerate(self.neuron_keys)}
        self.outputs = [self.neuron_keys[i] for i in compiled.outputs]
        self._syn_cache: Optional[Tuple[Dict, Dict]] = None

    # ------------------------------------------------------- construction
    @classmethod
    def from_spec(cls, spec: NetworkSpec, backend: str = "engine",
                  **kwargs) -> "CRI_network":
        """Build from a columnar `NetworkSpec` (bulk array construction
        — the scalable front door). placement/axon_placement kwargs are
        id-keyed here."""
        return cls(spec=spec, backend=backend, **kwargs)

    @classmethod
    def from_compiled(cls, compiled: CompiledNetwork,
                      **kwargs) -> "CRI_network":
        """Wrap an already-compiled (possibly `CompiledNetwork.load`ed)
        artifact; backend comes from the artifact's target."""
        kwargs.setdefault("backend", compiled.target)
        return cls(compiled=compiled, **kwargs)

    # ------------------------------------------------------------- running
    def step(self, inputs: Sequence = (), membranePotential: bool = False):
        """Run one timestep with the given axon keys active. Returns the
        keys of output neurons that spiked (plus all membrane potentials
        when membranePotential=True)."""
        ids = [self._aid[k] for k in inputs]
        spikes = np.asarray(self._impl.step(ids))
        fired = [k for k in self.outputs if spikes[self._nid[k]]]
        if membranePotential:
            V = np.asarray(self._impl.V)
            return fired, [(k, int(V[self._nid[k]]))
                           for k in self.neuron_keys]
        return fired

    def reset(self):
        self._impl.reset()

    # ----------------------------------------------------- batched running
    def _encode_schedule(self, schedule) -> np.ndarray:
        """Length-T sequence of axon-key sequences -> (T, A) int32 event
        counts via the shared core.schedule encoder (an axon listed twice
        in a step is driven twice, the event queue semantics). Unknown
        axon keys raise KeyError; pre-encoded count arrays are validated,
        never re-interpreted."""
        if isinstance(schedule, (np.ndarray, jnp.ndarray)) \
                and schedule.dtype != object:
            if schedule.ndim != 2:
                raise ValueError(
                    f"count-array schedule must be 2-D (T, A), "
                    f"got shape {schedule.shape}")
            return sched.encode_schedule(schedule, len(self.axon_keys))
        return sched.encode_schedule(
            [[self._aid[k] for k in keys] for keys in schedule],
            len(self.axon_keys))

    def run(self, schedule) -> List[List]:
        """T timesteps in one backend dispatch (lax.scan on all
        backends). schedule: length-T sequence of axon-key sequences, or
        a (T, A) int32 count array (A = len(axon_keys), axon order =
        insertion order). Returns the per-step fired output keys —
        exactly what T `step` calls would return, state and access
        counter included."""
        counts = self._encode_schedule(schedule)
        spikes = self._impl.run(self._pad_axons(counts))
        return [[k for k in self.outputs if spikes[t, self._nid[k]]]
                for t in range(counts.shape[0])]

    def run_batch(self, schedules) -> np.ndarray:
        """B samples × T timesteps per dispatch (vmap over the scan).
        schedules: (B, T, A) int32 counts or a length-B sequence of
        `run`-style schedules. Each sample starts from V = 0 under an
        independent PRNG stream (fold_in(key, sample)); the network's own
        membrane state and last-spike record are untouched, but the PRNG
        key advances once (so a later batch draws fresh streams — noisy
        sequential stepping after a run_batch therefore continues from a
        different stream). Returns (B, T, n_outputs) bool output-neuron
        spikes, ordered like `self.outputs`."""
        if len(schedules) == 0:
            return np.zeros((0, 0, len(self.outputs)), bool)
        if isinstance(schedules, (np.ndarray, jnp.ndarray)) \
                and schedules.dtype != object and schedules.ndim == 3:
            counts = sched.encode_schedule(schedules, len(self.axon_keys))
        else:
            counts = np.stack([self._encode_schedule(s) for s in schedules])
        spikes = self._impl.run_batch(self._pad_axons(counts))
        out_ids = np.asarray([self._nid[k] for k in self.outputs])
        return spikes[..., out_ids]

    def _pad_axons(self, counts: np.ndarray) -> np.ndarray:
        """Validate the schedule width (must be exactly len(axon_keys)),
        then pad only for the empty-network case: the engine's flattened
        axon table is never narrower than 1 slot."""
        if counts.shape[-1] != len(self.axon_keys):
            raise ValueError(
                f"schedule width {counts.shape[-1]} != number of axons "
                f"{len(self.axon_keys)}")
        return sched.pad_width(counts, self._dep.n_axon_slots)

    # ------------------------------------------------------------ synapses
    def _encode_pre(self, keys) -> np.ndarray:
        """Key sequence -> encoded source ids (axon keys win the
        namespace, matching the legacy scan order: an axon and a neuron
        sharing a key resolve to the axon)."""
        out = np.empty((len(keys),), np.int64)
        for i, k in enumerate(keys):
            if k in self._aid:
                out[i] = encode_axon(self._aid[k])
            else:
                out[i] = self._nid[k]       # KeyError on unknown keys
        return out

    @staticmethod
    def _missing_key(seq, index):
        """Map a missing-pair index (position in the BROADCAST pair
        array) back to the user's key: a length-1 sequence was
        broadcast, so every index refers to its only element."""
        seq = list(seq)
        return seq[index] if len(seq) > 1 else seq[0]

    def read_synapses(self, pres: Sequence, posts: Sequence) -> np.ndarray:
        """Batched synapse read (one gather): current weight of each
        (pre, post) key pair. KeyError on any missing synapse."""
        pre = self._encode_pre(list(pres))
        post = np.asarray([self._nid[k] for k in posts], np.int64)
        try:
            return self._dep.read_synapses(pre, post)
        except MissingSynapseError as e:
            raise KeyError(f"no synapse "
                           f"{self._missing_key(pres, e.index)!r}->"
                           f"{self._missing_key(posts, e.index)!r}") \
                from None

    def write_synapses(self, pres: Sequence, posts: Sequence,
                       weights) -> None:
        """Batched synapse write, applied as ONE backend weight upload /
        re-shard (the PCIe-batch path that makes host-side plasticity
        practical). All pairs are validated before anything mutates;
        KeyError on any missing synapse."""
        pre = self._encode_pre(list(pres))
        post = np.asarray([self._nid[k] for k in posts], np.int64)
        try:
            self._dep.write_synapses(pre, post, np.asarray(weights))
        except MissingSynapseError as e:
            raise KeyError(f"no synapse "
                           f"{self._missing_key(pres, e.index)!r}->"
                           f"{self._missing_key(posts, e.index)!r}") \
                from None
        self._syn_cache = None

    def read_synapse(self, pre, post) -> int:
        return int(self.read_synapses([pre], [post])[0])

    def write_synapse(self, pre, post, weight: int):
        self.write_synapses([pre], [post], [int(weight)])

    def read_membrane(self, *keys) -> List[int]:
        V = np.asarray(self._impl.V)
        return [int(V[self._nid[k]]) for k in keys]

    # ----------------------------------------------- legacy introspection
    def _syn_dicts(self) -> Tuple[Dict, Dict]:
        """Materialize the legacy id-keyed adjacency dicts
        {axon_id: [(post_id, w), ...]} / {neuron_id: [...]} from the
        columns (current weights). Kept for introspection-style callers;
        rebuilt after weight writes."""
        if self._syn_cache is None:
            c = self.compiled
            axon_syn: Dict[int, List] = {i: [] for i in
                                         range(len(self.axon_keys))}
            neuron_syn: Dict[int, List] = {i: [] for i in
                                           range(len(self.neuron_keys))}
            item = c.syn_item
            base = c.item_base
            for it, p, w in zip(item.tolist(), c.syn_post.tolist(),
                                c.syn_weight.tolist()):
                if it < base:
                    axon_syn[it].append((p, w))
                else:
                    neuron_syn[it - base].append((p, w))
            self._syn_cache = (axon_syn, neuron_syn)
        return self._syn_cache

    @property
    def _axon_syn(self) -> Dict[int, List]:
        return self._syn_dicts()[0]

    @property
    def _neuron_syn(self) -> Dict[int, List]:
        return self._syn_dicts()[1]
