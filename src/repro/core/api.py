"""hs_api-compatible user interface — §5.2 and Appendix A.1.

    from repro.core.api import CRI_network, LIF_neuron, ANN_neuron

    lif = LIF_neuron(threshold=3, nu=-32, lam=60)
    axons   = {"alpha": [("a", 3), ("c", 2)], "beta": [("b", 3)]}
    neurons = {"a": ([("b", 1), ("a", 2)], lif),
               "b": ([], lif),
               "c": ([], LIF_neuron(threshold=4, nu=-32, lam=2)),
               "d": ([("c", 1)], ANN_neuron(threshold=5, nu=0))}
    outputs = ["a", "b"]
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs)
    fired = net.step(["alpha", "beta"])

The same API runs on the dense software simulator (local development), the
event-driven HBM engine (the accelerator path, with energy/latency
accounting), or the hierarchical multi-core HiAER tier (per-core HBM
shards with level-aware spike exchange and measured NoC/FireFly/Ethernet
traffic) — backend="simulator" | "engine" | "hiaer". Results are
bit-identical across all three (tests/test_api.py, tests/test_hiaer.py);
this mirrors the paper's seamless local-to-cluster transition.

The hiaer backend takes a `partition.Hierarchy` (`hierarchy=...`) plus
optional explicit placements (`placement={neuron_key: core_id}`,
`axon_placement={axon_key: core_id}`); by default neurons are placed by
the locality-first BFS partitioner and axons home with the majority of
their targets.

Batched execution (both backends, bit-exact vs the per-step loop):

    fired_per_step = net.run(schedule)        # T steps, one lax.scan
    spikes = net.run_batch(batch_schedules)   # (B, T, n_outputs) bool

`run` takes a length-T sequence of axon-key lists (or a (T, A) int32
event-count array) and advances the network exactly as T `step` calls
would, counter included. `run_batch` evaluates B independent samples per
dispatch (each from V = 0 under PRNG stream fold_in(key, sample)) — the
Table-2 evaluation path (core.spiking.infer_frames_batch).
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import hbm
from repro.core import schedule as sched
from repro.core.costmodel import AccessCounter
from repro.core.engine import EventEngine
from repro.core.hiaer import HiAERNetwork
from repro.core.neuron import ANN_neuron, LIF_neuron, pack_models
from repro.core.partition import Hierarchy
from repro.core.simulator import DenseSimulator

__all__ = ["CRI_network", "LIF_neuron", "ANN_neuron", "Hierarchy"]


class CRI_network:
    def __init__(self, axons: Dict, neurons: Dict, outputs: Sequence,
                 backend: str = "engine", seed: int = 0,
                 dense_pack: bool = True, vectorized: bool = True,
                 use_pallas: bool = False,
                 hierarchy: Optional[Hierarchy] = None,
                 placement: Optional[Dict] = None,
                 axon_placement: Optional[Dict] = None):
        self.axon_keys = list(axons.keys())
        self.neuron_keys = list(neurons.keys())
        self._aid = {k: i for i, k in enumerate(self.axon_keys)}
        self._nid = {k: i for i, k in enumerate(self.neuron_keys)}
        self.outputs = list(outputs)
        for k in self.outputs:
            if k not in self._nid:
                raise KeyError(f"output {k!r} is not a neuron")
        A, N = len(self.axon_keys), len(self.neuron_keys)

        models = []
        neuron_syn: Dict[int, List[Tuple[int, int]]] = {}
        for k in self.neuron_keys:
            syns, model = neurons[k]
            models.append(model)
            neuron_syn[self._nid[k]] = [(self._nid[p], int(w))
                                        for p, w in syns]
        axon_syn = {self._aid[k]: [(self._nid[p], int(w))
                                   for p, w in axons[k]]
                    for k in self.axon_keys}
        theta, nu, lam, is_lif = pack_models(models)
        self._theta, self._nu, self._lam, self._is_lif = theta, nu, lam, is_lif
        self._axon_syn, self._neuron_syn = axon_syn, neuron_syn
        self.backend = backend
        out_ids = [self._nid[k] for k in self.outputs]
        # distinct model-parameter tuples define the model groups in HBM
        sig = {}
        model_ids = {}
        for i, m in enumerate(models):
            s = (m.kind, m.threshold, m.nu, m.lam)
            model_ids[i] = sig.setdefault(s, len(sig))
        self._model_ids = model_ids

        if backend == "simulator":
            axonW = np.zeros((A, N), np.int32)
            for a, syns in axon_syn.items():
                for p, w in syns:
                    axonW[a, p] += w
            neuronW = np.zeros((N, N), np.int32)
            for n, syns in neuron_syn.items():
                for p, w in syns:
                    neuronW[n, p] += w
            self._impl = DenseSimulator(axonW, neuronW, theta, nu, lam,
                                        is_lif, seed=seed)
            self.counter: Optional[AccessCounter] = None
        elif backend == "engine":
            image = hbm.compile_network(axon_syn, neuron_syn, model_ids,
                                        out_ids, N, dense_pack=dense_pack)
            self.image = image
            self._impl = EventEngine(image, theta, nu, lam, is_lif, N,
                                     out_ids, seed=seed,
                                     vectorized=vectorized,
                                     use_pallas=use_pallas)
            self.counter = self._impl.counter
        elif backend == "hiaer":
            image = hbm.compile_network(axon_syn, neuron_syn, model_ids,
                                        out_ids, N, dense_pack=dense_pack)
            self.image = image
            pl = None if placement is None else \
                {self._nid[k]: int(c) for k, c in placement.items()}
            apl = None if axon_placement is None else \
                {self._aid[k]: int(c) for k, c in axon_placement.items()}
            self._impl = HiAERNetwork(image, theta, nu, lam, is_lif, N,
                                      out_ids, axon_syn=axon_syn,
                                      neuron_syn=neuron_syn,
                                      hierarchy=hierarchy, placement=pl,
                                      axon_placement=apl, seed=seed)
            self.counter = self._impl.counter
        else:
            raise ValueError(f"unknown backend {backend!r}")

    # ------------------------------------------------------------- running
    def step(self, inputs: Sequence = (), membranePotential: bool = False):
        """Run one timestep with the given axon keys active. Returns the
        keys of output neurons that spiked (plus all membrane potentials
        when membranePotential=True)."""
        ids = [self._aid[k] for k in inputs]
        spikes = np.asarray(self._impl.step(ids))
        fired = [k for k in self.outputs if spikes[self._nid[k]]]
        if membranePotential:
            V = np.asarray(self._impl.V)
            return fired, [(k, int(V[self._nid[k]]))
                           for k in self.neuron_keys]
        return fired

    def reset(self):
        self._impl.reset()

    # ----------------------------------------------------- batched running
    def _encode_schedule(self, schedule) -> np.ndarray:
        """Length-T sequence of axon-key sequences -> (T, A) int32 event
        counts via the shared core.schedule encoder (an axon listed twice
        in a step is driven twice, the event queue semantics). Unknown
        axon keys raise KeyError; pre-encoded count arrays are validated,
        never re-interpreted."""
        if isinstance(schedule, (np.ndarray, jnp.ndarray)) \
                and schedule.dtype != object:
            if schedule.ndim != 2:
                raise ValueError(
                    f"count-array schedule must be 2-D (T, A), "
                    f"got shape {schedule.shape}")
            return sched.encode_schedule(schedule, len(self.axon_keys))
        return sched.encode_schedule(
            [[self._aid[k] for k in keys] for keys in schedule],
            len(self.axon_keys))

    def run(self, schedule) -> List[List]:
        """T timesteps in one backend dispatch (lax.scan on both backends).
        schedule: length-T sequence of axon-key sequences, or a (T, A)
        int32 count array (A = len(axon_keys), axon order = insertion
        order). Returns the per-step fired output keys — exactly what T
        `step` calls would return, state and access counter included."""
        counts = self._encode_schedule(schedule)
        spikes = self._impl.run(self._pad_axons(counts))
        return [[k for k in self.outputs if spikes[t, self._nid[k]]]
                for t in range(counts.shape[0])]

    def run_batch(self, schedules) -> np.ndarray:
        """B samples × T timesteps per dispatch (vmap over the scan).
        schedules: (B, T, A) int32 counts or a length-B sequence of
        `run`-style schedules. Each sample starts from V = 0 under an
        independent PRNG stream (fold_in(key, sample)); the network's own
        membrane state and last-spike record are untouched, but the PRNG
        key advances once (so a later batch draws fresh streams — noisy
        sequential stepping after a run_batch therefore continues from a
        different stream). Returns (B, T, n_outputs) bool output-neuron
        spikes, ordered like `self.outputs`."""
        if len(schedules) == 0:
            return np.zeros((0, 0, len(self.outputs)), bool)
        if isinstance(schedules, (np.ndarray, jnp.ndarray)) \
                and schedules.dtype != object and schedules.ndim == 3:
            counts = sched.encode_schedule(schedules, len(self.axon_keys))
        else:
            counts = np.stack([self._encode_schedule(s) for s in schedules])
        spikes = self._impl.run_batch(self._pad_axons(counts))
        out_ids = np.asarray([self._nid[k] for k in self.outputs])
        return spikes[..., out_ids]

    def _pad_axons(self, counts: np.ndarray) -> np.ndarray:
        """Validate the schedule width (must be exactly len(axon_keys)),
        then pad only for the empty-network case: the engine's flattened
        axon table is never narrower than 1 slot."""
        if counts.shape[-1] != len(self.axon_keys):
            raise ValueError(
                f"schedule width {counts.shape[-1]} != number of axons "
                f"{len(self.axon_keys)}")
        want = getattr(self._impl, "n_axon_slots", counts.shape[-1])
        return sched.pad_width(counts, want)

    # ------------------------------------------------------------ synapses
    def read_synapse(self, pre, post) -> int:
        pid = self._nid[post]
        if pre in self._aid:
            table = self._axon_syn[self._aid[pre]]
        else:
            table = self._neuron_syn[self._nid[pre]]
        for p, w in table:
            if p == pid:
                return w
        raise KeyError(f"no synapse {pre!r}->{post!r}")

    def write_synapse(self, pre, post, weight: int):
        pid = self._nid[post]
        if pre in self._aid:
            table = self._axon_syn[self._aid[pre]]
        else:
            table = self._neuron_syn[self._nid[pre]]
        for i, (p, w) in enumerate(table):
            if p == pid:
                old = w
                table[i] = (p, int(weight))
                break
        else:
            raise KeyError(f"no synapse {pre!r}->{post!r}")
        # apply to the backend storage in place
        if self.backend == "simulator":
            if pre in self._aid:
                self._impl.axonW = self._impl.axonW.at[
                    self._aid[pre], pid].add(int(weight) - old)
            else:
                self._impl.neuronW = self._impl.neuronW.at[
                    self._nid[pre], pid].add(int(weight) - old)
        else:
            img = self.image
            ptr = (img.axon_ptr[self._aid[pre]] if pre in self._aid
                   else img.neuron_ptr[self._nid[pre]])
            rows = slice(ptr.base_row, ptr.base_row + ptr.n_rows)
            slot = pid % hbm.SLOTS
            col_post = img.syn_post[rows, slot]
            hit = np.nonzero(col_post == pid)[0]
            img.syn_weight[ptr.base_row + hit[0], slot] = np.int16(weight)
            self._impl.update_weights(img.syn_weight)

    def read_membrane(self, *keys) -> List[int]:
        V = np.asarray(self._impl.V)
        return [int(V[self._nid[k]]) for k in keys]
