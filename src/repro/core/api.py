"""hs_api-compatible user interface — §5.2 and Appendix A.1.

    from repro.core.api import CRI_network, LIF_neuron, ANN_neuron

    lif = LIF_neuron(threshold=3, nu=-32, lam=60)
    axons   = {"alpha": [("a", 3), ("c", 2)], "beta": [("b", 3)]}
    neurons = {"a": ([("b", 1), ("a", 2)], lif),
               "b": ([], lif),
               "c": ([], LIF_neuron(threshold=4, nu=-32, lam=2)),
               "d": ([("c", 1)], ANN_neuron(threshold=5, nu=0))}
    outputs = ["a", "b"]
    net = CRI_network(axons=axons, neurons=neurons, outputs=outputs)
    fired = net.step(["alpha", "beta"])

The same API runs on the dense software simulator (local development) or the
event-driven HBM engine (the accelerator path, with energy/latency
accounting) — backend="simulator" | "engine". Results are bit-identical
(tests/test_api.py); this mirrors the paper's seamless local-to-cluster
transition.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import hbm
from repro.core.costmodel import AccessCounter
from repro.core.engine import EventEngine
from repro.core.neuron import ANN_neuron, LIF_neuron, pack_models
from repro.core.simulator import DenseSimulator

__all__ = ["CRI_network", "LIF_neuron", "ANN_neuron"]


class CRI_network:
    def __init__(self, axons: Dict, neurons: Dict, outputs: Sequence,
                 backend: str = "engine", seed: int = 0,
                 dense_pack: bool = True):
        self.axon_keys = list(axons.keys())
        self.neuron_keys = list(neurons.keys())
        self._aid = {k: i for i, k in enumerate(self.axon_keys)}
        self._nid = {k: i for i, k in enumerate(self.neuron_keys)}
        self.outputs = list(outputs)
        for k in self.outputs:
            if k not in self._nid:
                raise KeyError(f"output {k!r} is not a neuron")
        A, N = len(self.axon_keys), len(self.neuron_keys)

        models = []
        neuron_syn: Dict[int, List[Tuple[int, int]]] = {}
        for k in self.neuron_keys:
            syns, model = neurons[k]
            models.append(model)
            neuron_syn[self._nid[k]] = [(self._nid[p], int(w))
                                        for p, w in syns]
        axon_syn = {self._aid[k]: [(self._nid[p], int(w))
                                   for p, w in axons[k]]
                    for k in self.axon_keys}
        theta, nu, lam, is_lif = pack_models(models)
        self._theta, self._nu, self._lam, self._is_lif = theta, nu, lam, is_lif
        self._axon_syn, self._neuron_syn = axon_syn, neuron_syn
        self.backend = backend
        out_ids = [self._nid[k] for k in self.outputs]
        # distinct model-parameter tuples define the model groups in HBM
        sig = {}
        model_ids = {}
        for i, m in enumerate(models):
            s = (m.kind, m.threshold, m.nu, m.lam)
            model_ids[i] = sig.setdefault(s, len(sig))
        self._model_ids = model_ids

        if backend == "simulator":
            axonW = np.zeros((A, N), np.int32)
            for a, syns in axon_syn.items():
                for p, w in syns:
                    axonW[a, p] += w
            neuronW = np.zeros((N, N), np.int32)
            for n, syns in neuron_syn.items():
                for p, w in syns:
                    neuronW[n, p] += w
            self._impl = DenseSimulator(axonW, neuronW, theta, nu, lam,
                                        is_lif, seed=seed)
            self.counter: Optional[AccessCounter] = None
        elif backend == "engine":
            image = hbm.compile_network(axon_syn, neuron_syn, model_ids,
                                        out_ids, N, dense_pack=dense_pack)
            self.image = image
            self._impl = EventEngine(image, theta, nu, lam, is_lif, N,
                                     out_ids, seed=seed)
            self.counter = self._impl.counter
        else:
            raise ValueError(f"unknown backend {backend!r}")

    # ------------------------------------------------------------- running
    def step(self, inputs: Sequence = (), membranePotential: bool = False):
        """Run one timestep with the given axon keys active. Returns the
        keys of output neurons that spiked (plus all membrane potentials
        when membranePotential=True)."""
        ids = [self._aid[k] for k in inputs]
        spikes = np.asarray(self._impl.step(ids))
        fired = [k for k in self.outputs if spikes[self._nid[k]]]
        if membranePotential:
            V = np.asarray(self._impl.V)
            return fired, [(k, int(V[self._nid[k]]))
                           for k in self.neuron_keys]
        return fired

    def reset(self):
        self._impl.reset()

    # ------------------------------------------------------------ synapses
    def read_synapse(self, pre, post) -> int:
        pid = self._nid[post]
        if pre in self._aid:
            table = self._axon_syn[self._aid[pre]]
        else:
            table = self._neuron_syn[self._nid[pre]]
        for p, w in table:
            if p == pid:
                return w
        raise KeyError(f"no synapse {pre!r}->{post!r}")

    def write_synapse(self, pre, post, weight: int):
        pid = self._nid[post]
        if pre in self._aid:
            table = self._axon_syn[self._aid[pre]]
        else:
            table = self._neuron_syn[self._nid[pre]]
        for i, (p, w) in enumerate(table):
            if p == pid:
                old = w
                table[i] = (p, int(weight))
                break
        else:
            raise KeyError(f"no synapse {pre!r}->{post!r}")
        # apply to the backend storage in place
        if self.backend == "simulator":
            if pre in self._aid:
                self._impl.axonW = self._impl.axonW.at[
                    self._aid[pre], pid].add(int(weight) - old)
            else:
                self._impl.neuronW = self._impl.neuronW.at[
                    self._nid[pre], pid].add(int(weight) - old)
        else:
            img = self.image
            ptr = (img.axon_ptr[self._aid[pre]] if pre in self._aid
                   else img.neuron_ptr[self._nid[pre]])
            rows = slice(ptr.base_row, ptr.base_row + ptr.n_rows)
            slot = pid % hbm.SLOTS
            col_post = img.syn_post[rows, slot]
            hit = np.nonzero(col_post == pid)[0]
            img.syn_weight[ptr.base_row + hit[0], slot] = np.int16(weight)
            self._impl._w = np.asarray(img.syn_weight, np.int32)

    def read_membrane(self, *keys) -> List[int]:
        V = np.asarray(self._impl.V)
        return [int(V[self._nid[k]]) for k in keys]
