"""Hierarchical multi-core HiAER execution tier — §3 over the §4 tables.

`HiAERNetwork` runs the same packed `HBMImage` as the monolithic
`EventEngine`, but partitioned across the cores of a deployment
`partition.Hierarchy` (servers x FPGAs x cores):

  1. neurons are placed on cores by `partition.partition` (locality-first
     BFS) or by an explicit placement; each axon homes on the core
     holding most of its targets;
  2. the image is split into per-core destination shards
     (`hbm.shard_image`): core-local 'grey matter' plus cross-core
     'white matter' fan-in tables, both stored as one per-core CSR;
  3. every timestep runs core-local fire + routing interleaved with a
     hierarchical spike exchange (`kernels.exchange`): fired-neuron
     event vectors are aggregated level by level (core -> FPGA ->
     server) inside one jit-compiled step, and the per-level event
     traffic (NoC / FireFly / Ethernet) is measured into the
     `AccessCounter` — `partition.traffic_cost` made empirical.

Bit-exactness vs `backend="engine"` (property-tested in
tests/test_hiaer.py) rests on three invariants:

  * PRNG parity — noise uniforms are drawn once per step in GLOBAL
    neuron-id order (`noise_draw(sub, N)`) and gathered into the
    per-core layout; the elementwise fire phase
    (`neuron.fire_phase_from_u`) commutes with the permutation;
  * routing parity — the per-core CSRs collectively hold exactly the
    monolithic multiset of (weight x event-count) terms, each post
    neuron's terms all on its home core, and int32 wraparound addition
    is order-free;
  * counting parity — pointer/row reads are tallied against the
    monolithic pointer spans (`kernels.route.access_counts`), the same
    HBM work merely executed on more cores.

The step is single-device jax (scan over T, vmap over B, exactly like
`EventEngine.run/run_batch`); `core.mesh_runtime` maps the same
per-core data model onto a real `shard_map` device mesh, with each
device owning only its cores' shards and the exchange lowered to
hierarchical `lax.all_gather` collectives.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hbm
from repro.core import neuron as nrn
from repro.core import schedule as sched
from repro.core.costmodel import AccessCounter
from repro.core.hbm import HBMImage
from repro.core.partition import Hierarchy, partition
from repro.kernels import exchange as exch_k
from repro.kernels import route as route_k

_INT32_MAX = np.iinfo(np.int32).max


class HiAERTables(NamedTuple):
    """Device-resident per-core state (pytree, passed as a traced
    argument so weight edits swap arrays under the compiled step).

    The synapse tables are the RAGGED per-core layout of
    `hbm.CoreShards`: every core's records live in one flat entry array
    (memory linear in synapses), each core carrying its own weight
    storage (`entry_w`) — there is no monolithic dense `w_ext`
    weight-gather image anywhere on this path."""
    entry_w: jnp.ndarray           # (nnz,) int32 per-core weights,
    #                                core-major entry order
    entry_item: jnp.ndarray        # (nnz,) int32 into item counts
    csr_indptr: jnp.ndarray        # (C, n_max + 1) int32 ABSOLUTE
    #                                offsets into the entry arrays
    core_nids_idx: jnp.ndarray     # (C, n_max) int32 global id, pad -> N
    theta: jnp.ndarray             # (C, n_max) int32, pad = INT32_MAX
    nu: jnp.ndarray                # (C, n_max) int32, pad = -32
    lam: jnp.ndarray               # (C, n_max) int32
    is_lif: jnp.ndarray            # (C, n_max) bool, pad = False
    exchange: exch_k.ExchangeTables
    # monolithic pointer spans, for access-count parity with the engine
    axon_rows: jnp.ndarray         # (A,) int32
    axon_present: jnp.ndarray      # (A,) bool
    neuron_rows: jnp.ndarray       # (N,) int32
    neuron_present: jnp.ndarray    # (N,) bool


_to_cores = hbm.gather_to_cores


def _axon_majority_placement(axon_syn, neuron_core, n_axon_slots,
                             n_cores) -> np.ndarray:
    """Home each axon on the core holding most of its targets (ties to
    the lowest core id; axons with no in-range targets home on core 0) —
    the axon-side analogue of the partitioner's locality objective."""
    core = np.zeros((n_axon_slots,), np.int32)
    n_neurons = len(neuron_core)
    for a, syns in axon_syn.items():
        if not 0 <= a < n_axon_slots:
            continue
        tgt = [int(neuron_core[p]) for p, _ in syns if 0 <= p < n_neurons]
        if tgt:
            counts = np.bincount(tgt, minlength=n_cores)
            core[a] = int(counts.argmax())
    return core


class HiAERNetwork:
    """Multi-core HiAER engine; mirrors `EventEngine`'s interface
    (step/run/run_batch/reset/V/counter/update_weights) so
    `CRI_network(..., backend="hiaer")` drops in unchanged."""

    def __init__(self, image: HBMImage, theta, nu, lam, is_lif,
                 n_neurons: int, outputs: Sequence[int],
                 axon_syn: Optional[Dict[int, List]] = None,
                 neuron_syn: Optional[Dict[int, List]] = None,
                 hierarchy: Optional[Hierarchy] = None,
                 placement: Optional[Dict[int, int]] = None,
                 axon_placement: Optional[Dict[int, int]] = None,
                 seed: int = 0, flat=None, neuron_core=None,
                 axon_core=None, shards=None, axon_ndest=None,
                 neuron_ndest=None, packed: bool = True):
        """Either pass the legacy adjacency dicts (axon_syn/neuron_syn;
        placement, shards, and traffic tables are derived here), or pass
        the compiler's prebuilt pieces (neuron_core, axon_core, shards,
        axon_ndest, neuron_ndest — all five together) and skip the
        per-dict derivation entirely (the core.compile staged path).
        `packed` selects the bit-packed spike wire format
        (`kernels.exchange.exchange_packed`, uint32 presence words) —
        bit-exact vs the unpacked int32 exchange, default on."""
        self.image = image
        self.packed = bool(packed)
        self.n = n_neurons
        self.outputs = list(outputs)
        self.flat = flat if flat is not None else image.flatten()
        self.n_axon_slots = int(self.flat.axon_rows.shape[0])
        self.hier = hierarchy if hierarchy is not None else \
            Hierarchy(1, 1, 1, max(n_neurons, 1))
        self.spec = exch_k.HierSpec.from_hierarchy(self.hier)

        prebuilt = shards is not None
        if prebuilt:
            if neuron_core is None or axon_core is None \
                    or axon_ndest is None or neuron_ndest is None:
                raise ValueError("prebuilt shards need neuron_core, "
                                 "axon_core and both ndest tables")
            self.neuron_core = np.asarray(neuron_core, np.int32)
            self.axon_core = np.asarray(axon_core, np.int32)
            self.shards = shards
        else:
            if axon_syn is None or neuron_syn is None:
                raise ValueError("need axon_syn/neuron_syn when no "
                                 "prebuilt shards are given")
            # -------------------------------------------------- placement
            if placement is None:
                adjacency = {i: neuron_syn.get(i, [])
                             for i in range(n_neurons)}
                placement = partition(adjacency, self.hier)
            self.neuron_core = self._check_placement(placement)
            # axons default to majority-target homing; an explicit
            # axon_placement overrides per axon (unlisted axons keep the
            # majority rule, matching the api docstring)
            self.axon_core = _axon_majority_placement(
                axon_syn, self.neuron_core, self.n_axon_slots,
                self.hier.n_cores)
            if axon_placement is not None:
                for a, c in axon_placement.items():
                    if not 0 <= a < self.n_axon_slots:
                        raise ValueError(f"axon_placement has unknown "
                                         f"axon id {a}")
                    if not 0 <= c < self.hier.n_cores:
                        raise ValueError(
                            f"axon {a} placed on core {c}, hierarchy "
                            f"has {self.hier.n_cores}")
                    self.axon_core[a] = c

            # ----------------------------------------------------- shards
            self.shards = hbm.shard_image(image, self.flat,
                                          self.neuron_core,
                                          self.axon_core,
                                          self.hier.n_cores, n_neurons)
            axon_ndest, neuron_ndest = exch_k.build_dest_tables(
                axon_syn, neuron_syn, self.axon_core, self.neuron_core,
                self.hier, self.n_axon_slots, n_neurons)
        sh = self.shards
        core_nids_idx = np.where(sh.core_nids >= 0, sh.core_nids,
                                 n_neurons).astype(np.int32)
        pos_of_neuron = (sh.core_of_neuron.astype(np.int64) * sh.n_max
                         + sh.local_id).astype(np.int32)
        pos_word, pos_bit = exch_k.packed_positions(
            sh.core_of_neuron, sh.local_id, sh.n_max)
        self.shard_rebuilds = 0        # per-core weight-table uploads
        self._tables = HiAERTables(
            entry_w=jnp.asarray(sh.entry_w, jnp.int32),
            entry_item=jnp.asarray(sh.entry_item, jnp.int32),
            csr_indptr=jnp.asarray(sh.csr_indptr, jnp.int32),
            core_nids_idx=jnp.asarray(core_nids_idx),
            theta=jnp.asarray(_to_cores(np.asarray(theta, np.int32),
                                        core_nids_idx, _INT32_MAX)),
            nu=jnp.asarray(_to_cores(np.asarray(nu, np.int32),
                                     core_nids_idx, -32)),
            lam=jnp.asarray(_to_cores(np.asarray(lam, np.int32),
                                      core_nids_idx, 63)),
            is_lif=jnp.asarray(_to_cores(np.asarray(is_lif, bool),
                                         core_nids_idx, False)),
            exchange=exch_k.ExchangeTables(
                pos_of_neuron=jnp.asarray(pos_of_neuron),
                axon_ndest=jnp.asarray(axon_ndest),
                neuron_ndest=jnp.asarray(neuron_ndest),
                pos_word=jnp.asarray(pos_word),
                pos_bit=jnp.asarray(pos_bit)),
            axon_rows=jnp.asarray(self.flat.axon_rows),
            axon_present=jnp.asarray(self.flat.axon_present),
            neuron_rows=jnp.asarray(self.flat.neuron_rows),
            neuron_present=jnp.asarray(self.flat.neuron_present),
        )

        self.Vc = jnp.zeros((self.hier.n_cores, sh.n_max), jnp.int32)
        self.key = jax.random.PRNGKey(seed)
        self.counter = AccessCounter()
        self._spikes = np.zeros((n_neurons,), bool)
        self._jit_step = jax.jit(self._step_impl)
        self._jit_run = jax.jit(self._run_impl)
        self._jit_run_batch = jax.jit(self._run_batch_impl)
        self._jit_run_lanes = jax.jit(self._run_lanes_impl)

    def _check_placement(self, placement: Dict[int, int]) -> np.ndarray:
        core = np.full((self.n,), -1, np.int64)
        for nid, c in placement.items():
            if not 0 <= nid < self.n:
                raise ValueError(f"placement has unknown neuron id {nid}")
            if not 0 <= c < self.hier.n_cores:
                raise ValueError(
                    f"neuron {nid} placed on core {c}, hierarchy has "
                    f"{self.hier.n_cores}")
            core[nid] = c
        if self.n and core.min() < 0:
            missing = int(np.nonzero(core < 0)[0][0])
            raise ValueError(f"placement missing neuron {missing}")
        if self.n and (core.max() >= self.hier.n_cores):
            raise ValueError(
                f"placement uses core {int(core.max())}, hierarchy has "
                f"{self.hier.n_cores}")
        load = np.bincount(core, minlength=self.hier.n_cores) if self.n \
            else np.zeros(self.hier.n_cores, int)
        if load.size and load.max() > self.hier.neurons_per_core:
            raise ValueError(
                f"core {int(load.argmax())} holds {int(load.max())} "
                f"neurons > capacity {self.hier.neurons_per_core}")
        return core.astype(np.int32)

    # ------------------------------------------------------------- state
    @property
    def V(self):
        """Membrane potentials in global neuron-id order."""
        flat = self.Vc.reshape(-1)
        return flat[self._tables.exchange.pos_of_neuron]

    def reset(self):
        self.Vc = jnp.zeros_like(self.Vc)
        self._spikes = np.zeros((self.n,), bool)

    # -------------------------------------------------- weight updates
    def _refresh_cores(self, cores) -> None:
        """Re-upload only the touched cores' weight spans, as ONE
        combined device update (per-core weight storage means a weight
        edit never touches the other cores' memories)."""
        cores = np.asarray(list(cores), np.int64)
        sh = self.shards
        if cores.size >= sh.n_cores:
            ew = jnp.asarray(sh.entry_w, jnp.int32)      # full refresh
        else:
            off = sh.core_offsets
            spans = [np.arange(off[c], off[c + 1]) for c in cores]
            idx = np.concatenate(spans) if spans else \
                np.zeros((0,), np.int64)
            ew = self._tables.entry_w
            if idx.size:
                ew = ew.at[jnp.asarray(idx)].set(
                    jnp.asarray(sh.entry_w[idx], jnp.int32))
        self._tables = self._tables._replace(entry_w=ew)
        self.shard_rebuilds += int(cores.size)

    def update_entry_weights(self, positions, weights) -> None:
        """Batched weight edit at flat monolithic positions: rebuilds
        ONLY the shards whose entries changed (tables are traced
        arguments, so there is no retrace/recompile either way)."""
        cores = self.shards.apply_entry_updates(positions, weights)
        if cores.size:
            self._refresh_cores(cores)

    def update_weights(self, syn_weight) -> None:
        """Full refresh after an in-place dense `syn_weight` edit (the
        legacy whole-image surface; batched runtime edits go through
        `update_entry_weights`, which touches only the changed shards).
        The gather happens host-side — the device never sees the dense
        image."""
        w = np.asarray(syn_weight, np.int32)
        self.flat.syn_weight = np.ascontiguousarray(w)
        self.shards.entry_w[:] = w.reshape(-1)[self.shards.entry_pos]
        self._refresh_cores(range(self.shards.n_cores))

    # -------------------------------------------------- vectorized core
    def _step_impl(self, Vc, key, axon_counts, tables: HiAERTables):
        """One timestep: per-core fire -> hierarchical exchange ->
        per-core CSR routing -> per-core integrate. Returns
        (Vc', key', spikes (N,), ptr_reads, row_reads, traffic (4,))."""
        key, sub = jax.random.split(key)
        # global-order noise draw (PRNG parity with the monolithic
        # engine), gathered into the per-core layout
        u = nrn.noise_draw(sub, self.n)
        uc = jnp.concatenate([u, jnp.zeros((1,), jnp.int32)])[
            tables.core_nids_idx]
        Vc_mid, spikes_c = nrn.fire_phase_from_u(
            Vc, tables.theta, tables.nu, tables.lam, tables.is_lif, uc)
        # hierarchical spike exchange: every core learns the global fired
        # vector; per-level deliveries are measured as they happen. The
        # wire format is a trace-time switch: packed uint32 presence
        # words (32x narrower, consumed by word gather + bit extract) or
        # the int32 event lanes — bit-exact either way.
        xfn = exch_k.exchange_packed if self.packed else exch_k.exchange
        neuron_counts, traffic = xfn(
            spikes_c, axon_counts, self.spec, tables.exchange)
        _, _, pr, rr = route_k.access_counts(
            axon_counts, neuron_counts, tables.axon_rows,
            tables.axon_present, tables.neuron_rows,
            tables.neuron_present)
        # per-core phase 2: every core reduces its grey + white tables
        # with one scatter-free segment sum over the flat ragged entries
        # (each core's own weight storage — no monolithic w_ext gather)
        item_counts = jnp.concatenate([axon_counts, neuron_counts])
        vals = tables.entry_w * item_counts[tables.entry_item]
        syn_c = route_k.ragged_segment_sum(vals, tables.csr_indptr)
        Vc_next = nrn.integrate_phase(Vc_mid, syn_c)
        return (Vc_next, key, neuron_counts.astype(bool), pr, rr, traffic)

    def _run_impl(self, Vc, key, counts, tables):
        """T timesteps under one lax.scan; counts: (T, A) int32. Access
        and traffic tallies come back per step (int32 is safe within a
        step); callers sum them host-side in exact Python ints."""
        def body(carry, c):
            Vc, key = carry
            Vc, key, spikes, pr, rr, tr = self._step_impl(Vc, key, c,
                                                          tables)
            return (Vc, key), (spikes, pr, rr, tr)

        (Vc, key), outs = jax.lax.scan(body, (Vc, key), counts)
        return (Vc, key) + outs

    def _run_batch_impl(self, key, counts, tables):
        """B independent samples per dispatch; counts: (B, T, A) int32.
        Sample b runs from V = 0 under PRNG stream fold_in(key, b) —
        identical to EventEngine.run_batch."""
        B = counts.shape[0]
        keys = jax.vmap(lambda b: jax.random.fold_in(key, b))(jnp.arange(B))
        V0 = jnp.zeros((B,) + self.Vc.shape, jnp.int32)
        _, _, spikes, prs, rrs, trs = jax.vmap(
            self._run_impl, in_axes=(0, 0, 0, None))(V0, keys, counts,
                                                     tables)
        return spikes, prs, rrs, trs

    def _run_lanes_impl(self, V0, keys, counts, tables):
        """Serving-tier stateful batch: each lane carries its own
        (C, n_max) membrane state and PRNG key through the dispatch;
        lane b is bit-identical to running alone (every per-lane op is
        elementwise in the lane axis)."""
        return jax.vmap(self._run_impl, in_axes=(0, 0, 0, None))(
            V0, keys, counts, tables)

    def run_lanes(self, V0, keys, counts):
        """Stateful batched run for the serving tier. V0: (B, C, n_max)
        int32 per-core membranes, keys: (B,) PRNG keys, counts:
        (B, T, A) int32. Returns (V_final, keys_final, spikes (B, T, n)
        bool); the engine's own sequential state is untouched."""
        B, T = counts.shape[0], counts.shape[1]
        self.counter.timesteps += B * T
        Vc, keys, spikes, prs, rrs, trs = self._jit_run_lanes(
            jnp.asarray(V0, jnp.int32), keys, jnp.asarray(counts),
            self._tables)
        self.counter.tally(prs, rrs, trs)
        return Vc, keys, np.asarray(spikes, bool)

    def lanes_membrane(self, V_lanes) -> np.ndarray:
        """Per-lane (C, n_max) state -> (B, n) membranes in global
        neuron-id order."""
        V = np.asarray(V_lanes)
        pos = np.asarray(self._tables.exchange.pos_of_neuron)
        return V.reshape(V.shape[0], -1)[:, pos]

    def lane_state_zeros(self, B: int) -> np.ndarray:
        """Fresh per-lane membrane state, (B,) + the backend's state
        shape — the V = 0 a `run_batch` sample starts from."""
        return np.zeros((B,) + tuple(self.Vc.shape), np.int32)

    # ----------------------------------------------------------- stepping
    def step(self, axon_inputs: Sequence[int]) -> np.ndarray:
        """One timestep; returns bool (n,) spikes fired this step."""
        self.counter.timesteps += 1
        counts = jnp.asarray(sched.encode_ids(axon_inputs,
                                              self.n_axon_slots))
        self.Vc, self.key, spikes, pr, rr, tr = self._jit_step(
            self.Vc, self.key, counts, self._tables)
        self.counter.tally(pr, rr, tr)
        self._spikes = np.asarray(spikes, bool)
        return self._spikes

    def run(self, schedule) -> np.ndarray:
        """T timesteps in one dispatch; same contract as
        EventEngine.run. Returns (T, n) bool spikes."""
        counts = sched.encode_schedule(schedule, self.n_axon_slots)
        T = counts.shape[0]
        self.counter.timesteps += T
        self.Vc, self.key, spikes, prs, rrs, trs = self._jit_run(
            self.Vc, self.key, jnp.asarray(counts), self._tables)
        self.counter.tally(prs, rrs, trs)
        spikes = np.asarray(spikes, bool)
        if T:
            self._spikes = spikes[-1]
        return spikes

    def run_batch(self, schedules) -> np.ndarray:
        """B samples x T timesteps per dispatch; same contract as
        EventEngine.run_batch (fresh V = 0 and stream fold_in(key, b)
        per sample; the engine's own key advances once). Returns
        (B, T, n) bool spikes."""
        if len(schedules) == 0:
            return np.zeros((0, 0, self.n), bool)
        counts = sched.encode_batch(schedules, self.n_axon_slots)
        B, T = counts.shape[0], counts.shape[1]
        self.counter.timesteps += B * T
        spikes, prs, rrs, trs = self._jit_run_batch(
            self.key, jnp.asarray(counts), self._tables)
        self.counter.tally(prs, rrs, trs)
        self.key, _ = jax.random.split(self.key)
        return np.asarray(spikes, bool)

    def read_membrane(self, ids: Sequence[int]) -> List[int]:
        V = np.asarray(self.V)
        return [int(V[i]) for i in ids]
