"""Pure-software simulator — the jnp equivalent of the paper's Fig. 8 code.

The network is represented by two (sparse-in-spirit, dense-in-storage for
XLA) integer weight matrices — axonW (A, N) and neuronW (N, N) — and the
membrane update follows the exact Fig. 8 order. This is the semantic oracle
the event-driven engine (engine.py) and the Pallas spike kernel are tested
against, and doubles as the local `hs_api`-style backend users run on their
own machines before submitting to the cluster.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import neuron as nrn


class DenseSimulator:
    def __init__(self, axonW, neuronW, theta, nu, lam, is_lif, seed=0):
        self.axonW = jnp.asarray(axonW, jnp.int32)      # (A, N)
        self.neuronW = jnp.asarray(neuronW, jnp.int32)  # (N, N)
        self.theta = jnp.asarray(theta, jnp.int32)
        self.nu = jnp.asarray(nu, jnp.int32)
        self.lam = jnp.asarray(lam, jnp.int32)
        self.is_lif = jnp.asarray(is_lif, bool)
        self.n_axons = self.axonW.shape[0]
        self.n_neurons = self.neuronW.shape[0]
        self.V = jnp.zeros((self.n_neurons,), jnp.int32)
        self.key = jax.random.PRNGKey(seed)
        self._step = jax.jit(self._step_impl)

    def reset(self):
        self.V = jnp.zeros((self.n_neurons,), jnp.int32)

    def _step_impl(self, V, key, fired_axons, axonW, neuronW):
        key, sub = jax.random.split(key)
        V_mid, spikes = nrn.fire_phase(V, self.theta, self.nu, self.lam,
                                       self.is_lif, sub)
        syn = (fired_axons.astype(jnp.int32) @ axonW
               + spikes.astype(jnp.int32) @ neuronW)
        V_next = nrn.integrate_phase(V_mid, syn)
        return V_next, key, spikes

    def step(self, axon_inputs):
        """axon_inputs: iterable of axon indices active this timestep.
        Returns bool (N,) spike vector (this step's fired neurons)."""
        fired = jnp.zeros((self.n_axons,), bool)
        if len(axon_inputs):
            fired = fired.at[jnp.asarray(list(axon_inputs))].set(True)
        self.V, self.key, spikes = self._step(self.V, self.key, fired,
                                              self.axonW, self.neuronW)
        return spikes

    def run(self, steps_axon_inputs):
        return [self.step(a) for a in steps_axon_inputs]
