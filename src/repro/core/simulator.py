"""Pure-software simulator — the jnp equivalent of the paper's Fig. 8 code.

The network is represented by two (sparse-in-spirit, dense-in-storage for
XLA) integer weight matrices — axonW (A, N) and neuronW (N, N) — and the
membrane update follows the exact Fig. 8 order. This is the semantic oracle
the event-driven engine (engine.py) and the Pallas spike kernel are tested
against, and doubles as the local `hs_api`-style backend users run on their
own machines before submitting to the cluster.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuron as nrn
from repro.core import schedule as sched


class DenseSimulator:
    def __init__(self, axonW, neuronW, theta, nu, lam, is_lif, seed=0):
        self.axonW = jnp.asarray(axonW, jnp.int32)      # (A, N)
        self.neuronW = jnp.asarray(neuronW, jnp.int32)  # (N, N)
        self.theta = jnp.asarray(theta, jnp.int32)
        self.nu = jnp.asarray(nu, jnp.int32)
        self.lam = jnp.asarray(lam, jnp.int32)
        self.is_lif = jnp.asarray(is_lif, bool)
        self.n_axons = self.axonW.shape[0]
        self.n_neurons = self.neuronW.shape[0]
        self.V = jnp.zeros((self.n_neurons,), jnp.int32)
        self.key = jax.random.PRNGKey(seed)
        self._step = jax.jit(self._step_impl)
        self._scan = jax.jit(self._scan_impl)
        self._scan_batch = jax.jit(self._scan_batch_impl)
        self._scan_lanes = jax.jit(self._scan_lanes_impl)

    def reset(self):
        self.V = jnp.zeros((self.n_neurons,), jnp.int32)

    def _step_impl(self, V, key, axon_counts, axonW, neuronW):
        key, sub = jax.random.split(key)
        V_mid, spikes = nrn.fire_phase(V, self.theta, self.nu, self.lam,
                                       self.is_lif, sub)
        syn = (axon_counts.astype(jnp.int32) @ axonW
               + spikes.astype(jnp.int32) @ neuronW)
        V_next = nrn.integrate_phase(V_mid, syn)
        return V_next, key, spikes

    def step(self, axon_inputs):
        """axon_inputs: iterable of axon indices active this timestep
        (event-count semantics: an index listed twice is driven twice,
        matching the engine's pointer queue). Returns bool (N,) spike
        vector (this step's fired neurons)."""
        counts = sched.encode_ids(axon_inputs, self.n_axons)
        self.V, self.key, spikes = self._step(self.V, self.key,
                                              jnp.asarray(counts),
                                              self.axonW, self.neuronW)
        return spikes

    # ------------------------------------------------------ batched paths
    # Same per-step semantics and PRNG stream as `step` (split per step),
    # folded into one XLA dispatch — mirrors EventEngine.run/run_batch so
    # the two backends stay bit-identical on the batched API too. Schedules
    # are (T, A) / (B, T, A) int32 axon event COUNTS (counts, not booleans:
    # an axon driven twice in a step contributes its weights twice, the
    # event-queue semantics of the engine).
    def _scan_impl(self, V, key, counts, axonW, neuronW):
        # weights are traced arguments (like _step_impl's), so
        # write_synapse edits reach already-compiled scans.
        def body(carry, c):
            V, key = carry
            key, sub = jax.random.split(key)
            V_mid, spikes = nrn.fire_phase(V, self.theta, self.nu, self.lam,
                                           self.is_lif, sub)
            syn = (c.astype(jnp.int32) @ axonW
                   + spikes.astype(jnp.int32) @ neuronW)
            return (nrn.integrate_phase(V_mid, syn), key), spikes

        (V, key), spikes = jax.lax.scan(body, (V, key), counts)
        return V, key, spikes

    def _scan_batch_impl(self, key, counts, axonW, neuronW):
        B = counts.shape[0]
        keys = jax.vmap(lambda b: jax.random.fold_in(key, b))(jnp.arange(B))
        V0 = jnp.zeros((B, self.n_neurons), jnp.int32)
        _, _, spikes = jax.vmap(
            self._scan_impl, in_axes=(0, 0, 0, None, None))(
            V0, keys, counts, axonW, neuronW)
        return spikes

    def _scan_lanes_impl(self, V0, keys, counts, axonW, neuronW):
        """Serving-tier stateful batch: each lane carries its own
        membranes and PRNG key; lane b is bit-identical to running
        alone (elementwise in the lane axis)."""
        return jax.vmap(self._scan_impl, in_axes=(0, 0, 0, None, None))(
            V0, keys, counts, axonW, neuronW)

    def run_lanes(self, V0, keys, counts):
        """Stateful batched run. V0: (B, N) int32, keys: (B,) PRNG
        keys, counts: (B, T, A) int32. Returns (V_final, keys_final,
        spikes (B, T, N) bool); the simulator's own state is
        untouched."""
        V, keys, spikes = self._scan_lanes(
            jnp.asarray(V0, jnp.int32), keys, jnp.asarray(counts),
            self.axonW, self.neuronW)
        return V, keys, np.asarray(spikes, bool)

    def lanes_membrane(self, V_lanes):
        """Per-lane membranes are already in global neuron-id order."""
        return np.asarray(V_lanes)

    def lane_state_zeros(self, B: int):
        return np.zeros((B, self.n_neurons), np.int32)

    def run(self, schedule):
        """T timesteps in one dispatch. schedule: (T, A) int32 counts or a
        length-T sequence of axon-index sequences. Returns (T, N) bool."""
        counts = self._encode(schedule)
        self.V, self.key, spikes = self._scan(self.V, self.key,
                                              jnp.asarray(counts),
                                              self.axonW, self.neuronW)
        return np.asarray(spikes)

    def run_batch(self, schedules):
        """(B, T, A) counts or a length-B sequence of `run`-style
        schedules -> (B, T, N) bool spikes; sample b runs from V = 0 under
        fold_in(key, b) (identical to EventEngine.run_batch)."""
        # every per-sample slice goes through _encode so 3-D count arrays
        # get the same width/dtype validation as 2-D `run` schedules
        if len(schedules) == 0:
            return np.zeros((0, 0, self.n_neurons), bool)
        counts = np.stack([self._encode(s) for s in schedules])
        spikes = self._scan_batch(self.key, jnp.asarray(counts),
                                  self.axonW, self.neuronW)
        self.key, _ = jax.random.split(self.key)
        return np.asarray(spikes)

    def _encode(self, schedule):
        # shared core.schedule encoding: only an actual ndarray is taken
        # as a pre-encoded counts matrix; a plain list of axon-index lists
        # is always per-element events (unknown ids dropped, like step())
        return sched.encode_schedule(schedule, self.n_axons)
