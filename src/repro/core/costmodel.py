"""Energy & latency cost model — §6 ("energy usage is primarily dominated by
HBM accesses; energy consumption was approximated by the product of the
energy cost of a single HBM access and the number of HBM accesses performed
during an inference"), Tables 2-4, Fig. 10.

Counting comes from the two-phase routing over the HBM image (engine.py):
  phase-1: one pointer read per fired axon/neuron,
  phase-2: one row read per synapse row spanned by each fired item.

Constants are calibrated against Table 2's first row (MLP 784→128→10:
1.1 µJ / 4.2 µs per inference with ~1.5k accesses at typical MNIST pixel
activity): ≈ 744 pJ per 64-bit HBM access (~93 pJ/B, consistent with HBM2
energy/bit literature) and ≈ 2.84 ns effective per access (16-lane pipelined
at the FPGA clock). benchmarks/fig10_scaling.py re-derives the paper's
linear energy/latency-vs-neurons regressions from this model.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

E_ACCESS_PJ = 744.0       # energy per HBM access (64-bit slot read)
NS_PER_ACCESS = 2.84      # effective pipelined latency per access
FIXED_NS = 120.0          # per-timestep control overhead (pointer setup)

# the membrane-accumulate path (kernels/route.py segment sums, the
# 16-lane Fig. 2b units) adds int16 synapse records into an int32
# accumulator: these are the hardware bounds the static analyzer
# (repro.analysis.validate) checks worst-case per-neuron fan-in against
ACC_MIN = -(2 ** 31)      # int32 accumulator range
ACC_MAX = 2 ** 31 - 1

# interconnect levels of the deployment hierarchy (§3, Fig. 1b): the
# index into AccessCounter.level_events — 0 = delivery within the source
# item's own core, then one entry per link the event had to cross
LEVEL_NAMES = ("local", "noc", "firefly", "ethernet")


@dataclass
class AccessCounter:
    pointer_reads: int = 0
    row_reads: int = 0
    timesteps: int = 0
    # spike/axon events by the hierarchy level of each (source item ->
    # destination core) delivery — measured by the hiaer engine's
    # per-step exchange (kernels/exchange.py), zero on the monolithic
    # engine (a single core has only local deliveries it never tallies).
    # This turns partition.traffic_cost's static estimate into a
    # measured quantity.
    level_events: list = field(
        default_factory=lambda: [0] * len(LEVEL_NAMES))

    @property
    def total_accesses(self) -> int:
        return self.pointer_reads + self.row_reads

    @property
    def cross_level_events(self) -> int:
        """Events that left their source core (NoC + FireFly + Ethernet)."""
        return sum(self.level_events[1:])

    def add_level_events(self, per_level) -> None:
        for i, v in enumerate(per_level):
            self.level_events[i] += int(v)

    def tally(self, pointer_reads, row_reads, level_events=None) -> None:
        """Fold device tallies into the counter host-side, in exact
        Python ints (device tallies are int32 per step/sample; summing
        here keeps long runs from wrapping). Accepts scalars or any
        per-step/per-sample array shape; `level_events` is any stack of
        (N_LEVELS,) traffic rows. The one tally path shared by
        engine/hiaer/mesh step/run/run_batch."""
        self.pointer_reads += int(np.asarray(pointer_reads,
                                             np.int64).sum())
        self.row_reads += int(np.asarray(row_reads, np.int64).sum())
        if level_events is not None:
            self.add_level_events(
                np.asarray(level_events, np.int64)
                .reshape(-1, len(LEVEL_NAMES)).sum(axis=0))

    def energy_uJ(self) -> float:
        return self.total_accesses * E_ACCESS_PJ * 1e-6

    def latency_us(self) -> float:
        return (self.total_accesses * NS_PER_ACCESS
                + self.timesteps * FIXED_NS) * 1e-3

    def merge(self, other: "AccessCounter"):
        self.pointer_reads += other.pointer_reads
        self.row_reads += other.row_reads
        self.timesteps += other.timesteps
        self.add_level_events(other.level_events)

    def reset(self):
        self.pointer_reads = self.row_reads = self.timesteps = 0
        self.level_events = [0] * len(LEVEL_NAMES)

    def as_dict(self):
        d = {"pointer_reads": self.pointer_reads,
             "row_reads": self.row_reads,
             "timesteps": self.timesteps,
             "total_accesses": self.total_accesses,
             "energy_uJ": self.energy_uJ(),
             "latency_us": self.latency_us()}
        for name, v in zip(LEVEL_NAMES, self.level_events):
            d[f"events_{name}"] = v
        return d
