"""Energy & latency cost model — §6 ("energy usage is primarily dominated by
HBM accesses; energy consumption was approximated by the product of the
energy cost of a single HBM access and the number of HBM accesses performed
during an inference"), Tables 2-4, Fig. 10.

Counting comes from the two-phase routing over the HBM image (engine.py):
  phase-1: one pointer read per fired axon/neuron,
  phase-2: one row read per synapse row spanned by each fired item.

Constants are calibrated against Table 2's first row (MLP 784→128→10:
1.1 µJ / 4.2 µs per inference with ~1.5k accesses at typical MNIST pixel
activity): ≈ 744 pJ per 64-bit HBM access (~93 pJ/B, consistent with HBM2
energy/bit literature) and ≈ 2.84 ns effective per access (16-lane pipelined
at the FPGA clock). benchmarks/fig10_scaling.py re-derives the paper's
linear energy/latency-vs-neurons regressions from this model.
"""
from __future__ import annotations

from dataclasses import dataclass, field

E_ACCESS_PJ = 744.0       # energy per HBM access (64-bit slot read)
NS_PER_ACCESS = 2.84      # effective pipelined latency per access
FIXED_NS = 120.0          # per-timestep control overhead (pointer setup)


@dataclass
class AccessCounter:
    pointer_reads: int = 0
    row_reads: int = 0
    timesteps: int = 0

    @property
    def total_accesses(self) -> int:
        return self.pointer_reads + self.row_reads

    def energy_uJ(self) -> float:
        return self.total_accesses * E_ACCESS_PJ * 1e-6

    def latency_us(self) -> float:
        return (self.total_accesses * NS_PER_ACCESS
                + self.timesteps * FIXED_NS) * 1e-3

    def merge(self, other: "AccessCounter"):
        self.pointer_reads += other.pointer_reads
        self.row_reads += other.row_reads
        self.timesteps += other.timesteps

    def reset(self):
        self.pointer_reads = self.row_reads = self.timesteps = 0

    def as_dict(self):
        return {"pointer_reads": self.pointer_reads,
                "row_reads": self.row_reads,
                "timesteps": self.timesteps,
                "total_accesses": self.total_accesses,
                "energy_uJ": self.energy_uJ(),
                "latency_us": self.latency_us()}
