"""Mesh context shared by model code.

Model code needs the mesh (a) to build shard_map'd blocks (MoE dispatch,
hierarchical HiAER exchange) and (b) to phrase sharding constraints in terms
of whatever axes exist ('pod' only on the multi-pod mesh). A context variable
avoids threading the mesh through every layer signature.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_mesh() -> Mesh:
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        # default: trivial 1x1 mesh over the available devices[0]
        dev = jax.devices()[0]
        mesh = Mesh(
            __import__("numpy").array([[dev]]), ("data", "model"))
        _state.mesh = mesh
    return mesh


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(prev)


def batch_axes() -> Tuple[str, ...]:
    """Axes the global batch is sharded over ('pod' included when present)."""
    mesh = get_mesh()
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def tp_axis() -> str:
    return "model"


def tp_size() -> int:
    return get_mesh().shape[tp_axis()]


def dp_size() -> int:
    mesh = get_mesh()
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def constrain(x, *spec):
    """with_sharding_constraint against the context mesh.

    'batch' resolves to the batch axes ('pod','data' on multi-pod meshes);
    axes whose size does not divide the dim are dropped (e.g. batch=1 in the
    long_500k cell stays replicated instead of erroring)."""
    mesh = get_mesh()
    resolved = []
    for dim, s in zip(x.shape, spec):
        s = batch_axes() if s == "batch" else s
        axes = s if isinstance(s, tuple) else (s,) if s else ()
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        resolved.append(s if size and dim % max(size, 1) == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
