"""Elastic scaling + straggler mitigation.

Node-failure story for 1000+ node deployments:
  1. a heartbeat/watchdog detects the failure (StepWatchdog below at step
     granularity; the real cluster agent at process granularity);
  2. surviving hosts rebuild a smaller mesh (drop the failed pod / data
     row — mesh shapes stay rectangular);
  3. the latest checkpoint is restored ONTO THE NEW MESH: `reshard_tree`
     re-derives sharding specs from the same ShardingRules against the new
     mesh and device_puts the restored host arrays — no dependence on the
     old layout (checkpoints store global arrays / reassemblable shards);
  4. the data pipeline cursor (saved in checkpoint aux) resumes exactly;
     global batch is either kept (more grad-accum microbatches per device)
     or rescaled with the LR (config policy).

StepWatchdog also implements straggler *mitigation*: a step exceeding
`factor` x the rolling median is flagged; after `patience` consecutive
flags the runner is told to trigger the elastic path (or, with
backup-workers enabled in the launcher, to cut over to the spare).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Optional

import jax

from repro.launch.sharding import ShardingRules, to_named


def reshard_tree(host_tree, cfg, new_mesh, kind="params", layout="heads"):
    """Re-device_put a restored host tree onto a (possibly different) mesh."""
    rules = ShardingRules(cfg, new_mesh, layout)
    import jax.numpy as jnp
    shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), host_tree)
    if kind == "params":
        specs = rules.params_specs(shapes)
    elif kind == "opt":
        specs = rules.opt_specs(shapes["mu"],
                                rules.params_specs(shapes["mu"]))
    else:
        raise ValueError(kind)
    sh = to_named(specs, new_mesh)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), host_tree, sh)


class StepWatchdog:
    def __init__(self, factor: float = 3.0, patience: int = 3,
                 window: int = 32):
        self.factor = factor
        self.patience = patience
        self.times = deque(maxlen=window)
        self.strikes = 0
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> dict:
        dt = time.monotonic() - self._t0
        med = sorted(self.times)[len(self.times) // 2] if self.times else dt
        straggling = len(self.times) >= 8 and dt > self.factor * med
        self.strikes = self.strikes + 1 if straggling else 0
        self.times.append(dt)
        return {"step_s": dt, "median_s": med, "straggler": straggling,
                "evict": self.strikes >= self.patience}
