"""Gradient compression with error feedback — the distributed-optimization
hooks for scarce cross-pod (DCN) bandwidth.

Two compressors, both with error-feedback state (residual carried into the
next step so compression error doesn't bias convergence):
  * int8 blockwise quantization  (~4x over f32, exact scale per 256-block)
  * top-k magnitude sparsification (k as a fraction; indices+values)

They plug into make_train_step(compressor=...) and are applied to gradients
before the optimizer. On a real multi-pod run they sit between the
intra-pod reduce (full precision over ICI) and the cross-pod all-reduce
(compressed over DCN) — the HiAER principle again: full-rate traffic on
fast local links, summarized traffic on slow global links.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad(x, m):
    n = x.size
    pad = (-n) % m
    return jnp.pad(x.reshape(-1), (0, pad)), n


def int8_compress(g):
    """g (any shape) -> (q int8, scale f32 per block)."""
    flat, n = _pad(g.astype(jnp.float32), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale, n


def int8_decompress(q, scale, n, shape):
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return out.reshape(shape)


def topk_compress(g, frac: float):
    flat = g.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx, flat.size


def topk_decompress(vals, idx, size, shape):
    return jnp.zeros((size,), jnp.float32).at[idx].set(vals).reshape(shape)


class ErrorFeedback:
    """Stateful wrapper: grads <- decompress(compress(grads + residual));
    residual <- (grads + residual) - decompressed."""

    def __init__(self, mode: str = "int8", topk_frac: float = 0.01):
        self.mode = mode
        self.topk_frac = topk_frac

    def init(self, grads):
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def apply(self, grads, residual):
        """Returns (compressed-then-decompressed grads, new residual)."""
        def one(g, r):
            x = g.astype(jnp.float32) + r
            if self.mode == "int8":
                q, s, n = int8_compress(x)
                d = int8_decompress(q, s, n, x.shape)
            else:
                v, i, n = topk_compress(x, self.topk_frac)
                d = topk_decompress(v, i, n, x.shape)
            return d.astype(g.dtype), x - d
        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = tdef.flatten_up_to(residual)
        out = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))


def compressed_bytes(grads, mode="int8", topk_frac=0.01) -> int:
    total = 0
    for g in jax.tree.leaves(grads):
        if mode == "int8":
            total += g.size + 4 * (g.size // BLOCK + 1)
        else:
            k = max(1, int(g.size * topk_frac))
            total += 8 * k
    return total
