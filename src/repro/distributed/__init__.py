from repro.distributed.context import (batch_axes, get_mesh, mesh_context,
                                       set_mesh, tp_axis, tp_size)

__all__ = ["batch_axes", "get_mesh", "mesh_context", "set_mesh", "tp_axis",
           "tp_size"]
