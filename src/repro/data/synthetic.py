"""Synthetic datasets + token pipeline.

MNIST / DVS-Gesture / CIFAR-10 are not available offline in this container
(DESIGN.md §7): `digits()` procedurally generates class-conditional binary
images with stroke-like structure and controlled pixel-flip noise, matching
the input shapes and activity levels (~20% active pixels) of binarized
MNIST, so the entire pipeline — QAT training, int16 quantization, A.2
conversion, event-driven execution, energy/latency accounting — runs end to
end. `event_frames()` does the same for 2-channel DVS-style inputs.

`TokenPipeline` is the LM-side data loader: sharded, deterministic,
checkpointable (the cursor is part of the training state — required for
exact fault-tolerant resume).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _class_templates(n_classes, shape, seed):
    rng = np.random.default_rng(seed)
    H, W = shape
    templates = np.zeros((n_classes, H, W), bool)
    for c in range(n_classes):
        r = np.random.default_rng(seed * 1000 + c)
        img = np.zeros((H, W), bool)
        # stroke-like structure: random walks biased per class
        for _ in range(3 + c % 3):
            y, x = r.integers(2, H - 2), r.integers(2, W - 2)
            dy, dx = r.choice([-1, 0, 1]), r.choice([-1, 0, 1])
            for _ in range(H + W):
                img[max(0, min(H - 1, y)), max(0, min(W - 1, x))] = True
                if r.random() < 0.3:
                    dy, dx = r.choice([-1, 0, 1]), r.choice([-1, 0, 1])
                y += dy + (c % 2)
                x += dx
                y %= H
                x %= W
        templates[c] = img
    return templates


def digits(n, shape=(28, 28), n_classes=10, noise=0.03, seed=0):
    """Binary 'digit' images: (n, H, W) bool + labels (n,)."""
    rng = np.random.default_rng(seed)
    templates = _class_templates(n_classes, shape, seed=17)
    labels = rng.integers(0, n_classes, n)
    imgs = templates[labels].copy()
    flips = rng.random(imgs.shape) < noise
    imgs ^= flips
    return imgs, labels


def event_frames(n, shape=(63, 63), n_classes=11, frames=10, noise=0.02,
                 seed=0):
    """DVS-gesture-like: (n, frames, 2, H, W) bool ON/OFF events + labels."""
    rng = np.random.default_rng(seed)
    templates = _class_templates(n_classes, shape, seed=29)
    labels = rng.integers(0, n_classes, n)
    out = np.zeros((n, frames, 2, *shape), bool)
    for i, c in enumerate(labels):
        base = templates[c]
        for f in range(frames):
            shift = (f * (1 + c % 3)) % shape[1]
            moved = np.roll(base, shift, axis=1)
            prev = np.roll(base, shift - 1, axis=1)
            out[i, f, 0] = moved & ~prev          # ON events
            out[i, f, 1] = prev & ~moved          # OFF events
    flips = rng.random(out.shape) < noise
    out ^= flips
    return out, labels


@dataclass
class TokenPipeline:
    """Deterministic synthetic token stream for LM training, sharded by
    data-parallel rank and resumable from a step cursor."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0

    def next_batch(self):
        rng = np.random.default_rng((self.seed, self.step))
        # Markov-ish structure so the loss is learnable, not pure noise
        base = rng.integers(1, self.vocab_size,
                            (self.global_batch, self.seq_len), dtype=np.int32)
        repeat = rng.random((self.global_batch, self.seq_len)) < 0.5
        toks = base.copy()
        toks[:, 1:] = np.where(repeat[:, 1:], toks[:, :-1], base[:, 1:])
        self.step += 1
        return {"tokens": toks}

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d):
        self.seed, self.step = int(d["seed"]), int(d["step"])
