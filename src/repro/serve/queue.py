"""Shared serving primitives: double-buffered ingestion and slot pools.

`DoubleBuffer` is the software analogue of the hardware external-events
processor's present/future BRAM pair: producers always write into the
FUTURE buffer and never contend with the batch currently executing;
the dispatcher promotes future -> present only at a batch boundary
(inside `take`). `take` also implements the micro-batch admission
policy — wait for the first item, then keep admitting until either
`max_n` items are aboard or `max_wait_s` has elapsed since the batch
opened (deadline + max-batch).

`SlotPool` is a fixed-capacity slot allocator shared by the spike
server's session lanes and the LM server's decode slots
(`repro.launch.serve`) — acquire a free slot id, release it when the
stream ends, read the active mask for batched state updates.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, List, Optional

import numpy as np

__all__ = ["DoubleBuffer", "SlotPool", "BufferFull", "BufferClosed"]


class BufferFull(RuntimeError):
    """Raised when a bounded serving resource is at capacity — the
    `DoubleBuffer` ingestion queue, or the LM server's decode
    `SlotPool`. Backpressure is the caller's contract: the portal maps
    this to HTTP 503 + Retry-After instead of queueing without bound.
    Carries `pending` and `capacity`; dispatch layers may attach
    `retry_after_s` before re-raising."""

    def __init__(self, pending: int, capacity: int,
                 what: str = "ingestion buffer"):
        super().__init__(
            f"{what} full: {pending} pending >= capacity "
            f"{capacity} — retry after the present batch drains")
        self.pending = int(pending)
        self.capacity = int(capacity)
        self.retry_after_s: Optional[float] = None


class BufferClosed(RuntimeError):
    """Raised by `put` after `close()` — the server is shutting down
    (portal maps it to 503)."""

    def __init__(self):
        super().__init__("buffer is closed")


class DoubleBuffer:
    """Two-sided request buffer: `put` appends to the future side (and
    never blocks on an executing batch); `take` promotes accumulated
    items to the present side at batch boundaries and applies the
    deadline + max-batch admission policy. FIFO order is preserved
    across promotions. `capacity` bounds the TOTAL pending count
    (present + future): a put beyond it raises `BufferFull` — loaded
    callers shed instead of queueing unboundedly."""

    def __init__(self, capacity: Optional[int] = None):
        self.capacity = None if capacity is None else int(capacity)
        self._future: List = []
        self._present: deque = deque()
        self._cond = threading.Condition()
        self._closed = False
        # ingestion statistics (read under the lock via `stats`)
        self.swaps = 0
        self.max_future_depth = 0
        self.rejected = 0

    # ------------------------------------------------------- producers
    def put(self, item) -> None:
        """Enqueue into the FUTURE buffer. Never blocks on the present
        batch — this is the double-buffering contract. Raises
        `BufferFull` at capacity, `BufferClosed` after `close()`."""
        with self._cond:
            if self._closed:
                raise BufferClosed()
            if self.capacity is not None \
                    and self._pending_locked() >= self.capacity:
                self.rejected += 1
                raise BufferFull(self._pending_locked(), self.capacity)
            self._future.append(item)
            self.max_future_depth = max(self.max_future_depth,
                                        len(self._future))
            self._cond.notify_all()

    def close(self) -> None:
        """Wake all waiters; further `put` calls raise."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def reopen(self) -> None:
        """Accept items again after `close()` — a restarted server
        reuses its buffer (stats and capacity carry over)."""
        with self._cond:
            self._closed = False

    # ------------------------------------------------------ dispatcher
    def _promote_locked(self) -> None:
        """future -> present (the batch-boundary buffer swap)."""
        if self._future:
            self._present.extend(self._future)
            self._future = []
            self.swaps += 1

    def _pending_locked(self) -> int:
        return len(self._present) + len(self._future)

    @property
    def pending(self) -> int:
        with self._cond:
            return self._pending_locked()

    def take(self, max_n: int, max_wait_s: float = 0.0,
             coalesce: Optional[Callable] = None,
             idle_wait_s: float = 0.05) -> List:
        """Admit the next micro-batch. Blocks up to `idle_wait_s` for a
        first item (returns [] if none arrives — the dispatcher's idle
        tick), then admits items in FIFO order until `max_n` are aboard
        or `max_wait_s` has passed since the batch opened.

        `coalesce(batch, next_item) -> bool` decides whether
        `next_item` may join the open batch; a refused item stays at
        the head for the next take — that is how reconfiguration
        barriers and model switches cut batches without reordering."""
        out: List = []
        with self._cond:
            if not self._pending_locked() and not self._closed:
                self._cond.wait(idle_wait_s)
            if not self._pending_locked():
                return out
            opened = time.monotonic()
            while len(out) < max_n:
                self._promote_locked()
                while self._present and len(out) < max_n:
                    nxt = self._present[0]
                    if out and coalesce is not None \
                            and not coalesce(out, nxt):
                        return out
                    out.append(self._present.popleft())
                if len(out) >= max_n:
                    break
                remain = max_wait_s - (time.monotonic() - opened)
                if remain <= 0 or self._closed:
                    break
                self._cond.wait(remain)
                if not self._pending_locked() \
                        and time.monotonic() - opened >= max_wait_s:
                    break
        return out

    def drain(self) -> List:
        """Remove and return everything still pending (both sides), in
        FIFO order. Used by `SpikeServer.shutdown` to resolve or cancel
        leftover futures so no client ever hangs on process exit."""
        with self._cond:
            self._promote_locked()
            out = list(self._present)
            self._present.clear()
            return out

    def stats(self) -> dict:
        with self._cond:
            return {"pending": self._pending_locked(),
                    "swaps": self.swaps,
                    "max_future_depth": self.max_future_depth,
                    "capacity": self.capacity,
                    "rejected": self.rejected}


class SlotPool:
    """Fixed-capacity slot allocator. Slot ids are stable integers in
    [0, n_slots); `mask` is the bool active vector batched state
    updates index with (the LM server's `active` array, the spike
    server's session-lane occupancy)."""

    def __init__(self, n_slots: int):
        self.n_slots = int(n_slots)
        self._free = deque(range(self.n_slots))
        self._mask = np.zeros((self.n_slots,), bool)
        self._cond = threading.Condition()

    def acquire(self, timeout: Optional[float] = None) -> Optional[int]:
        """Claim a free slot id; blocks up to `timeout` (None = no
        wait). Returns None if none freed up in time."""
        with self._cond:
            if not self._free and timeout:
                self._cond.wait(timeout)
            if not self._free:
                return None
            s = self._free.popleft()
            self._mask[s] = True
            return s

    def acquire_slot(self, slot: int) -> int:
        """Claim one SPECIFIC free slot (checkpoint restore re-pins
        sessions to the exact lanes they held — session ids double as
        lane ids in the serving tier). Raises if the slot is out of
        range or already held."""
        with self._cond:
            if not 0 <= slot < self.n_slots:
                raise IndexError(f"slot {slot} outside pool of "
                                 f"{self.n_slots}")
            if self._mask[slot]:
                raise ValueError(f"slot {slot} is already held")
            self._free.remove(slot)
            self._mask[slot] = True
            return slot

    def release(self, slot: int) -> None:
        with self._cond:
            if not 0 <= slot < self.n_slots:
                raise IndexError(f"slot {slot} outside pool of "
                                 f"{self.n_slots}")
            if not self._mask[slot]:
                raise ValueError(f"slot {slot} is not held")
            self._mask[slot] = False
            self._free.append(slot)
            self._cond.notify()

    @property
    def mask(self) -> np.ndarray:
        """Bool (n_slots,) active vector — a live view, index it
        read-only."""
        return self._mask

    @property
    def n_active(self) -> int:
        with self._cond:
            return int(self._mask.sum())

    @property
    def n_free(self) -> int:
        with self._cond:
            return len(self._free)
