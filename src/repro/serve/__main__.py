"""`python -m repro.serve` — self-contained spike-serving demo.

Builds a random recurrent SNN, makes it resident in a `SpikeServer`,
drives it from N concurrent client threads (a mix of stateless
requests and resident streaming sessions), and prints the serving
statistics: p50/p99 latency, requests/sec, mean micro-batch size, and
the compiled batch shapes (the power-of-two buckets).

    PYTHONPATH=src python -m repro.serve --clients 8 --requests 4
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core.api import LIF_neuron
from repro.core.compile import compile_spec
from repro.core.spec import NetworkSpec
from repro.serve import SpikeServer


def demo_spec(n_axons: int, n_neurons: int, fanout: int = 6,
              seed: int = 0) -> NetworkSpec:
    """Random recurrent LIF network via the bulk columnar builder."""
    rng = np.random.default_rng(seed)
    spec = NetworkSpec()
    ax = spec.add_axons(n_axons)
    nid = spec.add_neurons(n_neurons,
                           LIF_neuron(threshold=6, nu=-32, lam=40))
    pre = np.concatenate([
        np.repeat(ax, fanout),
        np.repeat(nid, fanout)])
    post = rng.integers(0, n_neurons, pre.shape[0])
    w = rng.integers(-3, 8, pre.shape[0])
    spec.connect(pre, post, w)
    spec.set_outputs(list(range(min(8, n_neurons))))
    return spec


def _client(srv: SpikeServer, model: str, cid: int, n_requests: int,
            window: int, n_axons: int, use_session: bool,
            results: list) -> None:
    rng = np.random.default_rng(100 + cid)
    sid = srv.open_session(model) if use_session else None
    for r in range(n_requests):
        counts = rng.integers(0, 2, (window, n_axons)).astype(np.int32)
        for attempt in range(4):
            try:
                res = srv.submit(model, counts, session=sid,
                                 seed=cid * 1000 + r).result(timeout=120)
                break
            except RuntimeError:
                # chaos / dispatcher restart: state was rolled back,
                # the same window is safe to resubmit bit-exactly
                if attempt == 3:
                    raise
                time.sleep(0.05)
        results.append(res)
        if srv.tel.log.enabled:
            srv.tel.log.request(
                trace_id=res.trace_id, token="", model=model,
                op="run", status=200, code=None, bucket=res.bucket,
                batch_size=res.batch_size,
                queue_wait_ms=round(res.queue_wait_ms, 3),
                dispatch_ms=round(res.dispatch_ms, 3),
                latency_ms=round(res.latency_ms, 3))
    if sid is not None:
        srv.close_session(model, sid)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve")
    ap.add_argument("--backend", default="engine",
                    choices=["simulator", "engine", "hiaer", "mesh"])
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per client")
    ap.add_argument("--window", type=int, default=8,
                    help="timesteps per serving window")
    ap.add_argument("--axons", type=int, default=16)
    ap.add_argument("--neurons", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--wait-ms", type=float, default=5.0,
                    help="micro-batch deadline")
    ap.add_argument("--sessions", action="store_true",
                    help="give every client a resident session lane")
    ap.add_argument("--log-json", default=None, metavar="PATH|-",
                    help="write one JSON line per request to PATH "
                         "('-' = stdout)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's spans as Chrome trace-event "
                         "JSON (open in Perfetto / chrome://tracing)")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="arm chaos sites, e.g. "
                         "'dispatch_crash@2;batch_exception%%0.05' "
                         "(see python -m repro.faults list)")
    ap.add_argument("--faults-seed", type=int, default=0)
    ap.add_argument("--faults-log", default=None, metavar="PATH",
                    help="append one NDJSON line per fired fault")
    args = ap.parse_args(argv)

    from repro import faults
    from repro.obs import Telemetry, chrome_trace

    if args.faults:
        faults.install(faults.FaultPlan.from_spec(
            args.faults, seed=args.faults_seed,
            log_path=args.faults_log))
    else:
        faults.install_from_env()

    tel = Telemetry(log_json=args.log_json)
    compiled = compile_spec(demo_spec(args.axons, args.neurons),
                            target=args.backend)
    srv = SpikeServer(max_batch=args.max_batch, max_wait_ms=args.wait_ms,
                      telemetry=tel)
    srv.add_model("demo", compiled, window=args.window,
                  n_sessions=args.clients, seed=0)

    # warm the compile caches outside the timed window so the printed
    # latencies are serving latencies, not trace latencies
    with srv:
        srv.submit("demo", np.zeros((args.window, args.axons),
                                    np.int32)).result()
        srv.reset_stats()
        results: list = []
        t0 = time.monotonic()
        threads = [threading.Thread(
            target=_client,
            args=(srv, "demo", c, args.requests, args.window,
                  args.axons, args.sessions, results))
            for c in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.monotonic() - t0
        stats = srv.stats()

    total = args.clients * args.requests
    spike_rate = float(np.mean([r.spikes.mean() for r in results]))
    print(f"served {total} requests from {args.clients} clients in "
          f"{wall:.3f}s  ({total / wall:.1f} req/s)")
    print(f"p50 {stats['p50_ms']:.2f} ms   p99 {stats['p99_ms']:.2f} ms"
          f"   mean batch {stats['mean_batch_size']:.2f}")
    print(f"buffer swaps {stats['buffer']['swaps']}  max future depth "
          f"{stats['buffer']['max_future_depth']}")
    print(f"batch shapes {stats['models']['demo']['batch_shapes']}  "
          f"mean spike rate {spike_rate:.3f}")
    if args.trace_out:
        import json

        obj = chrome_trace(tel.tracer.spans())
        with open(args.trace_out, "w", encoding="utf-8") as fh:
            json.dump(obj, fh)
        print(f"wrote {len(obj['traceEvents'])} trace events to "
              f"{args.trace_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
