"""`SpikeServer` — the always-on serving tier over resident
`Deployment`s.

The paper exposes HiAER-Spike to the community over a web portal; this
is the layer that makes one process serve many concurrent clients:

  * requests enter a double-buffered queue (`serve.queue.DoubleBuffer`
    — the present/future BRAM scheme of the hardware's external-events
    processor: clients append to the FUTURE buffer while the PRESENT
    batch executes, and the swap happens only at a batch boundary);
  * the dispatcher micro-batches them under a deadline + max-batch
    policy into ONE `Deployment.run_lanes` dispatch — the mesh tier's
    amortized collectives (one per hierarchy level per step for the
    whole batch) are what make this an almost-free multiplexing;
  * batch shapes are BUCKETED to powers of two, so a serving session
    compiles each model's lane path at most log2(max_batch) + 1 times
    no matter how client concurrency fluctuates (pinned by the
    `repro.analysis.retrace` gate in tests/test_retrace.py);
  * every client lane is state-isolated: a stateless request runs from
    V = 0 under its own deterministic PRNG stream, a session request
    runs on its private resident lane — either way the result is
    bit-identical to running the request alone, regardless of which
    neighbours shared its micro-batch;
  * `write_synapses` reconfigurations ride the same ordered queue as
    requests but act as BARRIERS: they are applied strictly between
    batches (never mid-flight), so the weight history every request
    observes equals the serial execution of the submission order;
  * multiple resident models share the process; requests route by
    model id and batches never mix models.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, InvalidStateError
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro import faults
from repro.analysis.validate import structural_error
from repro.core import schedule as sched
from repro.core.compile import CompiledNetwork
from repro.core.costmodel import LEVEL_NAMES
from repro.core.deploy import Deployment, deploy
from repro.distributed.elastic import StepWatchdog
from repro.obs import Telemetry
from repro.serve.queue import BufferFull, DoubleBuffer
from repro.serve.session import (DeadlineError, DispatchRestart,
                                 Reconfigure, Request, ServeResult,
                                 Session, SessionStore)

__all__ = ["SpikeServer", "ResidentModel", "next_pow2",
           "DispatchRestart"]


def _resolve(fut: Future, value) -> None:
    """Race-safe `set_result`. A client may cancel its Future at any
    moment (the portal's `wait_for` cancels on timeout, and a bridge
    worker dropping cancels every answer it was waiting on); settling
    a cancelled future raises InvalidStateError, which must neither
    kill the dispatcher thread nor poison the other requests of the
    micro-batch. The done() check cannot close the race — cancellation
    comes from another thread — so the set is also guarded."""
    if not fut.done():
        try:
            fut.set_result(value)
        except InvalidStateError:
            pass


def _reject(fut: Future, exc: BaseException) -> None:
    """Race-safe `set_exception` (see `_resolve`)."""
    if not fut.done():
        try:
            fut.set_exception(exc)
        except InvalidStateError:
            pass


def next_pow2(n: int) -> int:
    """Smallest power of two >= n."""
    n = int(n)
    if n <= 0:
        raise ValueError(
            f"next_pow2 needs a positive batch size, got {n}")
    return 1 << (n - 1).bit_length()


@dataclass
class ResidentModel:
    """One deployed network held resident by the server: its runtime
    handle, the fixed serving window (every dispatch runs exactly
    `window` timesteps — the frame tick of the event processor), and
    its session lanes."""
    name: str
    dep: Deployment
    window: int
    sessions: SessionStore
    requests: int = 0
    batches: int = 0
    lane_steps: int = 0
    trace_shapes: set = field(default_factory=set)
    # applied-reconfigure count: how many write_synapses barriers this
    # resident model has executed — checkpointed alongside the weights
    # so a restore can assert it resumed the same weight history
    reconfig_applied: int = 0


class SpikeServer:
    """Micro-batching spike-stream server over resident deployments.

        srv = SpikeServer(max_batch=8, max_wait_ms=2.0)
        srv.add_model("snn", compiled, window=16, n_sessions=8)
        with srv:
            fut = srv.submit("snn", counts)          # stateless
            sid = srv.open_session("snn")            # resident lane
            fut2 = srv.submit("snn", counts, session=sid)
            res = fut.result()          # ServeResult: spikes, membrane

    Responses are `ServeResult`s carrying the client's own lane sliced
    out of whatever micro-batch it rode in — bit-identical to running
    the request alone.
    """

    def __init__(self, *, max_batch: int = 8, max_wait_ms: float = 2.0,
                 bucket_batch: bool = True,
                 max_pending: Optional[int] = None,
                 telemetry: Optional[Telemetry] = None,
                 stall_after_s: float = 30.0,
                 supervise: bool = True, max_restarts: int = 5,
                 checkpoint_dir: Optional[str] = None,
                 degraded_grace_s: float = 5.0):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) * 1e-3
        self.bucket_batch = bool(bucket_batch)
        self.models: Dict[str, ResidentModel] = {}
        # max_pending bounds the ingestion queue: a submit beyond it
        # raises BufferFull (the portal's 503 + Retry-After) instead of
        # queueing without bound
        self._buf = DoubleBuffer(capacity=max_pending)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._stats_lock = threading.Lock()
        self.latencies_ms: List[float] = []
        self.batch_sizes: List[int] = []
        # telemetry is always AVAILABLE (a default bundle is built when
        # none is passed); only its cost profile changes with tel.on
        self.tel = telemetry if telemetry is not None else Telemetry()
        # dispatcher liveness: the loop stamps _last_tick each
        # iteration (take() idle-ticks every <=50 ms, so a stale stamp
        # means a wedged dispatcher, not an idle one); stall_after_s is
        # generous because a first-compile of a new bucket legitimately
        # holds the loop for seconds
        self.stall_after_s = float(stall_after_s)
        self._last_tick = time.monotonic()
        self._started = False
        self._shutdown_done = False
        # --- fault tolerance (supervised dispatcher) ---
        # supervise=True runs a supervisor thread that restarts a dead
        # dispatch loop: only the in-flight batch is rejected (with
        # DispatchRestart), session lanes roll back to their pre-batch
        # snapshot, and service continues on the SAME compiled
        # executables (recovery adds zero compiles — retrace-gated).
        # After max_restarts exceeded the server goes DOWN (healthz
        # 503) instead of crash-looping. checkpoint_dir, when set, gets
        # an atomic state checkpoint after every recovery.
        self.supervise = bool(supervise)
        self.max_restarts = int(max_restarts)
        self.checkpoint_dir = checkpoint_dir
        self.degraded_grace_s = float(degraded_grace_s)
        self._sup_thread: Optional[threading.Thread] = None
        self._restarts = 0
        self._last_restart: Optional[float] = None
        self._down_reason: Optional[str] = None
        self._crash: Optional[BaseException] = None
        self._inflight: Optional[List] = None
        self._undo = None
        self._shutdown_lock = threading.Lock()
        self._shutdown_started = False
        # hang detection: per-batch wall time through a StepWatchdog —
        # a batch `factor`x over the rolling median for `patience`
        # batches flags the dispatcher as straggling (healthz
        # "degraded"; a hung thread cannot be killed from Python, so
        # stalls degrade rather than restart)
        self._wd = StepWatchdog(factor=8.0, patience=2)
        self._straggler_until = 0.0
        self._setup_metrics()

    # ---------------------------------------------------------- telemetry
    def _setup_metrics(self) -> None:
        mreg = self.tel.metrics
        self._m_requests = mreg.counter(
            "repro_serve_requests_total",
            "Serve requests by model and outcome",
            ("model", "outcome"))
        self._m_latency = mreg.histogram(
            "repro_serve_latency_ms",
            "Per-stage request latency in milliseconds",
            ("model", "stage"))
        self._m_batch = mreg.histogram(
            "repro_serve_batch_size",
            "Dispatched micro-batch sizes",
            ("model",), buckets=[1, 2, 4, 8, 16, 32, 64])
        # last-seen cumulative tallies so scrape-time callbacks can
        # expose monotone sources (AccessCounter, buffer rejects) as
        # true counters via deltas
        self._level_last: Dict = {}
        self._rejected_last = 0
        self._m_level = mreg.counter(
            "repro_level_events_total",
            "Spike exchange events by hierarchy level "
            "(local/NoC/FireFly/Ethernet)", ("model", "level"))
        self._m_rejected = mreg.counter(
            "repro_serve_rejected_total",
            "Submissions shed by the bounded ingestion buffer")
        self._m_restarts = mreg.counter(
            "repro_dispatcher_restarts_total",
            "Supervised dispatcher restarts after a loop crash")
        mreg.register_callback(self._scrape)

    def _scrape(self, mreg) -> None:
        """Scrape-time gauges — values that live elsewhere are read at
        collect instead of instrumenting hot paths."""
        buf = self._buf.stats()
        mreg.gauge("repro_serve_queue_depth",
                   "Pending items in the ingestion buffer"
                   ).set(buf["pending"])
        mreg.gauge("repro_serve_queue_swaps",
                   "Present/future buffer swaps").set(buf["swaps"])
        if buf["rejected"] > self._rejected_last:
            self._m_rejected.inc(buf["rejected"] - self._rejected_last)
            self._rejected_last = buf["rejected"]
        alive = self._thread is not None and self._thread.is_alive()
        mreg.gauge("repro_dispatcher_alive",
                   "1 while the dispatch loop is live").set(int(alive))
        mreg.gauge("repro_dispatcher_status",
                   "Tri-state dispatcher health: 0 ok / 1 degraded / "
                   "2 down").set(
            {"ok": 0, "degraded": 1, "down": 2}[
                self.health()["status"]])
        g_used = mreg.gauge("repro_lanes_in_use",
                            "Resident session lanes held", ("model",))
        g_cap = mreg.gauge("repro_lanes_capacity",
                           "Resident session lanes allocated",
                           ("model",))
        g_compile = mreg.gauge(
            "repro_compile_count",
            "jit compile-cache entries per traced function — a rising "
            "value in steady state is a retrace leak", ("model", "fn"))
        for name, m in list(self.models.items()):
            g_used.set(m.sessions.pool.n_active, model=name)
            g_cap.set(m.sessions.pool.n_slots, model=name)
            ctr = getattr(m.dep, "counter", None)
            if ctr is not None:
                for lvl, v in zip(LEVEL_NAMES, ctr.level_events):
                    key = (name, lvl)
                    last = self._level_last.get(key, 0)
                    if v > last:
                        self._m_level.inc(v - last, model=name,
                                          level=lvl)
                        self._level_last[key] = v
            try:
                from repro.analysis.retrace import compile_counts
                for (_, fn), n in compile_counts(m.dep.impl).items():
                    g_compile.set(n, model=name, fn=fn)
            except Exception:       # noqa: BLE001 — scrape never fails
                pass

    # ------------------------------------------------------------ models
    def add_model(self, name: str,
                  compiled: Optional[CompiledNetwork] = None, *,
                  deployment: Optional[Deployment] = None,
                  window: int, n_sessions: int = 8, seed: int = 0,
                  **deploy_kw) -> ResidentModel:
        """Make a network resident under `name`. Pass a compiled
        artifact (deployed here with `seed`/`deploy_kw`) or an existing
        `Deployment`. `window` fixes the per-dispatch timestep count —
        stateless requests shorter than the window are zero-padded and
        their responses sliced back; session requests must fill it.
        `n_sessions` lanes are allocated for resident client state."""
        if name in self.models:
            raise ValueError(f"model {name!r} already resident")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if deployment is None:
            if compiled is None:
                raise TypeError("add_model needs compiled= or "
                                "deployment=")
            deployment = deploy(compiled, seed=seed, **deploy_kw)
        deployment.alloc_lanes(n_sessions)
        m = ResidentModel(name=name, dep=deployment, window=int(window),
                          sessions=SessionStore(n_sessions))
        self.models[name] = m
        return m

    def _model(self, name: str) -> ResidentModel:
        m = self.models.get(name)
        if m is None:
            raise KeyError(f"no resident model {name!r} "
                           f"(have {sorted(self.models)})")
        return m

    # ---------------------------------------------------------- sessions
    def open_session(self, model: str) -> int:
        """Claim a resident lane for a streaming client; returns the
        session id. The lane's membranes and PRNG stream persist
        between this client's windows."""
        return self._model(model).sessions.open(model).id

    def close_session(self, model: str, session_id: int) -> None:
        """Release the session's lane (per-lane reset first, so the
        next occupant starts clean)."""
        m = self._model(model)
        s = m.sessions.close(session_id)
        m.dep.reset(lanes=[s.lane])

    def reset_session(self, model: str, session_id: int) -> None:
        """Reset ONE client's lane to V = 0 and its construction-seed
        stream; every other lane is untouched."""
        m = self._model(model)
        m.dep.reset(lanes=[m.sessions.get(session_id).lane])

    def session_membrane(self, model: str, session_id: int) -> np.ndarray:
        """Current (n,) membranes of a session's lane."""
        m = self._model(model)
        return m.dep.lane_membrane(m.sessions.get(session_id).lane)

    # ------------------------------------------------------------ submit
    def submit(self, model: str, schedule, *,
               session: Optional[int] = None, seed: int = 0,
               timeout: Optional[float] = None,
               trace: Optional[dict] = None) -> Future:
        """Enqueue one spike window; returns a Future[ServeResult].
        `schedule` is a (T, A) int32 count array or a length-T sequence
        of axon-id lists, T <= the model's window (== for session
        requests — a resident lane always advances exactly one window
        per request, the frame-tick contract that keeps every serving
        batch one compiled shape). `timeout` (seconds) bounds the QUEUE
        wait: a request no batch admits in time resolves its Future
        with a structured `DeadlineError` instead of hanging. `trace`
        is a `Span.ctx()` propagation dict from an upstream span (the
        portal's gateway call) — queue-wait and dispatch spans recorded
        for this request join that trace."""
        m = self._model(model)
        n_axons = m.dep.compiled.n_axons
        if getattr(schedule, "ndim", 0) >= 2 \
                and schedule.shape[-1] > n_axons:
            # same structured report Deployment._pad raises for
            # over-wide padded schedules — a client driving more axons
            # than the model has must fail loudly, not silently clip
            raise structural_error(
                "schedule", "E_SCHED_WIDTH",
                f"schedule drives {schedule.shape[-1]} axon slots but "
                f"model {model!r} has {n_axons} axons; the trailing "
                f"columns would be silently dropped or mis-routed",
                schedule_width=schedule.shape[-1], axon_slots=n_axons)
        counts = sched.encode_schedule(schedule, n_axons)
        T = counts.shape[0]
        if T > m.window:
            raise ValueError(
                f"request has {T} steps, model {model!r} serves "
                f"windows of {m.window} — split it across windows")
        if session is not None:
            m.sessions.get(session)          # raises on unknown ids
            if T != m.window:
                raise ValueError(
                    f"session requests must fill the {m.window}-step "
                    f"window exactly, got {T} (a resident lane always "
                    f"advances one full window per request)")
        if T < m.window:
            counts = np.concatenate(
                [counts, np.zeros((m.window - T, counts.shape[1]),
                                  np.int32)])
        now = time.monotonic()
        req = Request(model=model, counts=counts, steps=T,
                      session=session, seed=int(seed), t_submit=now,
                      deadline=None if timeout is None
                      else now + float(timeout),
                      trace=trace, t_submit_ns=time.monotonic_ns())
        self._put(req)
        return req.future

    def _put(self, item) -> None:
        try:
            self._buf.put(item)
        except BufferFull as e:
            # hint: the present batch drains within one admission
            # deadline — tell shedding clients when to come back
            e.retry_after_s = max(2 * self.max_wait_s, 0.05)
            self._m_requests.inc(model=item.model, outcome="rejected")
            raise

    def reconfigure(self, model: str, pre, post, weight) -> Future:
        """Enqueue a batched `write_synapses` edit. It is applied
        strictly BETWEEN batches, in submission order: requests
        submitted before it observe the old weights, requests after it
        the new ones — exactly the serial execution order."""
        self._model(model)
        rc = Reconfigure(model=model, pre=np.asarray(pre),
                         post=np.asarray(post),
                         weight=np.asarray(weight))
        self._put(rc)
        return rc.future

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "SpikeServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._started = True
        self._shutdown_done = False
        self._shutdown_started = False
        self._down_reason = None
        self._restarts = 0          # explicit start = fresh budget
        self._buf.reopen()          # restart after shutdown/down
        self._last_tick = time.monotonic()
        self._thread = threading.Thread(target=self._dispatch_main,
                                        name="spike-server-dispatch",
                                        daemon=True)
        self._thread.start()
        if self.supervise:
            self._sup_thread = threading.Thread(
                target=self._supervise_loop,
                name="spike-server-supervisor", daemon=True)
            self._sup_thread.start()
        return self

    def shutdown(self, drain: bool = True) -> None:
        """Stop the dispatcher cleanly: every pending Future is
        RESOLVED or CANCELLED before this returns, so no client ever
        hangs on process exit. `drain=True` (default) serves every
        already-queued item first; `drain=False` cancels them. Safe to
        call more than once and CONCURRENTLY from any thread (the
        portal calls it from its signal handler while `__exit__` may
        be mid-shutdown): a once-guard makes every call after the
        first a no-op, so futures are never double-drained. Also safe
        with the dispatcher never started — queued items are then
        cancelled (there is nothing to drain with)."""
        with self._shutdown_lock:
            if self._shutdown_started:
                return
            self._shutdown_started = True
        self._drain = drain
        self._stop.set()
        self._buf.close()          # wakes the dispatcher, put now raises
        t, self._thread = self._thread, None
        sup, self._sup_thread = self._sup_thread, None
        if t is not None:
            t.join()
        if sup is not None:
            sup.join()
        for it in self._buf.drain():    # leftovers (never-started case)
            if not it.future.cancel():
                _reject(it.future,
                        RuntimeError("server stopped before dispatch"))
        self._shutdown_done = True

    # the historical name — same contract
    stop = shutdown

    def __enter__(self) -> "SpikeServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ---------------------------------------------------------- dispatch
    def _coalesce(self, batch: List, nxt) -> bool:
        """May `nxt` join the open micro-batch? Reconfiguration items
        are barriers (always alone); batches never mix models; a
        session can run at most one window per dispatch (its lane is a
        single carry)."""
        head = batch[0]
        if isinstance(head, Reconfigure) or isinstance(nxt, Reconfigure):
            return False
        if nxt.model != head.model:
            return False
        if nxt.session is not None and any(
                r.session == nxt.session for r in batch):
            return False
        return True

    def _dispatch_main(self) -> None:
        """Thread target: the dispatch loop plus a crash trap. A loop
        death (organic or injected `dispatch_crash`) lands here; the
        supervisor reads `_crash` to report the cause when it
        restarts."""
        try:
            self._dispatch_loop()
        except BaseException as e:              # noqa: BLE001 — trap
            self._crash = e
            if not self.supervise:
                # nobody will recover us: roll back + settle the
                # in-flight batch here so no client future hangs on a
                # dead thread (healthz then reports DOWN)
                items, self._inflight = self._inflight, None
                self._rollback_undo()
                self._undo = None
                for it in (items or []):
                    _reject(it.future, e)

    def _dispatch_loop(self) -> None:
        while True:
            self._last_tick = time.monotonic()
            items = self._buf.take(self.max_batch, self.max_wait_s,
                                   coalesce=self._coalesce)
            if not items:
                if self._stop.is_set():
                    break
                continue
            # `_inflight` is what the supervisor rejects if this
            # thread dies before the batch settles; cleared (with the
            # lane undo log) once the batch is fully handled
            self._inflight = items
            # OUTSIDE the per-batch guard: a triggered dispatch_crash
            # kills the THREAD with the batch in flight — the
            # supervised-restart path, not the batch-poison path
            faults.fire("dispatch_crash")
            try:
                if self._stop.is_set() \
                        and not getattr(self, "_drain", True):
                    for it in items:
                        if not it.future.cancel():
                            _reject(it.future,
                                    RuntimeError("server stopped "
                                                 "before dispatch"))
                    continue
                items = self._expire(items)
                if items:
                    self._wd.start()
                    try:
                        if isinstance(items[0], Reconfigure):
                            self._apply_reconfigure(items[0])
                        else:
                            self._run_batch(items)
                    finally:
                        if self._wd.stop()["straggler"]:
                            self._straggler_until = time.monotonic() \
                                + self.degraded_grace_s
            except BaseException as e:          # noqa: BLE001 — futures
                # batch poison (bad input, injected batch_exception, a
                # backend error): roll session lanes back to their
                # pre-batch snapshot, reject ONLY this batch, keep the
                # loop alive
                self._rollback_undo()
                for it in items:                # carry the error out
                    self._m_requests.inc(model=it.model,
                                         outcome="error")
                    _reject(it.future, e)
            finally:
                self._inflight = None
                self._undo = None

    # --------------------------------------------------------- supervisor
    def _supervise_loop(self) -> None:
        """Watch the dispatcher thread; restart it when it dies outside
        shutdown. Runs until `shutdown()` (which joins it)."""
        while not self._stop.wait(0.05):
            t = self._thread
            if t is None or t.is_alive():
                continue
            if self._stop.is_set() or self._shutdown_started:
                break
            if not self._recover():
                break                   # restart budget exhausted: down

    def _rollback_undo(self) -> None:
        """Restore the pre-batch snapshot of every session lane whose
        request did NOT deliver a result — lanes whose futures already
        resolved keep their advanced state (the client observed it),
        the rest roll back so a retry replays the window bit-exactly."""
        undo, self._undo = self._undo, None
        if undo is None:
            return
        m, lanes, V, K, futs = undo
        for i, (lane, fut) in enumerate(zip(lanes, futs)):
            delivered = fut.done() and not fut.cancelled() \
                and fut.exception() is None
            if not delivered:
                m.dep.lane_restore([lane], V[i:i + 1], K[i:i + 1])

    def _recover(self) -> bool:
        """One supervised restart: reject the poisoned in-flight batch
        with `DispatchRestart`, roll undelivered session lanes back,
        checkpoint (if configured), and start a fresh dispatch thread
        on the SAME deployments — no state rebuild, no new compiles.
        Returns False (and marks the server down) once the restart
        budget is exhausted."""
        if self._stop.is_set() or self._shutdown_started:
            return False
        self._restarts += 1
        crash, self._crash = self._crash, None
        t0 = time.monotonic_ns()
        items, self._inflight = self._inflight or [], None
        err = DispatchRestart(
            self._restarts, cause=crash,
            retry_after_s=max(2 * self.max_wait_s, 0.05))
        self._rollback_undo()
        for it in items:
            self._m_requests.inc(model=it.model, outcome="restart")
            _reject(it.future, err)
        self._m_restarts.inc()
        down = self._restarts > self.max_restarts
        if down:
            self._down_reason = (
                f"dispatcher crashed {self._restarts} times "
                f"(max_restarts={self.max_restarts}); last cause: "
                f"{type(crash).__name__ if crash else 'unknown'}")
            # stop accepting and fail everything already queued — a
            # crash-looping dispatcher must go DOWN loudly, not hang
            # its clients
            self._buf.close()
            for it in self._buf.drain():
                _reject(it.future, err)
        elif self.checkpoint_dir is not None:
            try:
                self.checkpoint(self.checkpoint_dir)
            except Exception:   # noqa: BLE001 — recovery must proceed
                pass
        tracer = self.tel.tracer
        if tracer.on:
            tracer.record_batch([tracer.span_record(
                "dispatch_restart", start=t0, end=time.monotonic_ns(),
                restart=self._restarts, in_flight=len(items),
                cause=type(crash).__name__ if crash else "unknown",
                down=down)])
        if down:
            return False
        self._last_restart = self._last_tick = time.monotonic()
        self._thread = threading.Thread(target=self._dispatch_main,
                                        name="spike-server-dispatch",
                                        daemon=True)
        self._thread.start()
        return True

    def _expire(self, items: List) -> List:
        """Resolve queue-expired requests with a structured
        `DeadlineError` (submit(..., timeout=)) and drop them from the
        batch. Reconfigure barriers never expire — they gate weight
        history, and skipping one would change what later requests
        observe."""
        now = time.monotonic()
        live = []
        for it in items:
            dl = getattr(it, "deadline", None)
            if dl is not None and now > dl:
                self._m_requests.inc(model=it.model,
                                     outcome="deadline")
                _reject(it.future, DeadlineError(
                    it.model, dl - it.t_submit, now - it.t_submit))
            else:
                live.append(it)
        return live

    def _apply_reconfigure(self, rc: Reconfigure) -> None:
        m = self._model(rc.model)
        m.dep.write_synapses(rc.pre, rc.post, rc.weight)
        m.reconfig_applied += 1
        _resolve(rc.future, m.dep.weight_uploads)

    def _run_batch(self, reqs: List[Request]) -> None:
        """ONE `run_lanes` dispatch for the whole micro-batch: stack
        the (window, A) counts, bucket B up to a power of two with
        scratch rows (lane -1, zero events), execute, slice each
        client's own lane back out."""
        # injection sites: slow_batch sleeps (hang/watchdog paths),
        # batch_exception raises (the batch-poison recovery path);
        # both fire BEFORE any lane state is read or advanced, so a
        # rejected batch is trivially retryable
        faults.fire("slow_batch")
        faults.fire("batch_exception")
        m = self._model(reqs[0].model)
        B = len(reqs)
        Bp = min(next_pow2(B), self.max_batch) if self.bucket_batch \
            else B
        t_assembled = time.monotonic_ns()   # batch closed: queue wait
        counts = np.stack([r.counts for r in reqs]      # ends here
                          + [np.zeros_like(reqs[0].counts)] * (Bp - B))
        lanes = [(-1 if r.session is None
                  else m.sessions.get(r.session).lane)
                 for r in reqs] + [-1] * (Bp - B)
        seeds = [r.seed for r in reqs] + [0] * (Bp - B)
        # undo log: snapshot the session lanes this batch will advance
        # (host numpy copies, O(batch)); if the dispatch dies before
        # delivering, _rollback_undo restores exactly the undelivered
        # lanes so a client retry replays its window bit-exactly
        live = [(ln, r.future) for ln, r in zip(lanes, reqs)
                if ln >= 0]
        if live:
            snapV, snapK = m.dep.lane_snapshot([ln for ln, _ in live])
            self._undo = (m, [ln for ln, _ in live], snapV, snapK,
                          [f for _, f in live])
        t_dispatch = time.monotonic_ns()
        spikes, membranes = m.dep.run_lanes(lanes, counts, seeds=seeds)
        t_done = time.monotonic_ns()
        dispatch_ms = (t_done - t_dispatch) / 1e6
        m.trace_shapes.add((Bp, m.window))
        done = time.monotonic()
        m.requests += B
        m.batches += 1
        m.lane_steps += B * m.window
        tracer = self.tel.tracer
        lats, qwaits = [], []
        span_out, resolved = [], []
        for i, r in enumerate(reqs):
            lat = (done - r.t_submit) * 1e3
            lats.append(lat)
            qwaits.append((t_assembled - r.t_submit_ns) / 1e6)
            if r.session is not None:
                s = m.sessions.get(r.session)
                s.requests += 1
                s.steps += m.window
            # per-request spans: queue_wait covers submit -> batch
            # assembly, dispatch the (shared) run_lanes execution; both
            # nest under the upstream gateway-call span when the
            # request carried a propagation ctx. They are built as
            # plain finished dicts and committed in ONE record_batch
            # below — two Span objects plus two ring-lock round-trips
            # per request would dominate telemetry's 5% overhead
            # envelope at high request rates
            tid = (r.trace or {}).get("trace_id", "")
            if tracer.on:
                qd = tracer.span_record("queue_wait", ctx=r.trace,
                                        start=r.t_submit_ns,
                                        end=t_assembled, model=r.model)
                tid = qd["trace_id"]
                span_out.append(qd)
                span_out.append(tracer.span_record(
                    "dispatch", trace_id=tid, parent=qd["parent_id"],
                    start=t_dispatch, end=t_done, model=r.model,
                    batch_size=B, bucket=Bp))
            resolved.append((r.future, ServeResult(
                spikes=spikes[i, :r.steps], membrane=membranes[i],
                latency_ms=lat, batch_size=B, model=r.model,
                session=r.session, queue_wait_ms=qwaits[-1],
                dispatch_ms=dispatch_ms, bucket=Bp, trace_id=tid)))
        if tracer.on:
            # commit spans BEFORE resolving futures: a client that has
            # its response can immediately fetch the full trace
            tracer.record_batch(span_out)
        for fut, res in resolved:
            _resolve(fut, res)
        if tracer.on:
            # metric updates are per BATCH, not per request: one key
            # build + lock acquire each, so obs-on stays within the
            # bench's 5% overhead envelope at high request rates
            self._m_requests.inc(B, model=m.name, outcome="ok")
            self._m_latency.observe_many(lats, model=m.name,
                                         stage="total")
            self._m_latency.observe_many(qwaits, model=m.name,
                                         stage="queue_wait")
            self._m_latency.observe(dispatch_ms, model=m.name,
                                    stage="dispatch")
            self._m_batch.observe(B, model=m.name)
        with self._stats_lock:
            self.latencies_ms.extend(lats)
            self.batch_sizes.append(B)

    # ------------------------------------------------------------ health
    def health(self) -> dict:
        """Tri-state liveness + capacity report for `GET /healthz`.

        `status` is one of:
          ok        serving normally (also: not yet started / cleanly
                    shut down — readiness probing during startup and
                    drain-phase scrapes must not flap)
          degraded  still answering but impaired: the supervisor is
                    mid-restart, a restart happened within
                    `degraded_grace_s`, the watchdog flagged straggling
                    batches, or no loop tick for `stall_after_s`
                    (generous — a first-compile legitimately holds the
                    loop for seconds). HTTP 200: the backend recovers
                    on its own, draining it would lose session state.
          down      dead for good: the dispatcher thread died with no
                    supervisor to restart it, the restart budget is
                    exhausted, or no tick for 4x `stall_after_s`. HTTP
                    503 — load balancers eject this backend.

        `ok` stays the boolean transport key (False only for `down`),
        so existing probes keep working."""
        buf = self._buf.stats()
        alive = self._thread is not None and self._thread.is_alive()
        now = time.monotonic()
        tick_age = now - self._last_tick
        status, reason = "ok", None
        if self._down_reason is not None:
            status, reason = "down", self._down_reason
        elif self._started and not self._shutdown_started:
            sup_alive = self._sup_thread is not None \
                and self._sup_thread.is_alive()
            if not alive:
                if sup_alive:
                    status, reason = "degraded", \
                        "dispatcher died; supervisor restarting"
                else:
                    status, reason = "down", \
                        "dispatcher thread dead (unsupervised)"
            elif tick_age > 4 * self.stall_after_s:
                status, reason = "down", (
                    f"dispatcher unresponsive for {tick_age:.1f}s")
            elif tick_age > self.stall_after_s:
                status, reason = "degraded", (
                    f"no dispatch tick for {tick_age:.1f}s")
            elif self._last_restart is not None \
                    and now - self._last_restart < self.degraded_grace_s:
                status, reason = "degraded", (
                    f"dispatcher restarted "
                    f"{now - self._last_restart:.1f}s ago")
            elif now < self._straggler_until:
                status, reason = "degraded", \
                    "watchdog flagged straggling batches"
        return {
            "ok": status != "down",
            "status": status,
            "reason": reason,
            "restarts": self._restarts,
            "dispatcher": {"alive": alive,
                           "started": self._started,
                           "supervised": self.supervise,
                           "restarts": self._restarts,
                           "last_tick_age_s": round(tick_age, 3),
                           "stall_after_s": self.stall_after_s},
            "queue": {"pending": buf["pending"],
                      "capacity": buf["capacity"],
                      "rejected": buf["rejected"]},
            "lanes": {name: {"in_use": m.sessions.pool.n_active,
                             "capacity": m.sessions.pool.n_slots}
                      for name, m in self.models.items()},
        }

    # ------------------------------------------------- checkpoint/restore
    def checkpoint(self, path) -> dict:
        """Atomic on-disk snapshot of every resident model's runtime
        state: lane membranes + PRNG keys, the synapse-weight column,
        and (in aux) the lane->session map, request tallies, and the
        applied-reconfigure count. Written through
        `checkpoint.store.save_tree` (tmp + fsync + rename), so a
        crash mid-save never corrupts the previous checkpoint. Call
        while the dispatcher is quiesced (stopped, drained, or from
        the supervisor between restarts) — lane state is read
        unlocked."""
        from repro.checkpoint.store import save_tree
        tree: Dict[str, dict] = {}
        models_aux: Dict[str, dict] = {}
        for name, m in self.models.items():
            entry = {"syn_weight": m.dep.compiled.syn_weight.copy()}
            st = m.dep.lane_state()
            if st is not None:
                entry["lane_V"] = st["V"]
                entry["lane_keys"] = st["keys"]
            tree[name] = entry
            models_aux[name] = {
                "window": m.window,
                "requests": m.requests,
                "batches": m.batches,
                "reconfigures": m.reconfig_applied,
                "sessions": [{"id": s.id, "lane": s.lane,
                              "requests": s.requests,
                              "steps": s.steps}
                             for s in m.sessions.all()]}
        aux = {"models": models_aux, "restarts": self._restarts}
        save_tree(path, tree, aux=aux)
        return aux

    def restore(self, path) -> dict:
        """Load a `checkpoint()` back into this server: lane state and
        weights onto each deployment (weights as a diff — an unchanged
        column uploads nothing), sessions re-opened on their exact
        original lanes (ids unchanged, so clients resume seamlessly).
        The server must hold the same models (same compiled artifacts,
        same `n_sessions`) with no sessions open yet; call before
        `start()` or while quiesced. Recovered sessions continue
        bit-exact vs the uninterrupted run — pinned in
        tests/test_fault_tolerance.py."""
        from repro.checkpoint.store import restore_tree
        like: Dict[str, dict] = {}
        for name, m in self.models.items():
            entry = {"syn_weight": m.dep.compiled.syn_weight}
            st = m.dep.lane_state()
            if st is not None:
                entry["lane_V"] = st["V"]
                entry["lane_keys"] = st["keys"]
            like[name] = entry
        tree, aux = restore_tree(path, like)
        for name, m in self.models.items():
            entry = tree[name]
            if "lane_V" in entry:
                m.dep.load_lane_state(
                    np.asarray(entry["lane_V"]),
                    np.asarray(entry["lane_keys"]))
            m.dep.load_weights(np.asarray(entry["syn_weight"]))
            ma = (aux or {}).get("models", {}).get(name)
            if ma:
                m.requests = int(ma.get("requests", m.requests))
                m.batches = int(ma.get("batches", m.batches))
                m.reconfig_applied = int(ma.get("reconfigures", 0))
                m.sessions.restore(name, ma.get("sessions", []))
        return aux

    # ------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        """Drop accumulated latency/batch samples (e.g. after warmup,
        so percentiles reflect serving, not tracing)."""
        with self._stats_lock:
            self.latencies_ms.clear()
            self.batch_sizes.clear()

    def stats(self) -> dict:
        """Serving statistics: latency percentiles, occupancy, and the
        ingestion buffer's swap accounting."""
        with self._stats_lock:
            lats = np.asarray(self.latencies_ms, float)
            sizes = list(self.batch_sizes)
        out = {
            "requests": int(lats.size),
            "batches": len(sizes),
            "mean_batch_size": float(np.mean(sizes)) if sizes else 0.0,
            "p50_ms": float(np.percentile(lats, 50)) if lats.size
            else 0.0,
            "p99_ms": float(np.percentile(lats, 99)) if lats.size
            else 0.0,
            "buffer": self._buf.stats(),
            "models": {name: {"requests": mm.requests,
                              "batches": mm.batches,
                              "lane_steps": mm.lane_steps,
                              "open_sessions": mm.sessions.n_open,
                              "batch_shapes":
                                  sorted(mm.trace_shapes)}
                       for name, mm in self.models.items()},
        }
        return out
