"""Always-on serving tier: micro-batched spike-stream serving over
resident `Deployment`s.

    from repro.serve import SpikeServer

    srv = SpikeServer(max_batch=8, max_wait_ms=2.0)
    srv.add_model("snn", compiled, window=16, n_sessions=8)
    with srv:
        res = srv.submit("snn", counts).result()      # ServeResult

Requests from many clients enter a double-buffered queue (the
present/future BRAM scheme of the hardware's external-events
processor), are micro-batched under a deadline + max-batch policy into
single `Deployment.run_lanes` dispatches, and come back per-client:
bit-identical to running each request alone. `python -m repro.serve`
runs a self-contained demo server against a synthetic network.
"""
from repro.serve.queue import (BufferClosed, BufferFull, DoubleBuffer,
                               SlotPool)
from repro.serve.server import ResidentModel, SpikeServer, next_pow2
from repro.serve.session import (DeadlineError, DispatchRestart,
                                 Reconfigure, Request, ServeResult,
                                 Session, SessionStore)

__all__ = [
    "SpikeServer", "ResidentModel", "next_pow2",
    "DoubleBuffer", "SlotPool", "BufferFull", "BufferClosed",
    "Request", "Reconfigure", "ServeResult", "Session", "SessionStore",
    "DeadlineError", "DispatchRestart",
]
