"""Serving-tier data model: requests, results, sessions.

A `Request` is one client window of spike input for one resident
model; a `Session` pins a client to a persistent deployment lane
(membranes + PRNG stream survive between windows, so a streaming
client observes exactly the dynamics of one uninterrupted run). A
`Reconfigure` item is a batched `write_synapses` edit that rides the
same ordered queue as requests but acts as a BARRIER: it is never
applied while a batch is in flight, and every request submitted before
it runs under the old weights, every request after under the new ones
— the serial-equivalence contract tests/test_serve.py pins.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.serve.queue import SlotPool

__all__ = ["Request", "Reconfigure", "ServeResult", "Session",
           "SessionStore", "DeadlineError", "DispatchRestart"]


class DispatchRestart(RuntimeError):
    """The dispatcher thread died while this request's batch was in
    flight; the supervisor restarted it. Only the poisoned batch is
    rejected — session lane state was rolled back to the pre-batch
    snapshot, so resubmitting the same window yields the bit-exact
    uninterrupted result. The portal maps this to HTTP 503
    E_DISPATCH_RESTART with Retry-After = `retry_after_s`."""

    def __init__(self, restart: int, cause: Optional[BaseException]
                 = None, retry_after_s: float = 0.05):
        why = f" ({type(cause).__name__}: {cause})" if cause else ""
        super().__init__(
            f"dispatcher crashed mid-batch and was restarted "
            f"(restart #{restart}){why} — this request was rejected, "
            f"session state rolled back; safe to retry")
        self.restart = int(restart)
        self.cause = cause
        self.retry_after_s = float(retry_after_s)


class DeadlineError(TimeoutError):
    """A request expired in the ingestion queue before its batch was
    dispatched (`SpikeServer.submit(..., timeout=)`). Structured: the
    portal maps it to HTTP 504 with these fields in the JSON body."""

    def __init__(self, model: str, timeout_s: float, waited_s: float):
        super().__init__(
            f"request for model {model!r} expired after waiting "
            f"{waited_s * 1e3:.1f} ms in the queue "
            f"(timeout {timeout_s * 1e3:.1f} ms) — the dispatcher "
            f"never admitted it to a batch")
        self.model = model
        self.timeout_s = float(timeout_s)
        self.waited_s = float(waited_s)


@dataclass
class Request:
    """One client window: (T, A) int32 axon event counts for `model`.
    `session` is a lane-backed session id (None = stateless scratch
    run under the deterministic stream derived from `seed`); `steps`
    is the client's un-padded T, used to slice the response;
    `deadline` (monotonic seconds, None = wait forever) expires the
    request with a `DeadlineError` if no batch admits it in time."""
    model: str
    counts: np.ndarray
    steps: int
    session: Optional[int] = None
    seed: int = 0
    future: Future = field(default_factory=Future)
    t_submit: float = 0.0
    deadline: Optional[float] = None
    # telemetry: `trace` is a Span.ctx() propagation dict ({"trace_id",
    # "parent"}) carried from the portal, or None for untraced callers;
    # `t_submit_ns` is the monotonic-ns twin of t_submit so queue-wait
    # spans can be backdated to the submit instant.
    trace: Optional[dict] = None
    t_submit_ns: int = 0


@dataclass
class Reconfigure:
    """A batched synapse-weight edit queued as a batch barrier."""
    model: str
    pre: np.ndarray
    post: np.ndarray
    weight: np.ndarray
    future: Future = field(default_factory=Future)


@dataclass
class ServeResult:
    """Per-request response: the client's own lane sliced out of the
    micro-batch. `spikes` is (steps, n) bool, `membrane` the (n,) int32
    final potentials of the lane (global neuron-id order)."""
    spikes: np.ndarray
    membrane: np.ndarray
    latency_ms: float
    batch_size: int
    model: str
    session: Optional[int] = None
    # stage latencies + trace id (telemetry; zero/empty when off):
    # queue_wait_ms covers submit -> batch assembly, dispatch_ms the
    # run_lanes execution, bucket the padded power-of-two batch shape
    queue_wait_ms: float = 0.0
    dispatch_ms: float = 0.0
    bucket: int = 0
    trace_id: str = ""


@dataclass
class Session:
    """A client's resident state handle: deployment lane `lane` of
    model `model`."""
    id: int
    model: str
    lane: int
    requests: int = 0
    steps: int = 0


class SessionStore:
    """Lane-backed session registry for one resident model. Lanes come
    from a `SlotPool` over the deployment's allocated lanes; closing a
    session releases its lane for the next client (after a per-lane
    reset, so no state leaks between successive occupants)."""

    def __init__(self, n_lanes: int):
        self.pool = SlotPool(n_lanes)
        self._sessions: Dict[int, Session] = {}
        self._lock = threading.Lock()

    def open(self, model: str) -> Session:
        lane = self.pool.acquire()
        if lane is None:
            raise RuntimeError(
                f"model {model!r} has no free session lanes "
                f"({self.pool.n_slots} allocated)")
        s = Session(id=lane, model=model, lane=lane)
        with self._lock:
            self._sessions[s.id] = s
        return s

    def get(self, session_id: int) -> Session:
        with self._lock:
            s = self._sessions.get(session_id)
        if s is None:
            raise KeyError(f"unknown session {session_id}")
        return s

    def close(self, session_id: int) -> Session:
        with self._lock:
            s = self._sessions.pop(session_id, None)
        if s is None:
            raise KeyError(f"unknown session {session_id}")
        self.pool.release(s.lane)
        return s

    def all(self) -> list:
        """Stable-ordered snapshot of the open sessions (checkpoint
        serialization)."""
        with self._lock:
            return sorted(self._sessions.values(), key=lambda s: s.id)

    def restore(self, model: str, entries) -> None:
        """Re-open checkpointed sessions on their exact original lanes
        (session id == lane id, so clients resume with unchanged ids).
        `entries` is the list of dicts `SpikeServer.checkpoint` wrote:
        {"id", "lane", "requests", "steps"}."""
        for e in entries:
            lane = self.pool.acquire_slot(int(e["lane"]))
            s = Session(id=int(e["id"]), model=model, lane=lane,
                        requests=int(e.get("requests", 0)),
                        steps=int(e.get("steps", 0)))
            with self._lock:
                self._sessions[s.id] = s

    @property
    def n_open(self) -> int:
        with self._lock:
            return len(self._sessions)
