"""`python -m repro.faults` — chaos CLI for the serving stack.

    python -m repro.faults                  # list sites + env plan
    python -m repro.faults demo             # replayable dispatcher chaos
    python -m repro.faults portal-smoke     # kill-a-worker portal smoke

`demo` arms a seeded plan against a live `SpikeServer`, drives a fixed
request sequence through injected dispatcher crashes and poisoned
batches, then REPLAYS the identical plan on a fresh server and asserts
the two runs produced the same outcome sequence and the same response
digests — deterministic chaos, the property the test-suite matrix is
built on.

`portal-smoke` starts a multi-worker portal with `worker_exit` armed in
the workers (via REPRO_FAULTS, which spawned workers inherit), lets one
front-end process hard-exit mid-traffic, and verifies the parent
respawns it while every surviving response stays bit-exact. CI runs
both and uploads the NDJSON fault log.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.faults import SITES, FaultPlan, install, install_from_env, \
    uninstall


def _cmd_list(args) -> int:
    print("fault sites:")
    for name, action in SITES.items():
        print(f"  {name:<16} default action: {action}")
    plan = install_from_env()
    if plan is not None:
        print(f"env plan (REPRO_FAULTS): {plan.spec()!r} "
              f"seed={plan.seed}")
    else:
        print("no env plan (REPRO_FAULTS unset)")
    return 0


def _chaos_run(plan_spec: str, seed: int, n_requests: int,
               log_path) -> list:
    """One chaos pass: fresh server, fresh plan, fixed request
    sequence; returns the per-request outcome list."""
    import numpy as np

    from repro.core.compile import compile_spec
    from repro.portal.gateway import result_digest
    from repro.serve import SpikeServer
    from repro.serve.__main__ import demo_spec

    compiled = compile_spec(demo_spec(16, 64), target="engine")
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0)
    srv.add_model("demo", compiled, window=8, n_sessions=4, seed=0)
    plan = install(FaultPlan.from_spec(plan_spec, seed=seed,
                                       log_path=log_path))
    outcomes = []
    try:
        with srv:
            rng = np.random.default_rng(0)
            for r in range(n_requests):
                counts = rng.integers(0, 2, (8, 16)).astype(np.int32)
                try:
                    res = srv.submit("demo", counts, seed=r).result(
                        timeout=60)
                    outcomes.append(
                        ("ok", result_digest(res.spikes, res.membrane)))
                except Exception as e:  # noqa: BLE001 — outcome record
                    outcomes.append(("err", type(e).__name__))
            hz = srv.health()
            outcomes.append(("health", hz["status"],
                             f"restarts={hz['restarts']}"))
    finally:
        uninstall()
    return outcomes


def _cmd_demo(args) -> int:
    spec = args.plan
    print(f"plan: {spec!r}  seed={args.seed}  "
          f"requests={args.requests}")
    run1 = _chaos_run(spec, args.seed, args.requests, args.log)
    run2 = _chaos_run(spec, args.seed, args.requests, args.log)
    for i, o in enumerate(run1):
        print(f"  req[{i}] -> {o}")
    identical = run1 == run2
    print(f"replay bit-identical: {identical}")
    if args.log:
        print(f"fault log: {args.log}")
    return 0 if identical else 1


def _cmd_portal_smoke(args) -> int:
    import http.client
    import time

    import numpy as np

    from repro.core.compile import compile_spec
    from repro.portal.gateway import Portal
    from repro.serve import SpikeServer
    from repro.serve.__main__ import demo_spec

    # workers inherit the armed plan through the environment: the K-th
    # admitted request in SOME worker hard-exits that worker process
    os.environ["REPRO_FAULTS"] = args.plan
    os.environ["REPRO_FAULTS_SEED"] = str(args.seed)
    if args.log:
        os.environ["REPRO_FAULTS_LOG"] = os.path.abspath(args.log)

    compiled = compile_spec(demo_spec(16, 64), target="engine")
    srv = SpikeServer(max_batch=4, max_wait_ms=1.0)
    srv.add_model("demo", compiled, window=8, n_sessions=4, seed=0)

    def req(port, method, path, body=None):
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        payload = None if body is None else json.dumps(body)
        conn.request(method, path, body=payload,
                     headers={"Content-Type": "application/json"})
        r = conn.getresponse()
        out = json.loads(r.read().decode("utf-8"))
        conn.close()
        return r.status, out

    counts = np.random.default_rng(0).integers(
        0, 2, (8, 16)).astype(np.int32).tolist()
    digests, retried = [], 0
    with srv, Portal(srv, port=0, workers=args.workers) as portal:
        for i in range(args.requests):
            for attempt in range(6):
                try:
                    s, out = req(portal.port, "POST",
                                 "/v1/demo/run",
                                 {"counts": counts, "seed": 0})
                except OSError:
                    # the connection we hit belonged to the dying
                    # worker — retry lands on a survivor (or the
                    # respawned one)
                    retried += 1
                    time.sleep(0.2)
                    continue
                break
            else:
                print("FAIL: request never succeeded after retries")
                return 1
            if s != 200:
                print(f"FAIL: request {i} -> HTTP {s}: {out}")
                return 1
            digests.append(out["digest"])
            time.sleep(args.spacing_s)
        deadline = time.monotonic() + 30
        while portal.worker_restarts < 1 \
                and time.monotonic() < deadline:
            time.sleep(0.1)
        restarts = portal.worker_restarts
        s, hz = req(portal.port, "GET", "/healthz")
    ok = (restarts >= 1 and s == 200 and hz["status"] == "ok"
          and len(set(digests)) == 1)
    print(f"served {len(digests)} requests across worker kill "
          f"(retried {retried}); worker restarts: {restarts}; "
          f"final healthz: {hz['status']}; "
          f"digests identical: {len(set(digests)) == 1}")
    print("portal-smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.faults")
    sub = ap.add_subparsers(dest="cmd")

    ls = sub.add_parser("list", help="show fault sites + the env plan")
    ls.set_defaults(fn=_cmd_list)

    d = sub.add_parser("demo", help="deterministic chaos replay "
                                    "against a live SpikeServer")
    d.add_argument("--plan",
                   default="dispatch_crash@2;batch_exception@5")
    d.add_argument("--seed", type=int, default=0)
    d.add_argument("--requests", type=int, default=8)
    d.add_argument("--log", default=None, metavar="PATH",
                   help="append NDJSON trigger records to PATH")
    d.set_defaults(fn=_cmd_demo)

    p = sub.add_parser("portal-smoke",
                       help="multi-worker portal; one worker "
                            "hard-exits mid-traffic and is respawned")
    p.add_argument("--plan", default="worker_exit@3")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--requests", type=int, default=10)
    p.add_argument("--spacing-s", type=float, default=0.05)
    p.add_argument("--log", default=None, metavar="PATH")
    p.set_defaults(fn=_cmd_portal_smoke)

    args = ap.parse_args(argv)
    if not getattr(args, "fn", None):
        return _cmd_list(args)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
