"""Deterministic fault injection for the serving stack — stdlib only.

Large neuromorphic deployments treat component failure as the normal
case; to test that our serve -> portal -> bridge stack actually
recovers, failures must be INJECTABLE and REPLAYABLE: the same armed
plan must produce the same crash at the same batch on every run, so a
chaos test that passed yesterday pins the same recovery path today.

A `FaultPlan` arms named injection SITES. The production code calls
`faults.fire("<site>")` at each site — a module-level no-op (one global
load + `is None` check) unless a plan is installed, which is what keeps
the disarmed hooks inside the serve/portal bench's <= 5% overhead
envelope. Armed sites trigger either at exact hit indices (`@i,j` —
the i-th time that site is reached, 1-based) or with a seeded Bernoulli
rate (`%p` — `random.Random(seed ^ site)` drives it, so the sequence
of triggers is a pure function of (plan spec, seed)).

Sites (all wired through serve/server.py, portal/bridge.py,
portal/http.py):

  dispatch_crash   dispatcher loop dies mid-batch  -> supervisor restart
  batch_exception  one micro-batch raises          -> batch rejected,
                                                      loop survives
  slow_batch       one micro-batch sleeps delay_s  -> watchdog/deadline
  bridge_drop      worker's UDS transport severed  -> auto-reconnect
  worker_exit      front-end worker hard-exits     -> parent respawns

Plans come from code (`FaultPlan().arm(...)`), from a spec string
(`FaultPlan.from_spec("dispatch_crash@2;slow_batch%0.25:delay=0.05")`),
or from the environment (`install_from_env()` reads REPRO_FAULTS /
REPRO_FAULTS_SEED / REPRO_FAULTS_LOG) — the env route is how bridge
worker subprocesses inherit the chaos plan of their parent. Every
trigger appends one NDJSON line to the log path (O_APPEND single
writes, so N processes sharing one file stay line-atomic).

This module must stay importable by the jax-free bridge workers:
stdlib only, no numpy.
"""
from __future__ import annotations

import json
import os
import random
import threading
import time
import zlib
from typing import Dict, Iterable, Optional

__all__ = ["FaultPlan", "InjectedFault", "SITES", "fire", "install",
           "uninstall", "current", "install_from_env"]

# site name -> default action when triggered
SITES = {
    "dispatch_crash": "raise",
    "batch_exception": "raise",
    "slow_batch": "sleep",
    "bridge_drop": "flag",
    "worker_exit": "exit",
}

_EXIT_CODE = 17          # distinguishable from crashes and signals


class InjectedFault(RuntimeError):
    """Raised by a triggered `raise`-action site. Carries the site and
    the 1-based hit index so recovery paths (and their tests) can tell
    injected failures from organic ones."""

    def __init__(self, site: str, hit: int):
        super().__init__(
            f"injected fault at site {site!r} (hit #{hit})")
        self.site = site
        self.hit = hit


class _Site:
    """Armed state of one injection site."""

    __slots__ = ("name", "at", "rate", "delay_s", "action", "hits",
                 "fired", "_rng")

    def __init__(self, name: str, at: Iterable[int] = (),
                 rate: float = 0.0, delay_s: float = 0.05,
                 action: Optional[str] = None, seed: int = 0):
        if name not in SITES:
            raise ValueError(f"unknown fault site {name!r} "
                             f"(have {sorted(SITES)})")
        self.name = name
        self.at = frozenset(int(i) for i in at)
        self.rate = float(rate)
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        self.delay_s = float(delay_s)
        self.action = action or SITES[name]
        self.hits = 0
        self.fired = 0
        # per-site deterministic stream: the trigger sequence depends
        # only on (seed, site name), never on dict order or other sites
        self._rng = random.Random(seed ^ zlib.crc32(name.encode()))

    def spec(self) -> str:
        s = self.name
        if self.at:
            s += "@" + ",".join(str(i) for i in sorted(self.at))
        if self.rate:
            s += f"%{self.rate:g}"
        if self.action == "sleep":
            s += f":delay={self.delay_s:g}"
        return s


class FaultPlan:
    """A seeded, replayable set of armed injection sites.

        plan = FaultPlan(seed=7).arm("dispatch_crash", at=[2])
        faults.install(plan)
        ... exercise the server; batch #2's dispatch dies ...
        faults.uninstall()

    Thread-safe: `fire` is called from the dispatcher thread, client
    threads, and asyncio loops concurrently; hit counting is locked so
    `@i` means the i-th arrival globally."""

    def __init__(self, seed: int = 0, log_path: Optional[str] = None):
        self.seed = int(seed)
        self.log_path = log_path
        self._sites: Dict[str, _Site] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------ build
    def arm(self, site: str, *, at: Iterable[int] = (),
            rate: float = 0.0, delay_s: float = 0.05,
            action: Optional[str] = None) -> "FaultPlan":
        self._sites[site] = _Site(site, at=at, rate=rate,
                                  delay_s=delay_s, action=action,
                                  seed=self.seed)
        return self

    @classmethod
    def from_spec(cls, spec: str, *, seed: int = 0,
                  log_path: Optional[str] = None) -> "FaultPlan":
        """Parse `site[@i,j][%rate][:delay=s]` entries joined by `;`.

            dispatch_crash@2;slow_batch%0.25:delay=0.05;worker_exit@3
        """
        plan = cls(seed=seed, log_path=log_path)
        for entry in spec.split(";"):
            entry = entry.strip()
            if not entry:
                continue
            body, _, opts = entry.partition(":")
            delay_s = 0.05
            for kv in filter(None, opts.split(":")):
                k, _, v = kv.partition("=")
                if k.strip() != "delay":
                    raise ValueError(
                        f"unknown fault option {k!r} in {entry!r}")
                delay_s = float(v)
            rate = 0.0
            if "%" in body:
                body, _, r = body.partition("%")
                rate = float(r)
            at: tuple = ()
            if "@" in body:
                body, _, idx = body.partition("@")
                at = tuple(int(i) for i in idx.split(",") if i)
            plan.arm(body.strip(), at=at, rate=rate, delay_s=delay_s)
        return plan

    def spec(self) -> str:
        """Round-trippable spec string (the form workers inherit via
        REPRO_FAULTS)."""
        return ";".join(s.spec() for s in self._sites.values())

    # ------------------------------------------------------------ fire
    def fire(self, site: str, **ctx) -> bool:
        """Count one arrival at `site`; trigger per the armed policy.
        Returns True for `flag`-action triggers (the call site performs
        the fault itself, e.g. severing a transport), False when
        disarmed/untriggered; raises `InjectedFault` for raise-action
        sites; sleeps for `sleep`; hard-exits for `exit`."""
        st = self._sites.get(site)
        if st is None:
            return False
        with self._lock:
            st.hits += 1
            hit = st.hits
            trig = hit in st.at or (
                st.rate > 0.0 and st._rng.random() < st.rate)
            if trig:
                st.fired += 1
        if not trig:
            return False
        self._log(site, hit, st.action, ctx)
        if st.action == "sleep":
            time.sleep(st.delay_s)
            return False
        if st.action == "exit":
            # simulate a worker process dying uncleanly: no atexit, no
            # finally blocks — the parent's reaper must cope
            os._exit(_EXIT_CODE)
        if st.action == "flag":
            return True
        raise InjectedFault(site, hit)

    def _log(self, site: str, hit: int, action: str, ctx: dict) -> None:
        if not self.log_path:
            return
        rec = {"ts": round(time.time(), 6), "pid": os.getpid(),
               "site": site, "hit": hit, "action": action}
        if ctx:
            rec.update(ctx)
        line = (json.dumps(rec) + "\n").encode("utf-8")
        try:
            fd = os.open(self.log_path,
                         os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
            try:
                os.write(fd, line)
            finally:
                os.close(fd)
        except OSError:
            pass                       # chaos logging never adds faults

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        with self._lock:
            return {name: {"hits": st.hits, "fired": st.fired,
                           "action": st.action}
                    for name, st in self._sites.items()}


# --------------------------------------------------------------- global
# the one installed plan; `fire` below is the hook production code
# calls — when no plan is installed it is one global read + None check
_PLAN: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> FaultPlan:
    global _PLAN
    _PLAN = plan
    return plan


def uninstall() -> None:
    global _PLAN
    _PLAN = None


def current() -> Optional[FaultPlan]:
    return _PLAN


def fire(site: str, **ctx) -> bool:
    """The injection hook. Disarmed (no plan installed) it returns
    False immediately — cheap enough to leave compiled into every hot
    path (bench-gated <= 5% with hooks in and disarmed)."""
    p = _PLAN
    if p is None:
        return False
    return p.fire(site, **ctx)


def install_from_env() -> Optional[FaultPlan]:
    """Install a plan from REPRO_FAULTS (spec), REPRO_FAULTS_SEED, and
    REPRO_FAULTS_LOG (NDJSON trigger log). No-op without REPRO_FAULTS.
    Bridge workers call this on startup, so `--faults` on the parent
    portal arms the whole process tree with one deterministic plan."""
    spec = os.environ.get("REPRO_FAULTS")
    if not spec:
        return None
    plan = FaultPlan.from_spec(
        spec, seed=int(os.environ.get("REPRO_FAULTS_SEED", "0")),
        log_path=os.environ.get("REPRO_FAULTS_LOG") or None)
    return install(plan)
