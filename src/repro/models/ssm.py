"""Mamba-2 SSD (state-space duality) block — chunked scan formulation.

Train/prefill run the blocked SSD algorithm (intra-chunk quadratic +
inter-chunk state recurrence, chunk=cfg.ssm.chunk_size); decode runs the O(1)
recurrent update against carried (conv_state, ssd_state) — which is why
mamba2-780m is long_500k-eligible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.layers import dense_init, apply_norm


def ssm_init(key, cfg, dtype):
    s, d = cfg.ssm, cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    N = s.d_state
    conv_dim = d_in + 2 * N            # x_ssm + B + C (single group)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * d_in + 2 * N + H), dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), dtype,
                             fan_in=s.d_conv),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "out_norm": {"scale": jnp.ones((d_in,), dtype)},
        "w_out": dense_init(ks[2], (d_in, d), dtype),
    }


def _split_in(cfg, proj):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    N = s.d_state
    H = d_in // s.head_dim
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * N], axis=-1)
    return z, xbc, dt, d_in, N, H


def _causal_conv(w, b, x, state=None):
    """Depthwise causal conv, width K. x (B,S,C). state (B,K-1,C) for decode.
    Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state, x], axis=1)
    y = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = pad[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(y), new_state


def ssd_chunked(xh, a, Bm, Cm, chunk, init_state=None):
    """Blocked SSD. xh (B,S,H,P) inputs (dt-scaled); a (B,S,H) decay factors
    in (0,1); Bm/Cm (B,S,N). Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    r = lambda t: t.reshape(B, nc, chunk, *t.shape[2:])
    xc, ac, Bc, Cc = r(xh), r(a), r(Bm), r(Cm)
    la = jnp.log(jnp.maximum(ac.astype(jnp.float32), 1e-20))   # (B,nc,c,H)
    cum = jnp.cumsum(la, axis=2)                               # within-chunk
    # intra-chunk (quadratic in chunk): y_t = sum_{s<=t} C_t.B_s prod a
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,t,s,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    M = cb[..., None] * decay                                  # (B,nc,t,s,H)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", M, xc.astype(jnp.float32))
    # chunk-final states: sum_s prod_{s<u<=c} a * B_s x_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                    # (B,nc,c,H)
    st = jnp.einsum("bcsh,bcsn,bcshp->bchpn", tail, Bc.astype(jnp.float32),
                    xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H)

    def scan_fn(h, inp):
        st_c, dec_c = inp
        h_new = h * dec_c[:, :, None, None] + st_c
        return h_new, h
    h0 = (jnp.zeros((B, H, Pd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    hT, h_prev = jax.lax.scan(scan_fn, h0,
                              (st.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prev = h_prev.swapaxes(0, 1)                             # (B,nc,H,P,N)
    # inter-chunk contribution: C_t . (decay-to-t * h_prev)
    y_inter = jnp.einsum("bcth,bctn,bchpn->bcthp", jnp.exp(cum), Cc.astype(jnp.float32),
                         h_prev)
    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return y, hT


def ssm_apply(p, x, cfg, cache=None):
    """x (B,S,d). cache = {'conv': (B,K-1,C), 'ssd': (B,H,P,N)} for decode."""
    s = cfg.ssm
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt, d_in, N, H = _split_in(cfg, proj)
    Pd = s.head_dim
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(p["conv_w"], p["conv_b"], xbc, conv_state)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = jnp.exp(-jnp.exp(p["a_log"]) * dt)                        # (B,S,H)
    xh = xs.reshape(*xs.shape[:2], H, Pd)
    xh = constrain(xh, "batch", None, "model", None)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    if cache is not None and x.shape[1] == 1:
        # recurrent decode step: h = a h + B x_dt ; y = C.h
        h = cache["ssd"].astype(jnp.float32)                      # (B,H,P,N)
        h = h * a[:, 0, :, None, None] + jnp.einsum(
            "bhp,bn->bhpn", xdt[:, 0], Bm[:, 0].astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
        y = y[:, None]
        new_cache = {"conv": new_conv, "ssd": h.astype(cache["ssd"].dtype)}
    else:
        y, hT = ssd_chunked(xdt, a, Bm, Cm, min(s.chunk_size, x.shape[1]))
        new_cache = None
        if cache is not None:   # prefill
            new_cache = {"conv": new_conv, "ssd": hT.astype(x.dtype)}
    y = y + xh.astype(jnp.float32) * p["d_skip"][..., None]
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = apply_norm(p["out_norm"], y)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"]), new_cache


def ssm_cache_shape(cfg, batch, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return {"conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
            "ssd": jnp.zeros((batch, H, s.head_dim, s.d_state), dtype)}
