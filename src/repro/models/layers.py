"""Shared layers: norms, init, RoPE/sinusoidal positions, MLPs, embeddings,
and the sequence-chunked cross-entropy loss (never materializes full logits).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain


def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def norm_init(dim, dtype, kind="rmsnorm"):
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- positions
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) or (..., S, hd); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim - ang.ndim >= 2:                         # head axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d_model: int):
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------- MLP
def mlp_init(key, cfg, d_ff, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (d, d_ff), dtype),
         "w_out": dense_init(ks[1], (d_ff, d), dtype)}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], (d, d_ff), dtype)
    return p


def mlp_apply(p, x, cfg):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(g) * h
    elif cfg.act == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.gelu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


# ---------------------------------------------------------------- embeddings
def padded_vocab(cfg) -> int:
    return ((cfg.vocab_size + 127) // 128) * 128


def embed_init(key, cfg, dtype):
    vp, d = padded_vocab(cfg), cfg.d_model
    ks = jax.random.split(key, 2)
    p = {"table": dense_init(ks[0], (vp, d), dtype, fan_in=d)}
    if not cfg.tie_embeddings:
        p["out"] = dense_init(ks[1], (d, vp), dtype)
    return p


def embed_lookup(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, h, cfg):
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h, p["table"])
    return jnp.einsum("...d,dv->...v", h, p["out"])


def chunked_ce_loss(emb_params, h, labels, mask, cfg):
    """Cross entropy over next tokens, seq-chunked so (B,S,Vp) logits never
    materialize (Vp up to 256k). Differentiable; each chunk rematerialized."""
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    while S % chunk:        # largest divisor of S not above loss_chunk
        chunk -= 1
    n = S // chunk
    hc = h.reshape(B, n, chunk, D).swapaxes(0, 1)          # (n,B,c,D)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        hx, lx, mx = xs
        logits = unembed(emb_params, hx, cfg).astype(jnp.float32)
        # mask vocab padding
        vp = logits.shape[-1]
        pad = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mx
        return (carry[0] + ce.sum(), carry[1] + mx.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
