"""Attention: GQA (full + sliding-window local) and DeepSeek-V2 MLA.

Two tensor-parallel layouts, chosen per-arch by head divisibility
(launch/sharding.py):
  * head-sharded  — heads split over 'model' (Megatron style), when
    n_heads % tp == 0;
  * seq-sharded   — query positions split over 'model' and K/V gathered,
    for ragged head counts (qwen2-7b 28H, musicgen 24H, recurrentgemma 10H).

Long sequences use q-chunked, rematerialized attention (flash-attention via
remat): scores for one query chunk only are ever live; the backward pass
recomputes them. The Pallas flash kernel (kernels/flash_attention.py) is the
TPU runtime path; XLA lowering here is what the dry-run rooflines.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


def gqa_init(key, cfg, dtype):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, H, hd), dtype, fan_in=d),
         "wk": dense_init(ks[1], (d, KV, hd), dtype, fan_in=d),
         "wv": dense_init(ks[2], (d, KV, hd), dtype, fan_in=d),
         "wo": dense_init(ks[3], (H, hd, d), dtype, fan_in=H * hd)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def _qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, q_start, kv_len, window, scale):
    """Scores for q block vs full k/v with causal (+optional window) mask.
    q: (B,c,H,hd) k/v: (B,T,KV,hd). kv_len: valid kv prefix length (int or
    traced scalar). Returns (B,c,H,hd)."""
    B, c, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qg = q.reshape(B, c, KV, rep, hd)
    s = jnp.einsum("bcgrk,btgk->bgrct", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))               # (B,KV,rep,c,T)
    q_idx = q_start + jnp.arange(c)
    k_idx = jnp.arange(T)
    mask = k_idx[None, :] <= q_idx[:, None]
    mask &= (k_idx < kv_len)[None, :]
    if window is not None:
        mask &= (k_idx[None, :] > q_idx[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrct,btgk->bcgrk", a, v.astype(jnp.float32))
    return o.reshape(B, c, H, hd).astype(q.dtype)


def attend(q, k, v, cfg, q_start=0, kv_len=None, window=None, q_chunk=1024):
    """Causal attention, q-chunked + rematerialized above q_chunk rows."""
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    if kv_len is None:
        kv_len = k.shape[1]
    if S <= q_chunk:
        return _sdpa(q, k, v, q_start, kv_len, window, scale)
    assert S % q_chunk == 0, (S, q_chunk)
    n = S // q_chunk
    qc = q.reshape(B, n, q_chunk, H, hd).swapaxes(0, 1)

    @jax.checkpoint
    def body(i, q_blk):
        return _sdpa(q_blk, k, v, q_start + i * q_chunk, kv_len, window, scale)

    o = jax.lax.map(lambda args: body(*args),
                    (jnp.arange(n), qc))
    return o.swapaxes(0, 1).reshape(B, S, H, hd)


def gqa_apply(p, x, cfg, positions, layout="heads", window=None,
              cache=None, cache_pos=None):
    """Full/local GQA. cache: dict(k,v,(ring) ) for decode; None for train.

    Returns (out, new_cache). For training new_cache is None; for prefill the
    cache dict is created; for decode (x has S==1) the cache is updated at
    cache_pos."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    new_cache = None
    if cache is not None and S == 1:
        # decode: append to cache (ring buffer when windowed)
        if window is not None:
            slot = cache_pos % cache["k"].shape[1]
        else:
            slot = cache_pos
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        if window is not None:
            # ring buffer (size = min(window, max_len)): positions are
            # cache_pos-T+1..cache_pos laid out mod T; build per-slot
            # validity+causality mask by absolute position of each slot.
            T = ck.shape[1]
            slots = jnp.arange(T)
            # absolute position stored in each slot
            abs_pos = cache_pos - ((slot - slots) % T)
            mask = (abs_pos >= 0) & (abs_pos <= cache_pos) \
                & (abs_pos > cache_pos - window)
            out = _masked_decode_attend(p, q, k, v, mask)
            return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache
        kv_len = cache_pos + 1
        out = attend(q, k, v, cfg, q_start=cache_pos, kv_len=kv_len,
                     window=None)
    else:
        if cache is not None:   # prefill: return cache of full seq (or window)
            if window is not None:
                Wc = min(window, S)
                new_cache = {"k": k[:, S - Wc:], "v": v[:, S - Wc:]}
            else:
                new_cache = {"k": _seq_shard(k), "v": _seq_shard(v)}
        if layout == "seq" and cfg.attn_impl == "shardmap":
            out = _shardmap_seq_attention(q, k, v, cfg, window)
        elif layout == "heads":
            # KV heads shard over 'model' when divisible (MHA / wide GQA:
            # zero attention collectives); narrow GQA replicates KV.
            from repro.distributed.context import tp_size
            kv_ax = "model" if cfg.n_kv_heads % max(tp_size(), 1) == 0 \
                else None
            q = constrain(q, "batch", None, "model", None)
            k = constrain(k, "batch", None, kv_ax, None)
            v = constrain(v, "batch", None, kv_ax, None)
            out = attend(q, k, v, cfg, window=window)
            out = constrain(out, "batch", None, "model", None)
        else:
            q = constrain(q, "batch", "model", None, None)
            k = constrain(k, "batch", None, None, None)
            v = constrain(v, "batch", None, None, None)
            out = attend(q, k, v, cfg, window=window)
            out = constrain(out, "batch", "model", None, None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def _shardmap_seq_attention(q, k, v, cfg, window):
    """Explicit sequence-parallel attention (DeepSpeed-Ulysses-shaped) for
    ragged head counts (28H/24H/10H vs tp=16), §Perf hillclimb #1.

    GSPMD cannot shard a 28-head einsum 16 ways and falls back to
    replicating the whole attention on every model shard (~16x redundant
    FLOPs + a full-seq all-gather of q). Here the query axis is explicitly
    shard_map'd over 'model': each device all-gathers the (small, GQA) K/V
    once and computes only its S/16 query block."""
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.context import batch_axes, get_mesh
    mesh = get_mesh()
    baxes = batch_axes()
    spec = P(baxes, "model", None, None)

    def f(qb, kb, vb):
        kf = jax.lax.all_gather(kb, "model", axis=1, tiled=True)
        vf = jax.lax.all_gather(vb, "model", axis=1, tiled=True)
        S_loc = qb.shape[1]
        start = jax.lax.axis_index("model") * S_loc
        return attend(qb, kf, vf, cfg, q_start=start, kv_len=kf.shape[1],
                      window=window, q_chunk=min(1024, S_loc))

    return shard_map(f, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_vma=False)(q, k, v)


def _seq_shard(t, axis=1):
    """Shard a long cache seq dim over 'model' (2D KV-cache sharding: batch
    x seq) — required for 32k caches of 100B+ archs to fit HBM."""
    from repro.distributed.context import get_mesh, tp_axis
    tp = get_mesh().shape[tp_axis()]
    S = t.shape[axis]
    if S >= 4096 and S % tp == 0:
        spec = ["batch"] + [None] * (t.ndim - 1)
        spec[axis] = "model"
        return constrain(t, *spec)
    return t


def _masked_decode_attend(p, q, k, v, mask):
    """q (B,1,H,hd); k/v (B,T,KV,hd); mask (T,) bool."""
    B, _, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, KV, rep, hd)
    s = jnp.einsum("bgrk,btgk->bgrt", qg.astype(jnp.float32) * hd ** -0.5,
                   k.astype(jnp.float32))
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrt,btgk->bgrk", a, v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def gqa_cache_shape(cfg, batch, max_len, window=None, dtype=jnp.bfloat16):
    T = min(window, max_len) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, T, kv, hd), dtype),
            "v": jnp.zeros((batch, T, kv, hd), dtype)}


# ===================================================================== MLA
def mla_init(key, cfg, dtype):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, H,
                                   m.qk_nope_head_dim + m.qk_rope_head_dim),
                           dtype, fan_in=m.q_lora_rank),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            dtype),
        "w_uk": dense_init(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim),
                           dtype, fan_in=m.kv_lora_rank),
        "w_uv": dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim), dtype,
                           fan_in=m.kv_lora_rank),
        "w_o": dense_init(ks[5], (H, m.v_head_dim, d), dtype,
                          fan_in=H * m.v_head_dim),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), dtype)},
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), dtype)},
    }


def _mla_compress(p, x, cfg, positions):
    """Down-projections shared by all MLA paths. Returns (cq, c_kv, k_rope)."""
    from repro.models.layers import apply_norm
    m = cfg.mla
    cq = apply_norm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["w_dq"]))
    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = apply_norm(p["kv_norm"], dkv[..., :m.kv_lora_rank])
    k_rope = apply_rope(dkv[..., m.kv_lora_rank:], positions, cfg.rope_theta)
    return cq, c_kv, k_rope


def mla_apply(p, x, cfg, positions, cache=None, cache_pos=None):
    """MLA. Train/prefill: naive (decompressed) form. Decode: absorbed form
    against the compressed cache (c_kv, k_rope) — the paper-relevant memory
    saving of MLA."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    cq, c_kv_new, k_rope_new = _mla_compress(p, x, cfg, positions)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = (q[..., :m.qk_nope_head_dim],
                      apply_rope(q[..., m.qk_nope_head_dim:], positions,
                                 cfg.rope_theta))
    if cache is not None and S == 1:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv_new,
                                                 cache_pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope_new,
                                                 cache_pos, axis=1)
        new_cache = {"c_kv": ck, "k_rope": cr}
        # absorbed: q~ = q_nope @ w_uk  -> score in latent space
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
        s = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        ck.astype(jnp.float32))
             + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                          cr.astype(jnp.float32))) * scale
        t_idx = jnp.arange(ck.shape[1])
        s = jnp.where((t_idx <= cache_pos)[None, None, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", a, ck.astype(jnp.float32))
        o = jnp.einsum("bshr,rhk->bshk", o_lat.astype(x.dtype), p["w_uv"])
        return jnp.einsum("bshk,hkd->bsd", o, p["w_o"]), new_cache
    # naive (train / prefill)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv_new, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv_new, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_new[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    qq = constrain(qq, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    # pad v to qk head dim for the shared attend() then slice back
    out = attend(qq, k, _pad_last(v, qq.shape[-1]), cfg)
    out = out[..., :m.v_head_dim]
    new_cache = None
    if cache is not None:
        new_cache = {"c_kv": c_kv_new, "k_rope": k_rope_new}
    return jnp.einsum("bshk,hkd->bsd", out, p["w_o"]), new_cache


def _pad_last(x, dim):
    pad = dim - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def mla_cache_shape(cfg, batch, max_len, dtype=jnp.bfloat16):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}
