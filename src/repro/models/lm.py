"""LM assembly: builds any assigned architecture from its ArchConfig.

All backbones are layer-stacked ``lax.scan``s over homogeneous segments
(dense: one segment; MoE: leading-dense + MoE segments; hybrid: 8 scanned
(rglru, rglru, local_attn) groups + 2 tail rglru layers; ssm: one segment),
with ``jax.checkpoint`` per block in training. Vocab logits are never
materialized over the full sequence (layers.chunked_ce_loss).

Three entry points lowered by the dry-run:
  loss_fn      — training loss (batch -> scalar)
  prefill      — full-sequence forward building the KV/state cache
  decode_step  — one token with a seq_len-deep cache
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_norm, chunked_ce_loss, embed_init,
                                 embed_lookup, norm_init, mlp_init, mlp_apply,
                                 sinusoidal_positions, unembed)


# ------------------------------------------------------------------ blocks
def _mix_kind(cfg) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.attn_kind == "mla":
        return "mla"
    return "attn"


def init_block(key, cfg, kind, dtype, use_moe=False):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": norm_init(cfg.d_model, dtype, cfg.norm)}
    if kind == "attn" or kind == "local_attn":
        p["attn"] = attn.gqa_init(ks[0], cfg, dtype)
    elif kind == "mla":
        p["attn"] = attn.mla_init(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
        return p                      # mamba2 block: norm + mixer only
    elif kind == "rglru":
        p["rec"] = rglru_mod.rglru_init(ks[0], cfg, dtype)
    p["ln2"] = norm_init(cfg.d_model, dtype, cfg.norm)
    if use_moe:
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    else:
        d_ff = cfg.d_ff if cfg.moe is None else (cfg.moe.d_ff_dense or cfg.d_ff)
        p["mlp"] = mlp_init(ks[1], cfg, d_ff, dtype)
    return p


def block_apply(p, x, cfg, kind, positions, layout, cache=None,
                cache_pos=None, decode=False):
    h = apply_norm(p["ln1"], x, cfg.norm)
    new_cache = None
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local_attn"):
        window = cfg.rglru.window if (kind == "local_attn" and cfg.rglru) else None
        y, new_cache = attn.gqa_apply(p["attn"], h, cfg, positions,
                                      layout=layout, window=window,
                                      cache=cache, cache_pos=cache_pos)
    elif kind == "mla":
        y, new_cache = attn.mla_apply(p["attn"], h, cfg, positions,
                                      cache=cache, cache_pos=cache_pos)
    elif kind == "ssm":
        y, new_cache = ssm_mod.ssm_apply(p["ssm"], h, cfg, cache=cache)
        return x + y, new_cache, aux
    elif kind == "rglru":
        y, new_cache = rglru_mod.rglru_apply(p["rec"], h, cfg, cache=cache)
    x = x + y
    h2 = apply_norm(p["ln2"], x, cfg.norm)
    if "moe" in p:
        y2, aux = moe_mod.moe_apply(p["moe"], h2, cfg, decode=decode)
    else:
        y2 = mlp_apply(p["mlp"], h2, cfg)
    x = x + y2
    if cfg.seq_parallel and x.shape[1] > 1:
        # sequence-parallel residual: stays S-sharded over 'model' between
        # blocks (norms are per-token); attention/MoE reshard as needed.
        x = constrain(x, "batch", "model", None)
    return x, new_cache, aux


# ------------------------------------------------------------------ init
def init_params(cfg, key, dtype=jnp.bfloat16):
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg, dtype),
        "final_norm": norm_init(cfg.d_model, dtype, cfg.norm),
    }
    kind = _mix_kind(cfg)
    if cfg.family == "hybrid":
        g = len(cfg.rglru.pattern)
        n_groups = cfg.n_layers // g           # 8 full groups
        n_tail = cfg.n_layers - n_groups * g   # 2 trailing rglru layers

        def init_group(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"rec1": init_block(k1, cfg, "rglru", dtype),
                    "rec2": init_block(k2, cfg, "rglru", dtype),
                    "attn": init_block(k3, cfg, "local_attn", dtype)}
        params["groups"] = jax.vmap(init_group)(
            jax.random.split(keys[1], n_groups))
        if n_tail:
            params["tail"] = jax.vmap(
                lambda k: init_block(k, cfg, "rglru", dtype))(
                jax.random.split(keys[2], n_tail))
    elif cfg.moe is not None:
        kd = cfg.moe.first_k_dense
        params["dense_blocks"] = jax.vmap(
            lambda k: init_block(k, cfg, kind, dtype, use_moe=False))(
            jax.random.split(keys[1], kd))
        params["blocks"] = jax.vmap(
            lambda k: init_block(k, cfg, kind, dtype, use_moe=True))(
            jax.random.split(keys[2], cfg.n_layers - kd))
    else:
        params["blocks"] = jax.vmap(
            lambda k: init_block(k, cfg, kind, dtype))(
            jax.random.split(keys[1], cfg.n_layers))
    return params


# ------------------------------------------------------------------ forward
def _remat(cfg, fn):
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _scan_segment(params_stack, x, cfg, kind, positions, layout, remat,
                  cache_stack=None, cache_pos=None, decode=False,
                  with_cache=False):
    """Scan a homogeneous layer segment; returns (x, aux_sum, new_caches)."""

    def body(carry, xs):
        xc, auxc = carry
        if cache_stack is not None:
            pl, cl = xs
        else:
            pl, cl = xs, None
        xc, nc, aux = block_apply(pl, xc, cfg, kind, positions, layout,
                                  cache=cl, cache_pos=cache_pos,
                                  decode=decode)
        if nc is None and with_cache:
            nc = ()
        return (xc, auxc + aux), (nc if with_cache else None)

    if remat:
        body = _remat(cfg, body)
    xs = (params_stack, cache_stack) if cache_stack is not None else params_stack
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, caches


def _embed_input(params, cfg, batch, positions):
    tokens = batch["tokens"]
    h = embed_lookup(params["embed"], tokens)
    if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
        h = jnp.concatenate([batch["patch_embeds"].astype(h.dtype), h], axis=1)
    if cfg.pos == "sinusoidal":
        h = h + sinusoidal_positions(positions, cfg.d_model).astype(h.dtype)
    return h


def _attn_layout(cfg, tp: int) -> str:
    return "heads" if cfg.n_heads % max(tp, 1) == 0 else "seq"


def backbone(params, cfg, batch, positions=None, layout="heads",
             caches=None, cache_pos=None, decode=False, remat=None):
    """Returns (h, aux, new_caches)."""
    remat = cfg.remat if remat is None else remat
    tokens = batch["tokens"]
    B = tokens.shape[0]
    S_total = tokens.shape[1] + (
        cfg.n_patch_tokens if cfg.frontend == "vision_patches"
        and "patch_embeds" in batch else 0)
    if positions is None:
        positions = jnp.arange(S_total)
    h = _embed_input(params, cfg, batch, positions)
    h = constrain(h, "batch", None, None)
    kind = _mix_kind(cfg)
    new_caches: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)
    with_cache = caches is not None
    if cfg.family == "hybrid":
        def group_body(carry, xs):
            xc, auxc = carry
            gp = xs[0] if with_cache else xs
            gc = xs[1] if with_cache else {"rec1": None, "rec2": None,
                                           "attn": None}
            ncs = {}
            xc, ncs["rec1"], a1 = block_apply(
                gp["rec1"], xc, cfg, "rglru", positions, layout,
                cache=gc["rec1"], cache_pos=cache_pos, decode=decode)
            xc, ncs["rec2"], a2 = block_apply(
                gp["rec2"], xc, cfg, "rglru", positions, layout,
                cache=gc["rec2"], cache_pos=cache_pos, decode=decode)
            xc, ncs["attn"], a3 = block_apply(
                gp["attn"], xc, cfg, "local_attn", positions, layout,
                cache=gc["attn"], cache_pos=cache_pos, decode=decode)
            return (xc, auxc + a1 + a2 + a3), (ncs if with_cache else None)
        gb = _remat(cfg, group_body) if remat else group_body
        xs = ((params["groups"], caches["groups"]) if with_cache
              else params["groups"])
        (h, aux_total), gc = jax.lax.scan(
            gb, (h, aux_total), xs)
        if with_cache:
            new_caches["groups"] = gc
        if "tail" in params:
            h, aux2, tc = _scan_segment(
                params["tail"], h, cfg, "rglru", positions, layout, remat,
                cache_stack=caches["tail"] if with_cache else None,
                cache_pos=cache_pos, decode=decode, with_cache=with_cache)
            aux_total = aux_total + aux2
            if with_cache:
                new_caches["tail"] = tc
    elif cfg.moe is not None:
        h, a1, dc = _scan_segment(
            params["dense_blocks"], h, cfg, kind, positions, layout, remat,
            cache_stack=caches["dense_blocks"] if with_cache else None,
            cache_pos=cache_pos, decode=decode, with_cache=with_cache)
        h, a2, mc = _scan_segment(
            params["blocks"], h, cfg, kind, positions, layout, remat,
            cache_stack=caches["blocks"] if with_cache else None,
            cache_pos=cache_pos, decode=decode, with_cache=with_cache)
        aux_total = a1 + a2
        if with_cache:
            new_caches = {"dense_blocks": dc, "blocks": mc}
    else:
        h, aux_total, bc = _scan_segment(
            params["blocks"], h, cfg, kind, positions, layout, remat,
            cache_stack=caches["blocks"] if with_cache else None,
            cache_pos=cache_pos, decode=decode, with_cache=with_cache)
        if with_cache:
            new_caches = {"blocks": bc}
    h = apply_norm(params["final_norm"], h, cfg.norm)
    return h, aux_total, (new_caches if with_cache else None)


# ------------------------------------------------------------------ losses
def loss_fn(params, cfg, batch, layout="heads"):
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    h, aux, _ = backbone(params, cfg, batch, layout=layout)
    n_patch = h.shape[1] - S_text
    h_text = h[:, n_patch:] if n_patch else h
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones((B, S_text), jnp.float32).at[:, -1].set(0.0)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"]
    loss = chunked_ce_loss(params["embed"], h_text, labels, mask, cfg)
    return loss + aux, {"ce": loss, "aux": aux}


# ------------------------------------------------------------------ serving
def init_cache(cfg, batch_size, max_len, dtype=jnp.bfloat16):
    kind = _mix_kind(cfg)

    def stack(fn, n):
        leaves = fn()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape),
                            leaves)
    if cfg.family == "hybrid":
        g = len(cfg.rglru.pattern)
        n_groups = cfg.n_layers // g
        n_tail = cfg.n_layers - n_groups * g
        group = {
            "rec1": rglru_mod.rglru_cache_shape(cfg, batch_size, dtype),
            "rec2": rglru_mod.rglru_cache_shape(cfg, batch_size, dtype),
            "attn": attn.gqa_cache_shape(cfg, batch_size, max_len,
                                         window=cfg.rglru.window,
                                         dtype=dtype),
        }
        out = {"groups": stack(lambda: group, n_groups)}
        if n_tail:
            out["tail"] = stack(
                lambda: rglru_mod.rglru_cache_shape(cfg, batch_size, dtype),
                n_tail)
        return out
    if cfg.family == "ssm":
        return {"blocks": stack(
            lambda: ssm_mod.ssm_cache_shape(cfg, batch_size, dtype),
            cfg.n_layers)}
    if kind == "mla":
        layer = lambda: attn.mla_cache_shape(cfg, batch_size, max_len, dtype)
    else:
        layer = lambda: attn.gqa_cache_shape(cfg, batch_size, max_len,
                                             dtype=dtype)
    if cfg.moe is not None:
        kd = cfg.moe.first_k_dense
        return {"dense_blocks": stack(layer, kd),
                "blocks": stack(layer, cfg.n_layers - kd)}
    return {"blocks": stack(layer, cfg.n_layers)}


def _pad_cache_to(cache_leaf, max_len, seq_axis_hint=1):
    return cache_leaf


def prefill(params, cfg, batch, layout="heads"):
    """Full-sequence forward; returns (last_token_logits, caches). Cache seq
    dims equal the prefill length (extend before decoding further)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    caches = init_cache(cfg, B, S)
    h, _, new_caches = backbone(params, cfg, batch, caches=caches,
                                layout=layout, remat=False)
    logits = unembed(params["embed"], h[:, -1], cfg)
    return logits.astype(jnp.float32), new_caches


def decode_step(params, cfg, token, cache, cache_pos, layout="heads"):
    """token (B,1) int32; cache from init_cache(cfg, B, max_len); cache_pos
    scalar int32 = number of tokens already in the cache."""
    positions = cache_pos + jnp.arange(1)
    batch = {"tokens": token}
    h, _, new_caches = backbone(params, cfg, batch, positions=positions,
                                caches=cache, cache_pos=cache_pos,
                                decode=True, layout=layout, remat=False)
    logits = unembed(params["embed"], h[:, -1], cfg)
    return logits.astype(jnp.float32), new_caches
