"""Mixture-of-Experts with HiAER-style address-event routing.

The paper's core routing insight — spikes are *events* multicast through a
hierarchy (NoC within an FPGA, FireFly within a server, Ethernet between
servers), with dense local traffic kept on fast links — maps directly onto
MoE token dispatch: a token choosing top-k experts is an address-event; the
expert-parallel all-to-all is the multicast fabric.

Layout: experts are sharded over the 'model' axis (= cores within an FPGA).
Tokens are sharded over (batch-axes, 'model'): each device routes its own
token shard, packs per-expert capacity buffers ordered by owner device, and
exchanges them with a single all_to_all over 'model' (phase 1 = pointer
lookup, phase 2 = payload delivery — the paper's two-phase routing).

``hierarchical_a2a`` (beyond-paper optimization, §Perf): on the multi-pod
mesh the exchange is split into an intra-pod all_to_all followed by a
cross-pod exchange of aggregated buffers, mirroring HiAER's level-by-level
multicast so the slow (DCN) hop carries each payload once.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.context import batch_axes, get_mesh, tp_axis
from repro.models.layers import dense_init


def moe_init(key, cfg, dtype):
    mo, d = cfg.moe, cfg.d_model
    E, F = mo.n_routed, mo.d_ff_expert
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_in": dense_init(ks[1], (E, d, F), dtype, fan_in=d),
        "w_gate": dense_init(ks[2], (E, d, F), dtype, fan_in=d),
        "w_out": dense_init(ks[3], (E, F, d), dtype, fan_in=F),
    }
    if mo.n_shared:
        Fs = mo.n_shared * F
        p["shared"] = {
            "w_in": dense_init(ks[4], (d, Fs), dtype),
            "w_gate": dense_init(ks[5], (d, Fs), dtype),
            "w_out": dense_init(ks[6], (Fs, d), dtype),
        }
    return p


def _act(cfg, g, h):
    if cfg.act == "geglu":
        return jax.nn.gelu(g) * h
    return jax.nn.silu(g) * h


def _route(x_tok, router, cfg):
    """x_tok (T,d) -> top-k weights/ids + aux load-balance loss."""
    mo = cfg.moe
    logits = jnp.einsum("td,de->te", x_tok.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, mo.top_k)            # (T,k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch style): E * sum_e f_e * p_e
    E = mo.n_routed
    f = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    pbar = probs.mean(0)
    aux = E * jnp.sum(f * pbar) * mo.router_aux_weight
    return w, ids, aux


def _capacity(T, cfg):
    mo = cfg.moe
    c = int(math.ceil(T * mo.top_k / mo.n_routed * mo.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)


def _pack(x_tok, ids, w, C, E):
    """Scatter tokens into (E*C, d) capacity buffers; returns buffers and the
    (slot, keep) addressing needed to unpack. Event-packing = phase 1."""
    T, d = x_tok.shape
    k = ids.shape[1]
    flat_e = ids.reshape(-1)                            # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot           # position within expert
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)
    buf = jnp.zeros((E * C + 1, d), x_tok.dtype)
    src = jnp.repeat(x_tok, k, axis=0)                  # token per (t,k) event
    buf = buf.at[slot].add(src)
    return buf[:-1], slot, keep


def _unpack(buf, slot, keep, w, T, k):
    buf = jnp.concatenate([buf, jnp.zeros_like(buf[:1])], axis=0)
    y = buf[slot]                                       # (T*k, d)
    y = y * (keep[:, None] * w.reshape(-1)[:, None]).astype(y.dtype)
    return y.reshape(T, k, -1).sum(1)


def _expert_ffn(p, cfg, toks):
    """toks (E_loc, N, d) -> (E_loc, N, d), gated FFN per local expert."""
    h = jnp.einsum("end,edf->enf", toks, p["w_in"])
    g = jnp.einsum("end,edf->enf", toks, p["w_gate"])
    h = _act(cfg, g, h)
    return jnp.einsum("enf,efd->end", h, p["w_out"])


def moe_apply(p, x, cfg, decode=False):
    """x (B,S,d) -> (y, aux). Sharded dispatch via shard_map (train/prefill);
    replicated dispatch + psum for single-token decode."""
    mesh = get_mesh()
    tp = mesh.shape[tp_axis()]
    mo = cfg.moe
    E = mo.n_routed
    E_loc = E // tp
    baxes = batch_axes()

    expert_specs = {"router": P(), "w_in": P(tp_axis()), "w_gate": P(tp_axis()),
                    "w_out": P(tp_axis())}
    if "shared" in p:
        expert_specs["shared"] = {k: P() for k in p["shared"]}

    if (decode or x.shape[1] == 1) and cfg.fsdp and tp > 1 \
            and "data" in mesh.axis_names:
        return _decode_moe_2d(p, x, cfg)
    if decode or x.shape[1] == 1 or tp == 1:
        x_spec = P(baxes, None, None)
        out_specs = (P(baxes, None, None), P())

        def f(pp, xx):
            B, S, d = xx.shape
            xt = xx.reshape(B * S, d)
            w, ids, aux = _route(xt, pp["router"], cfg)
            C = _capacity(B * S, cfg)
            buf, slot, keep = _pack(xt, ids, w, C, E)
            idx = jax.lax.axis_index(tp_axis())
            mine = jax.lax.dynamic_slice_in_dim(
                buf.reshape(E, C, d), idx * E_loc, E_loc, axis=0)
            out = _expert_ffn(pp, cfg, mine)
            full = jnp.zeros((E, C, d), out.dtype)
            full = jax.lax.dynamic_update_slice_in_dim(full, out, idx * E_loc,
                                                       axis=0)
            full = jax.lax.psum(full, tp_axis())
            y = _unpack(full.reshape(E * C, d), slot, keep, w, B * S,
                        mo.top_k)
            y = y.reshape(B, S, d)
            if "shared" in pp:
                y = y + _shared_ffn(pp["shared"], cfg, xx)
            aux = jax.lax.pmean(aux, baxes + (tp_axis(),))
            return y, aux
    else:
        x_spec = P(baxes, tp_axis(), None)
        out_specs = (P(baxes, tp_axis(), None), P())

        def f(pp, xx):
            B, S, d = xx.shape
            T = B * S
            xt = xx.reshape(T, d)
            w, ids, aux = _route(xt, pp["router"], cfg)
            C = _capacity(T, cfg)
            buf, slot, keep = _pack(xt, ids, w, C, E)   # (E*C, d) peer-ordered
            if mo.hierarchical_a2a and "pod" in mesh.axis_names:
                ex = _hiaer_exchange(buf, tp, E_loc, C, d)
            else:
                ex = jax.lax.all_to_all(
                    buf.reshape(tp, E_loc * C, d), tp_axis(), 0, 0,
                    tiled=False)
            # ex: (tp, E_loc*C, d) -- axis0 = source peer
            toks = ex.reshape(tp, E_loc, C, d).transpose(1, 0, 2, 3) \
                     .reshape(E_loc, tp * C, d)
            out = _expert_ffn(pp, cfg, toks)
            back = out.reshape(E_loc, tp, C, d).transpose(1, 0, 2, 3)
            back = jax.lax.all_to_all(back.reshape(tp, E_loc * C, d),
                                      tp_axis(), 0, 0, tiled=False)
            y = _unpack(back.reshape(E * C, d), slot, keep, w, T, mo.top_k)
            y = y.reshape(B, S, d)
            if "shared" in pp:
                y = y + _shared_ffn(pp["shared"], cfg, xx)
            aux = jax.lax.pmean(aux, baxes + (tp_axis(),))
            return y, aux

    fn = shard_map(f, mesh=mesh, in_specs=(expert_specs, x_spec),
                   out_specs=out_specs, check_vma=False)
    return fn(p, x)


def _decode_moe_2d(p, x, cfg):
    """Decode-path MoE against 2D-sharded experts (E over 'model', d over
    'data' — the FSDP layout of 236B-scale MoE). §Perf hillclimb #3.

    Baseline GSPMD gathers each layer's full expert weights over 'data'
    (~472 MB/layer for deepseek-v2) to serve a handful of tokens. Here the
    WEIGHTS never move: the few routed tokens are all-gathered to their
    expert's (model-row, data-col) shards, each shard contracts its own
    d-slice, and pre-activation partials are psum'd over 'data' (exact for
    the gated nonlinearity). Token traffic is ~10 MB/layer — the paper's
    own principle that events (tokens), not synapse tables (weights),
    should traverse the interconnect."""
    mesh = get_mesh()
    tp = mesh.shape[tp_axis()]
    dp = mesh.shape["data"]
    mo = cfg.moe
    E = mo.n_routed
    E_loc = E // tp
    baxes = batch_axes()

    especs = {"router": P(),
              "w_in": P(tp_axis(), "data", None),
              "w_gate": P(tp_axis(), "data", None),
              "w_out": P(tp_axis(), None, "data")}
    if "shared" in p:
        especs["shared"] = {"w_in": P("data", tp_axis()),
                            "w_gate": P("data", tp_axis()),
                            "w_out": P(tp_axis(), "data")}
    x_spec = P(baxes, None, None)

    def f(pp, xx):
        B, S, d = xx.shape
        T = B * S
        d_loc = d // dp
        i_d = jax.lax.axis_index("data")
        i_m = jax.lax.axis_index(tp_axis())
        xt = xx.reshape(T, d)
        w, ids, aux = _route(xt, pp["router"], cfg)
        C = _capacity(T, cfg)
        buf, slot, keep = _pack(xt, ids, w, C, E)      # (E*C, d) local toks
        mine = jax.lax.dynamic_slice_in_dim(
            buf.reshape(E, C, d), i_m * E_loc, E_loc, axis=0)
        # gather this expert-row's tokens from every data shard
        toks = jax.lax.all_gather(mine, "data", axis=1, tiled=True)
        # contract own d-slice; psum partial pre-activations (exact)
        x_d = jax.lax.dynamic_slice_in_dim(toks, i_d * d_loc, d_loc, axis=2)
        g = jnp.einsum("ecd,edf->ecf", x_d, pp["w_gate"])
        h = jnp.einsum("ecd,edf->ecf", x_d, pp["w_in"])
        g, h = jax.lax.psum((g, h), "data")
        act = _act(cfg, g, h)
        out_d = jnp.einsum("ecf,efd->ecd", act, pp["w_out"])  # d-sliced out
        out = jax.lax.all_gather(out_d, "data", axis=2, tiled=True)
        own = jax.lax.dynamic_slice_in_dim(out, i_d * C, C, axis=1)
        full = jnp.zeros((E, C, d), own.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(full, own, i_m * E_loc,
                                                   axis=0)
        full = jax.lax.psum(full, tp_axis())
        y = _unpack(full.reshape(E * C, d), slot, keep, w, T, mo.top_k)
        y = y.reshape(B, S, d)
        if "shared" in pp:
            # shared experts 2D-sharded (d over 'data', ff over 'model'):
            # psum pre-activation over 'data', psum output over 'model'
            sp = pp["shared"]
            xs_d = jax.lax.dynamic_slice_in_dim(xx, i_d * d_loc, d_loc,
                                                axis=2)
            gs = jnp.einsum("bsd,df->bsf", xs_d, sp["w_gate"])
            hs = jnp.einsum("bsd,df->bsf", xs_d, sp["w_in"])
            gs, hs = jax.lax.psum((gs, hs), "data")
            ys_d = jnp.einsum("bsf,fd->bsd", _act(cfg, gs, hs),
                              sp["w_out"])
            ys_d = jax.lax.psum(ys_d, tp_axis())
            ys = jax.lax.all_gather(ys_d, "data", axis=2, tiled=True)
            y = y + ys
        aux = jax.lax.pmean(aux, baxes + (tp_axis(),))
        return y, aux

    fn = shard_map(f, mesh=mesh, in_specs=(especs, x_spec),
                   out_specs=(P(baxes, None, None), P()), check_vma=False)
    return fn(p, x)


def _shared_ffn(sp, cfg, x):
    h = jnp.einsum("bsd,df->bsf", x, sp["w_in"])
    g = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
    return jnp.einsum("bsf,fd->bsd", _act(cfg, g, h), sp["w_out"])


def _hiaer_exchange(buf, tp, E_loc, C, d):
    """Hierarchical (HiAER) dispatch on the multi-pod mesh.

    Design choice mirroring the paper's level-by-level multicast: expert
    weights are REPLICATED per pod (specs never shard experts over 'pod'),
    so token events all_to_all only over the fast intra-pod 'model' axis
    (ICI ≈ NoC/FireFly) and NO token ever crosses the DCN (≈ Ethernet) —
    the slow hop carries only the once-per-step gradient reduction. This is
    the "keep event traffic on fast local links" principle; the function is
    therefore the same intra-pod exchange, kept as an explicit seam for
    pod-sharded-expert variants (which would add a cross-pod hop here)."""
    ex = jax.lax.all_to_all(buf.reshape(tp, E_loc * C, d), tp_axis(), 0, 0,
                            tiled=False)
    return ex


def moe_flops(cfg, n_tokens: int) -> int:
    """Active FLOPs for roofline (§Roofline MODEL_FLOPS)."""
    mo = cfg.moe
    per_tok = (mo.top_k + mo.n_shared) * 3 * 2 * cfg.d_model * mo.d_ff_expert
    return per_tok * n_tokens
