"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU gated linear
recurrence. Train/prefill use an associative scan over the sequence; decode
carries (conv_state, h_state) — O(1) per token, so recurrentgemma-2b is
long_500k-eligible (together with its 2048-window local attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.layers import dense_init

_C = 8.0   # Griffin's fixed recurrence sharpness


def _gate_blocks(cfg):
    W = cfg.rglru.lru_width
    bw = min(cfg.rglru.gate_block, W)
    assert W % bw == 0, (W, bw)
    return W // bw, bw


def rglru_init(key, cfg, dtype):
    d, W = cfg.d_model, cfg.rglru.lru_width
    K = cfg.rglru.d_conv
    nb, bw = _gate_blocks(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_x": dense_init(ks[0], (d, W), dtype),
        "w_y": dense_init(ks[1], (d, W), dtype),       # gate branch
        "conv_w": dense_init(ks[2], (K, W), dtype, fan_in=K),
        "conv_b": jnp.zeros((W,), dtype),
        # Griffin input/recurrence gates are BLOCK-DIAGONAL (width 256):
        # faithful to the arch and collective-free under W-sharding (tiny
        # replicated weights instead of (W,W) sharded contractions)
        "w_i": dense_init(ks[3], (nb, bw, bw), dtype, fan_in=bw),
        "w_r": dense_init(ks[4], (nb, bw, bw), dtype, fan_in=bw),
        "lam": jnp.full((W,), 2.0, jnp.float32),       # Lambda param
        "w_out": dense_init(ks[5], (W, d), dtype),
    }


def _conv(w, b, x, state=None):
    K = w.shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state, x], axis=1)
    y = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    return y, (pad[:, -(K - 1):] if K > 1 else None)


def rglru_apply(p, x, cfg, cache=None):
    """x (B,S,d) -> (y, new_cache). cache = {'conv', 'h'} for decode."""
    B, S, _ = x.shape
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_y"]))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"])
    u = constrain(u, "batch", None, "model")
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _conv(p["conv_w"], p["conv_b"], u, conv_state)
    uf = u.astype(jnp.float32)
    nb, bw = _gate_blocks(cfg)
    ub = uf.reshape(*uf.shape[:-1], nb, bw)
    r = jax.nn.sigmoid(jnp.einsum("bshw,hwv->bshv", ub,
                                  p["w_r"].astype(jnp.float32)))
    r = r.reshape(uf.shape)
    i = jax.nn.sigmoid(jnp.einsum("bshw,hwv->bshv", ub,
                                  p["w_i"].astype(jnp.float32)))
    i = i.reshape(uf.shape)
    log_a = -_C * r * jax.nn.softplus(p["lam"])        # (B,S,W) <= 0
    a = jnp.exp(log_a)
    gated = i * uf
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    if cache is not None and S == 1:
        h = cache["h"].astype(jnp.float32)
        h = a[:, 0] * h + b[:, 0]
        y = h[:, None]
        new_cache = {"conv": new_conv, "h": h.astype(cache["h"].dtype)}
    else:
        # associative linear recurrence h_t = a_t h_{t-1} + b_t
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        aa, y = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_cache = None
        if cache is not None:   # prefill
            new_cache = {"conv": new_conv, "h": y[:, -1].astype(x.dtype)}
    y = y.astype(x.dtype) * gate
    y = constrain(y, "batch", None, "model")
    return jnp.einsum("bsw,wd->bsd", y, p["w_out"]), new_cache


def rglru_cache_shape(cfg, batch, dtype=jnp.bfloat16):
    W, K = cfg.rglru.lru_width, cfg.rglru.d_conv
    return {"conv": jnp.zeros((batch, K - 1, W), dtype),
            "h": jnp.zeros((batch, W), dtype)}
