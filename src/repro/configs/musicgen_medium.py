"""MusicGen-Medium [audio]: decoder-only LM over EnCodec tokens.

48L d_model=1536 24H (kv=24, i.e. MHA) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf]. Non-gated GELU FFN (4x), sinusoidal positions,
LayerNorm. The EnCodec frontend is a stub: input_specs() provides token ids
(precomputed frame tokens); the 4-codebook interleaving of the real system is
collapsed to a single stream (backbone-only per assignment).
"""
from repro.configs.base import ArchConfig, _shrink

CONFIG = ArchConfig(
    name="musicgen_medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    act="gelu", norm="layernorm", pos="sinusoidal",
    qkv_bias=False, frontend="audio_tokens",
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=256, vocab_size=128)
