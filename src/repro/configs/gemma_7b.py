"""Gemma-7B [dense]: GeGLU, head_dim=256.

28L d_model=3072 16H (kv=16, MHA) d_ff=24576 vocab=256000
[arXiv:2403.08295; hf]
"""
from repro.configs.base import ArchConfig, _shrink

CONFIG = ArchConfig(
    name="gemma_7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24_576, vocab_size=256_000,
    act="geglu", norm="rmsnorm", rope_theta=10_000.0, tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   head_dim=16, d_ff=256, vocab_size=256)
