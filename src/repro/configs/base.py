"""Config system: architecture configs, input shapes, registry.

Every assigned architecture is a selectable config (``--arch <id>``); each
config file instantiates :class:`ArchConfig` with the exact published
hyperparameters and provides ``reduced()`` for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 0            # routed experts
    n_shared: int = 0            # shared (always-on) experts
    top_k: int = 0
    d_ff_expert: int = 0         # per-expert FFN width (fine-grained)
    first_k_dense: int = 0       # leading dense layers (DeepSeek style)
    d_ff_dense: int = 0          # width of those dense layers
    capacity_factor: float = 2.0
    router_aux_weight: float = 0.001
    hierarchical_a2a: bool = False   # HiAER two-phase dispatch (beyond-paper opt)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin recurrent block."""
    lru_width: int = 2560
    d_conv: int = 4
    window: int = 2048           # local attention window
    pattern: Tuple[str, ...] = ("rglru", "rglru", "local_attn")
    gate_block: int = 256        # Griffin gates are block-diagonal


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    act: str = "swiglu"          # swiglu | geglu | gelu (non-gated)
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    rope_theta: float = 500_000.0
    pos: str = "rope"            # rope | sinusoidal | none
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # modality frontend stubs ([audio]/[vlm]): extra embedded inputs
    frontend: Optional[str] = None       # "audio_tokens" | "vision_patches"
    n_patch_tokens: int = 0              # vlm: precomputed patch embeds per image
    # --- distribution policy (tuned per arch; see launch/sharding.py) ---
    fsdp: bool = False           # shard params over data axis too (ZeRO-3)
    opt_dtype: str = "float32"   # optimizer moment dtype ("bfloat16" for 405B)
    remat: bool = True
    scan_layers: bool = True
    loss_chunk: int = 512        # seq chunk for vocab-sharded CE loss
    # attention flavor: full | local | mla ; long_500k eligibility derives
    # from sub-quadratic state (ssm/rglru/local) only.
    attn_kind: str = "full"
    # seq-layout attention impl: 'shardmap' (explicit sequence-parallel —
    # adopted default after §Perf hillclimb #1: 13x compute / 15x HBM
    # reduction) or 'gspmd' (constraint-driven baseline, kept selectable)
    attn_impl: str = "shardmap"
    # residual stream sharded over 'model' on the seq axis (sequence
    # parallelism; §Perf MoE hillclimb)
    seq_parallel: bool = False
    # remat policy: 'full' (recompute everything) or 'dots' (save dot
    # outputs — trades HBM for fewer bwd recompute collectives)
    remat_policy: str = "full"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            per_layer = d * (2 * d_in + 2 * s.d_state * (d_in // s.head_dim if False else 1)) \
                + 2 * d_in * d  # in/out proj dominate
            per_layer = d * d_in * 2 + d_in * d + d_in * (2 * s.d_state)
        else:
            q = self.n_heads * hd
            kv = self.n_kv_heads * hd
            if self.mla is not None:
                m = self.mla
                attn = (d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * d)
            else:
                attn = d * q + 2 * d * kv + q * d
            gated = self.act in ("swiglu", "geglu")
            ff_mult = 3 if gated else 2
            if self.moe is not None:
                mo = self.moe
                ff_moe = (mo.n_routed + mo.n_shared) * ff_mult * d * mo.d_ff_expert + d * mo.n_routed
                ff_dense = ff_mult * d * (mo.d_ff_dense or self.d_ff)
                per_layer = attn + ff_moe
                return emb + mo.first_k_dense * (attn + ff_dense) + (L - mo.first_k_dense) * per_layer
            per_layer = attn + ff_mult * d * self.d_ff
        return emb + L * per_layer

    def n_active_params(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        mo = self.moe
        hd = self.resolved_head_dim
        gated = self.act in ("swiglu", "geglu")
        ff_mult = 3 if gated else 2
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        act_ff = (mo.top_k + mo.n_shared) * ff_mult * d * mo.d_ff_expert + d * mo.n_routed
        dense_ff = ff_mult * d * (mo.d_ff_dense or self.d_ff)
        return emb + mo.first_k_dense * (attn + dense_ff) + (L - mo.first_k_dense) * (attn + act_ff)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeSpec("long_500k", "decode", 524_288, 1),
}

ARCH_IDS = [
    "musicgen_medium", "recurrentgemma_2b", "qwen2_7b", "llama3_405b",
    "qwen2_5_3b", "gemma_7b", "deepseek_moe_16b", "deepseek_v2_236b",
    "llava_next_mistral_7b", "mamba2_780m",
]
EXTRA_ARCH_IDS = ["hiaer_snn_40b"]  # the paper's own full-scale config


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.reduced()


def cells(arch_id: str):
    """The (arch, shape) cells this arch runs; long_500k only sub-quadratic."""
    cfg = get_arch(arch_id)
    out = []
    for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if s == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(SHAPES[s])
    return out


def _shrink(cfg: ArchConfig, **over) -> ArchConfig:
    return replace(cfg, **over)
