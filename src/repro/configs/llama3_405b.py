"""Llama-3 405B [dense]: GQA, 128k vocab.

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256
[arXiv:2407.21783; unverified]. FSDP + bf16 optimizer moments are required to
fit 256 x 16GB chips (see EXPERIMENTS.md §Dry-run memory table).
"""
from repro.configs.base import ArchConfig, _shrink

CONFIG = ArchConfig(
    name="llama3_405b", family="dense",
    n_layers=126, d_model=16_384, n_heads=128, n_kv_heads=8,
    d_ff=53_248, vocab_size=128_256,
    act="swiglu", norm="rmsnorm", rope_theta=500_000.0,
    fsdp=True, opt_dtype="bfloat16",
    seq_parallel=True,   # §Perf Cell E1: shards the remat-checkpoint stack
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                   d_ff=384, vocab_size=512, fsdp=False, opt_dtype="float32")
