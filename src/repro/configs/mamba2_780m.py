"""Mamba2-780m [ssm]: SSD (state-space duality), attention-free.

48L d_model=1536 vocab=50280 ssm_state=128 [arXiv:2405.21060; unverified].
d_inner = 2*d = 3072, head_dim 64 -> 48 SSD heads, conv width 4, chunk 256.
"""
from repro.configs.base import ArchConfig, SSMConfig, _shrink

CONFIG = ArchConfig(
    name="mamba2_780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=48, n_kv_heads=48,
    d_ff=0, vocab_size=50_280, pos="none", norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                   vocab_size=256,
                   ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                                 chunk_size=32))
