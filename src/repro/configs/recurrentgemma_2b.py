"""RecurrentGemma-2B [hybrid]: RG-LRU + local attention, 1:2 pattern.

26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000
[arXiv:2402.19427; hf]. head_dim=256, GeGLU, window 2048.
26 layers = 8 x (rglru, rglru, local_attn) + 2 rglru (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, RGLRUConfig, _shrink

CONFIG = ArchConfig(
    name="recurrentgemma_2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000,
    act="geglu", norm="rmsnorm", rope_theta=10_000.0,
    tie_embeddings=True, attn_kind="local",
    rglru=RGLRUConfig(lru_width=2560, d_conv=4, window=2048),
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=1,
                   head_dim=16, d_ff=192, vocab_size=256,
                   rglru=RGLRUConfig(lru_width=64, d_conv=4, window=32))
