from repro.configs.base import (ARCH_IDS, EXTRA_ARCH_IDS, SHAPES, ArchConfig,
                                MLAConfig, MoEConfig, RGLRUConfig, SSMConfig,
                                ShapeSpec, cells, get_arch, get_reduced)

__all__ = [
    "ARCH_IDS", "EXTRA_ARCH_IDS", "SHAPES", "ArchConfig", "MLAConfig",
    "MoEConfig", "RGLRUConfig", "SSMConfig", "ShapeSpec", "cells",
    "get_arch", "get_reduced",
]
