"""DeepSeekMoE-16B [moe]: fine-grained experts, 2 shared + 64 routed top-6.

28L d_model=2048 16H (kv=16, MHA) d_ff_expert=1408 vocab=102400
[arXiv:2401.06066; hf]. Layer 0 is dense (d_ff=10944).
MoE dispatch uses HiAER-style two-phase address-event routing (DESIGN §4).
"""
from repro.configs.base import ArchConfig, MoEConfig, _shrink

CONFIG = ArchConfig(
    name="deepseek_moe_16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10_944, vocab_size=102_400,
    act="swiglu", norm="rmsnorm", rope_theta=10_000.0,
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408,
                  first_k_dense=1, d_ff_dense=10_944,
                  capacity_factor=1.5),     # §Perf hillclimb #2
    remat_policy="dots",                    # §Perf hillclimb #2
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=256, vocab_size=256,
                   moe=MoEConfig(n_routed=8, n_shared=1, top_k=2,
                                 d_ff_expert=64, first_k_dense=1,
                                 d_ff_dense=256,
                                 # dropless at test scale so decode-vs-
                                 # teacher-forcing parity is exact
                                 capacity_factor=8.0))
