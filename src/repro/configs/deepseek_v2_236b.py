"""DeepSeek-V2 236B [moe]: MLA (kv_lora=512), 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff_expert=1536 vocab=102400 [arXiv:2405.04434; hf].
First layer dense (d_ff=12288). MLA decode uses the absorbed compressed-KV
form (cache = c_kv 512 + k_rope 64 per token).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, _shrink

CONFIG = ArchConfig(
    name="deepseek_v2_236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12_288, vocab_size=102_400,
    act="swiglu", norm="rmsnorm", rope_theta=10_000.0, attn_kind="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=160, n_shared=2, top_k=6, d_ff_expert=1536,
                  first_k_dense=1, d_ff_dense=12_288),
    fsdp=True, opt_dtype="bfloat16",
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=256, vocab_size=256, fsdp=False, opt_dtype="float32",
                   mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                 qk_nope_head_dim=16, qk_rope_head_dim=8,
                                 v_head_dim=16),
                   moe=MoEConfig(n_routed=8, n_shared=1, top_k=2,
                                 d_ff_expert=64, first_k_dense=1,
                                 d_ff_dense=256, capacity_factor=8.0))
