"""LLaVA-NeXT (Mistral-7B backbone) [vlm]: anyres tiling frontend stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].
The vision tower is a STUB per assignment: input_specs() provides precomputed
patch embeddings (n_patch_tokens per image, anyres base tile 576 patches)
that are prepended to the token embeddings.
"""
from repro.configs.base import ArchConfig, _shrink

CONFIG = ArchConfig(
    name="llava_next_mistral_7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14_336, vocab_size=32_000,
    act="swiglu", norm="rmsnorm", rope_theta=1_000_000.0,
    frontend="vision_patches", n_patch_tokens=576,
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=256, vocab_size=256, n_patch_tokens=8)
