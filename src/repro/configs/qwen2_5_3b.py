"""Qwen2.5-3B [dense]: GQA with QKV bias.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936
[hf:Qwen/Qwen2.5-0.5B family; hf]
"""
from repro.configs.base import ArchConfig, _shrink

CONFIG = ArchConfig(
    name="qwen2_5_3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11_008, vocab_size=151_936,
    qkv_bias=True, act="swiglu", norm="rmsnorm", rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=256, vocab_size=256)
