"""Qwen2-7B [dense]: GQA with QKV bias.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 [arXiv:2407.10671; hf]
"""
from repro.configs.base import ArchConfig, _shrink

CONFIG = ArchConfig(
    name="qwen2_7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18_944, vocab_size=152_064,
    qkv_bias=True, act="swiglu", norm="rmsnorm", rope_theta=1_000_000.0,
)


def reduced() -> ArchConfig:
    return _shrink(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=256, vocab_size=256)
