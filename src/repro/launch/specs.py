"""ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
shardable, zero allocation) plus eval_shape'd params / optimizer / cache
trees — the substrate of the dry-run.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeSpec
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init


def batch_shapes(cfg: ArchConfig, shape: ShapeSpec):
    """Abstract input batch for a (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    n_patch = cfg.n_patch_tokens if cfg.frontend == "vision_patches" else 0
    out = {"tokens": jax.ShapeDtypeStruct((B, S - n_patch), jnp.int32)}
    if n_patch:
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, n_patch, cfg.d_model), jnp.bfloat16)
    return out


def params_shapes(cfg: ArchConfig, dtype=jnp.bfloat16):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: lm.init_params(cfg, k, dtype), key)


def opt_shapes(cfg: ArchConfig, oc: AdamWConfig, dtype=jnp.bfloat16):
    p = params_shapes(cfg, dtype)
    return jax.eval_shape(lambda pp: adamw_init(pp, oc), p)


def cache_shapes(cfg: ArchConfig, shape: ShapeSpec, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len, dtype))


def concretize(tree, seed=0):
    """Materialize an SDS tree (smoke tests / examples only — never for the
    full configs)."""
    leaves, treedef = jax.tree.flatten(tree)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, l in enumerate(leaves):
        if jnp.issubdtype(l.dtype, jnp.integer):
            out.append(jnp.zeros(l.shape, l.dtype))
        else:
            out.append(jax.random.normal(jax.random.fold_in(key, i), l.shape,
                                         jnp.float32).astype(l.dtype) * 0.02)
    return jax.tree.unflatten(treedef, out)
