import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh using ShapeDtypeStruct stand-ins (no
allocation), then record memory / cost / collective analysis for §Dry-run
and §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The XLA_FLAGS line above MUST precede every jax-touching import: jax locks
the device count at first backend init. Everything else (tests, benches)
sees the single real CPU device.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, cells, get_arch
from repro.distributed.context import mesh_context
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import ShardingRules, as_sds, to_named
from repro.launch.specs import (batch_shapes, cache_shapes, opt_shapes,
                                params_shapes)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models.lm import _attn_layout
from repro.optim import AdamWConfig

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def lower_snn(multi_pod: bool):
    """Dry-run the paper's own full-scale config: 160M neurons / 40B+
    synapses, hierarchically routed (core/distributed_engine.py)."""
    from repro.core.distributed_engine import (SNNShardConfig,
                                               make_snn_step,
                                               snn_shardings,
                                               snn_state_shapes)
    cfg = SNNShardConfig()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh_context(mesh):
        shapes = snn_state_shapes(cfg, mesh)
        sh = snn_shardings(cfg, mesh)
        sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=sh[k])
               for k, v in shapes.items()}
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        step = make_snn_step(cfg, mesh)
        t0 = time.time()
        jfn = jax.jit(step, out_shardings=sh, donate_argnums=(0,))
        lowered = jfn.lower(sds, key)
        compiled = lowered.compile()
        t_compile = time.time() - t0
    text = compiled.as_text()
    an = hlo_analysis.analyze(text)
    mem = compiled.memory_analysis()
    return {
        "arch": "hiaer_snn_40b", "shape": "step_160M_40B",
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "variant": "baseline", "kind": "snn_step",
        "n_devices": mesh.devices.size,
        "n_neurons": cfg.n_neurons,
        "n_synapse_slots": cfg.fan_window_blocks * cfg.block * cfg.n_neurons,
        "analysis": an,
        "collectives": hlo_analysis.collective_breakdown(text),
        "memory": {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes")},
        "compile_s": round(t_compile, 2), "lower_s": 0.0,
        "layout": "hiaer", "seq_len": 1, "global_batch": 1,
    }


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline"):
    """Lower + compile one cell; returns the result record dict."""
    if arch_id == "hiaer_snn_40b":
        return lower_snn(multi_pod)
    cfg = get_arch(arch_id)
    microbatches = 1
    if variant != "baseline":
        for v in variant.split("+"):
            if v.startswith("mb"):
                microbatches = int(v[2:])
        variant_cfg = "+".join(v for v in variant.split("+")
                               if not v.startswith("mb"))
        if variant_cfg:
            cfg = apply_variant(cfg, variant_cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    oc = AdamWConfig(moment_dtype=cfg.opt_dtype)
    t0 = time.time()
    with mesh_context(mesh):
        layout = _attn_layout(cfg, mesh.shape["model"])
        rules = ShardingRules(cfg, mesh, layout)
        p_shapes = params_shapes(cfg)
        p_specs = rules.params_specs(p_shapes)
        p_sh = to_named(p_specs, mesh)
        p_sds = as_sds(p_shapes, p_sh)
        b_shapes = batch_shapes(cfg, shape)
        b_sh = to_named(rules.batch_specs(b_shapes), mesh)
        b_sds = as_sds(b_shapes, b_sh)

        if shape.kind == "train":
            o_shapes = opt_shapes(cfg, oc)
            o_specs = rules.opt_specs(p_shapes, p_specs)
            o_sh = to_named(o_specs, mesh)
            o_sds = as_sds(o_shapes, o_sh)
            fn = make_train_step(cfg, oc, layout=layout,
                                 microbatches=microbatches)
            jfn = jax.jit(fn, out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
            lowered = jfn.lower(p_sds, o_sds, b_sds)
        elif shape.kind == "prefill":
            fn = make_prefill_step(cfg, layout=layout)
            c_shapes = cache_shapes(cfg, shape)
            c_sh = to_named(rules.cache_specs(c_shapes), mesh)
            jfn = jax.jit(fn, out_shardings=(None, c_sh))
            lowered = jfn.lower(p_sds, b_sds)
        else:  # decode
            fn = make_decode_step(cfg, layout=layout)
            c_shapes = cache_shapes(cfg, shape)
            c_sh = to_named(rules.cache_specs(c_shapes), mesh)
            c_sds = as_sds(c_shapes, c_sh)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            jfn = jax.jit(fn, out_shardings=(None, c_sh),
                          donate_argnums=(2,))
            lowered = jfn.lower(p_sds, b_sds["tokens"], c_sds, pos)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    text = compiled.as_text()
    an = hlo_analysis.analyze(text)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    try:
        mem = compiled.memory_analysis()
        memd = {k: int(getattr(mem, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes")}
    except Exception as e:          # backend without memory analysis
        memd = {"error": str(e)}
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "variant": variant,
        "n_devices": mesh.devices.size,
        "layout": layout,
        "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "analysis": an,
        "collectives": hlo_analysis.collective_breakdown(text),
        "xla_cost_analysis": {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed")},
        "memory": memd,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    return rec


def apply_variant(cfg, variant: str):
    """Named beyond-baseline variants used by §Perf hillclimbing."""
    import dataclasses
    parts = variant.split("+")
    for v in parts:
        if v == "hier_a2a" and cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, hierarchical_a2a=True))
        elif v == "sm_attn":
            cfg = dataclasses.replace(cfg, attn_impl="shardmap")
        elif v == "seqpar":
            cfg = dataclasses.replace(cfg, seq_parallel=True)
        elif v == "loss_chunk_2k":
            cfg = dataclasses.replace(cfg, loss_chunk=2048)
        elif v.startswith("capacity_"):
            f = float(v.split("_")[1])
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=f))
        elif v == "remat_dots":
            cfg = dataclasses.replace(cfg, remat_policy="dots")
        elif v.startswith("remat_"):
            cfg = dataclasses.replace(cfg, remat=v == "remat_on")
        else:
            raise ValueError(f"unknown variant {v}")
    return cfg


def run(arch_id, shape_name, multi_pod, out_dir: Path, variant="baseline",
        force=False):
    tag = "multi" if multi_pod else "single"
    name = f"{arch_id}__{shape_name}__{tag}"
    if variant != "baseline":
        name += f"__{variant}"
    path = out_dir / f"{name}.json"
    if path.exists() and not force:
        print(f"[skip] {name} (artifact exists)")
        return json.loads(path.read_text())
    print(f"[dryrun] {name} ...", flush=True)
    try:
        rec = lower_cell(arch_id, shape_name, multi_pod, variant)
        rec["status"] = "ok"
    except Exception as e:
        rec = {"arch": arch_id, "shape": shape_name, "mesh": tag,
               "variant": variant, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        print(f"[FAIL] {name}: {e}", flush=True)
    out_dir.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1))
    if rec["status"] == "ok":
        a = rec["analysis"]
        print(f"[ok] {name}: compile={rec['compile_s']}s "
              f"flops={a['flops']:.3e} hbm={a['hbm_bytes_tight']:.3e} "
              f"coll={a['collective_bytes']:.3e} "
              f"temp={rec['memory'].get('temp_size_in_bytes', -1)/2**30:.2f}GiB",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()
    out_dir = Path(args.out)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        pairs = [(a, s.name) for a in ARCH_IDS for s in cells(a)]
        pairs.append(("hiaer_snn_40b", "step_160M_40B"))
    else:
        assert args.arch, "--arch required unless --all"
        if args.shape:
            pairs = [(args.arch, args.shape)]
        else:
            pairs = [(args.arch, s.name) for s in cells(args.arch)]
    n_ok = n_fail = 0
    for arch, shp in pairs:
        for mp in meshes:
            rec = run(arch, shp, mp, out_dir, variant=args.variant,
                      force=args.force)
            if rec.get("status") == "ok":
                n_ok += 1
            else:
                n_fail += 1
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
