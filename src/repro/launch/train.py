"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_3b \
        --steps 100 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/run

Wires together: config system -> mesh -> sharded params/opt -> jitted
train_step (grad-accum microbatching, optional gradient compression) ->
resumable TokenPipeline -> CheckpointManager (async, atomic, retention) ->
StepWatchdog (straggler detection). On restart it resumes from the latest
checkpoint, pipeline-cursor-exact.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, get_reduced
from repro.data.synthetic import TokenPipeline
from repro.distributed.context import mesh_context
from repro.distributed.elastic import StepWatchdog
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.models.lm import _attn_layout
from repro.optim import AdamWConfig, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", choices=["none", "int8", "topk"],
                    default="none")
    ap.add_argument("--mesh", choices=["local", "single", "multi"],
                    default="local")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    mesh = {"local": make_local_mesh,
            "single": make_production_mesh,
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    oc = AdamWConfig(lr=args.lr, moment_dtype=cfg.opt_dtype,
                     total_steps=args.steps)
    dtype = jnp.float32 if args.reduced else jnp.bfloat16

    compressor = None
    ef_state = {}
    if args.compress != "none":
        from repro.distributed.compression import ErrorFeedback
        ef = ErrorFeedback(mode=args.compress)

        def compressor(grads):
            nonlocal ef_state
            if not ef_state:
                ef_state = ef.init(grads)
            out, ef_state = ef.apply(grads, ef_state)
            return out

    with mesh_context(mesh):
        layout = _attn_layout(cfg, mesh.shape["model"])
        params = lm.init_params(cfg, jax.random.PRNGKey(0), dtype)
        opt = adamw_init(params, oc)
        step_fn = jax.jit(make_train_step(cfg, oc, layout=layout,
                                          microbatches=args.microbatches,
                                          compressor=compressor))
        pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)
        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if mgr and mgr.latest_step() is not None:
            restored, aux = mgr.restore({"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            pipe.load_state_dict({k: aux[k] for k in ("seed", "step")})
            start = int(aux["step_counter"])
            print(f"[resume] from step {start}")
        wd = StepWatchdog()
        for step in range(start, args.steps):
            wd.start()
            batch = jax.tree.map(jnp.asarray, pipe.next_batch())
            params, opt, metrics = step_fn(params, opt, batch)
            info = wd.stop()
            if info["evict"]:
                print(f"[watchdog] persistent straggler at step {step} — "
                      "elastic remesh would trigger here")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"({info['step_s']:.2f}s)", flush=True)
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt": opt},
                         aux={**pipe.state_dict(),
                              "step_counter": step + 1},
                         async_=True)
        if mgr:
            mgr.save(args.steps, {"params": params, "opt": opt},
                     aux={**pipe.state_dict(),
                          "step_counter": args.steps})
            mgr.wait()
        return float(metrics["loss"])


if __name__ == "__main__":
    main()
