"""Jitted step functions: train_step / prefill_step / decode_step.

``train_step`` optionally runs gradient accumulation over microbatches
(compute/comm overlap: each microbatch's reduce-scatter overlaps the next
microbatch's compute under GSPMD scheduling) and optional gradient
compression hooks (repro.distributed.compression).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg, oc: AdamWConfig, layout="heads", microbatches=1,
                    compressor=None):
    def loss(params, batch):
        l, parts = lm.loss_fn(params, cfg, batch, layout=layout)
        return l, parts

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def micro(c, mb):
                (l, parts), g = jax.value_and_grad(loss, has_aux=True)(
                    params, mb)
                gacc = jax.tree.map(jnp.add, c[0], g)
                return (gacc, c[1] + l), parts
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            l = lsum / microbatches
        else:
            (l, parts), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
        if compressor is not None:
            grads = compressor(grads)
        params, opt_state, om = adamw_update(params, grads, opt_state, oc)
        metrics = {"loss": l, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, layout="heads"):
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, layout=layout)
    return prefill_step


def make_decode_step(cfg, layout="heads"):
    def decode_step(params, token, cache, cache_pos):
        return lm.decode_step(params, cfg, token, cache, cache_pos,
                              layout=layout)
    return decode_step
