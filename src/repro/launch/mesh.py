"""Production meshes.

Functions (not module-level constants) so importing never touches jax device
state. The production system is a TPU v5e pod of 16x16 = 256 chips
('data' x 'model'); multi-pod doubles it with a leading 'pod' axis over DCN
(2 pods = 512 chips). Mapping to the paper's hierarchy: 'model' = cores
within an FPGA (NoC), 'data' = FPGAs within a server (FireFly), 'pod' =
servers (Ethernet).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh() -> Mesh:
    """Whatever devices exist, as a (1, n) ('data','model') mesh — used by
    smoke tests and the single-host examples."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))
