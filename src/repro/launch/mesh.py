"""Production meshes.

Functions (not module-level constants) so importing never touches jax device
state. The production system is a TPU v5e pod of 16x16 = 256 chips
('data' x 'model'); multi-pod doubles it with a leading 'pod' axis over DCN
(2 pods = 512 chips). Mapping to the paper's hierarchy: 'model' = cores
within an FPGA (NoC), 'data' = FPGAs within a server (FireFly), 'pod' =
servers (Ethernet).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh() -> Mesh:
    """Whatever devices exist, as a (1, n) ('data','model') mesh — used by
    smoke tests and the single-host examples."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"), axis_types=_auto(2))
