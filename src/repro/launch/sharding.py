"""Parameter/optimizer/input sharding rules.

Specs are derived from leaf *paths* in the params pytree plus the arch
config. Two regimes:
  TP    — weights sharded over 'model' only (heads / d_ff / experts / vocab),
          replicated over 'data' (+'pod'); right for <100B params.
  FSDP  — additionally shard the residual-stream dim over 'data' (ZeRO-3);
          required for llama3-405b / deepseek-v2-236b (memory table in
          EXPERIMENTS.md §Dry-run).
Optimizer moments get ZeRO-1 treatment for TP archs: the first unsharded,
divisible dim is additionally sharded over 'data'.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.context import batch_axes


def _names(path) -> tuple:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(k.key)
        elif hasattr(k, "name"):
            out.append(k.name)
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return tuple(out)


def _div(dim_size: int, mesh, axis) -> bool:
    if axis is None:
        return False
    return dim_size % mesh.shape[axis] == 0


class ShardingRules:
    def __init__(self, cfg, mesh, layout: str):
        self.cfg = cfg
        self.mesh = mesh
        self.layout = layout                      # attention layout
        self.fsdp = "data" if cfg.fsdp else None

    def _m(self, size, axis="model"):
        return axis if _div(size, self.mesh, axis) else None

    def _f(self, size):
        return self.fsdp if _div(size, self.mesh, self.fsdp) else None

    def param_spec(self, path, leaf) -> P:
        names = _names(path)
        shp = leaf.shape
        # leading layer-stack axis present for block params
        stacked = any(n in ("blocks", "dense_blocks", "groups", "tail")
                      for n in names)
        lead = (None,) if stacked else ()
        s = shp[1:] if stacked else shp
        name = names[-1]
        parent = names[-2] if len(names) > 1 else ""

        def spec(*axes):
            return P(*(lead + tuple(axes)))

        if name in ("scale", "bias", "a_log", "dt_bias", "d_skip", "lam",
                    "conv_b"):
            # norms / small vectors: lam & conv_b are width-sharded in rglru
            if name in ("lam", "conv_b") and parent != "ssm" \
                    and self.cfg.rglru is not None and len(s) == 1 \
                    and s[0] == self.cfg.rglru.lru_width:
                return spec(self._m(s[0]))
            return spec(*([None] * len(s)))
        if name == "table":                        # (Vp, d)
            return P(self._m(s[0]), self._f(s[1]))
        if name == "out" and parent == "embed":    # (d, Vp)
            return P(self._f(s[0]), self._m(s[1]))
        if name in ("wq", "wk", "wv"):             # (d, H, hd)
            h_ax = self._m(s[1]) if self.layout == "heads" else None
            return spec(self._f(s[0]), h_ax, None)
        if name in ("bq", "bk", "bv"):
            h_ax = self._m(s[0]) if self.layout == "heads" else None
            return spec(h_ax, None)
        if name == "wo":                           # (H, hd, d)
            h_ax = self._m(s[0]) if self.layout == "heads" else None
            return spec(h_ax, None, self._f(s[2]))
        # --- MLA ---
        if name in ("w_dq", "w_dkv"):              # (d, r)
            return spec(self._f(s[0]), None)
        if name in ("w_uq", "w_uk", "w_uv"):       # (r, H, k)
            return spec(None, self._m(s[1]), None)
        if name == "w_o":                          # (H, v, d)
            return spec(self._m(s[0]), None, self._f(s[2]))
        # --- SSM (before generic mlp names: w_in is a fused projection whose
        # output mixes z/x/B/C/dt -- keep it unsharded on the out dim) ---
        if parent == "ssm":
            if name == "w_in":
                return spec(self._f(s[0]), None)
            if name == "conv_w":
                return spec(None, None)
            if name == "w_out":                    # (d_in, d)
                return spec(self._m(s[0]), self._f(s[1]))
        # --- MoE ---
        if name == "router":                       # (d, E)
            return spec(None, None)
        if parent == "shared" or (self.cfg.moe is None):
            if name in ("w_in", "w_gate"):         # (d, ff)
                return spec(self._f(s[0]), self._m(s[1]))
            if name == "w_out":                    # (ff, d)
                return spec(self._m(s[0]), self._f(s[1]))
        if self.cfg.moe is not None and len(s) == 3 \
                and name in ("w_in", "w_gate", "w_out"):
            # routed experts (E, d, ff) / (E, ff, d): experts over 'model'
            if name == "w_out":
                return spec(self._m(s[0]), None, self._f(s[2]))
            return spec(self._m(s[0]), self._f(s[1]), None)
        if name in ("w_in", "w_gate"):             # dense mlp fallback (d,ff)
            return spec(self._f(s[0]), self._m(s[1]))
        if name == "w_out":
            return spec(self._m(s[0]), self._f(s[1]))
        # --- SSM ---
        if parent == "ssm" or name == "conv_w":
            if name == "w_in":
                return spec(self._f(s[0]), None)
            if name == "conv_w":                   # (K, C)
                return spec(None, None)
            if name == "w_out":                    # (d_in, d)
                return spec(self._m(s[0]), self._f(s[1]))
        # --- RG-LRU ---
        if name in ("w_x", "w_y"):                 # (d, W)
            return spec(self._f(s[0]), self._m(s[1]))
        if name in ("w_i", "w_r"):                 # (W, W)
            return spec(self._m(s[0]), None)
        return spec(*([None] * len(s)))

    def params_specs(self, params_shapes):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: self.param_spec(p, l), params_shapes)

    def zero1_spec(self, spec: P, shape) -> P:
        """Extend a param spec over 'data' for optimizer moments (ZeRO-1)."""
        if self.fsdp:                               # already data-sharded
            return spec
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for i, (ax, dim) in enumerate(zip(parts, shape)):
            if ax is None and _div(dim, self.mesh, "data"):
                parts[i] = "data"
                break
        return P(*parts)

    def opt_specs(self, params_shapes, param_specs):
        mom = jax.tree.map(
            lambda l, s: self.zero1_spec(s, l.shape),
            params_shapes, param_specs,
            is_leaf=lambda x: isinstance(x, P))
        return {"mu": mom, "nu": mom, "step": P()}

    def _bdiv(self, dim) -> bool:
        n = 1
        for a in batch_axes():
            n *= self.mesh.shape[a]
        return dim % n == 0

    def batch_specs(self, batch_shapes):
        b = batch_axes()
        return jax.tree.map(
            lambda l: P(b if self._bdiv(l.shape[0]) else None,
                        *([None] * (len(l.shape) - 1))), batch_shapes)

    def cache_specs(self, cache_shapes):
        b = batch_axes()

        def per_leaf(l):
            # (L, B, T, ...): batch over data axes; long seq dims (>=4096)
            # additionally over 'model' (2D KV-cache sharding).
            rest = [None] * (len(l.shape) - 2)
            if len(l.shape) >= 4 and l.shape[2] >= 4096 \
                    and _div(l.shape[2], self.mesh, "model"):
                rest[0] = "model"
            return P(None, b if self._bdiv(l.shape[1]) else None, *rest)
        return jax.tree.map(per_leaf, cache_shapes)


def to_named(specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def as_sds(shapes, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shapes, shardings)
